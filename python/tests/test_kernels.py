"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes and parameter ranges; every property asserts
allclose between the tiled/interpret kernel and the direct formula.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hyena_gating, modal_filter, ssm_decode_step
from compile.kernels.ref import (
    causal_conv_ref,
    fft_causal_conv,
    hyena_gating_ref,
    modal_filter_ref,
    ssm_decode_step_ref,
)

jax.config.update("jax_platform_name", "cpu")


def rng(seed):
    return np.random.default_rng(seed)


def modal_params(r, c, d):
    return (
        jnp.asarray(r.uniform(0.1, 0.999, (c, d)), jnp.float32),
        jnp.asarray(r.uniform(0.0, np.pi, (c, d)), jnp.float32),
        jnp.asarray(r.normal(0, 1, (c, d)), jnp.float32),
        jnp.asarray(r.normal(0, 1, (c, d)), jnp.float32),
    )


class TestModalFilter:
    @settings(max_examples=15, deadline=None)
    @given(
        c=st.integers(1, 5),
        d=st.sampled_from([1, 2, 4, 8, 16]),
        length=st.sampled_from([1, 7, 64, 512, 600, 1024]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, c, d, length, seed):
        decay, theta, r_re, r_im = modal_params(rng(seed), c, d)
        got = modal_filter(decay, theta, r_re, r_im, length=length)
        want = modal_filter_ref(decay, theta, r_re, r_im, length)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_tap_zero_is_residue_sum(self):
        decay, theta, r_re, r_im = modal_params(rng(0), 3, 8)
        h = modal_filter(decay, theta, r_re, r_im, length=4)
        np.testing.assert_allclose(
            h[:, 0], jnp.sum(r_re, axis=1), rtol=1e-5, atol=1e-5
        )

    def test_decay_shrinks_tail(self):
        decay = jnp.full((1, 4), 0.5, jnp.float32)
        theta = jnp.zeros((1, 4), jnp.float32)
        r_re = jnp.ones((1, 4), jnp.float32)
        r_im = jnp.zeros((1, 4), jnp.float32)
        h = np.asarray(modal_filter(decay, theta, r_re, r_im, length=32))
        assert abs(h[0, 20]) < 1e-4
        np.testing.assert_allclose(h[0, 1], 4 * 0.5, rtol=1e-5)

    def test_dead_mode_is_finite(self):
        decay = jnp.zeros((1, 2), jnp.float32)  # log-clamp path
        theta = jnp.zeros((1, 2), jnp.float32)
        h = modal_filter(decay, theta, jnp.ones((1, 2)), jnp.zeros((1, 2)),
                         length=8)
        assert np.isfinite(np.asarray(h)).all()

    def test_gradients_flow(self):
        decay, theta, r_re, r_im = modal_params(rng(1), 2, 4)
        tgt = jnp.zeros((2, 32), jnp.float32)

        def loss(a):
            return jnp.sum((modal_filter(a, theta, r_re, r_im, length=32) - tgt) ** 2)

        g = jax.grad(loss)(decay)
        assert np.isfinite(np.asarray(g)).all()
        assert float(jnp.max(jnp.abs(g))) > 0

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**16), length=st.sampled_from([16, 64, 600]))
    def test_custom_vjp_matches_autodiff_of_ref(self, seed, length):
        """The analytic backward kernel must agree with jax.grad through the
        pure-jnp oracle for all four parameter arrays."""
        c, d = 2, 4
        decay, theta, r_re, r_im = modal_params(rng(seed), c, d)
        tgt = jnp.asarray(rng(seed + 1).normal(0, 1, (c, length)), jnp.float32)

        def loss_kernel(p):
            h = modal_filter(p[0], p[1], p[2], p[3], length=length)
            return jnp.sum((h - tgt) ** 2)

        def loss_ref(p):
            h = modal_filter_ref(p[0], p[1], p[2], p[3], length)
            return jnp.sum((h - tgt) ** 2)

        p = (decay, theta, r_re, r_im)
        g_kernel = jax.grad(loss_kernel)(p)
        g_ref = jax.grad(loss_ref)(p)
        for gk, gr, name in zip(g_kernel, g_ref, "decay theta r_re r_im".split()):
            scale = float(jnp.max(jnp.abs(gr))) + 1e-6
            np.testing.assert_allclose(
                gk / scale, gr / scale, rtol=2e-3, atol=2e-3, err_msg=name
            )


class TestSsmDecode:
    @settings(max_examples=15, deadline=None)
    @given(
        b=st.integers(1, 4),
        c=st.sampled_from([1, 8, 32, 64]),
        d=st.sampled_from([1, 4, 16]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, b, c, d, seed):
        r = rng(seed)
        xr = jnp.asarray(r.normal(0, 1, (b, c, d)), jnp.float32)
        xi = jnp.asarray(r.normal(0, 1, (b, c, d)), jnp.float32)
        u = jnp.asarray(r.normal(0, 1, (b, c)), jnp.float32)
        lr_ = jnp.asarray(r.uniform(-0.9, 0.9, (c, d)), jnp.float32)
        li = jnp.asarray(r.uniform(-0.9, 0.9, (c, d)), jnp.float32)
        rr = jnp.asarray(r.normal(0, 1, (c, d)), jnp.float32)
        ri = jnp.asarray(r.normal(0, 1, (c, d)), jnp.float32)
        h0 = jnp.asarray(r.normal(0, 1, (c,)), jnp.float32)
        got = ssm_decode_step(xr, xi, u, lr_, li, rr, ri, h0)
        want = ssm_decode_step_ref(xr, xi, u, lr_, li, rr, ri, h0)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-5)

    def test_unrolled_steps_reproduce_modal_filter(self):
        """Driving the step with a unit impulse must reproduce the modal
        impulse response h0, h_1, h_2, ... — ties L1 kernels together."""
        r = rng(3)
        c, d, steps = 4, 8, 40
        decay, theta, r_re, r_im = modal_params(r, c, d)
        lam_re = decay * jnp.cos(theta)
        lam_im = decay * jnp.sin(theta)
        h0 = jnp.asarray(r.normal(0, 1, (c,)), jnp.float32)
        xr = jnp.zeros((1, c, d), jnp.float32)
        xi = jnp.zeros((1, c, d), jnp.float32)
        ys = []
        for t in range(steps):
            u = jnp.full((1, c), 1.0 if t == 0 else 0.0, jnp.float32)
            xr, xi, y = ssm_decode_step(xr, xi, u, lam_re, lam_im, r_re, r_im, h0)
            ys.append(np.asarray(y)[0])
        ys = np.stack(ys, axis=1)  # [c, steps]
        want_tail = np.asarray(
            modal_filter_ref(decay, theta, r_re, r_im, steps - 1)
        )
        np.testing.assert_allclose(ys[:, 0], h0, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(ys[:, 1:], want_tail, rtol=1e-3, atol=1e-4)

    def test_zero_state_zero_input(self):
        z = jnp.zeros((2, 8, 4), jnp.float32)
        u = jnp.zeros((2, 8), jnp.float32)
        p = jnp.ones((8, 4), jnp.float32) * 0.5
        h0 = jnp.ones((8,), jnp.float32)
        xr, xi, y = ssm_decode_step(z, z, u, p, p, p, p, h0)
        assert float(jnp.max(jnp.abs(y))) == 0.0
        assert float(jnp.max(jnp.abs(xr))) == 0.0


class TestGating:
    @settings(max_examples=10, deadline=None)
    @given(
        b=st.integers(1, 3),
        t=st.sampled_from([1, 16, 256, 300]),
        dm=st.sampled_from([8, 64, 128, 160]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, b, t, dm, seed):
        r = rng(seed)
        q = jnp.asarray(r.normal(0, 1, (b, t, dm)), jnp.float32)
        x = jnp.asarray(r.normal(0, 1, (b, t, dm)), jnp.float32)
        np.testing.assert_allclose(
            hyena_gating(q, x), hyena_gating_ref(q, x), rtol=1e-6, atol=1e-6
        )


class TestFftConv:
    @settings(max_examples=10, deadline=None)
    @given(
        b=st.integers(1, 2),
        t=st.sampled_from([4, 32, 100]),
        c=st.sampled_from([1, 3]),
        seed=st.integers(0, 2**16),
    )
    def test_fft_conv_matches_direct(self, b, t, c, seed):
        r = rng(seed)
        h = jnp.asarray(r.normal(0, 1, (c, t)), jnp.float32)
        u = jnp.asarray(r.normal(0, 1, (b, t, c)), jnp.float32)
        got = fft_causal_conv(h, u)
        want = causal_conv_ref(h, u)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
