"""AOT artifact sanity: manifests parse, HLO text loads, shapes line up.

Runs only when `make artifacts` has produced the output directory (pytest
is invoked after artifacts in the Makefile)."""

import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.isdir(ART), reason="run `make artifacts` first"
)


def manifest(name):
    path = os.path.join(ART, f"{name}.manifest.txt")
    rows = []
    with open(path) as f:
        for line in f:
            if line.startswith("#") or not line.strip():
                continue
            rows.append(line.split())
    return rows


def test_expected_artifacts_exist():
    expected = [
        "train_step_multihyena_small",
        "train_step_hyena_small",
        "train_step_gpt_small",
        "eval_loss_multihyena_small",
        "prefill_multihyena_small",
        "decode_multihyena_small",
        "distill_step_c24_d16_l256",
        "train_step_multihyena_ar",
        "train_step_hyena_ar",
    ]
    for name in expected:
        assert os.path.exists(os.path.join(ART, f"{name}.hlo.txt")), name
        assert os.path.exists(os.path.join(ART, f"{name}.manifest.txt")), name


def test_hlo_text_is_parseable_header():
    with open(os.path.join(ART, "decode_multihyena_small.hlo.txt")) as f:
        head = f.read(200)
    assert head.startswith("HloModule"), head[:50]


def test_train_step_manifest_roundtrip():
    rows = manifest("train_step_multihyena_small")
    ins = [r for r in rows if r[0] == "in"]
    outs = [r for r in rows if r[0] == "out"]
    # params + m + v appear symmetrically in inputs and outputs
    n_leaves = sum(1 for r in ins if r[2].startswith("0."))
    assert n_leaves > 10
    assert len(outs) == 3 * n_leaves + 1  # params', m', v', loss
    # tokens/targets are i32, mask f32
    dtypes = {r[2]: r[3] for r in ins}
    assert dtypes["4"] == "i32" and dtypes["5"] == "i32" and dtypes["6"] == "f32"


def test_checkpoint_manifest_offsets_contiguous():
    rows = manifest("params_multihyena_small")
    off = 0
    for r in rows:
        assert r[0] == "leaf"
        assert int(r[4]) == off
        off += int(r[5])
    blob = os.path.getsize(os.path.join(ART, "params_multihyena_small.bin"))
    assert blob == off


def test_decode_manifest_state_shapes():
    rows = manifest("decode_multihyena_small")
    ins = {r[2]: r[4] for r in rows if r[0] == "in"}
    # x_re input (arg 3) is [B, n_layer, D, d_state] = 8,3,96,16
    assert ins["3"] == "8,3,96,16"
    assert ins["4"] == "8,3,96,16"
    assert ins["5"] == "8,3,288,2"  # short-conv buffer
