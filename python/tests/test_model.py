"""L2 model tests: shapes, training signal, and — critically — agreement
between the convolutional forward pass and the distilled recurrent mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.TINY


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def tokens(b, t, seed=0, vocab=None):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.integers(0, vocab or CFG.vocab, (b, t)), jnp.int32)


class TestForward:
    def test_logits_shape(self, params):
        x = tokens(2, CFG.seq_len)
        logits = M.forward(CFG, params, x)
        assert logits.shape == (2, CFG.seq_len, CFG.vocab)
        assert np.isfinite(np.asarray(logits)).all()

    def test_causality(self, params):
        """Perturbing token t must not change logits at positions < t."""
        x = tokens(1, 32, seed=1)
        base = M.forward(CFG, params, x)
        x2 = x.at[0, 20].set((x[0, 20] + 1) % CFG.vocab)
        pert = M.forward(CFG, params, x2)
        np.testing.assert_allclose(base[0, :20], pert[0, :20], atol=1e-5)
        assert not np.allclose(base[0, 20:], pert[0, 20:], atol=1e-5)

    def test_gpt_variant_runs(self):
        cfg = M.variant(CFG, "gpt")
        p = M.init_params(cfg, jax.random.PRNGKey(1))
        logits = M.forward(cfg, p, tokens(2, cfg.seq_len))
        assert logits.shape == (2, cfg.seq_len, cfg.vocab)

    def test_hyena_variant_runs(self):
        cfg = M.variant(CFG, "hyena")
        assert cfg.n_filters == cfg.d_model
        p = M.init_params(cfg, jax.random.PRNGKey(1))
        logits = M.forward(cfg, p, tokens(1, 16))
        assert np.isfinite(np.asarray(logits)).all()

    def test_filter_taps_shape_and_decay(self, params):
        h = M.filter_taps(CFG, params["layers"][0], CFG.seq_len)
        assert h.shape == (CFG.n_filters, CFG.seq_len)
        energy_head = np.abs(np.asarray(h))
        assert energy_head[:, -8:].mean() < energy_head[:, :8].mean()


class TestTraining:
    def test_loss_decreases(self, params):
        cfg = CFG
        p = params
        m, v = M.init_opt(p)
        x = tokens(4, cfg.seq_len, seed=2)
        y = jnp.roll(x, -1, axis=1)
        mask = jnp.ones(x.shape, jnp.float32)
        step = jax.jit(
            lambda p, m, v, s: M.train_step(cfg, p, m, v, s, x, y, mask)
        )
        losses = []
        for i in range(8):
            p, m, v, loss = step(p, m, v, jnp.float32(i))
            losses.append(float(loss))
        assert np.isfinite(losses).all()
        assert losses[-1] < losses[0]

    def test_masked_loss_ignores_positions(self, params):
        x = tokens(2, 16, seed=3)
        y = jnp.roll(x, -1, axis=1)
        full = M.loss_fn(CFG, params, x, y, jnp.ones(x.shape, jnp.float32))
        m = jnp.zeros(x.shape, jnp.float32).at[:, 5].set(1.0)
        only5 = M.loss_fn(CFG, params, x, y, m)
        logits = M.forward(CFG, params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        want = -jnp.mean(
            jnp.take_along_axis(logp[:, 5], y[:, 5][:, None], axis=-1)
        )
        np.testing.assert_allclose(only5, want, rtol=1e-5)
        assert not np.allclose(full, only5)


class TestRecurrentMode:
    """Conv-mode forward vs distilled prefill+decode (§3.4 deployment)."""

    def _distilled_modal(self, params, d, iters=3000):
        """Distill the model's true implicit filters into order-d modal
        SSMs (in-process gradient distillation, cosine lr)."""
        cfg = CFG
        stacks = {k: [] for k in ("lam_re", "lam_im", "r_re", "r_im", "h0")}
        key = jax.random.PRNGKey(7)
        for lp in params["layers"]:
            h = M.filter_taps(cfg, lp, cfg.seq_len)  # [M, L]
            tgt = h[:, 1:]  # taps tau=0.. map to h[1..]
            mp = M.init_modal(key, cfg.n_filters, d)
            m_ = {k: jnp.zeros_like(x) for k, x in mp.items()}
            v_ = dict(m_)
            step = jax.jit(
                lambda p, m, v, s, lr: M.distill_step(p, m, v, s, tgt, lr=lr)
            )
            for it in range(iters):
                lr = 0.05 * 0.5 * (1 + np.cos(np.pi * it / iters)) + 1e-4
                mp, m_, v_, loss = step(
                    mp, m_, v_, jnp.float32(it), jnp.float32(lr)
                )
            stacks["lam_re"].append(mp["decay"] * jnp.cos(mp["theta"]))
            stacks["lam_im"].append(mp["decay"] * jnp.sin(mp["theta"]))
            stacks["r_re"].append(mp["r_re"])
            stacks["r_im"].append(mp["r_im"])
            stacks["h0"].append(h[:, 0])
        return {k: jnp.stack(v) for k, v in stacks.items()}

    def test_prefill_decode_consistency(self, params):
        """Prefill(T) then K decode steps must track the full conv forward
        pass over the same T+K tokens (within distillation error).

        Untrained Siren filters are nearly full-rank (the paper's App. E.2
        observation), so this uses a generous order d=24 at L=64; the
        trained-model case distills far smaller (§5.2)."""
        cfg = CFG
        modal = self._distilled_modal(params, d=24)
        t, k = 24, 6
        full = tokens(2, t + k, seed=5)
        lengths = jnp.asarray([t, t - 3], jnp.int32)

        last, xr, xi, buf = M.prefill(cfg, params, modal, full[:, :t], lengths)
        ref_logits = M.forward(cfg, params, full)

        # prefill last-logit vs conv forward at position len-1 (exact: the
        # prefill output path IS the convolution)
        for b, ln in enumerate([t, t - 3]):
            np.testing.assert_allclose(
                last[b], ref_logits[b, ln - 1], rtol=2e-3, atol=2e-3
            )
        assert float(jnp.max(jnp.abs(xr))) < 1e3, "unstable prefill state"

        # teacher-forced decode for batch row 0 (full length t)
        errs = []
        for j in range(k):
            tok = full[:, t + j]
            logits, xr, xi, buf = M.decode_step(cfg, params, modal, tok, xr, xi, buf)
            want = ref_logits[0, t + j]
            got = logits[0]
            errs.append(
                float(jnp.linalg.norm(got - want) / (jnp.linalg.norm(want) + 1e-9))
            )
        assert max(errs) < 0.15, f"relative logit drift too large: {errs}"

    def test_decode_step_shapes(self, params):
        cfg = CFG
        b, nl, dm, ds = 3, cfg.n_layer, cfg.d_model, 8
        modal = {
            "lam_re": jnp.zeros((nl, cfg.n_filters, ds)),
            "lam_im": jnp.zeros((nl, cfg.n_filters, ds)),
            "r_re": jnp.zeros((nl, cfg.n_filters, ds)),
            "r_im": jnp.zeros((nl, cfg.n_filters, ds)),
            "h0": jnp.zeros((nl, cfg.n_filters)),
        }
        xr = jnp.zeros((b, nl, dm, ds))
        buf = jnp.zeros((b, nl, 3 * dm, cfg.short_kw - 1))
        logits, xr2, xi2, buf2 = M.decode_step(
            cfg, params, modal, jnp.zeros((b,), jnp.int32), xr, xr, buf
        )
        assert logits.shape == (b, cfg.vocab)
        assert xr2.shape == xr.shape and buf2.shape == buf.shape


class TestDistillStep:
    def test_converges_on_synthetic_ssm(self):
        """Distilling a filter that IS a d-dim modal SSM must recover it to
        near machine precision (well-specified case)."""
        r = np.random.default_rng(0)
        c, d, length = 4, 8, 128
        true = M.init_modal(jax.random.PRNGKey(3), c, d)
        true["r_re"] = jnp.asarray(r.normal(0, 0.3, (c, d)), jnp.float32)
        true["decay"] = jnp.asarray(r.uniform(0.7, 0.95, (c, d)), jnp.float32)
        from compile.kernels.ref import modal_filter_ref

        tgt = modal_filter_ref(
            true["decay"], true["theta"], true["r_re"], true["r_im"], length
        )
        p = M.init_modal(jax.random.PRNGKey(11), c, d)
        m = {k: jnp.zeros_like(v) for k, v in p.items()}
        v = dict(m)
        step = jax.jit(lambda p, m, v, s: M.distill_step(p, m, v, s, tgt))
        for it in range(600):
            p, m, v, loss = step(p, m, v, jnp.float32(it))
        assert float(loss) < 1e-3, float(loss)

    def test_h2_objective_matches_l2_scale(self):
        """Parseval: H2 and l2 objectives agree up to the DFT convention."""
        r = np.random.default_rng(1)
        c, d, length = 2, 4, 64
        p = M.init_modal(jax.random.PRNGKey(1), c, d)
        tgt = jnp.asarray(r.normal(0, 1, (c, length)), jnp.float32)
        l2 = M.distill_loss(p, tgt, "l2")
        h2 = M.distill_loss(p, tgt, "h2")
        # rfft of a real signal halves the spectrum; the H2 sum over rfft
        # bins is within a factor ~2 of the l2 energy — check same order.
        assert 0.2 < float(h2 / l2) < 2.5
