"""Pure-jnp oracles for every Pallas kernel (the CORE correctness signal).

Each function mirrors the mathematical definition with no tiling / layout
tricks; pytest asserts allclose between kernel and oracle across shape and
parameter sweeps (see python/tests/test_kernels.py).
"""

import jax.numpy as jnp
import numpy as np


def modal_filter_ref(decay, theta, r_re, r_im, length):
    """h_hat[c, tau] = sum_n A^tau (Rre cos(th tau) - Rim sin(th tau))."""
    tau = jnp.arange(length, dtype=jnp.float32)  # [L]
    amp = jnp.power(jnp.maximum(decay, 1e-20)[..., None], tau)  # [C, d, L]
    phase = theta[..., None] * tau
    h = amp * (r_re[..., None] * jnp.cos(phase) - r_im[..., None] * jnp.sin(phase))
    return jnp.sum(h, axis=1)  # [C, L]


def ssm_decode_step_ref(x_re, x_im, u, lam_re, lam_im, r_re, r_im, h0):
    """Reference complex-arithmetic decode step."""
    x = x_re + 1j * x_im
    lam = lam_re + 1j * lam_im
    res = r_re + 1j * r_im
    y = jnp.real(jnp.sum(res[None] * x, axis=-1)) + h0[None] * u
    x_new = lam[None] * x + u[..., None]
    return jnp.real(x_new), jnp.imag(x_new), y


def hyena_gating_ref(q, x):
    return q * x


def causal_conv_ref(h, u):
    """(h * u)_t = sum_{j<=t} h_{t-j} u_j  via explicit O(L^2) sum.

    h: [C, L] filters; u: [B, T, C] inputs with T <= L.  Returns [B, T, C].
    """
    h = np.asarray(h)
    u = np.asarray(u)
    b, t, c = u.shape
    out = np.zeros_like(u)
    for i in range(t):
        # sum_{j=0..i} h[i-j] * u[j]
        taps = h[:, : i + 1][:, ::-1]  # h[0..i] reversed -> h[i-j]
        out[:, i, :] = np.einsum("btc,ct->bc", u[:, : i + 1, :], taps.copy())
    return out


def fft_causal_conv(h, u):
    """FFT-based causal convolution matching causal_conv_ref.

    h: [C, L], u: [B, T, C] -> [B, T, C]; zero-padded to 2L to avoid wrap.
    """
    t = u.shape[1]
    n = 2 * max(h.shape[1], t)
    hf = jnp.fft.rfft(h, n=n, axis=-1)  # [C, F]
    uf = jnp.fft.rfft(u, n=n, axis=1)  # [B, F, C]
    yf = uf * jnp.transpose(hf)[None]  # broadcast over batch
    y = jnp.fft.irfft(yf, n=n, axis=1)[:, :t, :]
    return y.astype(u.dtype)
