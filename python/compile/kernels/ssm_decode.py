"""L1 Pallas kernel: fused diagonal-SSM decode step (paper Prop. 3.3).

One auto-regressive step of every distilled filter in a layer:

    y[b, c]    = Re( <R[c, :], x[b, c, :]> ) + h0[c] * u[b, c]
    x'[b, c, :] = lambda[c, :] * x[b, c, :] + u[b, c]        (B = ones)

The output uses the *pre-update* state: with x_0 = 0 this realizes
h_t = C A^{t-1} B for t >= 1 plus the h0 passthrough, exactly the modal
impulse response (paper eq. 2.2 / 3.2).

Complex state is stored split (re, im) in a structure-of-arrays layout so the
update is pure fused elementwise arithmetic; the mode reduction for y is a
VPU reduction over the last axis.  The step is memory-bound: the kernel
streams state once (read + write) per token, which is the O(d) cost of
Lemma 2.2.  Grid tiles (batch, channels); modal parameters are indexed per
channel tile only, so they stay resident in VMEM across the batch dimension.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

C_BLK = 32


def _ssm_decode_kernel(
    xr_ref, xi_ref, u_ref,
    lr_ref, li_ref, rr_ref, ri_ref, h0_ref,
    xr_out, xi_out, y_ref,
):
    """One (batch-row, channel-tile) program.

    xr/xi      : [1, C_BLK, d]  state (re / im)
    u          : [1, C_BLK]     layer input for this token
    lr/li      : [C_BLK, d]     poles lambda (re / im)
    rr/ri      : [C_BLK, d]     residues R (re / im)
    h0         : [C_BLK]        passthrough tap
    outputs    : next state (re, im) and y [1, C_BLK]
    """
    xr = xr_ref[0]  # [C_BLK, d]
    xi = xi_ref[0]
    u = u_ref[0]  # [C_BLK]

    # Output from pre-update state: y = sum_n (Rre*xre - Rim*xim) + h0*u.
    y = jnp.sum(rr_ref[...] * xr - ri_ref[...] * xi, axis=-1)
    y_ref[0, :] = y + h0_ref[...] * u

    # Diagonal complex update x' = lambda * x + u (B = ones).
    ub = u[:, None]
    xr_out[0] = lr_ref[...] * xr - li_ref[...] * xi + ub
    xi_out[0] = lr_ref[...] * xi + li_ref[...] * xr + ub * 0.0


@jax.jit
def ssm_decode_step(x_re, x_im, u, lam_re, lam_im, r_re, r_im, h0):
    """Batched fused decode step.

    Args:
      x_re, x_im: [B, C, d] split complex state.
      u:          [B, C] input (the gated signal k*v for Hyena layers).
      lam_re, lam_im, r_re, r_im: [C, d] modal parameters.
      h0:         [C] passthrough taps.

    Returns:
      (x_re', x_im', y) with y: [B, C].
    """
    b, c, d = x_re.shape
    assert c % C_BLK == 0 or c < C_BLK, f"channels {c} vs tile {C_BLK}"
    cb = min(C_BLK, c)
    grid = (b, c // cb)

    return pl.pallas_call(
        _ssm_decode_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b, c, d), jnp.float32),
            jax.ShapeDtypeStruct((b, c, d), jnp.float32),
            jax.ShapeDtypeStruct((b, c), jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cb, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, cb, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, cb), lambda i, j: (i, j)),
            pl.BlockSpec((cb, d), lambda i, j: (j, 0)),
            pl.BlockSpec((cb, d), lambda i, j: (j, 0)),
            pl.BlockSpec((cb, d), lambda i, j: (j, 0)),
            pl.BlockSpec((cb, d), lambda i, j: (j, 0)),
            pl.BlockSpec((cb,), lambda i, j: (j,)),
        ],
        out_specs=(
            pl.BlockSpec((1, cb, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, cb, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, cb), lambda i, j: (i, j)),
        ),
        interpret=True,
    )(x_re, x_im, u, lam_re, lam_im, r_re, r_im, h0)
