"""L1 Pallas kernels: modal filter materialization, forward + backward.

The Laughing Hyena modal form (paper eq. 3.2) represents a distilled filter as

    h_hat[tau] = sum_n Re( R_n * lambda_n^tau ),   tau = 0 .. L-1

(`tau = t-1` in the paper's 1-indexed convention; the passthrough tap h0 is
handled separately).  With the polar parametrization lambda_n = A_n e^{i th_n}
and cartesian residues R_n = Rre_n + i Rim_n (paper App. B.1) this is

    h_hat[tau] = sum_n A_n^tau (Rre_n cos(th_n tau) - Rim_n sin(th_n tau)).

This evaluation is the distillation hot spot (Lemma 3.1's O(dL) path): it
runs once per Adam iteration for every channel being distilled, and its VJP
runs once more.  `pallas_call` has no autodiff rule, so the backward pass is
its own kernel wired up through `jax.custom_vjp` — the cotangent
contractions are analytic:

    dL/dRre[n]  =  sum_t g_t A^t cos(th t)
    dL/dRim[n]  = -sum_t g_t A^t sin(th t)
    dL/dA[n]    =  sum_t g_t t A^(t-1) (Rre cos - Rim sin)
    dL/dth[n]   = -sum_t g_t t A^t      (Rre sin + Rim cos)

TPU mapping (DESIGN.md "Hardware-Adaptation"): instead of the CUDA
warp-per-channel reduction, each program materializes a damped-sinusoid
*basis matrix* [d, T_BLK] in VMEM and contracts it with the residue row
(forward) or the cotangent row (backward) via a matmul, so the MXU performs
the mode/time reduction.  Grid is (channels, L / T_BLK); the basis never
round-trips to HBM.  The backward kernel accumulates grads across time
tiles in its output block (grid iteration over tau-tiles is sequential).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Time-tile width.  d <= 64 and T_BLK = 512 keeps the basis at
# 64 * 512 * 4 B = 128 KiB of VMEM, far below the ~16 MiB budget, leaving
# room for double-buffering output tiles.
T_BLK = 512


def _basis(decay_ref, theta_ref, t0, d):
    """Damped-sinusoid basis for one channel: returns (amp, cos, sin),
    each [d, T_BLK], plus tau [d, T_BLK]."""
    tau = t0 + jax.lax.broadcasted_iota(jnp.float32, (d, T_BLK), 1)
    decay = decay_ref[0, :][:, None]
    theta = theta_ref[0, :][:, None]
    log_a = jnp.log(jnp.maximum(decay, 1e-20))
    amp = jnp.exp(tau * log_a)
    phase = theta * tau
    return amp, jnp.cos(phase), jnp.sin(phase), tau


def _fwd_kernel(decay_ref, theta_ref, res_ref, out_ref):
    """One (channel, time-tile) program.

    decay_ref : [1, d]    pole magnitudes A_n (>= 0)
    theta_ref : [1, d]    pole phases th_n
    res_ref   : [1, 2, d] row 0 = Re(R), row 1 = -Im(R)
    out_ref   : [1, T_BLK]
    """
    d = decay_ref.shape[1]
    t0 = pl.program_id(1) * T_BLK
    amp, cos, sin, _ = _basis(decay_ref, theta_ref, t0, d)
    basis = jnp.concatenate([amp * cos, amp * sin], axis=0)  # [2d, T_BLK]
    res = res_ref[0, :, :].reshape(1, 2 * d)
    out_ref[...] = jnp.dot(res, basis, preferred_element_type=jnp.float32)


def _bwd_kernel(decay_ref, theta_ref, rre_ref, rim_ref, g_ref,
                gdecay_ref, gtheta_ref, grre_ref, grim_ref):
    """One (channel, time-tile) program; accumulates grads over tau tiles.

    g_ref: [1, T_BLK] cotangent; parameter refs as in forward; the four
    gradient outputs are [1, d] blocks shared across the tau grid axis.
    """
    d = decay_ref.shape[1]
    j = pl.program_id(1)
    t0 = j * T_BLK
    amp, cos, sin, tau = _basis(decay_ref, theta_ref, t0, d)
    g = g_ref[0, :][None, :]  # [1, T_BLK]
    rre = rre_ref[0, :][:, None]
    rim = rim_ref[0, :][:, None]
    decay = jnp.maximum(decay_ref[0, :][:, None], 1e-20)

    # All four contractions reduce over tau within the tile; the tile sums
    # accumulate into the [1, d] output blocks across the sequential grid.
    env = rre * cos - rim * sin  # [d, T_BLK]
    odd = rre * sin + rim * cos
    g_rre = jnp.sum(g * (amp * cos), axis=1)
    g_rim = -jnp.sum(g * (amp * sin), axis=1)
    g_dec = jnp.sum(g * (tau * amp / decay * env), axis=1)
    g_th = -jnp.sum(g * (tau * amp * odd), axis=1)

    @pl.when(j == 0)
    def _init():
        gdecay_ref[...] = jnp.zeros_like(gdecay_ref)
        gtheta_ref[...] = jnp.zeros_like(gtheta_ref)
        grre_ref[...] = jnp.zeros_like(grre_ref)
        grim_ref[...] = jnp.zeros_like(grim_ref)

    gdecay_ref[0, :] += g_dec
    gtheta_ref[0, :] += g_th
    grre_ref[0, :] += g_rre
    grim_ref[0, :] += g_rim


def _padded(length):
    return ((length + T_BLK - 1) // T_BLK) * T_BLK


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _modal_filter(decay, theta, r_re, r_im, length):
    return _modal_filter_fwd_impl(decay, theta, r_re, r_im, length)


def _modal_filter_fwd_impl(decay, theta, r_re, r_im, length):
    c, d = decay.shape
    padded = _padded(length)
    res = jnp.stack([r_re, -r_im], axis=1)  # [C, 2, d]
    out = pl.pallas_call(
        _fwd_kernel,
        out_shape=jax.ShapeDtypeStruct((c, padded), jnp.float32),
        grid=(c, padded // T_BLK),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 2, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, T_BLK), lambda i, j: (i, j)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(decay, theta, res)
    return out[:, :length]


def _modal_filter_fwd(decay, theta, r_re, r_im, length):
    out = _modal_filter_fwd_impl(decay, theta, r_re, r_im, length)
    return out, (decay, theta, r_re, r_im)


def _modal_filter_bwd(length, resids, g):
    decay, theta, r_re, r_im = resids
    c, d = decay.shape
    padded = _padded(length)
    gp = jnp.pad(g, ((0, 0), (0, padded - length)))
    grads = pl.pallas_call(
        _bwd_kernel,
        out_shape=tuple(
            jax.ShapeDtypeStruct((c, d), jnp.float32) for _ in range(4)
        ),
        grid=(c, padded // T_BLK),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, T_BLK), lambda i, j: (i, j)),
        ],
        out_specs=tuple(
            pl.BlockSpec((1, d), lambda i, j: (i, 0)) for _ in range(4)
        ),
        interpret=True,
    )(decay, theta, r_re, r_im, gp)
    return grads


_modal_filter.defvjp(_modal_filter_fwd, _modal_filter_bwd)


def modal_filter(decay, theta, r_re, r_im, *, length):
    """Evaluate modal filters for a batch of channels.

    Args:
      decay, theta, r_re, r_im: [C, d] float32 modal parameters.
      length: number of taps L to materialize.

    Returns:
      [C, length] float32, tap tau = sum_n A^tau (Rre cos - Rim sin).
      Differentiable in all four parameter arrays (custom VJP, own kernel).
    """
    return _modal_filter(decay, theta, r_re, r_im, length)
