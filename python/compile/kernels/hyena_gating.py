"""L1 Pallas kernel: fused Hyena output gating  y = q * conv_out.

The H-block computes y_t = q_t * (h * (k . v))_t (paper eq. 2.3 written
element-wise).  After the FFT long convolution the gating is a pure
element-wise epilogue; fusing it avoids one [B, T, D] HBM round-trip, which
on TPU is the entire cost of the op (it is strictly bandwidth bound).

Grid tiles (rows = B*T, channels); blocks sized for VMEM residency.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

R_BLK = 256
C_BLK = 128


def _gating_kernel(q_ref, x_ref, out_ref):
    out_ref[...] = q_ref[...] * x_ref[...]


@jax.custom_vjp
def hyena_gating(q, x):
    """Element-wise gate: returns q * x for [B, T, D] operands.

    Differentiable via custom VJP (pallas_call has no autodiff rule); the
    backward pass reuses the same kernel: dq = g*x, dx = g*q.
    """
    return _gating_impl(q, x)


def _gating_fwd(q, x):
    return _gating_impl(q, x), (q, x)


def _gating_bwd(resids, g):
    q, x = resids
    return _gating_impl(g, x), _gating_impl(g, q)


hyena_gating.defvjp(_gating_fwd, _gating_bwd)


@jax.jit
def _gating_impl(q, x):
    assert q.shape == x.shape
    b, t, dm = q.shape
    rows = b * t
    q2 = q.reshape(rows, dm)
    x2 = x.reshape(rows, dm)
    rb = min(R_BLK, rows)
    cb = min(C_BLK, dm)
    # Fall back to whole-array blocks when shapes do not tile evenly; the
    # demo model dims are chosen to tile exactly.
    if rows % rb != 0:
        rb = rows
    if dm % cb != 0:
        cb = dm
    out = pl.pallas_call(
        _gating_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, dm), jnp.float32),
        grid=(rows // rb, dm // cb),
        in_specs=[
            pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
            pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
        interpret=True,
    )(q2, x2)
    return out.reshape(b, t, dm)
