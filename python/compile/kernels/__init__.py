"""Pallas kernels (L1) and their pure-jnp oracles."""

from .hyena_gating import hyena_gating
from .modal_filter import modal_filter
from .ssm_decode import ssm_decode_step

__all__ = ["hyena_gating", "modal_filter", "ssm_decode_step"]
