"""L2: JAX models for the Laughing Hyena reproduction (build-time only).

Defines, in functional pytree style:

  * MultiHyena / Hyena language models (paper §2.1, §4) with implicit
    (Siren-MLP) long-convolution filters, multi-head weight tying, short
    depthwise convolutions on q/k/v and FFT long convolutions;
  * a GPT-style Transformer baseline (causal MHA) trained on the same data;
  * AdamW train steps (for Table 5.1 / Table E.1 pre-training runs);
  * the recurrent decode step over distilled modal SSMs (paper §3.4),
    calling the L1 `ssm_decode` Pallas kernel;
  * prompt prefill that runs the true convolutions AND initializes the
    modal states x_T (paper Prop. 3.2);
  * the batched modal-interpolation distillation step (paper §3.2),
    calling the L1 `modal_filter` Pallas kernel.

Everything here is lowered once by aot.py to HLO text and executed from the
Rust coordinator; Python is never on the request path.
"""

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .kernels import hyena_gating, modal_filter, ssm_decode_step

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Config:
    """Model/architecture configuration (mirrored by rust/src/config.rs)."""

    kind: str = "multihyena"  # multihyena | hyena | gpt
    vocab: int = 512
    d_model: int = 96
    n_layer: int = 3
    heads: int = 8  # M long-conv heads (multihyena); hyena uses heads=d_model
    seq_len: int = 256  # training L
    short_kw: int = 3  # short depthwise conv width on q/k/v
    mlp_mult: int = 2
    attn_heads: int = 4  # for the gpt baseline
    filter_emb: int = 9  # implicit filter positional features (odd: 1 + 2k)
    filter_width: int = 32  # implicit filter MLP width
    filter_sine_freq: float = 4.0  # paper D.1: sine activation frequency 4
    lr: float = 3e-3
    weight_decay: float = 0.1
    # distilled-state dimension used by prefill/decode artifacts
    d_state: int = 16

    @property
    def n_filters(self) -> int:
        return self.d_model if self.kind == "hyena" else self.heads

    @property
    def group(self) -> int:
        """Channels per long-conv head (N = D / M)."""
        return self.d_model // self.n_filters


TINY = Config(vocab=64, d_model=32, n_layer=2, heads=4, seq_len=64,
              filter_width=16, d_state=8)
SMALL = Config(vocab=512, d_model=96, n_layer=3, heads=8, seq_len=256)
# Associative recall (Table E.1): 2-layer, long sequences, small vocab of
# key/value symbols; rust generates the episodes.
AR = Config(vocab=128, d_model=64, n_layer=2, heads=8, seq_len=512,
            filter_width=16, lr=1e-3, d_state=16)


def variant(cfg: Config, kind: str) -> Config:
    heads = cfg.d_model if kind == "hyena" else cfg.heads
    return dataclasses.replace(cfg, kind=kind, heads=heads)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def _dense_init(key, fan_in, fan_out):
    scale = 1.0 / math.sqrt(fan_in)
    return jax.random.uniform(key, (fan_in, fan_out), jnp.float32, -scale, scale)


def init_params(cfg: Config, key) -> Params:
    """Random init; layout documented for the rust checkpoint loader."""
    keys = jax.random.split(key, 4 + cfg.n_layer)
    p: Params = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * 0.02,
        "ln_f_g": jnp.ones((cfg.d_model,)),
        "ln_f_b": jnp.zeros((cfg.d_model,)),
    }
    if cfg.kind == "gpt":
        p["pos"] = jax.random.normal(keys[1], (cfg.seq_len, cfg.d_model)) * 0.02
    layers = []
    for i in range(cfg.n_layer):
        k = jax.random.split(keys[4 + i], 10)
        d = cfg.d_model
        lp = {
            "ln1_g": jnp.ones((d,)), "ln1_b": jnp.zeros((d,)),
            "ln2_g": jnp.ones((d,)), "ln2_b": jnp.zeros((d,)),
            "w_qkv": _dense_init(k[0], d, 3 * d),
            "b_qkv": jnp.zeros((3 * d,)),
            "w_out": _dense_init(k[1], d, d),
            "b_out": jnp.zeros((d,)),
            "w_mlp1": _dense_init(k[2], d, cfg.mlp_mult * d),
            "b_mlp1": jnp.zeros((cfg.mlp_mult * d,)),
            "w_mlp2": _dense_init(k[3], cfg.mlp_mult * d, d),
            "b_mlp2": jnp.zeros((d,)),
        }
        if cfg.kind != "gpt":
            m = cfg.n_filters
            lp.update({
                # short depthwise causal conv over q,k,v
                "short": jax.random.normal(k[4], (3 * d, cfg.short_kw)) * 0.3,
                # implicit long filter: Siren MLP  emb -> W -> W -> M
                "f_w1": _dense_init(k[5], cfg.filter_emb, cfg.filter_width),
                "f_b1": jnp.zeros((cfg.filter_width,)),
                "f_w2": _dense_init(k[6], cfg.filter_width, cfg.filter_width),
                "f_b2": jnp.zeros((cfg.filter_width,)),
                "f_w3": _dense_init(k[7], cfg.filter_width, m),
                "f_b3": jnp.zeros((m,)),
                # per-head exponential decay rate (softplus -> positive)
                "f_decay": jnp.linspace(0.3, 2.0, m),
                # per-head passthrough bias (adds to tap 0)
                "f_bias": jax.random.normal(k[8], (m,)) * 0.02,
            })
        layers.append(lp)
    p["layers"] = layers
    return p


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def filter_taps(cfg: Config, lp: Params, length: int) -> jnp.ndarray:
    """Materialize implicit long filters h: [M, length] (paper §2, implicit
    parametrization; Siren features per [2] with decay window)."""
    t = jnp.arange(length, dtype=jnp.float32) / float(cfg.seq_len)
    ks = jnp.arange(1, (cfg.filter_emb - 1) // 2 + 1, dtype=jnp.float32)
    feats = [t[:, None]]
    ang = 2.0 * jnp.pi * t[:, None] * ks[None, :]
    feats += [jnp.sin(ang), jnp.cos(ang)]
    z = jnp.concatenate(feats, axis=-1)  # [L, emb]
    w0 = cfg.filter_sine_freq
    z = jnp.sin(w0 * (z @ lp["f_w1"] + lp["f_b1"]))
    z = jnp.sin(w0 * (z @ lp["f_w2"] + lp["f_b2"]))
    h = z @ lp["f_w3"] + lp["f_b3"]  # [L, M]
    decay = jax.nn.softplus(lp["f_decay"])  # [M]
    window = jnp.exp(-decay[None, :] * t[:, None] * float(cfg.seq_len) / 64.0)
    h = h * window
    h = jnp.transpose(h)  # [M, L]
    # tap-0 bias = the h0 passthrough the distillery treats separately
    h = h.at[:, 0].add(lp["f_bias"])
    return h


def short_conv(u, w, kw):
    """Causal depthwise conv, u: [B, T, C], w: [C, kw]."""
    pads = jnp.pad(u, ((0, 0), (kw - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for j in range(kw):
        out = out + pads[:, j : j + u.shape[1], :] * w[None, None, :, kw - 1 - j]
    return out


def fft_long_conv(h, u):
    """Causal FFT convolution: h [M, L] filters, u [B, T, D] with the D
    channels grouped into M heads of size N=D/M (weight tying, paper §4)."""
    b, t, d = u.shape
    m, filt_len = h.shape
    n = d // m
    length = 2 * max(filt_len, t)
    hf = jnp.fft.rfft(h, n=length, axis=-1)  # [M, F]
    uf = jnp.fft.rfft(u, n=length, axis=1)  # [B, F, D]
    hf_full = jnp.repeat(hf, n, axis=0)  # [D, F]
    yf = uf * jnp.transpose(hf_full)[None]
    y = jnp.fft.irfft(yf, n=length, axis=1)[:, :t, :]
    return y.astype(u.dtype)


def hyena_mixer(cfg: Config, lp: Params, x, filt_len=None):
    """Multi-head Hyena operator (order 2): y = q . (h * (k . v))."""
    b, t, d = x.shape
    qkv = x @ lp["w_qkv"] + lp["b_qkv"]
    qkv = short_conv(qkv, lp["short"], cfg.short_kw)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    z = k * v
    h = filter_taps(cfg, lp, filt_len or t)
    zc = fft_long_conv(h, z)
    y = hyena_gating(q, zc)  # L1 Pallas kernel
    return y @ lp["w_out"] + lp["b_out"]


def attn_mixer(cfg: Config, lp: Params, x):
    b, t, d = x.shape
    nh = cfg.attn_heads
    hd = d // nh
    qkv = x @ lp["w_qkv"] + lp["b_qkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask[None, None], att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    y = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    y = y.transpose(0, 2, 1, 3).reshape(b, t, d)
    return y @ lp["w_out"] + lp["b_out"]


def block(cfg: Config, lp: Params, x):
    h = layer_norm(x, lp["ln1_g"], lp["ln1_b"])
    if cfg.kind == "gpt":
        x = x + attn_mixer(cfg, lp, h)
    else:
        x = x + hyena_mixer(cfg, lp, h)
    h = layer_norm(x, lp["ln2_g"], lp["ln2_b"])
    h = jax.nn.gelu(h @ lp["w_mlp1"] + lp["b_mlp1"]) @ lp["w_mlp2"] + lp["b_mlp2"]
    return x + h


def forward(cfg: Config, p: Params, tokens) -> jnp.ndarray:
    """tokens [B, T] int32 -> logits [B, T, V]."""
    x = p["embed"][tokens]
    if cfg.kind == "gpt":
        x = x + p["pos"][None, : tokens.shape[1]]
    for lp in p["layers"]:
        x = block(cfg, lp, x)
    x = layer_norm(x, p["ln_f_g"], p["ln_f_b"])
    return x @ jnp.transpose(p["embed"])  # weight-tied LM head


def loss_fn(cfg: Config, p: Params, tokens, targets, mask=None):
    logits = forward(cfg, p, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# AdamW train step
# ---------------------------------------------------------------------------


def init_opt(p: Params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, p)
    return zeros, jax.tree_util.tree_map(jnp.zeros_like, p)


def train_step(cfg: Config, p, m, v, step, tokens, targets, mask=None):
    """One AdamW step; returns (p', m', v', loss)."""
    loss, grads = jax.value_and_grad(
        lambda q: loss_fn(cfg, q, tokens, targets, mask)
    )(p)
    b1, b2, eps = 0.9, 0.98, 1e-9
    t = step + 1.0
    bc1 = 1.0 - jnp.power(b1, t)
    bc2 = 1.0 - jnp.power(b2, t)

    def upd(pl, ml, vl, gl):
        ml = b1 * ml + (1 - b1) * gl
        vl = b2 * vl + (1 - b2) * gl * gl
        upd_ = (ml / bc1) / (jnp.sqrt(vl / bc2) + eps)
        pl = pl - cfg.lr * (upd_ + cfg.weight_decay * pl)
        return pl, ml, vl

    flat_p, tree = jax.tree_util.tree_flatten(p)
    flat_m = jax.tree_util.tree_flatten(m)[0]
    flat_v = jax.tree_util.tree_flatten(v)[0]
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    out = [upd(a, b, c, g) for a, b, c, g in zip(flat_p, flat_m, flat_v, flat_g)]
    p2 = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    m2 = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    v2 = jax.tree_util.tree_unflatten(tree, [o[2] for o in out])
    return p2, m2, v2, loss


# ---------------------------------------------------------------------------
# Recurrent deployment (paper §3.4): prefill + decode over distilled SSMs
# ---------------------------------------------------------------------------
#
# Modal parameters per layer (produced by the distillery, rust side or
# distill_step below), all float32:
#   lam_re/lam_im [n_layer, M, d_state]  poles
#   r_re/r_im     [n_layer, M, d_state]  residues
#   h0            [n_layer, M]           passthrough taps
# Decode state:
#   x_re/x_im     [B, n_layer, D, d_state]  (channels share head params)
#   sc_buf        [B, n_layer, 3D, short_kw-1]  short-conv tails


def _broadcast_heads(cfg: Config, a):
    """[M, d] -> [D, d] by repeating each head over its N channels."""
    return jnp.repeat(a, cfg.group, axis=0)


def decode_step(cfg: Config, p: Params, modal: Params, token, x_re, x_im, sc_buf):
    """One recurrent token step. token: [B] int32. Returns
    (logits [B,V], x_re', x_im', sc_buf')."""
    x = p["embed"][token]  # [B, D]
    new_xre, new_xim, new_buf = [], [], []
    for i, lp in enumerate(p["layers"]):
        h = layer_norm(x, lp["ln1_g"], lp["ln1_b"])
        qkv = h @ lp["w_qkv"] + lp["b_qkv"]  # [B, 3D]
        # short conv against the rolling buffer
        buf = sc_buf[:, i]  # [B, 3D, kw-1]
        window = jnp.concatenate([buf, qkv[:, :, None]], axis=-1)  # [B,3D,kw]
        qkv_c = jnp.sum(window * lp["short"][None, :, ::-1][:, :, :], axis=-1)
        # note: short filter applied with w[kw-1-j] over window -> reverse
        new_buf.append(window[:, :, 1:])
        q, k, v = jnp.split(qkv_c, 3, axis=-1)
        z = k * v  # [B, D]
        lam_re = _broadcast_heads(cfg, modal["lam_re"][i])
        lam_im = _broadcast_heads(cfg, modal["lam_im"][i])
        r_re = _broadcast_heads(cfg, modal["r_re"][i])
        r_im = _broadcast_heads(cfg, modal["r_im"][i])
        h0 = jnp.repeat(modal["h0"][i], cfg.group, axis=0)
        xr, xi, y = ssm_decode_step(  # L1 Pallas kernel
            x_re[:, i], x_im[:, i], z, lam_re, lam_im, r_re, r_im, h0
        )
        new_xre.append(xr)
        new_xim.append(xi)
        y = q * y
        x = x + (y @ lp["w_out"] + lp["b_out"])
        hh = layer_norm(x, lp["ln2_g"], lp["ln2_b"])
        hh = jax.nn.gelu(hh @ lp["w_mlp1"] + lp["b_mlp1"]) @ lp["w_mlp2"] + lp["b_mlp2"]
        x = x + hh
    x = layer_norm(x, p["ln_f_g"], p["ln_f_b"])
    logits = x @ jnp.transpose(p["embed"])
    return (
        logits,
        jnp.stack(new_xre, axis=1),
        jnp.stack(new_xim, axis=1),
        jnp.stack(new_buf, axis=1),
    )


def prefill(cfg: Config, p: Params, modal: Params, tokens, lengths):
    """Process a (right-padded) prompt batch.

    tokens: [B, T] int32, lengths: [B] int32 actual prompt lengths.
    Runs the TRUE convolution forward pass for logits and initializes the
    modal states x_T for every layer/channel:  x_T = sum_j lam^(T-1-j) z_j
    (Prop. 3.2's result computed via the powers contraction; the FFT variant
    lives in rust/src/distill/prefill.rs and is benchmarked in §Perf).

    Returns (last_logits [B, V], x_re, x_im, sc_buf).
    """
    b, t = tokens.shape
    d, kw = cfg.d_model, cfg.short_kw
    pos = jnp.arange(t, dtype=jnp.int32)
    valid = pos[None, :] < lengths[:, None]  # [B, T]

    x = p["embed"][tokens] * valid[..., None]
    xres, xims, bufs = [], [], []
    for i, lp in enumerate(p["layers"]):
        h = layer_norm(x, lp["ln1_g"], lp["ln1_b"])
        qkv_pre = h @ lp["w_qkv"] + lp["b_qkv"]  # [B, T, 3D]
        qkv = short_conv(qkv_pre, lp["short"], kw)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        z = (k * v) * valid[..., None]  # zero pad positions
        # --- true convolution for outputs
        hf = filter_taps(cfg, lp, t)
        zc = fft_long_conv(hf, z)
        y = hyena_gating(q, zc)
        x = x + (y @ lp["w_out"] + lp["b_out"])
        hh = layer_norm(x, lp["ln2_g"], lp["ln2_b"])
        hh = jax.nn.gelu(hh @ lp["w_mlp1"] + lp["b_mlp1"]) @ lp["w_mlp2"] + lp["b_mlp2"]
        x = x + hh
        # --- modal state init: exponent e[b, j] = len[b]-1-j (masked >= 0)
        expn = (lengths[:, None] - 1 - pos[None, :]).astype(jnp.float32)  # [B,T]
        lam_a = jnp.sqrt(
            modal["lam_re"][i] ** 2 + modal["lam_im"][i] ** 2
        )  # [M, d]
        lam_th = jnp.arctan2(modal["lam_im"][i], modal["lam_re"][i])
        log_a = jnp.log(jnp.maximum(lam_a, 1e-20))
        # powers[b, j, m, n] = A^e cos/sin(th e), masked to valid positions
        e = jnp.maximum(expn, 0.0)[:, :, None, None]  # [B,T,1,1]
        amp = jnp.exp(e * log_a[None, None]) * valid[:, :, None, None]
        pw_re = amp * jnp.cos(lam_th[None, None] * e)
        pw_im = amp * jnp.sin(lam_th[None, None] * e)
        ds = modal["lam_re"].shape[-1]
        zg = z.reshape(b, t, cfg.n_filters, cfg.group)  # [B,T,M,N]
        xre = jnp.einsum("btmn,btmd->bmnd", zg, pw_re).reshape(b, d, ds)
        xim = jnp.einsum("btmn,btmd->bmnd", zg, pw_im).reshape(b, d, ds)
        xres.append(xre)
        xims.append(xim)
        # --- short-conv tail: last kw-1 *pre-conv* qkv rows before length
        idx = jnp.clip(
            lengths[:, None] - (kw - 1) + jnp.arange(kw - 1)[None, :], 0, t - 1
        )  # [B, kw-1]
        tail_valid = (lengths[:, None] - (kw - 1) + jnp.arange(kw - 1)[None, :]) >= 0
        tail = jnp.take_along_axis(qkv_pre, idx[:, :, None], axis=1)  # [B,kw-1,3D]
        tail = tail * tail_valid[:, :, None]
        bufs.append(jnp.transpose(tail, (0, 2, 1)))  # [B, 3D, kw-1]

    x = layer_norm(x, p["ln_f_g"], p["ln_f_b"])
    logits = x @ jnp.transpose(p["embed"])  # [B, T, V]
    last = jnp.take_along_axis(
        logits, (lengths - 1)[:, None, None], axis=1
    )[:, 0]  # [B, V]
    return (
        last,
        jnp.stack(xres, axis=1),
        jnp.stack(xims, axis=1),
        jnp.stack(bufs, axis=1),
    )


# ---------------------------------------------------------------------------
# Distillation step (paper §3.2): batched modal interpolation, l2 or H2
# ---------------------------------------------------------------------------


def distill_loss(params, target, objective="l2"):
    """params: dict of decay/theta/r_re/r_im [C, d]; target: [C, L] filter
    taps for tau = 0..L-1 (h[1..L] in paper indexing)."""
    length = target.shape[1]
    hhat = modal_filter(  # L1 Pallas kernel
        params["decay"], params["theta"], params["r_re"], params["r_im"],
        length=length,
    )
    if objective == "h2":
        # Parseval: H2 distance == l2 distance; computing it in frequency
        # domain exercises the paper's eq. B.9 objective.
        err = jnp.fft.rfft(hhat - target, axis=-1)
        return jnp.mean(jnp.sum(jnp.abs(err) ** 2, axis=-1) / length)
    return jnp.mean(jnp.sum((hhat - target) ** 2, axis=-1))


def distill_step(params, m, v, step, target, lr=0.02, objective="l2"):
    """One Adam step of the modal interpolation program
    min ||h_hat - h||^2 over poles (polar) + residues (cartesian).

    Projected gradient on the pole magnitudes keeps |lambda| < 1: the paper
    (App. B.1) notes distillation itself needs no stability constraint, but
    the deployed recurrence does — an unstable pole makes the prefill
    powers x_T = sum lam^(T-1-j) z_j blow up.  The projection radius 0.9995
    leaves the optimizer the full useful range (lambda^L at L=256 still
    ~0.88)."""
    loss, grads = jax.value_and_grad(distill_loss)(params, target, objective)
    b1, b2, eps = 0.9, 0.999, 1e-8
    t = step + 1.0
    bc1 = 1.0 - jnp.power(b1, t)
    bc2 = 1.0 - jnp.power(b2, t)
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        new_m[k] = b1 * m[k] + (1 - b1) * g
        new_v[k] = b2 * v[k] + (1 - b2) * g * g
        new_p[k] = params[k] - lr * (new_m[k] / bc1) / (
            jnp.sqrt(new_v[k] / bc2) + eps
        )
    new_p["decay"] = jnp.clip(new_p["decay"], 0.0, 0.9995)
    return new_p, new_m, new_v, loss


def init_modal(key, c, d):
    """Ring-of-poles init (radius ~0.9, phases spread over the upper half
    circle in conjugate-symmetric pairs is implicit: real target keeps the
    optimization real-symmetric)."""
    k1, k2, k3 = jax.random.split(key, 3)
    theta = jnp.tile(jnp.linspace(0.0, jnp.pi, d)[None], (c, 1))
    theta = theta + jax.random.normal(k1, (c, d)) * 0.01
    # spread magnitudes so both fast and slow timescales are reachable
    decay = jnp.tile(jnp.linspace(0.6, 0.97, d)[None], (c, 1))
    decay = jnp.clip(decay + jax.random.normal(k2, (c, d)) * 0.01, 0.05, 0.999)
    return {
        "decay": decay,
        "theta": theta,
        "r_re": jax.random.normal(k3, (c, d)) * 0.01,
        "r_im": jnp.zeros((c, d)),
    }
