"""AOT pipeline: lower every L2 entry point to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()`` / proto ``.serialize()``) is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction
ids which the rust side's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

For every artifact we also emit a ``.manifest.txt`` describing the flattened
input/output order (tree paths, dtypes, shapes) so the Rust runtime can
construct and interpret PJRT literals without any Python at run time, plus
``params_*.bin`` initial checkpoints (raw little-endian f32) the Rust
launcher owns from then on.

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

import argparse
import hashlib
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return ".".join(parts) if parts else "value"


def _dtype_tag(x) -> str:
    return {"float32": "f32", "int32": "i32"}[str(x.dtype)]


def _manifest_lines(tag, tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    lines = []
    for i, (path, leaf) in enumerate(leaves):
        shape = ",".join(str(d) for d in leaf.shape) or "scalar"
        lines.append(f"{tag} {i} {_path_str(path)} {_dtype_tag(leaf)} {shape}")
    return lines


def emit(outdir, name, fn, example_args, meta=None):
    """Lower fn(*example_args) and write HLO text + manifest.

    keep_unused=True: the rust runtime feeds arguments positionally from the
    manifest, so the compiled program must keep parameters the graph does
    not consume (e.g. residues in the prefill graph, which only needs the
    poles)."""
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(os.path.join(outdir, f"{name}.hlo.txt"), "w") as f:
        f.write(text)
    out_shapes = jax.eval_shape(fn, *example_args)
    lines = ["# artifact manifest: flattened PJRT argument order"]
    for m in meta or []:
        lines.append(f"# {m}")
    lines += _manifest_lines("in", example_args)
    lines += _manifest_lines("out", out_shapes)
    with open(os.path.join(outdir, f"{name}.manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"  {name}: {len(text) // 1024} KiB hlo")


def spec_like(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def dump_params(outdir, name, params):
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    lines = ["# checkpoint manifest: leaf path, dtype, shape, byte offset, bytes"]
    blob = bytearray()
    for path, leaf in leaves:
        arr = np.asarray(leaf, dtype=np.float32)
        off = len(blob)
        blob.extend(arr.tobytes())
        shape = ",".join(str(d) for d in arr.shape) or "scalar"
        lines.append(
            f"leaf {_path_str(path)} f32 {shape} {off} {arr.nbytes}"
        )
    with open(os.path.join(outdir, f"{name}.bin"), "wb") as f:
        f.write(bytes(blob))
    with open(os.path.join(outdir, f"{name}.manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"  {name}: {len(blob) // 1024} KiB checkpoint")


def modal_spec(cfg):
    nl, m, d = cfg.n_layer, cfg.n_filters, cfg.d_state
    f32 = jnp.float32
    return {
        "lam_re": jax.ShapeDtypeStruct((nl, m, d), f32),
        "lam_im": jax.ShapeDtypeStruct((nl, m, d), f32),
        "r_re": jax.ShapeDtypeStruct((nl, m, d), f32),
        "r_im": jax.ShapeDtypeStruct((nl, m, d), f32),
        "h0": jax.ShapeDtypeStruct((nl, m), f32),
    }


def build_lm_artifacts(outdir, cfg_name, cfg, kinds, batch):
    t = cfg.seq_len
    tok = jax.ShapeDtypeStruct((batch, t), jnp.int32)
    mask = jax.ShapeDtypeStruct((batch, t), jnp.float32)
    step = jax.ShapeDtypeStruct((), jnp.float32)
    for kind in kinds:
        kcfg = M.variant(cfg, kind)
        params = M.init_params(kcfg, jax.random.PRNGKey(17))
        pspec = spec_like(params)
        dump_params(outdir, f"params_{kind}_{cfg_name}", params)

        emit(
            outdir, f"train_step_{kind}_{cfg_name}",
            lambda p, m_, v_, s, x, y, w, _k=kcfg: M.train_step(_k, p, m_, v_, s, x, y, w),
            (pspec, pspec, pspec, step, tok, tok, mask),
            meta=[f"kind={kind} cfg={cfg_name} batch={batch} seq={t}"],
        )
        emit(
            outdir, f"eval_loss_{kind}_{cfg_name}",
            lambda p, x, y, w, _k=kcfg: M.loss_fn(_k, p, x, y, w),
            (pspec, tok, tok, mask),
        )
    # logits + recurrent deployment only for the flagship multihyena model
    kcfg = M.variant(cfg, "multihyena")
    params = M.init_params(kcfg, jax.random.PRNGKey(17))
    pspec = spec_like(params)
    emit(
        outdir, f"fwd_logits_multihyena_{cfg_name}",
        lambda p, x, _k=kcfg: M.forward(_k, p, x),
        (pspec, tok),
    )
    # materialized long-filter taps [n_layer, M, L] — the rust distillery's
    # input when distilling a *trained* checkpoint
    emit(
        outdir, f"filters_multihyena_{cfg_name}",
        lambda p, _k=kcfg: jnp.stack(
            [M.filter_taps(_k, lp, _k.seq_len) for lp in p["layers"]]
        ),
        (pspec,),
    )
    mspec = modal_spec(kcfg)
    lens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    emit(
        outdir, f"prefill_multihyena_{cfg_name}",
        lambda p, mo, x, l, _k=kcfg: M.prefill(_k, p, mo, x, l),
        (pspec, mspec, tok, lens),
        meta=[f"d_state={kcfg.d_state}"],
    )
    tok1 = jax.ShapeDtypeStruct((batch,), jnp.int32)
    xsp = jax.ShapeDtypeStruct(
        (batch, kcfg.n_layer, kcfg.d_model, kcfg.d_state), jnp.float32
    )
    buf = jax.ShapeDtypeStruct(
        (batch, kcfg.n_layer, 3 * kcfg.d_model, kcfg.short_kw - 1), jnp.float32
    )
    emit(
        outdir, f"decode_multihyena_{cfg_name}",
        lambda p, mo, tk, xr, xi, sb, _k=kcfg: M.decode_step(_k, p, mo, tk, xr, xi, sb),
        (pspec, mspec, tok1, xsp, xsp, buf),
        meta=[f"d_state={kcfg.d_state}"],
    )


def build_distill_artifacts(outdir, channels, length, orders):
    f32 = jnp.float32
    tgt = jax.ShapeDtypeStruct((channels, length), f32)
    step = jax.ShapeDtypeStruct((), f32)
    for d in orders:
        pd = {k: jax.ShapeDtypeStruct((channels, d), f32)
              for k in ("decay", "theta", "r_re", "r_im")}
        emit(
            outdir, f"distill_step_c{channels}_d{d}_l{length}",
            lambda p, m_, v_, s, t_: M.distill_step(p, m_, v_, s, t_),
            (pd, pd, pd, step, tgt),
            meta=[f"channels={channels} order={d} length={length} objective=l2"],
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="tiny config only (CI smoke)")
    args = ap.parse_args()
    outdir = os.path.abspath(args.out)
    os.makedirs(outdir, exist_ok=True)

    print("== tiny (tests / smoke) ==")
    build_lm_artifacts(outdir, "tiny", M.TINY, ["multihyena"], batch=4)
    build_distill_artifacts(outdir, channels=8, length=64, orders=[8])
    if not args.quick:
        print("== small (experiments) ==")
        build_lm_artifacts(
            outdir, "small", M.SMALL, ["multihyena", "hyena", "gpt"], batch=8
        )
        build_distill_artifacts(outdir, channels=24, length=256, orders=[8, 16])
        print("== associative recall (Table E.1) ==")
        build_lm_artifacts(outdir, "ar", M.AR, ["multihyena", "hyena"], batch=8)

    # stamp: input digest for the Makefile no-op check
    srcs = []
    pkg = os.path.dirname(os.path.abspath(__file__))
    for root, _, files in os.walk(pkg):
        for f in sorted(files):
            if f.endswith(".py"):
                srcs.append(os.path.join(root, f))
    digest = hashlib.sha256()
    for s in srcs:
        digest.update(open(s, "rb").read())
    with open(os.path.join(outdir, "STAMP"), "w") as f:
        f.write(digest.hexdigest() + "\n")
    print("artifacts complete")


if __name__ == "__main__":
    main()
