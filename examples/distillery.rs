//! The full Distillery walkthrough (paper Figure 3.1 blueprint) across
//! model families, with every method in the repo compared on the same
//! filters: Hankel order selection, gradient modal interpolation, Prony,
//! Padé, modal truncation and balanced truncation.
//!
//!     cargo run --release --example distillery

use laughing_hyena::data::filters::{model_filters, Family};
use laughing_hyena::distill::modal_fit::{distill_modal, DistillConfig};
use laughing_hyena::distill::{balanced, pade, prony};
use laughing_hyena::hankel::{hankel_singular_values, suggest_order};
use laughing_hyena::ssm::TransferFunction;
use laughing_hyena::util::stats::rel_err;

fn main() {
    for fam in [Family::H3Iir, Family::H3Fir, Family::Hyena, Family::MultiHyena] {
        println!("\n==== {} filters ====", fam.label());
        let filters = model_filters(fam, 2, 256, 0xD157);
        for (i, f) in filters.iter().enumerate() {
            let (h0, taps) = (f[0], &f[1..]);
            let sv = hankel_singular_values(taps, Some(64));
            let order = suggest_order(&sv, 1e-3).clamp(2, 24);
            println!("filter {i}: suggested order {order} (sigma_d+1/sigma_1 = {:.1e})",
                sv.get(order).copied().unwrap_or(0.0) / sv[0]);

            // paper method
            let cfg = DistillConfig { order, iters: 2500, ..Default::default() };
            let fit = distill_modal(taps, h0, &cfg);
            println!("  modal-fit    rel err {:.2e} (stable: {})",
                fit.rel_err, fit.ssm.is_stable());

            // classical baselines at the same order
            if let Some(s) = prony::prony(taps, h0, order) {
                println!("  prony        rel err {:.2e} (rho = {:.3})",
                    rel_err(&s.impulse_response(taps.len()), taps), s.spectral_radius());
            } else {
                println!("  prony        failed (ill-conditioned)");
            }
            if let Some(tf) = pade::pade(taps, h0, order.min(16)) {
                let h = tf.impulse_response(taps.len() + 1);
                println!("  pade         rel err {:.2e}", rel_err(&h[1..], taps));
            } else {
                println!("  pade         failed (singular Toeplitz)");
            }
            if let Some(s) = balanced::balanced_truncate(taps, h0, order, Some(64)) {
                println!("  balanced     rel err {:.2e}",
                    rel_err(&s.impulse_response(taps.len()), taps));
            } else {
                println!("  balanced     failed");
            }

            // canonical forms: the O(d) companion recurrence (App. A)
            let tf = TransferFunction::from_modal(&fit.ssm);
            let comp = tf.to_companion();
            let h_comp = {
                let mut h = vec![comp.b0];
                h.extend(comp.impulse_response(taps.len() - 1));
                h
            };
            let h_modal = {
                let mut h = vec![fit.ssm.h0];
                h.extend(fit.ssm.impulse_response(taps.len() - 1));
                h
            };
            println!("  canonization modal->tf->companion drift {:.2e} (Lemma A.8)",
                rel_err(&h_comp, &h_modal));
        }
    }
    println!("\npaper shape: H3-family needs tiny orders; Hyena-family larger; \
              gradient fit dominates the classical methods on rough filters");
}
