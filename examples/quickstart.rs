//! Quickstart: distill one long-convolution filter into a compact modal
//! SSM and deploy the recurrence — the whole paper in ~60 lines of API.
//!
//!     cargo run --release --example quickstart

use laughing_hyena::distill::{DistillConfig, Distillery};
use laughing_hyena::dsp::conv::causal_conv_direct;
use laughing_hyena::hankel::{hankel_singular_values, suggest_order};
use laughing_hyena::util::stats::rel_err;
use laughing_hyena::util::Prng;

fn main() {
    // 1) a "pre-trained" long filter: mixture of damped sinusoids, L = 512
    let filter = laughing_hyena::data::filters::model_filters(
        laughing_hyena::data::filters::Family::Hyena,
        1,
        512,
        42,
    )
    .remove(0);
    println!("filter: {} taps, h0 = {:.4}", filter.len(), filter[0]);

    // 2) Hankel spectrum analysis (paper §3.3) picks the order
    let spectrum = hankel_singular_values(&filter[1..], Some(96));
    let order = suggest_order(&spectrum, 1e-3);
    println!(
        "Hankel spectrum: sigma_1 {:.3}, sigma_8/sigma_1 {:.2e}, sigma_16/sigma_1 {:.2e}",
        spectrum[0],
        spectrum[7] / spectrum[0],
        spectrum[15] / spectrum[0]
    );
    println!("suggested distillation order: {order}");

    // 3) modal interpolation (paper §3.2)
    let distillery = Distillery {
        order: Some(order),
        fit: DistillConfig { iters: 3000, ..Default::default() },
        hankel_window: Some(96),
        ..Default::default()
    };
    let out = distillery.distill_filter(&filter);
    println!(
        "distilled: order {}, rel l2 err {:.3e}, linf err {:.3e} (AAK bound {:.3e})",
        out.order, out.rel_err, out.linf_err, out.aak_bound
    );

    // 4) deploy: recurrent mode vs the original convolution
    let mut rng = Prng::new(7);
    let u = rng.normal_vec(768); // longer than the training length!
    let conv_out = causal_conv_direct(&filter, &u);
    let rec_out = out.ssm.filter(&u);
    println!(
        "recurrent vs conv output: rel err {:.3e} over {} tokens \
         (state: {} complex numbers instead of a {}-tap cache)",
        rel_err(&rec_out, &conv_out),
        u.len(),
        out.ssm.order(),
        filter.len()
    );

    // 5) constant-memory generation: the state never grows
    let mut st = out.ssm.zero_state();
    for &x in &u {
        out.ssm.step(&mut st, x);
    }
    println!("state after 768 tokens: {} entries (O(d), Lemma 2.2)", st.0.len());
}
