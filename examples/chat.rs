//! Multi-turn chat over the session subsystem: each turn resumes the
//! conversation's O(1) recurrence state from the coordinator's LRU session
//! store instead of re-prefilling the growing transcript — the serving win
//! the paper's constant-state claim (Lemma 2.2) buys.
//!
//!     cargo run --release --example chat -- [n_sessions] [n_turns]
//!
//! Runs `n_sessions` scripted conversations of `n_turns` turns each on the
//! native recurrent engine, then replays the same conversations through
//! plain one-shot requests (re-prefilling the transcript every turn) and
//! prints the latency and prefill-work comparison.  It also asserts the
//! core invariant live: resumed turns produce exactly the tokens the
//! uninterrupted transcript produces.

use laughing_hyena::config::ServeConfig;
use laughing_hyena::coordinator::server::{spawn, CoordinatorHandle, SlotEngine};
use laughing_hyena::engine::recurrent::RecurrentEngine;
use laughing_hyena::engine::LmShape;
use laughing_hyena::util::Prng;

fn coordinator(slots: usize) -> CoordinatorHandle {
    spawn(
        move || {
            let shape = LmShape::bench("nano").unwrap();
            Box::new(RecurrentEngine::new(&shape, slots, 11)) as Box<dyn SlotEngine>
        },
        ServeConfig { max_batch: slots, linger_ms: 1, ..ServeConfig::default() },
    )
}

fn main() -> anyhow::Result<()> {
    let n_sessions: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(3);
    let n_turns: usize =
        std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(5);
    let max_new = 12;
    let mut rng = Prng::new(7);
    // scripted user turns: [session][turn] -> delta tokens
    let scripts: Vec<Vec<Vec<i32>>> = (0..n_sessions)
        .map(|_| {
            (0..n_turns)
                .map(|_| (0..6 + rng.below(10)).map(|_| rng.below(64) as i32).collect())
                .collect()
        })
        .collect();

    // --- session path: submit only each turn's delta -------------------
    let h = coordinator(4);
    let mut transcripts: Vec<Vec<i32>> = vec![vec![]; n_sessions];
    let mut session_wall = vec![0.0f64; n_turns];
    for t in 0..n_turns {
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..n_sessions)
            .map(|s| {
                h.submit_in_session(s as u64, scripts[s][t].clone(), max_new)
                    .expect("coordinator alive")
            })
            .collect();
        for (s, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv()?;
            transcripts[s].extend(&scripts[s][t]);
            transcripts[s].extend(&r.tokens);
            println!(
                "session {s} turn {t}: {} new tokens in, {} out, e2e {:>6.1}ms",
                scripts[s][t].len(),
                r.tokens.len(),
                r.total_s * 1e3
            );
        }
        session_wall[t] = t0.elapsed().as_secs_f64();
    }
    println!("\nsession metrics:  {}\n", h.metrics.report());

    // --- invariant check: last turn == uninterrupted generation --------
    let s0_prefix_len =
        transcripts[0].len() - max_new.min(transcripts[0].len());
    let uninterrupted = h
        .submit(transcripts[0][..s0_prefix_len].to_vec(), max_new)
        .expect("coordinator alive")
        .recv()?;
    assert_eq!(
        &transcripts[0][s0_prefix_len..],
        &uninterrupted.tokens[..],
        "resumed session diverged from uninterrupted generation"
    );
    println!("invariant ok: resumed turns == uninterrupted transcript generation");
    let session_metrics = h.metrics.snapshot();
    h.shutdown();

    // --- baseline path: re-prefill the whole transcript every turn -----
    let h2 = coordinator(4);
    let mut base_transcripts: Vec<Vec<i32>> = vec![vec![]; n_sessions];
    let mut baseline_wall = vec![0.0f64; n_turns];
    for t in 0..n_turns {
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..n_sessions)
            .map(|s| {
                let mut full = base_transcripts[s].clone();
                full.extend(&scripts[s][t]);
                h2.submit(full, max_new).expect("coordinator alive")
            })
            .collect();
        for (s, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv()?;
            base_transcripts[s].extend(&scripts[s][t]);
            base_transcripts[s].extend(&r.tokens);
        }
        baseline_wall[t] = t0.elapsed().as_secs_f64();
    }
    h2.shutdown();
    assert_eq!(transcripts, base_transcripts, "paths must agree token-for-token");

    println!("\nper-turn wall clock, resume vs re-prefill:");
    for t in 0..n_turns {
        println!(
            "  turn {t}: resume {:>7.1}ms | re-prefill {:>7.1}ms | speedup {:.2}x",
            session_wall[t] * 1e3,
            baseline_wall[t] * 1e3,
            baseline_wall[t] / session_wall[t].max(1e-9)
        );
    }
    println!(
        "\nprefill tokens saved by sessions: {} (hits {}, misses {})",
        session_metrics.prefill_tokens_saved,
        session_metrics.session_hits,
        session_metrics.session_misses
    );
    Ok(())
}
