//! End-to-end driver (DESIGN.md §5): proves all three layers compose.
//!
//! 1. Rust drives the AOT `train_step_multihyena_small` artifact (JAX fwd/
//!    bwd + Pallas gating kernel inside) for a few hundred steps on a
//!    synthetic corpus, logging the loss curve.
//! 2. Extracts the *trained* implicit filters through the `filters_*`
//!    artifact, runs the native distillery (Hankel analysis → modal fit).
//! 3. Deploys the recurrent mode (`prefill_*` + `decode_*` artifacts with
//!    the distilled modal parameters) and cross-checks generated logits
//!    against the conv-mode forward pass.
//!
//!     cargo run --release --example e2e_train -- [steps]

use laughing_hyena::data::corpus::Corpus;
use laughing_hyena::experiments::common;
use laughing_hyena::hankel::hankel_singular_values;
use laughing_hyena::runtime::artifact::{Runtime, Value};
use laughing_hyena::runtime::lm::ServedModel;
use laughing_hyena::runtime::trainer::Trainer;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let dir = common::require_artifacts()?;
    let tag = "multihyena_small";
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    // ---- 1) pre-train ----
    let mut tr = Trainer::new(&rt, &dir, tag)?;
    println!(
        "training multihyena_small: batch {} x seq {} = {} tok/step, {steps} steps",
        tr.batch,
        tr.seq_len,
        tr.batch * tr.seq_len
    );
    let corpus_master = Corpus::new(512, 4, 1234);
    let mut corpus = corpus_master.fork(1);
    let mut heldout = corpus_master.fork(2);
    let mask = vec![1.0f32; tr.batch * tr.seq_len];
    let t0 = std::time::Instant::now();
    let mut curve = String::from("step,loss\n");
    for i in 0..steps {
        let (tok, tgt) = corpus.batch(tr.batch, tr.seq_len);
        let loss = tr.step(&tok, &tgt, &mask)?;
        curve.push_str(&format!("{i},{loss:.5}\n"));
        if i % 25 == 0 || i + 1 == steps {
            println!("  step {i:>4}  loss {loss:.4}  ({:.2} s/step)", t0.elapsed().as_secs_f64() / (i + 1) as f64);
        }
    }
    let (tok, tgt) = heldout.batch(tr.batch, tr.seq_len);
    let eval_loss = tr.eval(&tok, &tgt, &mask)?;
    println!("held-out loss {eval_loss:.4} (ppl {:.2})", (eval_loss as f64).exp());
    std::fs::create_dir_all("results")?;
    std::fs::write("results/e2e_loss_curve.csv", curve)?;

    // ---- 2) distill the trained filters ----
    let params: Vec<Value> = tr.params.clone();
    let filters = common::extract_filters(&rt, &dir, tag, &params)?;
    let sv = hankel_singular_values(&filters[0][0][1..], Some(64));
    println!(
        "layer0/head0 Hankel: sigma_8/sigma_1 {:.2e}, sigma_16/sigma_1 {:.2e}",
        sv[7] / sv[0],
        sv[15] / sv[0]
    );
    let mut lm = ServedModel::new(&rt, &dir, tag)?;
    let order = 16.min(lm.shape.d_state);
    let (systems, errs) = common::distill_filters(&filters, order, lm.shape.d_state, 2500);
    println!(
        "distilled {} filters at order {order}: rel err mean {:.3e} max {:.3e}",
        errs.len(),
        laughing_hyena::util::stats::mean(&errs),
        errs.iter().cloned().fold(0.0, f64::max)
    );

    // ---- 3) deploy recurrent mode + cross-check ----
    lm.set_params(params.clone());
    lm.set_modal(&systems)?;
    let (b, t, v) = (lm.shape.batch, lm.shape.seq_len, lm.shape.vocab);
    let (tokens, _) = heldout.batch(b, t);
    let fwd = rt.load(&dir, &format!("fwd_logits_{tag}"))?;
    let mut inputs = params.clone();
    inputs.push(Value::i32(tokens.clone(), &[b, t]));
    let conv_logits = fwd.execute(&inputs)?[0].as_f32()?.to_vec();

    let t0p = t / 2;
    let prompts: Vec<Vec<i32>> = (0..b).map(|r| tokens[r * t..r * t + t0p].to_vec()).collect();
    lm.prefill_batch(&prompts)?;
    let mut errs = vec![];
    for j in 0..8 {
        for r in 0..b {
            lm.last_tokens[r] = tokens[r * t + t0p + j];
        }
        let rec = lm.decode_step_logits()?;
        for r in 0..b {
            let want = &conv_logits[(r * t + t0p + j) * v..(r * t + t0p + j + 1) * v];
            errs.push(common::rel_l1(&rec[r * v..(r + 1) * v], want));
        }
    }
    println!(
        "recurrent vs conv logits over 8 teacher-forced steps: rel-l1 mean {:.3e} max {:.3e}",
        laughing_hyena::util::stats::mean(&errs),
        errs.iter().cloned().fold(0.0, f64::max)
    );

    // free generation for show
    lm.prefill_batch(&prompts)?;
    let mut text = prompts[0].clone();
    for _ in 0..12 {
        let toks = lm.decode_step()?;
        text.push(toks[0]);
    }
    println!("sample continuation (row 0): {:?}", &text[t0p.saturating_sub(4)..]);
    println!("e2e OK — loss curve in results/e2e_loss_curve.csv");
    Ok(())
}
