//! Associative recall (paper §4, Theorem 4.1, Table E.1): train 2-layer
//! Hyena vs MultiHyena on key-value recall episodes through the AOT
//! train artifacts and compare accuracy.
//!
//!     cargo run --release --example associative_recall -- [steps] [pairs]

use laughing_hyena::data::assoc_recall::AssocRecall;
use laughing_hyena::experiments::common;
use laughing_hyena::runtime::artifact::{Runtime, Value};
use laughing_hyena::runtime::trainer::Trainer;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(250);
    let pairs: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(24);
    let dir = common::require_artifacts()?;
    let rt = Runtime::cpu()?;
    for kind in ["hyena", "multihyena"] {
        let tag = format!("{kind}_ar");
        let mut tr = Trainer::new(&rt, &dir, &tag)?;
        println!("\n== {kind}: {pairs} kv-pairs, seq {}, {steps} steps ==", tr.seq_len);
        let mut gen = AssocRecall::new(pairs, tr.seq_len, 17);
        for i in 0..steps {
            let (tok, tgt, mask, _) = gen.batch(tr.batch);
            let loss = tr.step(&tok, &tgt, &mask)?;
            if i % 25 == 0 || i + 1 == steps {
                println!("  step {i:>4}  recall loss {loss:.4}");
            }
        }
        // masked eval loss on fresh episodes (accuracy proxy: exp(-loss));
        // multihyena additionally gets exact argmax accuracy via its
        // fwd_logits artifact
        let mut eval_gen = AssocRecall::new(pairs, tr.seq_len, 999);
        let (tok, tgt, mask, answers) = eval_gen.batch(tr.batch);
        let loss = tr.eval(&tok, &tgt, &mask)?;
        println!("  eval loss {loss:.4} (soft acc ~ {:.1}%)", 100.0 * (-loss as f64).exp());
        if kind == "multihyena" {
            if let Ok(art) = rt.load(&dir, "fwd_logits_multihyena_ar") {
                let mut inputs: Vec<Value> = tr.params.clone();
                inputs.push(Value::i32(tok.clone(), &[tr.batch, tr.seq_len]));
                let out = art.execute(&inputs)?;
                let vocab = out[0].shape()[2];
                let logits = out[0].as_f32()?;
                let mut hits = 0;
                for (r, (qpos, ans)) in answers.iter().enumerate() {
                    let base = (r * tr.seq_len + qpos) * vocab;
                    let row = &logits[base..base + vocab];
                    let pred = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0;
                    if pred == *ans as usize {
                        hits += 1;
                    }
                }
                println!(
                    "  exact recall accuracy: {}/{} = {:.0}%",
                    hits,
                    answers.len(),
                    100.0 * hits as f64 / answers.len() as f64
                );
            }
        }
    }
    println!("\npaper shape (Table E.1): MultiHyena 98 vs Hyena 65 at high vocab pressure");
    Ok(())
}
