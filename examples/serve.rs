//! Serving demo over the AOT artifacts: the coordinator runs continuous
//! batching against the PJRT decode/prefill executables (three-layer stack
//! on the request path, zero Python).
//!
//!     cargo run --release --example serve -- [n_requests]

use laughing_hyena::config::ServeConfig;
use laughing_hyena::coordinator::server::{spawn, SlotEngine};
use laughing_hyena::coordinator::state::PjrtSlotEngine;
use laughing_hyena::experiments::common;
use laughing_hyena::runtime::artifact::Runtime;
use laughing_hyena::runtime::lm::ServedModel;
use laughing_hyena::util::Prng;

fn main() -> anyhow::Result<()> {
    let n_requests: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let dir = common::require_artifacts()?;
    let max_new = 12;

    let handle = spawn(
        move || {
            let rt = Runtime::cpu().expect("pjrt");
            let lm = ServedModel::new(&rt, &dir, "multihyena_tiny").expect("load model");
            println!(
                "engine up: batch {}, vocab {}, {} B state/seq",
                lm.shape.batch,
                lm.shape.vocab,
                lm.state_bytes_per_seq()
            );
            Box::new(PjrtSlotEngine::new(lm)) as Box<dyn SlotEngine>
        },
        ServeConfig {
            max_batch: 4,
            linger_ms: 2,
            max_new_tokens: max_new,
            mem_budget: 1 << 30,
            ..ServeConfig::default()
        },
    );

    let mut rng = Prng::new(3);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n_requests)
        .map(|_| {
            let len = 4 + rng.below(12);
            let prompt: Vec<i32> = (0..len).map(|_| rng.below(64) as i32).collect();
            handle.submit(prompt, max_new).expect("coordinator alive")
        })
        .collect();
    for rx in rxs {
        let r = rx.recv()?;
        println!(
            "req {:>3}: ttft {:>7.1}ms  e2e {:>7.1}ms  tokens {:?}",
            r.id,
            r.ttft_s * 1e3,
            r.total_s * 1e3,
            &r.tokens[..4.min(r.tokens.len())]
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", handle.metrics.report());
    println!(
        "wall {wall:.2}s — {:.1} tok/s through the PJRT decode artifact",
        (n_requests * max_new) as f64 / wall
    );
    handle.shutdown();
    Ok(())
}
