//! Substrate micro-benchmarks: FFT, Hankel eigensolve, modal evaluation —
//! the building blocks whose costs bound every experiment driver.
//! (criterion is unavailable offline; benchkit prints mean/p50/p99.)

use laughing_hyena::benchkit::{bench, fmt_time, Table};
use laughing_hyena::dsp::fft::dft_real;
use laughing_hyena::dsp::C64;
use laughing_hyena::hankel::hankel_singular_values;
use laughing_hyena::ssm::ModalSsm;
use laughing_hyena::util::Prng;

fn main() {
    let mut table = Table::new(&["bench", "mean", "p50", "p99", "throughput"]);
    let mut rng = Prng::new(1);

    for n in [256usize, 1024, 4096] {
        let x = rng.normal_vec(n);
        let r = bench(&format!("fft n={n}"), 3, 30, || dft_real(&x)[0].re);
        table.row(&[
            r.name.clone(),
            fmt_time(r.mean_s),
            fmt_time(r.p50_s),
            fmt_time(r.p99_s),
            format!("{:.1} Melem/s", n as f64 / r.mean_s / 1e6),
        ]);
    }

    for n in [64usize, 128, 256] {
        let taps = rng.normal_vec(2 * n);
        let r = bench(&format!("hankel eig n={n}"), 1, 5, || {
            hankel_singular_values(&taps, Some(n))[0]
        });
        table.row(&[
            r.name.clone(),
            fmt_time(r.mean_s),
            fmt_time(r.p50_s),
            fmt_time(r.p99_s),
            format!("{:.2} solves/s", 1.0 / r.mean_s),
        ]);
    }

    for (d, l) in [(16usize, 256usize), (64, 1024)] {
        let sys = ModalSsm::new(
            (0..d).map(|i| C64::polar(0.9, 0.1 * i as f64)).collect(),
            (0..d).map(|_| C64::new(rng.normal(), rng.normal())).collect(),
            0.0,
        );
        let r = bench(&format!("modal impulse d={d} L={l}"), 3, 50, || {
            sys.impulse_response(l)[l - 1]
        });
        table.row(&[
            r.name.clone(),
            fmt_time(r.mean_s),
            fmt_time(r.p50_s),
            fmt_time(r.p99_s),
            format!("{:.1} Mtap/s", (d * l) as f64 / r.mean_s / 1e6),
        ]);
    }

    table.print("substrate micro-benchmarks");
    let _ = table.write_csv("bench_substrates.csv");
}
