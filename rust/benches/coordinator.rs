//! Coordinator benchmarks: scheduling overhead per token, continuous
//! batching utilization, and tail latency under load — L3 should not be the
//! bottleneck (§Perf target: overhead ≪ one engine decode step).

use laughing_hyena::benchkit::{fmt_time, Table};
use laughing_hyena::config::ServeConfig;
use laughing_hyena::coordinator::server::{spawn, SlotEngine};
use laughing_hyena::engine::recurrent::RecurrentEngine;
use laughing_hyena::engine::LmShape;

fn main() {
    let mut table = Table::new(&[
        "slots", "requests", "wall", "tok/s", "ttft p50", "e2e p99", "util %",
    ]);
    for (slots, n_req, max_new) in [(2usize, 16usize, 16usize), (4, 32, 16), (8, 64, 16)] {
        let handle = spawn(
            move || {
                let shape = LmShape::bench("nano").unwrap();
                Box::new(RecurrentEngine::new(&shape, slots, 11)) as Box<dyn SlotEngine>
            },
            ServeConfig {
                max_batch: slots,
                linger_ms: 1,
                max_new_tokens: max_new,
                mem_budget: 1 << 30,
                ..ServeConfig::default()
            },
        );
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..n_req)
            .map(|i| handle.submit(vec![1 + (i % 32) as i32; 24], max_new).expect("alive"))
            .collect();
        for rx in rxs {
            rx.recv().expect("response");
        }
        let wall = t0.elapsed().as_secs_f64();
        let m = handle.metrics.snapshot();
        // utilization: generated tokens / (decode steps * slots)
        let util = 100.0 * m.tokens_generated as f64
            / ((m.decode_steps as f64) * slots as f64).max(1.0);
        table.row(&[
            slots.to_string(),
            n_req.to_string(),
            fmt_time(wall),
            format!("{:.0}", (n_req * max_new) as f64 / wall),
            fmt_time(m.ttft.quantile(0.50)),
            fmt_time(m.e2e.quantile(0.99)),
            format!("{util:.0}"),
        ]);
        handle.shutdown();
    }
    table.print("coordinator under load (native recurrent engine, shape nano)");
    let _ = table.write_csv("bench_coordinator.csv");

    // pure scheduling overhead: 0-work engine
    struct NullEngine {
        slots: usize,
    }
    impl SlotEngine for NullEngine {
        fn n_slots(&self) -> usize {
            self.slots
        }
        fn bytes_per_seq(&self) -> u64 {
            1
        }
        fn prefill_slots(&mut self, jobs: &[(usize, Vec<i32>)]) -> Vec<(usize, i32)> {
            jobs.iter().map(|(s, _)| (*s, 1)).collect()
        }
        fn decode_slots(&mut self, active: &[usize]) -> Vec<(usize, i32)> {
            active.iter().map(|&s| (s, 1)).collect()
        }
        fn clear_slot(&mut self, _slot: usize) {}
    }
    let handle = spawn(
        || Box::new(NullEngine { slots: 8 }) as Box<dyn SlotEngine>,
        ServeConfig {
            max_batch: 8,
            linger_ms: 0,
            max_new_tokens: 64,
            mem_budget: 1 << 30,
            ..ServeConfig::default()
        },
    );
    let n_req = 200;
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> =
        (0..n_req).map(|_| handle.submit(vec![1; 4], 64).expect("alive")).collect();
    for rx in rxs {
        rx.recv().expect("response");
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = handle.metrics.snapshot();
    println!(
        "\nscheduling overhead (null engine): {} decode steps in {:.3}s -> {:.1}us/step",
        m.decode_steps,
        wall,
        wall * 1e6 / m.decode_steps as f64
    );
    handle.shutdown();
}
