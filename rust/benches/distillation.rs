//! Distillation benchmarks + method ablations:
//! * pooled vs sequential distillation of a multi-head filter bank — the
//!   `util::pool` fan-out (results are bit-identical; asserted here);
//! * modal-fit iteration cost vs (order, length) — the distillery hot path;
//! * gradient fit vs Prony vs Padé vs balanced truncation (accuracy + time)
//!   on clean and rough filters — the paper's §3.2 / App.-E comparison;
//! * prefill strategy ablation (recurrent vs powers vs Prop-3.2 FFT).

use laughing_hyena::benchkit::{bench, fmt_time, time_once, Table};
use laughing_hyena::data::filters::{model_filters, Family};
use laughing_hyena::distill::modal_fit::{distill_modal, DistillConfig};
use laughing_hyena::distill::prefill::{prefill_powers, prefill_recurrent, FftPrefiller};
use laughing_hyena::distill::{balanced, pade, prony, Distillery};
use laughing_hyena::util::pool::Pool;
use laughing_hyena::util::stats::rel_err;
use laughing_hyena::util::Prng;

fn main() {
    // 0) pooled vs sequential distillation of a filter bank (the tentpole
    //    fan-out): same per-filter seeds and order, so the reports must be
    //    bit-identical — only the wall time changes
    let cores = Pool::auto().threads();
    let mut pooled_tab = Table::new(&[
        "filters", "order", "sequential", "pooled", "speedup",
    ]);
    let mut headline = String::new();
    for n_filters in [8usize, 16] {
        let bank = model_filters(Family::MultiHyena, n_filters, 256, 0xBA);
        let mk = |threads: Option<usize>| Distillery {
            order: Some(12),
            fit: DistillConfig { iters: 600, ..Default::default() },
            hankel_window: Some(48),
            threads,
            ..Default::default()
        };
        let (seq, t_seq) = time_once(|| mk(Some(1)).distill_all(&bank));
        let (par, t_par) = time_once(|| mk(None).distill_all(&bank));
        for (a, b) in seq.filters.iter().zip(&par.filters) {
            assert_eq!(
                a.rel_err.to_bits(),
                b.rel_err.to_bits(),
                "pooled distillation must be bit-identical to sequential"
            );
        }
        let speedup = t_seq / t_par.max(1e-12);
        pooled_tab.row(&[
            n_filters.to_string(),
            "12".into(),
            fmt_time(t_seq),
            fmt_time(t_par),
            format!("{speedup:.2}x"),
        ]);
        if n_filters == 8 {
            headline = format!(
                "pooled distillation of the 8-filter bank: {speedup:.2}x faster \
                 than sequential on {cores} cores (bit-identical rel_err)"
            );
        }
    }
    pooled_tab.print(&format!(
        "pooled vs sequential distill_all ({cores} cores, util::pool)"
    ));
    let _ = pooled_tab.write_csv("bench_distill_pool.csv");
    println!("{headline}");

    // 1) modal-fit cost scaling
    let mut cost = Table::new(&["order", "L", "time/iter", "iters/s"]);
    let mut rng = Prng::new(2);
    for (d, l) in [(8usize, 256usize), (16, 256), (32, 256), (16, 1024)] {
        let taps = rng.normal_vec(l);
        let iters = 50;
        let cfg = DistillConfig { order: d, iters, restarts: 1, ..Default::default() };
        let r = bench(&format!("fit d={d} L={l}"), 1, 4, || {
            distill_modal(&taps, 0.0, &cfg).loss
        });
        cost.row(&[
            d.to_string(),
            l.to_string(),
            fmt_time(r.mean_s / iters as f64),
            format!("{:.0}", iters as f64 / r.mean_s),
        ]);
    }
    cost.print("modal interpolation cost (per Adam iteration)");
    let _ = cost.write_csv("bench_distill_cost.csv");

    // 2) method ablation: accuracy + wall time per method per family
    let mut ab = Table::new(&["family", "method", "rel err", "time"]);
    for fam in [Family::H3Iir, Family::MultiHyena] {
        let f = &model_filters(fam, 1, 256, 7)[0];
        let (h0, taps) = (f[0], &f[1..]);
        let d = 12;
        // gradient modal fit
        let cfg = DistillConfig { order: d, iters: 2000, ..Default::default() };
        let (fit, t_fit) = time_once(|| distill_modal(taps, h0, &cfg));
        ab.row(&[
            fam.label().into(),
            "modal-fit (paper)".into(),
            format!("{:.2e}", fit.rel_err),
            fmt_time(t_fit),
        ]);
        // Prony
        let (pr, t_pr) = time_once(|| prony::prony(taps, h0, d));
        let pr_err = pr
            .map(|s| rel_err(&s.impulse_response(taps.len()), taps))
            .unwrap_or(f64::NAN);
        ab.row(&[
            fam.label().into(),
            "prony".into(),
            format!("{pr_err:.2e}"),
            fmt_time(t_pr),
        ]);
        // Pade
        let (pd, t_pd) = time_once(|| pade::pade(taps, h0, d));
        let pd_err = pd
            .map(|tf| {
                let h = tf.impulse_response(taps.len() + 1);
                rel_err(&h[1..], taps)
            })
            .unwrap_or(f64::NAN);
        ab.row(&[
            fam.label().into(),
            "pade".into(),
            format!("{pd_err:.2e}"),
            fmt_time(t_pd),
        ]);
        // balanced truncation
        let (bt, t_bt) = time_once(|| balanced::balanced_truncate(taps, h0, d, Some(64)));
        let bt_err = bt
            .map(|s| rel_err(&s.impulse_response(taps.len()), taps))
            .unwrap_or(f64::NAN);
        ab.row(&[
            fam.label().into(),
            "balanced (Kung)".into(),
            format!("{bt_err:.2e}"),
            fmt_time(t_bt),
        ]);
    }
    ab.print("distillation method ablation (order 12)");
    let _ = ab.write_csv("bench_distill_methods.csv");

    // 3) prefill strategies (paper §3.4 trade-offs)
    let mut pf = Table::new(&["T", "recurrent", "powers", "fft (Prop 3.2)"]);
    let sys = {
        let f = &model_filters(Family::H3Iir, 1, 64, 9)[0];
        let cfg = DistillConfig { order: 8, iters: 1500, ..Default::default() };
        distill_modal(&f[1..], f[0], &cfg).ssm
    };
    let fftp = FftPrefiller::new(&sys).expect("prefiller");
    for t in [256usize, 1024, 4096, 16384] {
        let u = rng.normal_vec(t);
        let r1 = bench("rec", 2, 8, || prefill_recurrent(&sys, &u).0[0].re);
        let r2 = bench("pow", 2, 8, || prefill_powers(&sys, &u).0[0].re);
        let r3 = bench("fft", 2, 8, || fftp.prefill(&u).0[0].re);
        pf.row(&[
            t.to_string(),
            fmt_time(r1.mean_s),
            fmt_time(r2.mean_s),
            fmt_time(r3.mean_s),
        ]);
    }
    pf.print("prefill strategy ablation (order-8 modal SSM)");
    let _ = pf.write_csv("bench_prefill.csv");
}
