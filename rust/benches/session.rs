//! Session subsystem benchmarks: what does resuming a stored O(1) state
//! buy over re-prefilling the transcript, and what does a snapshot cost?
//!
//! The paper's Lemma 2.2 makes the per-sequence state constant in t; this
//! bench turns that into the serving numbers that motivate the session
//! store: per-turn latency of `submit_in_session` (restore + feed delta)
//! vs one-shot re-prefill of the growing transcript, the prefill tokens the
//! store saves, and snapshot blob sizes for the recurrent engine vs the
//! KV-cached Transformer baseline.

use laughing_hyena::benchkit::{fmt_bytes, fmt_time, Table};
use laughing_hyena::config::ServeConfig;
use laughing_hyena::coordinator::server::{spawn, CoordinatorHandle, SlotEngine};
use laughing_hyena::engine::recurrent::RecurrentEngine;
use laughing_hyena::engine::transformer::TransformerEngine;
use laughing_hyena::engine::LmShape;

fn coordinator(slots: usize) -> CoordinatorHandle {
    spawn(
        move || {
            let shape = LmShape::bench("nano").unwrap();
            Box::new(RecurrentEngine::new(&shape, slots, 11)) as Box<dyn SlotEngine>
        },
        ServeConfig { max_batch: slots, linger_ms: 1, ..ServeConfig::default() },
    )
}

fn main() {
    let max_new = 8usize;
    let delta_len = 8usize;

    // --- resume vs re-prefill turn latency over a growing transcript ---
    let mut table = Table::new(&[
        "turns", "transcript", "resume/turn", "reprefill/turn", "speedup", "saved tok",
    ]);
    for n_turns in [4usize, 8, 16] {
        let deltas: Vec<Vec<i32>> =
            (0..n_turns).map(|t| vec![1 + (t % 32) as i32; delta_len]).collect();

        // session path: delta-only turns against the stored state
        let h = coordinator(2);
        let t0 = std::time::Instant::now();
        let mut transcript_len = 0usize;
        for d in &deltas {
            let r = h
                .submit_in_session(1, d.clone(), max_new)
                .expect("alive")
                .recv()
                .expect("turn");
            transcript_len += d.len() + r.tokens.len();
        }
        let resume_s = t0.elapsed().as_secs_f64() / n_turns as f64;
        let m = h.metrics.snapshot();
        let saved = m.prefill_tokens_saved;
        h.shutdown();

        // baseline: re-prefill the full transcript every turn
        let h = coordinator(2);
        let mut transcript: Vec<i32> = vec![];
        let t0 = std::time::Instant::now();
        for d in &deltas {
            transcript.extend_from_slice(d);
            let r = h.submit(transcript.clone(), max_new).expect("alive").recv().expect("turn");
            transcript.extend_from_slice(&r.tokens);
        }
        let reprefill_s = t0.elapsed().as_secs_f64() / n_turns as f64;
        h.shutdown();

        table.row(&[
            n_turns.to_string(),
            transcript_len.to_string(),
            fmt_time(resume_s),
            fmt_time(reprefill_s),
            format!("{:.2}x", reprefill_s / resume_s.max(1e-12)),
            saved.to_string(),
        ]);
    }
    table.print("session resume vs transcript re-prefill (nano, 8-token turns)");
    let _ = table.write_csv("bench_session.csv");

    // --- snapshot blob size + cost: O(1) recurrent vs O(t) KV ----------
    let shape = LmShape::bench("nano").unwrap();
    let mut table = Table::new(&[
        "transcript", "recurrent blob", "kv blob", "snapshot", "restore",
    ]);
    for t_len in [64usize, 256, 1024] {
        let prompt: Vec<i32> = (0..t_len).map(|i| (i % 50) as i32).collect();
        let mut rec = RecurrentEngine::new(&shape, 1, 5);
        rec.prefill_row(0, &prompt);
        let mut tr = TransformerEngine::new(&shape, 1, 5);
        tr.prefill_row(0, &prompt);
        let t0 = std::time::Instant::now();
        let snap = rec.snapshot_slot(0).expect("supported");
        let snap_s = t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        rec.restore_slot(0, &snap).expect("restore");
        let restore_s = t0.elapsed().as_secs_f64();
        let kv = tr.snapshot_slot(0).expect("supported");
        table.row(&[
            t_len.to_string(),
            fmt_bytes(snap.state_bytes()),
            fmt_bytes(kv.state_bytes()),
            fmt_time(snap_s),
            fmt_time(restore_s),
        ]);
    }
    table.print("snapshot blob size: constant recurrent state vs growing KV cache");
    let _ = table.write_csv("bench_session_blobs.csv");
}
