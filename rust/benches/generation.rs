//! End-to-end generation benchmarks over the native engines — the timing
//! backbone for Figures 1.1 / 5.3 / D.11, runnable standalone via
//! `cargo bench --bench generation`.

use laughing_hyena::benchkit::{fmt_bytes, fmt_time, Table};
use laughing_hyena::engine::conv_cache::ConvCacheEngine;
use laughing_hyena::engine::recurrent::RecurrentEngine;
use laughing_hyena::engine::transformer::TransformerEngine;
use laughing_hyena::engine::{run_generation, Engine, LmShape};
use laughing_hyena::util::Prng;

fn main() {
    let shape = LmShape::bench("nano").unwrap();
    let mut rng = Prng::new(4);
    let mut table = Table::new(&[
        "engine", "T", "K", "batch", "prefill", "tok/s decode", "peak state",
    ]);
    for (t, k, b) in [(64usize, 32usize, 2usize), (256, 64, 2), (256, 64, 4)] {
        let prompts: Vec<Vec<i32>> = (0..b)
            .map(|_| (0..t).map(|_| rng.below(shape.vocab) as i32).collect())
            .collect();
        for which in ["transformer", "hyena-conv", "laughing-hyena"] {
            let mut eng: Box<dyn Engine> = match which {
                "transformer" => Box::new(TransformerEngine::new(&shape, b, 7)),
                "hyena-conv" => Box::new(ConvCacheEngine::new(&shape, b, 7)),
                _ => Box::new(RecurrentEngine::new(&shape, b, 7)),
            };
            let r = run_generation(eng.as_mut(), &prompts, k);
            table.row(&[
                which.into(),
                t.to_string(),
                k.to_string(),
                b.to_string(),
                fmt_time(r.prefill_s),
                format!("{:.1}", (b * (k - 1)) as f64 / r.decode_s),
                fmt_bytes(r.peak_state_bytes),
            ]);
        }
    }
    table.print("generation end-to-end (shape nano)");
    let _ = table.write_csv("bench_generation.csv");

    // per-component decode-step costs: modal update vs attention, isolated
    let mut steps = Table::new(&["engine", "context", "decode step (1 tok, b=1)"]);
    for t in [128usize, 512] {
        let prompts = vec![(0..t).map(|_| rng.below(shape.vocab) as i32).collect::<Vec<_>>()];
        for which in ["transformer", "hyena-conv", "laughing-hyena"] {
            let mut eng: Box<dyn Engine> = match which {
                "transformer" => Box::new(TransformerEngine::new(&shape, 1, 7)),
                "hyena-conv" => Box::new(ConvCacheEngine::new(&shape, 1, 7)),
                _ => Box::new(RecurrentEngine::new(&shape, 1, 7)),
            };
            eng.prefill(&prompts);
            let n = 64;
            let t0 = std::time::Instant::now();
            for _ in 0..n {
                eng.decode();
            }
            steps.row(&[
                which.into(),
                t.to_string(),
                fmt_time(t0.elapsed().as_secs_f64() / n as f64),
            ]);
        }
    }
    steps.print("single decode-step latency vs context length");
    let _ = steps.write_csv("bench_decode_step.csv");
}
