//! Decode hot-path benchmark: the fused, allocation-free, pooled token
//! step of [`RecurrentEngine`] against a transcription of the pre-fusion
//! path (per-token heap allocations, memmove-shifted short-conv windows,
//! four-plane modal lookup with a per-channel head division, and a serial
//! batch walk), plus the two constant-factor deltas on the fused path
//! itself:
//!
//! * **pool delta** — the same fused engine stepped through the pooled
//!   `decode()` vs a serial `decode_row` walk (isolates the persistent
//!   worker-pool handoff win at each batch size).  Batches at or below
//!   [`pool::INLINE_CUTOVER`] run inline on the caller by design (the
//!   condvar handoff costs more than 1-2 rows of work), so their
//!   `pool_speedup` is ~1.0 by construction — each sweep point records
//!   `pool_inline` so the JSON is unambiguous about which regime it
//!   measured;
//! * **SIMD delta** — the pooled step with auto modal-sweep dispatch vs
//!   [`modal_sweep::force_scalar`] (≈1.0 unless built with
//!   `--features simd` on an AVX2 machine; results are bit-identical
//!   either way, so the delta is pure speed).
//!
//! Both engines are built from the same seed, so they carry identical
//! weights and modal parameters — the bench asserts the two paths emit
//! bit-identical tokens before timing anything, then sweeps the batch size
//! and writes the machine-readable perf trajectory point to
//! `BENCH_decode.json` at the repo root (plus `results/bench_decode.csv`).
//!
//! Gate: with `DECODE_BENCH_GATE=1` (set by `make bench-decode`) the run
//! fails unless the best speedup over the sweep reaches 2x.
//!
//! Smoke: with `DECODE_BENCH_SMOKE=1` (set by `make ci`) the run shrinks
//! to one iteration, keeps every correctness cross-check, and skips the
//! gate and the file writes — it exists so the bench code cannot rot.

use laughing_hyena::benchkit::{bench, fmt_time, Json, Table};
use laughing_hyena::engine::recurrent::RecurrentEngine;
use laughing_hyena::engine::{modal_sweep, Engine, LmShape};
use laughing_hyena::util::pool::{self, Pool};

/// The pre-fusion decode path, faithful to the old implementation in
/// every perf-relevant behavior (see `mix_one_alloc` for the one
/// deliberate, cost-neutral alignment of the contraction order) so the
/// speedup is measured against what actually shipped.
mod baseline {
    use laughing_hyena::dsp::C64;
    use laughing_hyena::engine::backbone::Backbone;
    use laughing_hyena::engine::linear::{gelu, layer_norm};
    use laughing_hyena::engine::LmShape;
    use laughing_hyena::ssm::ModalSsm;
    use laughing_hyena::util::Prng;

    struct HeadModal {
        lam_re: Vec<f32>,
        lam_im: Vec<f32>,
        r_re: Vec<f32>,
        r_im: Vec<f32>,
        h0: f32,
    }

    impl HeadModal {
        fn from_ssm(sys: &ModalSsm) -> HeadModal {
            HeadModal {
                lam_re: sys.poles.iter().map(|p| p.re as f32).collect(),
                lam_im: sys.poles.iter().map(|p| p.im as f32).collect(),
                r_re: sys.residues.iter().map(|r| r.re as f32).collect(),
                r_im: sys.residues.iter().map(|r| r.im as f32).collect(),
                h0: sys.h0 as f32,
            }
        }
    }

    // same derived streams as RecurrentEngine::new -> identical parameters
    fn random_modal(rng: &mut Prng, d: usize) -> ModalSsm {
        let pairs: Vec<(C64, C64)> = (0..d / 2)
            .map(|_| {
                (
                    C64::polar(rng.range(0.5, 0.95), rng.range(0.1, 2.9)),
                    C64::new(rng.normal() * 0.2, rng.normal() * 0.2),
                )
            })
            .collect();
        ModalSsm::from_conjugate_pairs(&pairs, rng.normal() * 0.1)
    }

    pub struct UnfusedEngine {
        bb: Backbone,
        modal: Vec<Vec<HeadModal>>,
        d_state: usize,
        batch: usize,
        x_re: Vec<Vec<Vec<f32>>>,
        x_im: Vec<Vec<Vec<f32>>>,
        sc: Vec<Vec<Vec<f32>>>,
        last: Vec<i32>,
    }

    impl UnfusedEngine {
        pub fn new(shape: &LmShape, batch: usize, seed: u64) -> UnfusedEngine {
            let bb = Backbone::new(shape, seed);
            let d_state = shape.d_state;
            let mut modal: Vec<Vec<HeadModal>> = Vec::with_capacity(shape.n_layer);
            for l in 0..shape.n_layer {
                modal.push(
                    (0..shape.heads)
                        .map(|h| {
                            let idx = (l * shape.heads + h) as u64;
                            let mut rng = Prng::derived(seed ^ 0xD15711, idx);
                            HeadModal::from_ssm(&random_modal(&mut rng, d_state))
                        })
                        .collect(),
                );
            }
            let d = shape.d_model;
            let kw = shape.short_kw;
            UnfusedEngine {
                bb,
                modal,
                d_state,
                batch,
                x_re: vec![vec![vec![0.0; d * d_state]; shape.n_layer]; batch],
                x_im: vec![vec![vec![0.0; d * d_state]; shape.n_layer]; batch],
                sc: vec![vec![vec![0.0; 3 * d * (kw - 1)]; shape.n_layer]; batch],
                last: vec![0; batch],
            }
        }

        pub fn prefill(&mut self, prompts: &[Vec<i32>]) -> Vec<i32> {
            assert_eq!(prompts.len(), self.batch);
            let mut out = Vec::with_capacity(self.batch);
            for b in 0..self.batch {
                for l in 0..self.bb.shape.n_layer {
                    self.x_re[b][l].fill(0.0);
                    self.x_im[b][l].fill(0.0);
                    self.sc[b][l].fill(0.0);
                }
                out.push(self.consume_row(b, &prompts[b]));
            }
            out
        }

        /// The old serial batch walk: one row at a time, per-token allocs.
        pub fn decode(&mut self) -> Vec<i32> {
            let mut out = Vec::with_capacity(self.batch);
            for b in 0..self.batch {
                let tok = self.last[b];
                out.push(self.consume_row(b, &[tok]));
            }
            out
        }

        fn consume_row(&mut self, b: usize, tokens: &[i32]) -> i32 {
            let Self { bb, modal, x_re, x_im, sc, d_state, last, .. } = self;
            let (d, kw) = (bb.shape.d_model, bb.shape.short_kw);
            let group = d / bb.shape.heads;
            let (xr_b, xi_b, sc_b) = (&mut x_re[b], &mut x_im[b], &mut sc[b]);
            let mut logits = Vec::new();
            for &tok in tokens {
                logits = decode_one_alloc(bb, tok, |li, qkv| {
                    mix_one_alloc(
                        d,
                        kw,
                        group,
                        *d_state,
                        &modal[li],
                        &mut sc_b[li],
                        &mut xr_b[li],
                        &mut xi_b[li],
                        qkv,
                    )
                });
            }
            let next = bb.greedy(&logits);
            last[b] = next;
            next
        }
    }

    /// Verbatim pre-refactor `Backbone::decode_one`: allocates every
    /// intermediate on every token.
    fn decode_one_alloc(
        bb: &Backbone,
        token: i32,
        mut mixer: impl FnMut(usize, &[f32]) -> Vec<f32>,
    ) -> Vec<f32> {
        let d = bb.shape.d_model;
        let mut x: Vec<f32> =
            bb.embed[token as usize * d..(token as usize + 1) * d].to_vec();
        let mut qkv = vec![0.0f32; 3 * d];
        let mut proj = vec![0.0f32; d];
        let mut mid = vec![0.0f32; bb.shape.mlp_mult * d];
        for (li, layer) in bb.layers.iter().enumerate() {
            let mut h = x.clone();
            layer_norm(&mut h);
            layer.qkv.apply(&h, &mut qkv);
            let mixed = mixer(li, &qkv);
            layer.out.apply(&mixed, &mut proj);
            for (xi, p) in x.iter_mut().zip(&proj) {
                *xi += p;
            }
            let mut h2 = x.clone();
            layer_norm(&mut h2);
            layer.mlp1.apply(&h2, &mut mid);
            for v in mid.iter_mut() {
                *v = gelu(*v);
            }
            layer.mlp2.apply(&mid, &mut proj);
            for (xi, p) in x.iter_mut().zip(&proj) {
                *xi += p;
            }
        }
        layer_norm(&mut x);
        let mut logits = vec![0.0f32; bb.shape.vocab];
        bb.lm_head.apply(&x, &mut logits);
        logits
    }

    /// Verbatim pre-refactor `mix_one` in its dominant costs — allocates
    /// `qkv_c` and `y` and memmove-shifts every channel window on every
    /// token of every layer, with the per-channel `c / group` head
    /// division — except for one deliberate alignment: the output
    /// contraction accumulates in the canonical lane-tree order of
    /// `engine::modal_sweep` instead of the shipped single-accumulator
    /// chain.  Identical sums require identical associativity, so this is
    /// the price of keeping the pre-timing token cross-check bit-exact
    /// against the fused engine (worth more here than baseline purity:
    /// the cross-check is the bench's correctness evidence).  Known
    /// skew: the lane shape may let LLVM partially vectorize the
    /// baseline's modal loop too, flattering the baseline — but that loop
    /// is a minor share of its per-token cost next to the allocations,
    /// memmoves and GEMVs, so the fused-vs-unfused `speedup` is slightly
    /// *under*stated, never overstated.
    #[allow(clippy::too_many_arguments)]
    fn mix_one_alloc(
        d: usize,
        kw: usize,
        group: usize,
        ds: usize,
        modal_layer: &[HeadModal],
        buf: &mut [f32],
        xr: &mut [f32],
        xi: &mut [f32],
        qkv: &[f32],
    ) -> Vec<f32> {
        let mut qkv_c = vec![0.0f32; 3 * d];
        let w: [f32; 3] = [0.25, 0.35, 0.4];
        for c in 0..3 * d {
            let mut acc = w[kw - 1] * qkv[c];
            for j in 0..kw - 1 {
                acc += w[j] * buf[c * (kw - 1) + j];
            }
            qkv_c[c] = acc;
            for j in 0..kw - 2 {
                buf[c * (kw - 1) + j] = buf[c * (kw - 1) + j + 1];
            }
            buf[c * (kw - 1) + kw - 2] = qkv[c];
        }
        let (q, rest) = qkv_c.split_at(d);
        let (k, v) = rest.split_at(d);
        let mut y = vec![0.0f32; d];
        for c in 0..d {
            let head = &modal_layer[c / group];
            let u = k[c] * v[c];
            let base = c * ds;
            let full = ds - ds % 8;
            let mut lanes = [0.0f32; 8];
            for n in 0..full {
                let (re, im) = (xr[base + n], xi[base + n]);
                lanes[n % 8] += head.r_re[n] * re - head.r_im[n] * im;
                xr[base + n] = head.lam_re[n] * re - head.lam_im[n] * im + u;
                xi[base + n] = head.lam_re[n] * im + head.lam_im[n] * re;
            }
            let mut tail = 0.0f32;
            for n in full..ds {
                let (re, im) = (xr[base + n], xi[base + n]);
                tail += head.r_re[n] * re - head.r_im[n] * im;
                xr[base + n] = head.lam_re[n] * re - head.lam_im[n] * im + u;
                xi[base + n] = head.lam_re[n] * im + head.lam_im[n] * re;
            }
            let b = [
                lanes[0] + lanes[4],
                lanes[1] + lanes[5],
                lanes[2] + lanes[6],
                lanes[3] + lanes[7],
            ];
            let acc = (head.h0 * u + ((b[0] + b[2]) + (b[1] + b[3]))) + tail;
            y[c] = q[c] * acc;
        }
        y
    }
}

fn main() {
    let shape = LmShape::bench("nano").unwrap();
    let threads = Pool::auto().threads();
    let smoke = std::env::var("DECODE_BENCH_SMOKE").is_ok();
    // decode steps per timed iteration / sweep size (tiny under smoke —
    // the smoke run only proves the bench still compiles and agrees)
    let steps = if smoke { 4usize } else { 16 };
    let (warmup, iters) = if smoke { (0usize, 1usize) } else { (3, 24) };
    let batches: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    if smoke {
        println!("DECODE_BENCH_SMOKE=1: 1-iteration smoke (no gate, no file writes)");
    }
    let mut table = Table::new(&[
        "batch",
        "fused tok/s",
        "fused ns/tok",
        "unfused tok/s",
        "unfused ns/tok",
        "speedup",
        "pool dx",
        "simd dx",
        "p99/iter",
    ]);
    let mut points = Vec::new();
    let mut speedups = Vec::new();
    for &batch in batches {
        let prompts: Vec<Vec<i32>> =
            (0..batch).map(|b| vec![1 + (b % 7) as i32; 8]).collect();
        let mut fused = RecurrentEngine::new(&shape, batch, 11);
        let mut unfused = baseline::UnfusedEngine::new(&shape, batch, 11);
        // correctness cross-check before timing: same seed -> same weights
        // -> the fused path must emit bit-identical tokens, under both the
        // auto (possibly SIMD) and forced-scalar modal-sweep dispatch
        assert_eq!(
            fused.prefill(&prompts),
            unfused.prefill(&prompts),
            "fused prefill diverged from the unfused baseline"
        );
        for _ in 0..4 {
            assert_eq!(
                fused.decode(),
                unfused.decode(),
                "fused decode diverged from the unfused baseline"
            );
        }
        modal_sweep::force_scalar(true);
        for _ in 0..2 {
            assert_eq!(
                fused.decode(),
                unfused.decode(),
                "forced-scalar decode diverged from the unfused baseline"
            );
        }
        modal_sweep::force_scalar(false);
        // headline: fused + pooled + auto sweep dispatch
        let rf = bench(&format!("fused b{batch}"), warmup, iters, || {
            let mut sink = 0.0;
            for _ in 0..steps {
                sink += fused.decode()[0] as f64;
            }
            sink
        });
        // pool delta: identical math through the serial row walk
        let rs = bench(&format!("serial b{batch}"), warmup, iters, || {
            let mut sink = 0.0;
            for _ in 0..steps {
                for b in 0..batch {
                    sink += fused.decode_row(b) as f64;
                }
            }
            sink
        });
        // SIMD delta: pooled walk with the modal sweep forced scalar
        modal_sweep::force_scalar(true);
        let rns = bench(&format!("no-simd b{batch}"), warmup, iters, || {
            let mut sink = 0.0;
            for _ in 0..steps {
                sink += fused.decode()[0] as f64;
            }
            sink
        });
        modal_sweep::force_scalar(false);
        let ru = bench(&format!("unfused b{batch}"), warmup, iters, || {
            let mut sink = 0.0;
            for _ in 0..steps {
                sink += unfused.decode()[0] as f64;
            }
            sink
        });
        let tokens = (steps * batch) as f64;
        let f_tps = tokens / rf.mean_s;
        let s_tps = tokens / rs.mean_s;
        let ns_tps = tokens / rns.mean_s;
        let u_tps = tokens / ru.mean_s;
        let f_ns = rf.mean_s / tokens * 1e9;
        let u_ns = ru.mean_s / tokens * 1e9;
        let speedup = f_tps / u_tps;
        let pool_speedup = f_tps / s_tps;
        let simd_speedup = f_tps / ns_tps;
        speedups.push(speedup);
        table.row(&[
            batch.to_string(),
            format!("{f_tps:.0}"),
            format!("{f_ns:.0}"),
            format!("{u_tps:.0}"),
            format!("{u_ns:.0}"),
            format!("{speedup:.2}x"),
            format!("{pool_speedup:.2}x"),
            format!("{simd_speedup:.2}x"),
            fmt_time(rf.p99_s),
        ]);
        points.push(Json::obj(vec![
            ("batch", Json::Int(batch as i64)),
            ("fused_tok_per_s", Json::Num(f_tps)),
            ("fused_ns_per_token", Json::Num(f_ns)),
            ("serial_tok_per_s", Json::Num(s_tps)),
            ("scalar_sweep_tok_per_s", Json::Num(ns_tps)),
            ("unfused_tok_per_s", Json::Num(u_tps)),
            ("unfused_ns_per_token", Json::Num(u_ns)),
            ("speedup", Json::Num(speedup)),
            ("pool_speedup", Json::Num(pool_speedup)),
            ("pool_inline", Json::Bool(batch <= pool::INLINE_CUTOVER)),
            ("simd_speedup", Json::Num(simd_speedup)),
        ]));
    }
    table.print(&format!(
        "fused+pooled decode vs unfused serial baseline (nano, {threads} threads, \
         simd {})",
        if modal_sweep::simd_active() { "on" } else { "off" }
    ));

    let best = speedups.iter().cloned().fold(0.0f64, f64::max);
    if smoke {
        println!("\nsmoke run complete (no gate, no file writes)");
        return;
    }
    let _ = table.write_csv("bench_decode.csv");
    let doc = Json::obj(vec![
        ("bench", Json::Str("decode".into())),
        ("shape", Json::Str(shape.name.into())),
        ("threads", Json::Int(threads as i64)),
        ("simd_built", Json::Bool(cfg!(feature = "simd"))),
        ("simd_active", Json::Bool(modal_sweep::simd_active())),
        ("decode_steps_per_iter", Json::Int(steps as i64)),
        ("iters", Json::Int(iters as i64)),
        ("best_speedup", Json::Num(best)),
        ("points", Json::Arr(points)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_decode.json");
    doc.save(path).expect("write BENCH_decode.json");
    println!("\nwrote {path} (best speedup {best:.2}x)");

    if std::env::var("DECODE_BENCH_GATE").is_ok() {
        assert!(
            best >= 2.0,
            "decode perf gate: best speedup {best:.2}x over the batch sweep is below 2x"
        );
        println!("decode perf gate passed (>= 2x)");
    }
}
