//! # Laughing Hyena Distillery — Rust coordinator and distillation library
//!
//! Reproduction of *"Laughing Hyena Distillery: Extracting Compact
//! Recurrences From Convolutions"* (Massaroli, Poli, Fu et al., NeurIPS
//! 2023) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — serving coordinator (plus the sharded
//!   [`serve`] layer: wire protocol, shard servers, a consistent-hash
//!   router with live session migration), generation engines, and a
//!   native implementation of the full distillery (modal interpolation,
//!   Hankel-spectrum order selection, truncation baselines) plus every
//!   numerical substrate it needs (FFT, eigen/SVD, polynomial algebra,
//!   state-space realizations).
//! * **L2** — JAX MultiHyena/Hyena/GPT models, AOT-lowered to HLO text in
//!   `artifacts/` (see `python/compile/`), executed through [`runtime`].
//! * **L1** — Pallas kernels for the modal filter materialization and the
//!   fused diagonal-SSM decode step (see `python/compile/kernels/`).
//!
//! Python never runs on the request path: after `make artifacts` the
//! `repro` binary is self-contained.

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod distill;
pub mod dsp;
pub mod engine;
pub mod experiments;
pub mod hankel;
pub mod linalg;
pub mod loadgen;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod ssm;
pub mod util;
