//! LU factorization with partial pivoting: real and complex solves, plus a
//! complex least-squares helper (normal equations).
//!
//! Consumers: Prony's method (linear prediction system + Vandermonde
//! residue fit, paper §3.2's classical alternative), Padé rational
//! interpolation (App. B.2), and the truncation-correction inverse
//! C = C̄ (I - A^L)^{-1} (App. A.4).

use super::mat::Mat;
use crate::dsp::C64;

/// Solve A x = b for real square A (partial pivoting). Returns None if A is
/// numerically singular.
pub fn solve_real(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(a.rows, a.cols);
    assert_eq!(a.rows, b.len());
    let n = a.rows;
    let mut m = a.clone();
    let mut x = b.to_vec();
    for col in 0..n {
        // pivot
        let (piv, mag) = (col..n)
            .map(|r| (r, m[(r, col)].abs()))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())?;
        if mag < 1e-300 {
            return None;
        }
        if piv != col {
            for j in 0..n {
                let tmp = m[(col, j)];
                m[(col, j)] = m[(piv, j)];
                m[(piv, j)] = tmp;
            }
            x.swap(col, piv);
        }
        for r in col + 1..n {
            let f = m[(r, col)] / m[(col, col)];
            if f == 0.0 {
                continue;
            }
            for j in col..n {
                let v = m[(col, j)] * f;
                m[(r, j)] -= v;
            }
            x[r] -= f * x[col];
        }
    }
    // back substitution
    for col in (0..n).rev() {
        x[col] /= m[(col, col)];
        for r in 0..col {
            x[r] -= m[(r, col)] * x[col];
        }
    }
    Some(x)
}

/// Solve A x = b for complex square A (partial pivoting on |.|).
pub fn solve_c64(a: &[Vec<C64>], b: &[C64]) -> Option<Vec<C64>> {
    let n = a.len();
    assert!(a.iter().all(|r| r.len() == n));
    assert_eq!(b.len(), n);
    let mut m: Vec<Vec<C64>> = a.to_vec();
    let mut x = b.to_vec();
    for col in 0..n {
        let (piv, mag) = (col..n)
            .map(|r| (r, m[r][col].abs()))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())?;
        if mag < 1e-300 {
            return None;
        }
        m.swap(col, piv);
        x.swap(col, piv);
        for r in col + 1..n {
            let f = m[r][col] / m[col][col];
            if f.abs() == 0.0 {
                continue;
            }
            for j in col..n {
                let v = m[col][j] * f;
                m[r][j] -= v;
            }
            let v = x[col] * f;
            x[r] -= v;
        }
    }
    for col in (0..n).rev() {
        x[col] = x[col] / m[col][col];
        for r in 0..col {
            let v = m[r][col] * x[col];
            x[r] -= v;
        }
    }
    Some(x)
}

/// Complex least squares min ||A x - b||_2 for tall A (rows >= cols) via the
/// normal equations A^H A x = A^H b with Tikhonov jitter for conditioning.
pub fn lstsq_c64(a: &[Vec<C64>], b: &[C64], ridge: f64) -> Option<Vec<C64>> {
    let rows = a.len();
    let cols = if rows == 0 { 0 } else { a[0].len() };
    assert_eq!(b.len(), rows);
    let mut ata = vec![vec![C64::ZERO; cols]; cols];
    let mut atb = vec![C64::ZERO; cols];
    for r in 0..rows {
        for i in 0..cols {
            let ari = a[r][i].conj();
            atb[i] += ari * b[r];
            for j in 0..cols {
                ata[i][j] += ari * a[r][j];
            }
        }
    }
    let scale: f64 = (0..cols).map(|i| ata[i][i].abs()).fold(0.0, f64::max);
    for i in 0..cols {
        ata[i][i] += C64::real(ridge * scale.max(1e-30));
    }
    solve_c64(&ata, &atb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn real_solve_roundtrip() {
        check("A(solve(A,b)) == b", 24, |rng| {
            let n = 1 + rng.below(8);
            let a = Mat::from_fn(n, n, |_, _| rng.normal());
            let b = rng.normal_vec(n);
            let x = match solve_real(&a, &b) {
                Some(x) => x,
                None => return Ok(()), // singular draw
            };
            let back = a.matvec(&x);
            for (g, w) in back.iter().zip(&b) {
                if (g - w).abs() > 1e-6 * (1.0 + w.abs()) {
                    return Err(format!("n={n}: {g} vs {w}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn singular_matrix_detected() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(solve_real(&a, &[1.0, 2.0]).is_none());
    }

    #[test]
    fn complex_solve_roundtrip() {
        check("complex solve", 16, |rng| {
            let n = 1 + rng.below(6);
            let a: Vec<Vec<C64>> = (0..n)
                .map(|_| (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect())
                .collect();
            let b: Vec<C64> =
                (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect();
            let x = match solve_c64(&a, &b) {
                Some(x) => x,
                None => return Ok(()),
            };
            for r in 0..n {
                let mut acc = C64::ZERO;
                for j in 0..n {
                    acc += a[r][j] * x[j];
                }
                if (acc - b[r]).abs() > 1e-6 * (1.0 + b[r].abs()) {
                    return Err(format!("row {r}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn lstsq_exact_when_consistent() {
        // overdetermined but consistent system
        let a = vec![
            vec![C64::real(1.0), C64::real(0.0)],
            vec![C64::real(0.0), C64::real(1.0)],
            vec![C64::real(1.0), C64::real(1.0)],
        ];
        let x_true = [C64::real(2.0), C64::new(0.0, -1.0)];
        let b: Vec<C64> = a
            .iter()
            .map(|row| row[0] * x_true[0] + row[1] * x_true[1])
            .collect();
        let x = lstsq_c64(&a, &b, 0.0).unwrap();
        assert!((x[0] - x_true[0]).abs() < 1e-10);
        assert!((x[1] - x_true[1]).abs() < 1e-10);
    }
}
