//! Row-major dense real matrix.

use std::fmt;

/// Dense `f64` matrix, row-major storage.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "dim mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row =
                    &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    pub fn scale(&self, s: f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        self.add(&other.scale(-1.0))
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Spectral norm (largest singular value) via a few power iterations on
    /// A^T A — accurate enough for error reporting.
    pub fn spectral_norm(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        let mut v = vec![1.0 / (self.cols as f64).sqrt(); self.cols];
        let at = self.transpose();
        let mut sigma = 0.0;
        for _ in 0..60 {
            let av = self.matvec(&v);
            let atav = at.matvec(&av);
            let n = atav.iter().map(|x| x * x).sum::<f64>().sqrt();
            if n < 1e-300 {
                return 0.0;
            }
            for (x, y) in v.iter_mut().zip(&atav) {
                *x = y / n;
            }
            sigma = n.sqrt();
        }
        sigma
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            writeln!(
                f,
                "  {:?}",
                &self.row(i)[..self.cols.min(8)]
            )?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let i = Mat::eye(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_fn(3, 5, |i, j| (i * 5 + j) as f64);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn spectral_norm_of_diagonal() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 2.0;
        a[(1, 1)] = -7.0;
        a[(2, 2)] = 0.5;
        assert!((a.spectral_norm() - 7.0).abs() < 1e-6);
    }
}
