//! Singular values via one-sided Jacobi orthogonalization.
//!
//! Cross-checks the symmetric-eigen path on Hankel matrices (tests) and
//! serves general rectangular inputs (rank estimates in the distillery).

use super::mat::Mat;

/// Singular values of an arbitrary real matrix, descending.
/// One-sided Jacobi on the (tall) side: rotates column pairs of A until all
/// are mutually orthogonal; singular values are the column norms.
pub fn singular_values(a: &Mat) -> Vec<f64> {
    let work = if a.rows >= a.cols { a.clone() } else { a.transpose() };
    let (m, n) = (work.rows, work.cols);
    if n == 0 || m == 0 {
        return vec![];
    }
    // column-major copy for cache-friendly column rotations
    let mut cols: Vec<Vec<f64>> = (0..n)
        .map(|j| (0..m).map(|i| work[(i, j)]).collect())
        .collect();
    let eps = 1e-15;
    for _sweep in 0..60 {
        let mut rotated = false;
        for p in 0..n {
            for q in p + 1..n {
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..m {
                    app += cols[p][i] * cols[p][i];
                    aqq += cols[q][i] * cols[q][i];
                    apq += cols[p][i] * cols[q][i];
                }
                if apq.abs() <= eps * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                rotated = true;
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                for i in 0..m {
                    let xp = cols[p][i];
                    let xq = cols[q][i];
                    cols[p][i] = c * xp - s * xq;
                    cols[q][i] = s * xp + c * xq;
                }
            }
        }
        if !rotated {
            break;
        }
    }
    let mut sv: Vec<f64> = cols
        .iter()
        .map(|col| col.iter().map(|x| x * x).sum::<f64>().sqrt())
        .collect();
    sv.sort_by(|a, b| b.partial_cmp(a).unwrap());
    sv
}

/// Numerical rank: count of singular values above `tol * sigma_max`.
pub fn rank(a: &Mat, tol: f64) -> usize {
    let sv = singular_values(a);
    match sv.first() {
        None => 0,
        Some(&s0) if s0 == 0.0 => 0,
        Some(&s0) => sv.iter().filter(|&&s| s > tol * s0).count(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn diagonal_matrix() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = -1.0;
        a[(2, 2)] = 0.5;
        let sv = singular_values(&a);
        assert!((sv[0] - 3.0).abs() < 1e-10);
        assert!((sv[1] - 1.0).abs() < 1e-10);
        assert!((sv[2] - 0.5).abs() < 1e-10);
    }

    #[test]
    fn frobenius_consistency() {
        check("sum sigma^2 == ||A||_F^2", 16, |rng| {
            let m = 1 + rng.below(10);
            let n = 1 + rng.below(10);
            let a = Mat::from_fn(m, n, |_, _| rng.normal());
            let sv = singular_values(&a);
            let sum_sq: f64 = sv.iter().map(|s| s * s).sum();
            let fro2 = a.fro() * a.fro();
            if (sum_sq - fro2).abs() < 1e-8 * fro2.max(1.0) {
                Ok(())
            } else {
                Err(format!("{sum_sq} vs {fro2}"))
            }
        });
    }

    #[test]
    fn rank_of_outer_product() {
        check("rank(u v^T) == 1", 12, |rng| {
            let m = 2 + rng.below(8);
            let n = 2 + rng.below(8);
            let u = rng.normal_vec(m);
            let v = rng.normal_vec(n);
            let a = Mat::from_fn(m, n, |i, j| u[i] * v[j]);
            if rank(&a, 1e-9) == 1 {
                Ok(())
            } else {
                Err(format!("rank {}", rank(&a, 1e-9)))
            }
        });
    }

    #[test]
    fn matches_sym_eig_on_symmetric_input() {
        check("svd == |eig| for symmetric", 8, |rng| {
            let n = 2 + rng.below(8);
            let mut a = Mat::zeros(n, n);
            for i in 0..n {
                for j in i..n {
                    let x = rng.normal();
                    a[(i, j)] = x;
                    a[(j, i)] = x;
                }
            }
            let sv = singular_values(&a);
            let ev = super::super::eig_sym::sym_singular_values(&a);
            for (s, e) in sv.iter().zip(&ev) {
                if (s - e).abs() > 1e-7 * (1.0 + e) {
                    return Err(format!("{s} vs {e}"));
                }
            }
            Ok(())
        });
    }
}
