//! Eigenvalues of a general real matrix: Householder Hessenberg reduction
//! followed by the shifted Francis double-step QR iteration (the classic
//! `hqr` algorithm).
//!
//! Needed for the ss→tf conversion `a = poly(eig(A))`,
//! `b = poly(eig(A - BC)) + ...` (paper App. A.6 / Listing 1) and for
//! canonizing arbitrary dense state-space models (Lemma A.8).

use super::mat::Mat;
use crate::dsp::C64;

/// Reduce to upper Hessenberg form in place (Householder reflectors).
fn hessenberg(a: &mut Mat) {
    let n = a.rows;
    for k in 0..n.saturating_sub(2) {
        // Householder vector for column k below the subdiagonal
        let mut alpha = 0.0;
        for i in k + 1..n {
            alpha += a[(i, k)] * a[(i, k)];
        }
        alpha = alpha.sqrt();
        if alpha < 1e-300 {
            continue;
        }
        if a[(k + 1, k)] > 0.0 {
            alpha = -alpha;
        }
        let mut v = vec![0.0; n];
        v[k + 1] = a[(k + 1, k)] - alpha;
        for i in k + 2..n {
            v[i] = a[(i, k)];
        }
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 < 1e-300 {
            continue;
        }
        // A <- (I - 2 v v^T / v^T v) A
        for j in 0..n {
            let mut dot = 0.0;
            for i in k + 1..n {
                dot += v[i] * a[(i, j)];
            }
            let f = 2.0 * dot / vnorm2;
            for i in k + 1..n {
                a[(i, j)] -= f * v[i];
            }
        }
        // A <- A (I - 2 v v^T / v^T v)
        for i in 0..n {
            let mut dot = 0.0;
            for j in k + 1..n {
                dot += a[(i, j)] * v[j];
            }
            let f = 2.0 * dot / vnorm2;
            for j in k + 1..n {
                a[(i, j)] -= f * v[j];
            }
        }
    }
}

/// Eigenvalues of a general real square matrix (complex output).
/// Numerical Recipes-style `hqr` on the Hessenberg form.
pub fn eig_real(a_in: &Mat) -> Vec<C64> {
    assert_eq!(a_in.rows, a_in.cols);
    let n = a_in.rows;
    if n == 0 {
        return vec![];
    }
    let mut a = a_in.clone();
    hessenberg(&mut a);

    let mut wr = vec![0.0f64; n];
    let mut wi = vec![0.0f64; n];
    // overall matrix norm for deflation thresholds
    let mut anorm = 0.0;
    for i in 0..n {
        for j in i.saturating_sub(1)..n {
            anorm += a[(i, j)].abs();
        }
    }
    if anorm == 0.0 {
        return vec![C64::ZERO; n];
    }

    let mut nn = n as isize - 1;
    let mut t = 0.0f64;
    while nn >= 0 {
        let mut its = 0;
        loop {
            // search for a small subdiagonal element
            let mut l = nn;
            while l >= 1 {
                let s = a[((l - 1) as usize, (l - 1) as usize)].abs()
                    + a[(l as usize, l as usize)].abs();
                let s = if s == 0.0 { anorm } else { s };
                if a[(l as usize, (l - 1) as usize)].abs() <= f64::EPSILON * s {
                    break;
                }
                l -= 1;
            }
            let x = a[(nn as usize, nn as usize)];
            if l == nn {
                // one root found
                wr[nn as usize] = x + t;
                wi[nn as usize] = 0.0;
                nn -= 1;
                break;
            }
            let y = a[((nn - 1) as usize, (nn - 1) as usize)];
            let w = a[(nn as usize, (nn - 1) as usize)]
                * a[((nn - 1) as usize, nn as usize)];
            if l == nn - 1 {
                // two roots found
                let p = 0.5 * (y - x);
                let q = p * p + w;
                let z = q.abs().sqrt();
                let x2 = x + t;
                if q >= 0.0 {
                    let z = p + z.copysign(p);
                    wr[(nn - 1) as usize] = x2 + z;
                    wr[nn as usize] = if z != 0.0 { x2 - w / z } else { x2 + z };
                    wi[(nn - 1) as usize] = 0.0;
                    wi[nn as usize] = 0.0;
                } else {
                    wr[(nn - 1) as usize] = x2 + p;
                    wr[nn as usize] = x2 + p;
                    wi[(nn - 1) as usize] = -z;
                    wi[nn as usize] = z;
                }
                nn -= 2;
                break;
            }
            // no root yet: QR step
            if its == 60 {
                // convergence failure: report current diagonal (rare; the
                // callers treat eigenvalues statistically)
                wr[nn as usize] = x + t;
                wi[nn as usize] = 0.0;
                nn -= 1;
                break;
            }
            let mut x = x;
            let mut y = y;
            let mut w = w;
            if its == 10 || its == 20 {
                // exceptional shift
                t += x;
                for i in 0..=nn as usize {
                    a[(i, i)] -= x;
                }
                let s = a[(nn as usize, (nn - 1) as usize)].abs()
                    + a[((nn - 1) as usize, (nn - 2) as usize)].abs();
                x = 0.75 * s;
                y = x;
                w = -0.4375 * s * s;
            }
            its += 1;
            // look for two consecutive small subdiagonal elements
            let mut m = nn - 2;
            let (mut p, mut q, mut r) = (0.0f64, 0.0f64, 0.0f64);
            while m >= l {
                let z = a[(m as usize, m as usize)];
                let rr = x - z;
                let ss = y - z;
                p = (rr * ss - w) / a[((m + 1) as usize, m as usize)]
                    + a[(m as usize, (m + 1) as usize)];
                q = a[((m + 1) as usize, (m + 1) as usize)] - z - rr - ss;
                r = a[((m + 2) as usize, (m + 1) as usize)];
                let s = p.abs() + q.abs() + r.abs();
                p /= s;
                q /= s;
                r /= s;
                if m == l {
                    break;
                }
                let u = a[(m as usize, (m - 1) as usize)].abs() * (q.abs() + r.abs());
                let v = p.abs()
                    * (a[((m - 1) as usize, (m - 1) as usize)].abs()
                        + a[(m as usize, m as usize)].abs()
                        + a[((m + 1) as usize, (m + 1) as usize)].abs());
                if u <= f64::EPSILON * v {
                    break;
                }
                m -= 1;
            }
            for i in m + 2..=nn {
                a[(i as usize, (i - 2) as usize)] = 0.0;
                if i != m + 2 {
                    a[(i as usize, (i - 3) as usize)] = 0.0;
                }
            }
            // double QR step on rows l..nn
            let mut k = m;
            while k <= nn - 1 {
                if k != m {
                    p = a[(k as usize, (k - 1) as usize)];
                    q = a[((k + 1) as usize, (k - 1) as usize)];
                    r = if k != nn - 1 {
                        a[((k + 2) as usize, (k - 1) as usize)]
                    } else {
                        0.0
                    };
                    x = p.abs() + q.abs() + r.abs();
                    if x != 0.0 {
                        p /= x;
                        q /= x;
                        r /= x;
                    }
                }
                let s = (p * p + q * q + r * r).sqrt().copysign(p);
                if s == 0.0 {
                    k += 1;
                    continue;
                }
                if k == m {
                    if l != m {
                        a[(k as usize, (k - 1) as usize)] =
                            -a[(k as usize, (k - 1) as usize)];
                    }
                } else {
                    a[(k as usize, (k - 1) as usize)] = -s * x;
                }
                p += s;
                let x2 = p / s;
                let y2 = q / s;
                let z2 = r / s;
                q /= p;
                r /= p;
                // row modification
                for j in k as usize..=nn as usize {
                    let mut pp = a[(k as usize, j)] + q * a[((k + 1) as usize, j)];
                    if k != nn - 1 {
                        pp += r * a[((k + 2) as usize, j)];
                        a[((k + 2) as usize, j)] -= pp * z2;
                    }
                    a[((k + 1) as usize, j)] -= pp * y2;
                    a[(k as usize, j)] -= pp * x2;
                }
                // column modification
                let mmin = if nn < k + 3 { nn } else { k + 3 };
                for i in l as usize..=mmin as usize {
                    let mut pp =
                        x2 * a[(i, k as usize)] + y2 * a[(i, (k + 1) as usize)];
                    if k != nn - 1 {
                        pp += z2 * a[(i, (k + 2) as usize)];
                        a[(i, (k + 2) as usize)] -= pp * r;
                    }
                    a[(i, (k + 1) as usize)] -= pp * q;
                    a[(i, k as usize)] -= pp;
                }
                k += 1;
            }
        }
    }
    (0..n).map(|i| C64::new(wr[i], wi[i])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::poly::{poly_eval, poly_from_roots};
    use crate::util::prop::check;

    /// Match two multisets of complex numbers greedily.
    fn matches(got: &[C64], want: &[C64], tol: f64) -> Result<(), String> {
        if got.len() != want.len() {
            return Err("length".into());
        }
        let mut used = vec![false; got.len()];
        for w in want {
            let mut best = (usize::MAX, f64::MAX);
            for (i, g) in got.iter().enumerate() {
                if !used[i] {
                    let d = (*g - *w).abs();
                    if d < best.1 {
                        best = (i, d);
                    }
                }
            }
            if best.1 > tol {
                return Err(format!("unmatched {w:?} (best {:.2e})", best.1));
            }
            used[best.0] = true;
        }
        Ok(())
    }

    #[test]
    fn diagonal_and_triangular() {
        let a = Mat::from_rows(&[
            vec![3.0, 1.0, 0.0],
            vec![0.0, -2.0, 5.0],
            vec![0.0, 0.0, 0.5],
        ]);
        let got = eig_real(&a);
        matches(
            &got,
            &[C64::real(3.0), C64::real(-2.0), C64::real(0.5)],
            1e-9,
        )
        .unwrap();
    }

    #[test]
    fn rotation_has_complex_pair() {
        // rotation by 90 degrees: eigenvalues +-i
        let a = Mat::from_rows(&[vec![0.0, -1.0], vec![1.0, 0.0]]);
        let got = eig_real(&a);
        matches(&got, &[C64::I, -C64::I], 1e-9).unwrap();
    }

    #[test]
    fn companion_matrix_eigs_are_poly_roots() {
        check("eig(companion(p)) == roots(p)", 12, |rng| {
            let d = 2 + rng.below(8);
            // real-coefficient polynomial from conjugate-closed root set
            let mut roots: Vec<C64> = vec![];
            let mut k = 0;
            while k < d {
                if k + 1 < d && rng.uniform() < 0.6 {
                    let z = C64::polar(rng.range(0.2, 1.1), rng.range(0.1, 3.0));
                    roots.push(z);
                    roots.push(z.conj());
                    k += 2;
                } else {
                    roots.push(C64::real(rng.range(-1.0, 1.0)));
                    k += 1;
                }
            }
            let p = poly_from_roots(&roots);
            let n = roots.len();
            let a = Mat::from_fn(n, n, |i, j| {
                if i == 0 {
                    -p[n - 1 - j].re
                } else if i == j + 1 {
                    1.0
                } else {
                    0.0
                }
            });
            let got = eig_real(&a);
            // verify via the polynomial itself (roots may be clustered)
            for g in &got {
                if poly_eval(&p, *g).abs() > 1e-5 {
                    return Err(format!("p(eig) = {:.2e}", poly_eval(&p, *g).abs()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn trace_equals_eig_sum() {
        check("trace == sum eig", 16, |rng| {
            let n = 2 + rng.below(10);
            let a = Mat::from_fn(n, n, |_, _| rng.normal());
            let tr: f64 = (0..n).map(|i| a[(i, i)]).sum();
            let es: C64 = eig_real(&a).into_iter().fold(C64::ZERO, |s, e| s + e);
            if (es.re - tr).abs() < 1e-6 * (1.0 + tr.abs()) && es.im.abs() < 1e-6 {
                Ok(())
            } else {
                Err(format!("trace {tr} vs {es:?}"))
            }
        });
    }
}
