//! Symmetric eigendecomposition via the cyclic Jacobi rotation method.
//!
//! This is the workhorse behind the paper's Hankel analysis: the truncated
//! Hankel matrix S_L = (h_{i+j}) is real symmetric, so its singular values
//! are |eigenvalues| and Kung's balanced-truncation realization (App.
//! E.3.2) needs the eigenvectors too.  Jacobi is O(n^3) per sweep but
//! unconditionally stable and accurate for the L <= 1024 sizes used here.

use super::mat::Mat;

/// Eigendecomposition of a symmetric matrix.
/// `values[k]` corresponds to eigenvector column `vectors[:, k]`,
/// sorted by |value| descending (the Hankel convention used throughout).
pub struct SymEig {
    pub values: Vec<f64>,
    pub vectors: Mat, // columns are eigenvectors
}

/// Cyclic Jacobi with threshold sweeps. Panics on non-square input;
/// symmetry is assumed (the strictly-lower triangle is ignored).
pub fn eig_sym(a: &Mat) -> SymEig {
    assert_eq!(a.rows, a.cols, "eig_sym needs a square matrix");
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Mat::eye(n);
    if n == 0 {
        return SymEig { values: vec![], vectors: v };
    }

    let off = |m: &Mat| -> f64 {
        let mut s = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                s += m[(i, j)] * m[(i, j)];
            }
        }
        s.sqrt()
    };
    let scale = m.fro().max(1e-300);

    for _sweep in 0..60 {
        if off(&m) <= 1e-14 * scale {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[(p, q)];
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // rotate rows/cols p and q of m
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| {
        m[(j, j)].abs().partial_cmp(&m[(i, i)].abs()).unwrap()
    });
    let values: Vec<f64> = order.iter().map(|&i| m[(i, i)]).collect();
    let vectors = Mat::from_fn(n, n, |r, c| v[(r, order[c])]);
    SymEig { values, vectors }
}

/// Singular values of a symmetric matrix (|eigenvalues|, descending).
pub fn sym_singular_values(a: &Mat) -> Vec<f64> {
    eig_sym(a).values.into_iter().map(f64::abs).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::Prng;

    fn random_symmetric(rng: &mut Prng, n: usize) -> Mat {
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let x = rng.normal();
                a[(i, j)] = x;
                a[(j, i)] = x;
            }
        }
        a
    }

    #[test]
    fn reconstructs_matrix() {
        check("V diag(w) V^T == A", 12, |rng| {
            let n = 1 + rng.below(12);
            let a = random_symmetric(rng, n);
            let SymEig { values, vectors } = eig_sym(&a);
            let mut d = Mat::zeros(n, n);
            for (i, &w) in values.iter().enumerate() {
                d[(i, i)] = w;
            }
            let rec = vectors.matmul(&d).matmul(&vectors.transpose());
            if rec.sub(&a).fro() < 1e-8 * a.fro().max(1.0) {
                Ok(())
            } else {
                Err(format!("n={n}, err={}", rec.sub(&a).fro()))
            }
        });
    }

    #[test]
    fn vectors_orthonormal() {
        check("V^T V == I", 12, |rng| {
            let n = 2 + rng.below(10);
            let a = random_symmetric(rng, n);
            let v = eig_sym(&a).vectors;
            let g = v.transpose().matmul(&v);
            if g.sub(&Mat::eye(n)).fro() < 1e-9 * n as f64 {
                Ok(())
            } else {
                Err("not orthonormal".into())
            }
        });
    }

    #[test]
    fn known_2x2() {
        // [[2, 1], [1, 2]] has eigenvalues 3 and 1
        let a = Mat::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let w = eig_sym(&a).values;
        assert!((w[0] - 3.0).abs() < 1e-12);
        assert!((w[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn diagonal_matrix_is_fixed_point() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = -5.0;
        a[(1, 1)] = 2.0;
        a[(2, 2)] = 0.1;
        let w = eig_sym(&a).values;
        assert!((w[0] + 5.0).abs() < 1e-12); // sorted by |.|
        assert!((w[1] - 2.0).abs() < 1e-12);
        assert!((w[2] - 0.1).abs() < 1e-12);
    }
}
