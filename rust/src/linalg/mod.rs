//! Dense linear-algebra substrate (no external BLAS/LAPACK in the offline
//! image): LU solves, Jacobi symmetric eigendecomposition, one-sided Jacobi
//! SVD, and real-Hessenberg QR eigenvalues.
//!
//! Sized for the paper's workloads: Hankel matrices up to L x L with
//! L <= 1024 and state-space systems with d <= 64.

pub mod eig;
pub mod eig_sym;
pub mod lu;
pub mod mat;
pub mod svd;

pub use mat::Mat;
