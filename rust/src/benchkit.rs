//! Benchmark harness (criterion is unavailable in the offline image — see
//! DESIGN.md §6): warmup + timed iterations, percentile reporting, aligned
//! table printing and CSV output under `results/`.

use std::time::Instant;

use crate::util::stats;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub std_s: f64,
}

impl BenchResult {
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean_s
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
/// A `black_box`-style sink prevents the optimizer from deleting work: have
/// `f` return a value that folds into the checksum.
pub fn bench<F: FnMut() -> f64>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    let mut sink = 0.0f64;
    for _ in 0..warmup {
        sink += f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        sink += f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    // keep the sink alive
    if sink.is_nan() {
        eprintln!("(sink nan)");
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: stats::mean(&samples),
        p50_s: stats::percentile(&samples, 50.0),
        p99_s: stats::percentile(&samples, 99.0),
        std_s: stats::std_dev(&samples),
    }
}

/// Measure wall time of a single closure invocation.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Aligned plain-text table, printed to stdout.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {title} ==");
        let line = |cells: &[String]| {
            let joined: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            println!("  {}", joined.join("  "));
        };
        line(&self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        println!("  {}", "-".repeat(total));
        for row in &self.rows {
            line(row);
        }
    }

    /// Also write the table as CSV under `results/<file>`.
    pub fn write_csv(&self, file: &str) -> std::io::Result<()> {
        std::fs::create_dir_all("results")?;
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        std::fs::write(format!("results/{file}"), out)
    }
}

/// Format seconds human-readably.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}us", s * 1e6)
    }
}

/// Format bytes human-readably.
pub fn fmt_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let bf = b as f64;
    if bf >= K * K * K {
        format!("{:.2}GiB", bf / (K * K * K))
    } else if bf >= K * K {
        format!("{:.2}MiB", bf / (K * K))
    } else if bf >= K {
        format!("{:.1}KiB", bf / K)
    } else {
        format!("{b}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench("noop-ish", 2, 16, || {
            let mut acc = 0.0;
            for i in 0..100 {
                acc += (i as f64).sqrt();
            }
            acc
        });
        assert_eq!(r.iters, 16);
        assert!(r.mean_s >= 0.0 && r.p50_s >= 0.0 && r.p99_s >= r.p50_s * 0.5);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print("test table");
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_time(2.0), "2.00s");
        assert_eq!(fmt_time(0.002), "2.00ms");
        assert_eq!(fmt_bytes(2048), "2.0KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00MiB");
    }
}
