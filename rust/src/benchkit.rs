//! Benchmark harness (criterion is unavailable in the offline image — see
//! DESIGN.md §6): warmup + timed iterations, percentile reporting, aligned
//! table printing and CSV output under `results/`.

use std::time::Instant;

use crate::util::stats;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub std_s: f64,
}

impl BenchResult {
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean_s
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
/// A `black_box`-style sink prevents the optimizer from deleting work: have
/// `f` return a value that folds into the checksum.
pub fn bench<F: FnMut() -> f64>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    let mut sink = 0.0f64;
    for _ in 0..warmup {
        sink += f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        sink += f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    // keep the sink alive
    if sink.is_nan() {
        eprintln!("(sink nan)");
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: stats::mean(&samples),
        p50_s: stats::percentile(&samples, 50.0),
        p99_s: stats::percentile(&samples, 99.0),
        std_s: stats::std_dev(&samples),
    }
}

/// Measure wall time of a single closure invocation.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Aligned plain-text table, printed to stdout.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {title} ==");
        let line = |cells: &[String]| {
            let joined: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            println!("  {}", joined.join("  "));
        };
        line(&self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        println!("  {}", "-".repeat(total));
        for row in &self.rows {
            line(row);
        }
    }

    /// Also write the table as CSV under `results/<file>`.
    pub fn write_csv(&self, file: &str) -> std::io::Result<()> {
        std::fs::create_dir_all("results")?;
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        std::fs::write(format!("results/{file}"), out)
    }
}

/// Minimal JSON value for machine-readable bench artifacts (the
/// `BENCH_*.json` perf trajectory; serde is unavailable offline).  Numbers
/// use Rust's shortest-roundtrip `Display` (valid JSON for finite floats);
/// non-finite floats serialize as `null`.
#[derive(Clone, Debug)]
pub enum Json {
    Num(f64),
    Int(i64),
    Str(String),
    Bool(bool),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object from `(key, value)` pairs, preserving order.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = |out: &mut String, n: usize| {
            for _ in 0..n {
                out.push_str("  ");
            }
        };
        match self {
            Json::Num(v) => {
                if v.is_finite() {
                    out.push_str(&format!("{v}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Int(v) => out.push_str(&v.to_string()),
            Json::Bool(v) => out.push_str(if *v { "true" } else { "false" }),
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, item) in items.iter().enumerate() {
                    pad(out, indent + 1);
                    item.write(out, indent + 1);
                    if i + 1 < items.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in pairs.iter().enumerate() {
                    pad(out, indent + 1);
                    Json::Str(k.clone()).write(out, indent + 1);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    if i + 1 < pairs.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, indent);
                out.push('}');
            }
        }
    }

    /// Render with two-space indentation (stable and diff-friendly — these
    /// files are checked in as the perf trajectory).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s.push('\n');
        s
    }

    /// Write the pretty-printed document to `path`.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_string_pretty())
    }
}

/// Format seconds human-readably.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}us", s * 1e6)
    }
}

/// Format bytes human-readably.
pub fn fmt_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let bf = b as f64;
    if bf >= K * K * K {
        format!("{:.2}GiB", bf / (K * K * K))
    } else if bf >= K * K {
        format!("{:.2}MiB", bf / (K * K))
    } else if bf >= K {
        format!("{:.1}KiB", bf / K)
    } else {
        format!("{b}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench("noop-ish", 2, 16, || {
            let mut acc = 0.0;
            for i in 0..100 {
                acc += (i as f64).sqrt();
            }
            acc
        });
        assert_eq!(r.iters, 16);
        assert!(r.mean_s >= 0.0 && r.p50_s >= 0.0 && r.p99_s >= r.p50_s * 0.5);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print("test table");
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_time(2.0), "2.00s");
        assert_eq!(fmt_time(0.002), "2.00ms");
        assert_eq!(fmt_bytes(2048), "2.0KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00MiB");
    }

    #[test]
    fn json_renders_nested_documents() {
        let doc = Json::obj(vec![
            ("bench", Json::Str("decode".into())),
            ("ok", Json::Bool(true)),
            ("n", Json::Int(-3)),
            ("speedup", Json::Num(2.5)),
            ("empty", Json::Arr(vec![])),
            (
                "points",
                Json::Arr(vec![Json::obj(vec![("batch", Json::Int(1))])]),
            ),
        ]);
        let s = doc.to_string_pretty();
        assert!(s.starts_with("{\n"));
        assert!(s.contains("\"bench\": \"decode\""));
        assert!(s.contains("\"ok\": true"));
        assert!(s.contains("\"n\": -3"));
        assert!(s.contains("\"speedup\": 2.5"));
        assert!(s.contains("\"empty\": []"));
        assert!(s.contains("\"batch\": 1"));
        assert!(s.ends_with("}\n"));
    }

    #[test]
    fn json_escapes_strings_and_nulls_non_finite() {
        let s = Json::Str("a\"b\\c\nd\u{1}".into()).to_string_pretty();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\u0001\"\n");
        assert_eq!(Json::Num(f64::NAN).to_string_pretty(), "null\n");
        assert_eq!(Json::Num(f64::INFINITY).to_string_pretty(), "null\n");
        assert_eq!(Json::Num(0.125).to_string_pretty(), "0.125\n");
    }
}
