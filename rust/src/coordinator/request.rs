//! Request/response types crossing the coordinator boundary.

use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::obs::HopReport;

/// A generation request submitted to the coordinator.
pub struct GenRequest {
    pub id: u64,
    /// Wire-propagated trace id (0 = untraced).  Echoed on the
    /// response and stamped into the coordinator's trace ring so the
    /// shard's span report joins the front door's under one id.
    pub trace: u64,
    /// Record per-stage engine hot-path timings for this request (the
    /// sampled-profiling flag; costs one branch per token when false).
    pub profile: bool,
    /// Tokens to consume this turn.  For a session request this is only the
    /// *delta* (the new turn's tokens) — the coordinator owns the
    /// transcript and either resumes the stored state or re-prefills it.
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Multi-turn session this request belongs to (None = one-shot).
    pub session: Option<u64>,
    /// Channel the finished response is delivered on.
    pub reply: Sender<GenResponse>,
    /// Optional per-token stream, fed from the decode loop the moment each
    /// token is produced (the first from prefill/resume, the rest one per
    /// decode step) — so a consumer's time-to-first-byte equals the
    /// engine's time-to-first-token instead of the whole generation.  The
    /// sender is dropped when the request retires, which is how a stream
    /// consumer observes end-of-tokens; the buffered [`GenResponse`] on
    /// `reply` always carries the identical full token vector.  A dropped
    /// receiver never stalls or cancels the generation.
    pub stream: Option<Sender<i32>>,
    /// Enqueue timestamp (for latency accounting).
    pub enqueued: Instant,
    /// Absolute admission deadline.  A request still *queued* past it is
    /// shed with a typed [`Refusal::DeadlineExceeded`] instead of running
    /// late; once admitted a turn always runs to completion (exactly-once
    /// semantics for accepted work).  `None` = never shed.
    pub deadline: Option<Instant>,
}

impl GenRequest {
    /// Emit one generated token to the per-token stream, if any.  Send
    /// failures (consumer gone) are deliberately ignored: the generation
    /// itself must finish so session snapshots stay consistent.
    pub fn emit(&self, tok: i32) {
        if let Some(tx) = &self.stream {
            let _ = tx.send(tok);
        }
    }
}

/// Why the coordinator refused a request instead of generating.  A
/// refused turn was **never applied**: no tokens ran, the session's
/// transcript and state are untouched, so a client may safely retry the
/// identical turn.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Refusal {
    /// The admission queue was at capacity when the request arrived.
    Overloaded,
    /// The request's deadline budget expired while it was still queued.
    DeadlineExceeded,
}

/// The finished generation — or a typed refusal (`refusal` set, `tokens`
/// empty).  Work is never silently dropped: every submitted request gets
/// exactly one `GenResponse`.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    /// Trace id echoed from the request (0 = untraced).
    pub trace: u64,
    pub tokens: Vec<i32>,
    /// Seconds from enqueue to first generated token.
    pub ttft_s: f64,
    /// Seconds from enqueue to completion.
    pub total_s: f64,
    /// Set when the request was shed instead of served.
    pub refusal: Option<Refusal>,
    /// Span reports for traced requests: the coordinator hop (queue /
    /// prefill-or-resume / decode, offsets from enqueue) plus an
    /// "engine" hop with per-stage aggregates when the request was
    /// profiled.  Empty for untraced requests.
    pub hops: Vec<HopReport>,
}

/// Why a sequence left its slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
}
