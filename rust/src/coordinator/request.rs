//! Request/response types crossing the coordinator boundary.

use std::sync::mpsc::Sender;
use std::time::Instant;

/// A generation request submitted to the coordinator.
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Channel the finished response is delivered on.
    pub reply: Sender<GenResponse>,
    /// Enqueue timestamp (for latency accounting).
    pub enqueued: Instant,
}

/// The finished generation.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Seconds from enqueue to first generated token.
    pub ttft_s: f64,
    /// Seconds from enqueue to completion.
    pub total_s: f64,
}

/// Why a sequence left its slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
}
