//! Request/response types crossing the coordinator boundary.

use std::sync::mpsc::Sender;
use std::time::Instant;

/// A generation request submitted to the coordinator.
pub struct GenRequest {
    pub id: u64,
    /// Tokens to consume this turn.  For a session request this is only the
    /// *delta* (the new turn's tokens) — the coordinator owns the
    /// transcript and either resumes the stored state or re-prefills it.
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Multi-turn session this request belongs to (None = one-shot).
    pub session: Option<u64>,
    /// Channel the finished response is delivered on.
    pub reply: Sender<GenResponse>,
    /// Enqueue timestamp (for latency accounting).
    pub enqueued: Instant,
}

/// The finished generation.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Seconds from enqueue to first generated token.
    pub ttft_s: f64,
    /// Seconds from enqueue to completion.
    pub total_s: f64,
}

/// Why a sequence left its slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
}
