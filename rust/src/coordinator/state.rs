//! Slot-engine abstraction: the coordinator schedules over `B` fixed slots
//! whose per-sequence state the engine owns.  Three implementations:
//! the native [`crate::engine::recurrent::RecurrentEngine`], the
//! KV-cached [`crate::engine::transformer::TransformerEngine`] baseline,
//! and the PJRT [`crate::runtime::lm::ServedModel`] (AOT artifacts).

use crate::engine::backbone::StageTimes;
use crate::engine::recurrent::RecurrentEngine;
use crate::engine::transformer::TransformerEngine;
use crate::runtime::lm::{RowState, ServedModel};
use crate::session::{SessionError, SessionState};

/// What the scheduler needs from a generation backend.
///
/// Not `Send`: PJRT executables hold `Rc` internals, so the coordinator
/// constructs its engine *inside* the engine thread (see `server::spawn`).
///
/// The session methods (`snapshot_slot` / `restore_slot` / `feed_slot`)
/// are the O(1)-state checkpoint/resume surface: default implementations
/// report "unsupported" so simple engines still work — the coordinator
/// then falls back to re-prefilling the transcript for session turns.  An
/// engine that overrides `restore_slot` MUST also override `feed_slot`.
pub trait SlotEngine {
    fn n_slots(&self) -> usize;
    /// Per-sequence state bytes (for the admission ledger).
    fn bytes_per_seq(&self) -> u64;
    /// Prefill the given (slot, prompt) jobs; returns (slot, first token).
    fn prefill_slots(&mut self, jobs: &[(usize, Vec<i32>)]) -> Vec<(usize, i32)>;
    /// One decode step over the given active slots; returns (slot, token).
    fn decode_slots(&mut self, active: &[usize]) -> Vec<(usize, i32)>;
    fn clear_slot(&mut self, slot: usize);

    /// Tag stamped into snapshots; restore refuses blobs from other tags.
    fn state_tag(&self) -> &'static str {
        "unsupported"
    }

    /// Extract a slot's full generation state as a versioned blob, or
    /// `None` when the engine cannot snapshot.
    fn snapshot_slot(&self, _slot: usize) -> Option<SessionState> {
        None
    }

    /// Reinstall a snapshot into a slot, validating tag and shape.
    fn restore_slot(&mut self, _slot: usize, _state: &SessionState) -> Result<(), SessionError> {
        Err(SessionError::Unsupported)
    }

    /// Feed tokens through an already-populated slot *without* resetting
    /// it; returns the greedy token after the last fed token.  Only called
    /// after a successful `restore_slot`.
    fn feed_slot(&mut self, _slot: usize, _tokens: &[i32]) -> i32 {
        unimplemented!("engine overrides restore_slot but not feed_slot")
    }

    /// Feed several restored slots in one call — engines with independent
    /// rows override this with a pooled fan-out (the batched session-resume
    /// hot path); the default loops [`SlotEngine::feed_slot`].
    fn feed_slots(&mut self, jobs: &[(usize, Vec<i32>)]) -> Vec<(usize, i32)> {
        jobs.iter().map(|(s, t)| (*s, self.feed_slot(*s, t))).collect()
    }

    /// Enable/disable per-stage hot-path profiling for one slot (the
    /// sampled-tracing hook).  Default: no-op — engines without
    /// instrumentation simply report nothing.
    fn set_slot_profiling(&mut self, _slot: usize, _on: bool) {}

    /// Drain the per-stage timings a profiled slot accumulated since
    /// profiling was enabled (or last drained).  `None` when the engine
    /// does not instrument its hot path.
    fn take_slot_stage_times(&mut self, _slot: usize) -> Option<StageTimes> {
        None
    }
}

impl SlotEngine for RecurrentEngine {
    fn n_slots(&self) -> usize {
        self.batch()
    }

    fn bytes_per_seq(&self) -> u64 {
        self.bytes_per_row()
    }

    fn prefill_slots(&mut self, jobs: &[(usize, Vec<i32>)]) -> Vec<(usize, i32)> {
        // rows are independent: fan the prompt ingestion out across cores
        self.prefill_rows(jobs)
    }

    fn decode_slots(&mut self, active: &[usize]) -> Vec<(usize, i32)> {
        // rows are independent: the token step fans out across cores too,
        // bit-identical to stepping each row serially
        self.decode_rows(active)
    }

    fn clear_slot(&mut self, slot: usize) {
        self.reset_row(slot);
    }

    fn state_tag(&self) -> &'static str {
        crate::engine::recurrent::STATE_TAG
    }

    fn snapshot_slot(&self, slot: usize) -> Option<SessionState> {
        Some(self.snapshot_row(slot))
    }

    fn restore_slot(&mut self, slot: usize, state: &SessionState) -> Result<(), SessionError> {
        self.restore_row(slot, state)
    }

    fn feed_slot(&mut self, slot: usize, tokens: &[i32]) -> i32 {
        self.feed_row(slot, tokens)
    }

    fn feed_slots(&mut self, jobs: &[(usize, Vec<i32>)]) -> Vec<(usize, i32)> {
        // rows are independent: fan the resumed turns out across cores
        self.feed_rows(jobs)
    }

    fn set_slot_profiling(&mut self, slot: usize, on: bool) {
        self.set_row_profiling(slot, on);
    }

    fn take_slot_stage_times(&mut self, slot: usize) -> Option<StageTimes> {
        Some(self.take_row_stage_times(slot))
    }
}

use crate::engine::Engine as _;

/// The Transformer baseline as a slot engine: sessions still *work* (the
/// coordinator snapshots the KV cache), but the blob is O(t) — the contrast
/// with the recurrent engine's constant-size state that the session bench
/// measures.
impl SlotEngine for TransformerEngine {
    fn n_slots(&self) -> usize {
        self.batch()
    }

    fn bytes_per_seq(&self) -> u64 {
        // the ledger wants a per-sequence constant; charge the worst case —
        // a full-context KV cache (the honest admission cost of Lemma 2.3)
        let s = self.shape();
        crate::engine::memory::kv_cache_bytes(s, s.seq_len, crate::engine::memory::F32)
    }

    fn prefill_slots(&mut self, jobs: &[(usize, Vec<i32>)]) -> Vec<(usize, i32)> {
        jobs.iter().map(|(s, p)| (*s, self.prefill_row(*s, p))).collect()
    }

    fn decode_slots(&mut self, active: &[usize]) -> Vec<(usize, i32)> {
        active.iter().map(|&s| (s, self.decode_row(s))).collect()
    }

    fn clear_slot(&mut self, slot: usize) {
        self.reset_row(slot);
    }

    fn state_tag(&self) -> &'static str {
        crate::engine::transformer::STATE_TAG
    }

    fn snapshot_slot(&self, slot: usize) -> Option<SessionState> {
        Some(self.snapshot_row(slot))
    }

    fn restore_slot(&mut self, slot: usize, state: &SessionState) -> Result<(), SessionError> {
        self.restore_row(slot, state)
    }

    fn feed_slot(&mut self, slot: usize, tokens: &[i32]) -> i32 {
        self.feed_row(slot, tokens)
    }
}

/// Engine tag for PJRT-served snapshots.
pub const PJRT_STATE_TAG: &str = "pjrt-multihyena";

/// PJRT-backed slot engine: the decode artifact runs the *whole* fixed
/// batch each step (inactive slots carry dummy state — the padding cost of
/// fixed-shape compiled graphs); prefill runs the full batch and merges the
/// refreshed rows of the jobs while restoring untouched busy rows.
pub struct PjrtSlotEngine {
    pub lm: ServedModel,
    /// Rows currently owned by a request (prefilled or restored, not yet
    /// cleared) — decode must shield these when they are not active, while
    /// free rows may drift (they are reset by the next prefill anyway).
    occupied: Vec<bool>,
}

impl PjrtSlotEngine {
    pub fn new(lm: ServedModel) -> PjrtSlotEngine {
        let n = lm.shape.batch;
        PjrtSlotEngine { lm, occupied: vec![false; n] }
    }

    fn row_lens(&self) -> (usize, usize) {
        let s = &self.lm.shape;
        (s.n_layer * s.d_model * s.d_state, s.n_layer * s.sc_width * s.sc_tail)
    }
}

impl SlotEngine for PjrtSlotEngine {
    fn n_slots(&self) -> usize {
        self.lm.shape.batch
    }

    fn bytes_per_seq(&self) -> u64 {
        self.lm.state_bytes_per_seq()
    }

    fn prefill_slots(&mut self, jobs: &[(usize, Vec<i32>)]) -> Vec<(usize, i32)> {
        let b = self.lm.shape.batch;
        // snapshot rows that must survive the whole-batch prefill
        let keep: Vec<usize> =
            (0..b).filter(|s| !jobs.iter().any(|(j, _)| j == s)).collect();
        let saved: Vec<_> = keep.iter().map(|&s| (s, self.lm.save_row(s))).collect();
        let mut prompts: Vec<Vec<i32>> = vec![vec![0]; b];
        for (slot, p) in jobs {
            prompts[*slot] = p.clone();
        }
        let first = self.lm.prefill_batch(&prompts).expect("prefill");
        for (s, row) in &saved {
            self.lm.restore_row(*s, row);
        }
        for (slot, _) in jobs {
            self.occupied[*slot] = true;
        }
        jobs.iter().map(|(s, _)| (*s, first[*s])).collect()
    }

    fn decode_slots(&mut self, active: &[usize]) -> Vec<(usize, i32)> {
        // the decode artifact steps the whole fixed batch; occupied rows
        // NOT in `active` (busy-at-budget awaiting a session snapshot) must
        // not drift past their transcript, so shield them — free rows may
        // drift, the next prefill resets them
        let b = self.lm.shape.batch;
        let saved: Vec<_> = (0..b)
            .filter(|&s| self.occupied[s] && !active.contains(&s))
            .map(|s| (s, self.lm.save_row(s)))
            .collect();
        let toks = self.lm.decode_step().expect("decode");
        for (s, row) in &saved {
            self.lm.restore_row(*s, row);
        }
        active.iter().map(|&s| (s, toks[s])).collect()
    }

    fn clear_slot(&mut self, slot: usize) {
        self.lm.clear_row(slot);
        self.occupied[slot] = false;
    }

    fn state_tag(&self) -> &'static str {
        PJRT_STATE_TAG
    }

    fn snapshot_slot(&self, slot: usize) -> Option<SessionState> {
        let row = self.lm.save_row(slot);
        let mut st = SessionState::new(PJRT_STATE_TAG, row.last);
        st.push_plane("x_re", row.x_re);
        st.push_plane("x_im", row.x_im);
        st.push_plane("sc", row.sc);
        Some(st)
    }

    fn restore_slot(&mut self, slot: usize, state: &SessionState) -> Result<(), SessionError> {
        state.check_engine(PJRT_STATE_TAG)?;
        let (x_len, sc_len) = self.row_lens();
        let row = RowState {
            x_re: state.plane_checked("x_re", x_len)?.to_vec(),
            x_im: state.plane_checked("x_im", x_len)?.to_vec(),
            sc: state.plane_checked("sc", sc_len)?.to_vec(),
            last: state.last_token,
        };
        self.lm.restore_row(slot, &row);
        self.occupied[slot] = true;
        Ok(())
    }

    fn feed_slot(&mut self, slot: usize, tokens: &[i32]) -> i32 {
        // the decode artifact steps the whole fixed batch, so shield the
        // other rows while this slot consumes its resumed tokens
        let b = self.lm.shape.batch;
        let saved: Vec<_> =
            (0..b).filter(|&s| s != slot).map(|s| (s, self.lm.save_row(s))).collect();
        for &tok in tokens {
            self.lm.last_tokens[slot] = tok;
            let _ = self.lm.decode_step().expect("decode");
        }
        let next = self.lm.last_tokens[slot];
        for (s, row) in &saved {
            self.lm.restore_row(*s, row);
        }
        next
    }

    fn feed_slots(&mut self, jobs: &[(usize, Vec<i32>)]) -> Vec<(usize, i32)> {
        // One interleaved walk for k resumed turns: the decode artifact
        // steps the whole fixed batch anyway, and rows are independent in
        // every op of the graph, so the resumed slots can consume their
        // token streams *together*.  Occupied rows not being fed are saved
        // once up front (the inherited per-slot loop pays k whole-batch
        // walks and k x (B-1) save/restores); free rows may drift, exactly
        // as in `decode_slots` — the next prefill resets them.  Each fed
        // row is saved the moment its stream ends so the remaining steps
        // cannot drift it.
        if jobs.is_empty() {
            return Vec::new();
        }
        let b = self.lm.shape.batch;
        let mut fed = vec![false; b];
        for (slot, toks) in jobs {
            if !toks.is_empty() {
                fed[*slot] = true;
            }
        }
        let shielded: Vec<(usize, RowState)> = (0..b)
            .filter(|&s| !fed[s] && self.occupied[s])
            .map(|s| (s, self.lm.save_row(s)))
            .collect();
        let max_len = jobs.iter().map(|(_, t)| t.len()).max().unwrap_or(0);
        let mut finished: Vec<(usize, RowState)> = Vec::with_capacity(jobs.len());
        for i in 0..max_len {
            for (slot, toks) in jobs {
                if i < toks.len() {
                    self.lm.last_tokens[*slot] = toks[i];
                }
            }
            let _ = self.lm.decode_step().expect("decode");
            for (slot, toks) in jobs {
                if toks.len() == i + 1 {
                    finished.push((*slot, self.lm.save_row(*slot)));
                }
            }
        }
        // reinstall every row at its correct post-feed (or untouched) state
        for (s, row) in shielded.iter().chain(finished.iter()) {
            self.lm.restore_row(*s, row);
        }
        jobs.iter().map(|(s, _)| (*s, self.lm.last_tokens[*s])).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::LmShape;

    #[test]
    fn native_slot_engine_roundtrip() {
        let shape = LmShape::bench("nano").unwrap();
        let mut eng = RecurrentEngine::new(&shape, 3, 5);
        assert_eq!(SlotEngine::n_slots(&eng), 3);
        assert!(eng.bytes_per_seq() > 0);
        let first = eng.prefill_slots(&[(0, vec![1, 2, 3]), (2, vec![4, 5])]);
        assert_eq!(first.len(), 2);
        let toks = eng.decode_slots(&[0, 2]);
        assert_eq!(toks.len(), 2);
        assert!(toks.iter().all(|(_, t)| (*t as usize) < shape.vocab));
        eng.clear_slot(0);
    }

    #[test]
    fn native_rows_are_independent() {
        // prefilling row 1 must not change row 0's future tokens
        let shape = LmShape::bench("nano").unwrap();
        let mut a = RecurrentEngine::new(&shape, 2, 5);
        let mut b = RecurrentEngine::new(&shape, 2, 5);
        a.prefill_row(0, &[7, 8, 9]);
        b.prefill_row(0, &[7, 8, 9]);
        b.prefill_row(1, &[1, 2, 3, 4, 5]);
        for _ in 0..4 {
            assert_eq!(a.decode_row(0), b.decode_row(0));
        }
    }

    #[test]
    fn slot_engine_session_surface_roundtrips() {
        // the trait-level snapshot/restore path both engines share
        let shape = LmShape::bench("nano").unwrap();
        for eng in [
            Box::new(RecurrentEngine::new(&shape, 2, 5)) as Box<dyn SlotEngine>,
            Box::new(TransformerEngine::new(&shape, 2, 5)) as Box<dyn SlotEngine>,
        ] {
            let mut eng = eng;
            eng.prefill_slots(&[(0, vec![9, 8, 7, 6])]);
            let snap = eng.snapshot_slot(0).expect("supported");
            assert_eq!(snap.engine, eng.state_tag());
            let a: Vec<_> = (0..4).map(|_| eng.decode_slots(&[0])[0].1).collect();
            eng.clear_slot(0);
            eng.restore_slot(0, &snap).unwrap();
            let first = eng.feed_slot(0, &[snap.last_token]);
            assert_eq!(first, a[0], "resume replays the pending token");
            for i in 1..4 {
                assert_eq!(eng.decode_slots(&[0])[0].1, a[i]);
            }
        }
    }

    #[test]
    fn pooled_decode_slots_preserves_active_order() {
        // the scheduler relies on (slot, token) pairs; the pooled fan-out
        // must report them in the caller's order and agree with the serial
        // per-row step
        let shape = LmShape::bench("nano").unwrap();
        let mut pooled = RecurrentEngine::new(&shape, 3, 8);
        let mut serial = RecurrentEngine::new(&shape, 3, 8);
        for b in 0..3 {
            pooled.prefill_row(b, &[2 + b as i32, 7]);
            serial.prefill_row(b, &[2 + b as i32, 7]);
        }
        let active = [2usize, 0];
        let got = SlotEngine::decode_slots(&mut pooled, &active);
        let want: Vec<(usize, i32)> =
            active.iter().map(|&s| (s, serial.decode_row(s))).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn pjrt_feed_slots_matches_sequential_feed_slot() {
        // the interleaved multi-resume walk must agree with the inherited
        // per-slot loop and leave untouched slots bit-identical
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("decode_multihyena_tiny.hlo.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = crate::runtime::artifact::Runtime::cpu().unwrap();
        let mk = || {
            let lm =
                crate::runtime::lm::ServedModel::new(&rt, &dir, "multihyena_tiny").unwrap();
            PjrtSlotEngine::new(lm)
        };
        let mut batched = mk();
        let mut looped = mk();
        let prompts: Vec<(usize, Vec<i32>)> =
            (0..4).map(|s| (s, vec![1 + s as i32, 2, 3])).collect();
        batched.prefill_slots(&prompts);
        looped.prefill_slots(&prompts);
        // uneven resumed streams incl. an empty one; slot 1 untouched
        let jobs: Vec<(usize, Vec<i32>)> =
            vec![(0, vec![4, 5, 6]), (2, vec![7]), (3, vec![])];
        let got = batched.feed_slots(&jobs);
        let want: Vec<(usize, i32)> =
            jobs.iter().map(|(s, t)| (*s, looped.feed_slot(*s, t))).collect();
        assert_eq!(got, want);
        assert_eq!(
            batched.decode_slots(&[0, 1, 2, 3]),
            looped.decode_slots(&[0, 1, 2, 3]),
            "all slots (incl. untouched ones) must be bit-identical after resume"
        );
    }

    #[test]
    fn default_session_surface_reports_unsupported() {
        struct Null;
        impl SlotEngine for Null {
            fn n_slots(&self) -> usize {
                1
            }
            fn bytes_per_seq(&self) -> u64 {
                1
            }
            fn prefill_slots(&mut self, jobs: &[(usize, Vec<i32>)]) -> Vec<(usize, i32)> {
                jobs.iter().map(|(s, _)| (*s, 0)).collect()
            }
            fn decode_slots(&mut self, active: &[usize]) -> Vec<(usize, i32)> {
                active.iter().map(|&s| (s, 0)).collect()
            }
            fn clear_slot(&mut self, _slot: usize) {}
        }
        let mut n = Null;
        assert!(n.snapshot_slot(0).is_none());
        let st = SessionState::new("x", 0);
        assert!(matches!(n.restore_slot(0, &st), Err(SessionError::Unsupported)));
    }
}
