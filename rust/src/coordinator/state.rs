//! Slot-engine abstraction: the coordinator schedules over `B` fixed slots
//! whose per-sequence state the engine owns.  Two implementations:
//! the native [`crate::engine::recurrent::RecurrentEngine`] and the PJRT
//! [`crate::runtime::lm::ServedModel`] (AOT artifacts).

use crate::engine::recurrent::RecurrentEngine;
use crate::runtime::lm::ServedModel;

/// What the scheduler needs from a generation backend.
///
/// Not `Send`: PJRT executables hold `Rc` internals, so the coordinator
/// constructs its engine *inside* the engine thread (see `server::spawn`).
pub trait SlotEngine {
    fn n_slots(&self) -> usize;
    /// Per-sequence state bytes (for the admission ledger).
    fn bytes_per_seq(&self) -> u64;
    /// Prefill the given (slot, prompt) jobs; returns (slot, first token).
    fn prefill_slots(&mut self, jobs: &[(usize, Vec<i32>)]) -> Vec<(usize, i32)>;
    /// One decode step over the given active slots; returns (slot, token).
    fn decode_slots(&mut self, active: &[usize]) -> Vec<(usize, i32)>;
    fn clear_slot(&mut self, slot: usize);
}

impl SlotEngine for RecurrentEngine {
    fn n_slots(&self) -> usize {
        self.batch()
    }

    fn bytes_per_seq(&self) -> u64 {
        self.bytes_per_row()
    }

    fn prefill_slots(&mut self, jobs: &[(usize, Vec<i32>)]) -> Vec<(usize, i32)> {
        // rows are independent: fan the prompt ingestion out across cores
        self.prefill_rows(jobs)
    }

    fn decode_slots(&mut self, active: &[usize]) -> Vec<(usize, i32)> {
        active.iter().map(|&s| (s, self.decode_row(s))).collect()
    }

    fn clear_slot(&mut self, slot: usize) {
        self.reset_row(slot);
    }
}

use crate::engine::Engine as _;

/// PJRT-backed slot engine: the decode artifact runs the *whole* fixed
/// batch each step (inactive slots carry dummy state — the padding cost of
/// fixed-shape compiled graphs); prefill runs the full batch and merges the
/// refreshed rows of the jobs while restoring untouched busy rows.
pub struct PjrtSlotEngine {
    pub lm: ServedModel,
}

impl PjrtSlotEngine {
    pub fn new(lm: ServedModel) -> PjrtSlotEngine {
        PjrtSlotEngine { lm }
    }
}

impl SlotEngine for PjrtSlotEngine {
    fn n_slots(&self) -> usize {
        self.lm.shape.batch
    }

    fn bytes_per_seq(&self) -> u64 {
        self.lm.state_bytes_per_seq()
    }

    fn prefill_slots(&mut self, jobs: &[(usize, Vec<i32>)]) -> Vec<(usize, i32)> {
        let b = self.lm.shape.batch;
        // snapshot rows that must survive the whole-batch prefill
        let keep: Vec<usize> =
            (0..b).filter(|s| !jobs.iter().any(|(j, _)| j == s)).collect();
        let saved: Vec<_> = keep.iter().map(|&s| (s, self.lm.save_row(s))).collect();
        let mut prompts: Vec<Vec<i32>> = vec![vec![0]; b];
        for (slot, p) in jobs {
            prompts[*slot] = p.clone();
        }
        let first = self.lm.prefill_batch(&prompts).expect("prefill");
        for (s, row) in &saved {
            self.lm.restore_row(*s, row);
        }
        jobs.iter().map(|(s, _)| (*s, first[*s])).collect()
    }

    fn decode_slots(&mut self, active: &[usize]) -> Vec<(usize, i32)> {
        let toks = self.lm.decode_step().expect("decode");
        active.iter().map(|&s| (s, toks[s])).collect()
    }

    fn clear_slot(&mut self, slot: usize) {
        self.lm.clear_row(slot);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::LmShape;

    #[test]
    fn native_slot_engine_roundtrip() {
        let shape = LmShape::bench("nano").unwrap();
        let mut eng = RecurrentEngine::new(&shape, 3, 5);
        assert_eq!(SlotEngine::n_slots(&eng), 3);
        assert!(eng.bytes_per_seq() > 0);
        let first = eng.prefill_slots(&[(0, vec![1, 2, 3]), (2, vec![4, 5])]);
        assert_eq!(first.len(), 2);
        let toks = eng.decode_slots(&[0, 2]);
        assert_eq!(toks.len(), 2);
        assert!(toks.iter().all(|(_, t)| (*t as usize) < shape.vocab));
        eng.clear_slot(0);
    }

    #[test]
    fn native_rows_are_independent() {
        // prefilling row 1 must not change row 0's future tokens
        let shape = LmShape::bench("nano").unwrap();
        let mut a = RecurrentEngine::new(&shape, 2, 5);
        let mut b = RecurrentEngine::new(&shape, 2, 5);
        a.prefill_row(0, &[7, 8, 9]);
        b.prefill_row(0, &[7, 8, 9]);
        b.prefill_row(1, &[1, 2, 3, 4, 5]);
        for _ in 0..4 {
            assert_eq!(a.decode_row(0), b.decode_row(0));
        }
    }
}
