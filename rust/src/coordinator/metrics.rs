//! Coordinator metrics: counters and bounded latency histograms, shared
//! behind a mutex (the request path touches them once per token batch,
//! not per request, so contention is negligible — measured in
//! benches/coordinator).
//!
//! Latencies live in [`crate::obs::Hist`] — a fixed-bucket log-spaced
//! histogram — instead of the unbounded `Vec<f64>` reservoirs this
//! module used to keep, so a coordinator that serves millions of
//! requests holds the same few kilobytes of metric state as one that
//! served ten. [`Metrics::export_entries`] flattens everything into the
//! named-metric form the wire's `MetricsReport` frame ships to the
//! router for exact cluster-wide merging.

use std::sync::Mutex;

use super::request::Refusal;
use crate::engine::backbone::StageTimes;
use crate::obs::{Hist, MetricValue};

/// Metric-family names for the six profiled engine hot-path stages,
/// index-aligned with [`StageTimes::stages`].  Each histogram records
/// seconds spent in that stage *per profiled request* (summed over the
/// request's tokens), so the cluster-merged view answers "where inside
/// the engine does a request's time go?"
pub const ENGINE_STAGE_FAMILIES: [&str; 6] = [
    "lh_engine_short_conv_seconds",
    "lh_engine_modal_sweep_seconds",
    "lh_engine_qkv_seconds",
    "lh_engine_out_proj_seconds",
    "lh_engine_mlp_seconds",
    "lh_engine_lm_head_seconds",
];

#[derive(Default, Debug)]
pub struct MetricsInner {
    pub requests_in: u64,
    pub requests_done: u64,
    pub tokens_generated: u64,
    pub prefills: u64,
    pub decode_steps: u64,
    /// Enqueue → first token, bounded histogram.
    pub ttft: Hist,
    /// Enqueue → final token, bounded histogram.
    pub e2e: Hist,
    /// Enqueue → slot admission, bounded histogram.
    pub queue_wait: Hist,
    /// Per-request mean time per output token after the first (TPOT),
    /// recorded once per finished request with ≥ 2 tokens.
    pub tpot: Hist,
    /// Wall time of each prefill batch.
    pub prefill_time: Hist,
    /// Requests waiting for a slot right now (gauge).
    pub queue_depth: u64,
    pub queue_peak: usize,
    /// Session turns resumed from a stored state (no transcript re-prefill).
    pub session_hits: u64,
    /// Session turns whose state was gone (evicted, unspilled) and had to
    /// re-prefill the full transcript.
    pub session_misses: u64,
    /// Prefill tokens skipped by resuming instead of re-prefilling.
    pub prefill_tokens_saved: u64,
    /// Bytes currently resident in the session store (gauge).
    pub session_bytes_held: u64,
    /// Sessions currently RAM-resident in the store (gauge; spilled
    /// sessions are held on disk and not counted here).
    pub sessions_resident: u64,
    /// Session-store evictions so far (gauge, mirrors the store).
    pub session_evictions: u64,
    /// Evictions persisted to the spill directory (gauge).
    pub session_spills: u64,
    /// Idle sessions fully forgotten by the TTL sweep (transcript + state).
    pub session_ttl_evictions: u64,
    /// Live bytes currently held by the disk spill tier (gauge).
    pub spill_bytes: u64,
    /// Sessions the spill tier dropped to honor its byte cap (gauge,
    /// mirrors the store).
    pub spill_evictions: u64,
    /// Spill segments compacted so far (gauge, mirrors the store).
    pub spill_compactions: u64,
    /// Queued requests shed because their deadline budget expired.
    pub shed_deadline: u64,
    /// Requests refused at the door because the queue was at capacity.
    pub shed_overload: u64,
    /// Per-stage engine hot-path wall time, one histogram per stage in
    /// [`ENGINE_STAGE_FAMILIES`] order, fed only by profiled requests.
    pub engine_stages: [Hist; 6],
    /// Requests whose engine hot path was stage-profiled.
    pub engine_profiled: u64,
}

/// Shared metrics handle.
#[derive(Default)]
pub struct Metrics(Mutex<MetricsInner>);

impl Metrics {
    pub fn record_enqueue(&self, queue_len: usize) {
        let mut m = self.0.lock().unwrap();
        m.requests_in += 1;
        m.queue_depth = queue_len as u64;
        m.queue_peak = m.queue_peak.max(queue_len);
    }

    /// A request left the queue for a slot after `queue_wait` seconds;
    /// `queue_len` is the depth it left behind.
    pub fn record_admitted(&self, queue_wait: f64, queue_len: usize) {
        let mut m = self.0.lock().unwrap();
        m.queue_wait.record(queue_wait);
        m.queue_depth = queue_len as u64;
    }

    pub fn record_prefill(&self, n: usize) {
        let mut m = self.0.lock().unwrap();
        m.prefills += n as u64;
    }

    /// Wall time of one prefill batch.
    pub fn observe_prefill(&self, seconds: f64) {
        let mut m = self.0.lock().unwrap();
        m.prefill_time.record(seconds);
    }

    pub fn record_decode(&self, tokens: usize) {
        let mut m = self.0.lock().unwrap();
        m.decode_steps += 1;
        m.tokens_generated += tokens as u64;
    }

    /// A session turn resumed from a stored state; `tokens_saved` is the
    /// transcript prefill it skipped.
    pub fn record_session_hit(&self, tokens_saved: u64) {
        let mut m = self.0.lock().unwrap();
        m.session_hits += 1;
        m.prefill_tokens_saved += tokens_saved;
    }

    pub fn record_session_miss(&self) {
        let mut m = self.0.lock().unwrap();
        m.session_misses += 1;
    }

    /// Mirror the session store's gauges after a snapshot/eviction.
    pub fn set_session_store(
        &self,
        resident: u64,
        bytes_held: u64,
        evictions: u64,
        spills: u64,
    ) {
        let mut m = self.0.lock().unwrap();
        m.sessions_resident = resident;
        m.session_bytes_held = bytes_held;
        m.session_evictions = evictions;
        m.session_spills = spills;
    }

    /// Mirror the disk spill tier's gauges (live bytes, cap evictions,
    /// compactions) after store maintenance or mutation.
    pub fn set_spill_tier(&self, bytes: u64, evictions: u64, compactions: u64) {
        let mut m = self.0.lock().unwrap();
        m.spill_bytes = bytes;
        m.spill_evictions = evictions;
        m.spill_compactions = compactions;
    }

    /// The TTL sweep fully forgot one idle session.
    pub fn record_ttl_eviction(&self) {
        let mut m = self.0.lock().unwrap();
        m.session_ttl_evictions += 1;
    }

    /// A request was refused instead of served (typed shed).
    pub fn record_shed(&self, why: Refusal) {
        let mut m = self.0.lock().unwrap();
        match why {
            Refusal::DeadlineExceeded => m.shed_deadline += 1,
            Refusal::Overloaded => m.shed_overload += 1,
        }
    }

    /// A profiled request retired: fold its per-stage engine timings
    /// (nanoseconds summed over the request's tokens) into the
    /// per-stage histograms, one sample per stage per request.
    pub fn record_engine_stages(&self, t: &StageTimes) {
        let mut m = self.0.lock().unwrap();
        m.engine_profiled += 1;
        for (i, (_, ns)) in t.stages().iter().enumerate() {
            m.engine_stages[i].record(*ns as f64 * 1e-9);
        }
    }

    /// A request finished: `ttft`/`total` are seconds since enqueue,
    /// `tokens` the generation length (drives the TPOT sample).
    pub fn record_done(&self, ttft: Option<f64>, total: f64, tokens: usize) {
        let mut m = self.0.lock().unwrap();
        m.requests_done += 1;
        if let Some(t) = ttft {
            m.ttft.record(t);
            if tokens > 1 {
                m.tpot.record((total - t).max(0.0) / (tokens - 1) as f64);
            }
        }
        m.e2e.record(total);
    }

    pub fn snapshot(&self) -> MetricsInner {
        let m = self.0.lock().unwrap();
        MetricsInner {
            requests_in: m.requests_in,
            requests_done: m.requests_done,
            tokens_generated: m.tokens_generated,
            prefills: m.prefills,
            decode_steps: m.decode_steps,
            ttft: m.ttft.clone(),
            e2e: m.e2e.clone(),
            queue_wait: m.queue_wait.clone(),
            tpot: m.tpot.clone(),
            prefill_time: m.prefill_time.clone(),
            queue_depth: m.queue_depth,
            queue_peak: m.queue_peak,
            session_hits: m.session_hits,
            session_misses: m.session_misses,
            prefill_tokens_saved: m.prefill_tokens_saved,
            session_bytes_held: m.session_bytes_held,
            sessions_resident: m.sessions_resident,
            session_evictions: m.session_evictions,
            session_spills: m.session_spills,
            session_ttl_evictions: m.session_ttl_evictions,
            spill_bytes: m.spill_bytes,
            spill_evictions: m.spill_evictions,
            spill_compactions: m.spill_compactions,
            shed_deadline: m.shed_deadline,
            shed_overload: m.shed_overload,
            engine_stages: m.engine_stages.clone(),
            engine_profiled: m.engine_profiled,
        }
    }

    /// Flatten the shard's metrics into `(name, value)` entries under
    /// the stable `lh_*` names from [`crate::obs::SCHEMA`] — the payload
    /// of the wire's `MetricsReport` frame. Counters/gauges/histograms
    /// from different shards merge exactly on the router.
    pub fn export_entries(&self) -> Vec<(String, MetricValue)> {
        let m = self.0.lock().unwrap();
        let c = MetricValue::Counter;
        let g = MetricValue::Gauge;
        let mut out = vec![
            ("lh_requests_total".into(), c(m.requests_in)),
            ("lh_requests_done_total".into(), c(m.requests_done)),
            ("lh_tokens_generated_total".into(), c(m.tokens_generated)),
            ("lh_prefills_total".into(), c(m.prefills)),
            ("lh_decode_steps_total".into(), c(m.decode_steps)),
            ("lh_queue_depth".into(), g(m.queue_depth)),
            ("lh_queue_peak".into(), g(m.queue_peak as u64)),
            ("lh_ttft_seconds".into(), MetricValue::Hist(m.ttft.clone())),
            ("lh_e2e_seconds".into(), MetricValue::Hist(m.e2e.clone())),
            ("lh_queue_wait_seconds".into(), MetricValue::Hist(m.queue_wait.clone())),
            ("lh_tpot_seconds".into(), MetricValue::Hist(m.tpot.clone())),
            ("lh_prefill_seconds".into(), MetricValue::Hist(m.prefill_time.clone())),
            ("lh_session_hits_total".into(), c(m.session_hits)),
            ("lh_session_misses_total".into(), c(m.session_misses)),
            ("lh_prefill_tokens_saved_total".into(), c(m.prefill_tokens_saved)),
            ("lh_sessions_resident".into(), g(m.sessions_resident)),
            ("lh_session_bytes".into(), g(m.session_bytes_held)),
            ("lh_session_evictions_total".into(), c(m.session_evictions)),
            ("lh_session_spills_total".into(), c(m.session_spills)),
            ("lh_session_ttl_evictions_total".into(), c(m.session_ttl_evictions)),
            ("lh_spill_bytes".into(), g(m.spill_bytes)),
            ("lh_spill_evictions_total".into(), c(m.spill_evictions)),
            ("lh_spill_compactions_total".into(), c(m.spill_compactions)),
            ("lh_shed_deadline_total".into(), c(m.shed_deadline)),
            ("lh_shed_overload_total".into(), c(m.shed_overload)),
            ("lh_engine_profiled_total".into(), c(m.engine_profiled)),
        ];
        for (i, family) in ENGINE_STAGE_FAMILIES.iter().enumerate() {
            out.push(((*family).into(), MetricValue::Hist(m.engine_stages[i].clone())));
        }
        out
    }

    pub fn report(&self) -> String {
        let m = self.snapshot();
        let mut line = format!(
            "requests {}/{} | tokens {} | prefills {} | decode steps {} | \
             ttft p50 {:.1}ms p99 {:.1}ms | e2e p50 {:.1}ms p99 {:.1}ms | queue peak {}",
            m.requests_done,
            m.requests_in,
            m.tokens_generated,
            m.prefills,
            m.decode_steps,
            m.ttft.quantile(0.50) * 1e3,
            m.ttft.quantile(0.99) * 1e3,
            m.e2e.quantile(0.50) * 1e3,
            m.e2e.quantile(0.99) * 1e3,
            m.queue_peak
        );
        if m.session_hits + m.session_misses > 0 || m.session_bytes_held > 0 {
            line.push_str(&format!(
                " | sessions hit/miss {}/{} | prefill tokens saved {} | \
                 {} resident, {} session bytes (evictions {}, spills {})",
                m.session_hits,
                m.session_misses,
                m.prefill_tokens_saved,
                m.sessions_resident,
                m.session_bytes_held,
                m.session_evictions,
                m.session_spills
            ));
        }
        line
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.record_enqueue(3);
        m.record_enqueue(5);
        m.record_prefill(2);
        m.record_decode(8);
        m.record_done(Some(0.01), 0.05, 8);
        let s = m.snapshot();
        assert_eq!(s.requests_in, 2);
        assert_eq!(s.queue_peak, 5);
        assert_eq!(s.tokens_generated, 8);
        assert_eq!(s.requests_done, 1);
        assert!(m.report().contains("requests 1/2"));
        // no session traffic -> no session segment in the report
        assert!(!m.report().contains("sessions hit/miss"));
    }

    #[test]
    fn session_counters_accumulate_and_report() {
        let m = Metrics::default();
        m.record_session_hit(120);
        m.record_session_hit(80);
        m.record_session_miss();
        m.set_session_store(5, 4096, 3, 2);
        let s = m.snapshot();
        assert_eq!(s.session_hits, 2);
        assert_eq!(s.session_misses, 1);
        assert_eq!(s.prefill_tokens_saved, 200);
        assert_eq!(s.sessions_resident, 5);
        assert_eq!(s.session_bytes_held, 4096);
        assert_eq!(s.session_evictions, 3);
        assert_eq!(s.session_spills, 2);
        let r = m.report();
        assert!(r.contains("sessions hit/miss 2/1"), "{r}");
        assert!(r.contains("prefill tokens saved 200"), "{r}");
    }

    #[test]
    fn latency_memory_is_bounded() {
        // the old reservoirs grew a Vec entry per request; histograms
        // keep the struct size fixed no matter the traffic
        let m = Metrics::default();
        for i in 0..50_000 {
            m.record_done(Some(0.002 + (i % 7) as f64 * 1e-4), 0.04, 16);
        }
        let s = m.snapshot();
        assert_eq!(s.ttft.count(), 50_000);
        assert_eq!(s.e2e.count(), 50_000);
        assert_eq!(s.tpot.count(), 50_000);
        assert!(std::mem::size_of::<MetricsInner>() < 4096);
        // quantiles stay in range of the recorded values
        let p50 = s.ttft.quantile(0.5);
        assert!(p50 > 1e-3 && p50 < 1e-2, "{p50}");
    }

    #[test]
    fn queue_and_tpot_instrumentation() {
        let m = Metrics::default();
        m.record_enqueue(4);
        m.record_admitted(0.003, 3);
        m.observe_prefill(0.010);
        // 9 tokens over (0.1 - 0.01) s after the first token -> TPOT
        // samples land near 11.25 ms
        m.record_done(Some(0.01), 0.1, 9);
        let s = m.snapshot();
        assert_eq!(s.queue_depth, 3);
        assert_eq!(s.queue_wait.count(), 1);
        assert_eq!(s.prefill_time.count(), 1);
        assert_eq!(s.tpot.count(), 1);
        let tpot = s.tpot.quantile(0.5);
        assert!(tpot > 0.009 && tpot < 0.020, "{tpot}");
        // single-token requests contribute no TPOT sample
        m.record_done(Some(0.01), 0.01, 1);
        assert_eq!(m.snapshot().tpot.count(), 1);
    }

    #[test]
    fn overload_and_spill_counters_accumulate() {
        let m = Metrics::default();
        m.record_shed(Refusal::DeadlineExceeded);
        m.record_shed(Refusal::DeadlineExceeded);
        m.record_shed(Refusal::Overloaded);
        m.record_ttl_eviction();
        m.set_spill_tier(8192, 3, 1);
        let s = m.snapshot();
        assert_eq!(s.shed_deadline, 2);
        assert_eq!(s.shed_overload, 1);
        assert_eq!(s.session_ttl_evictions, 1);
        assert_eq!(s.spill_bytes, 8192);
        assert_eq!(s.spill_evictions, 3);
        assert_eq!(s.spill_compactions, 1);
    }

    #[test]
    fn engine_stage_histograms_accumulate() {
        let m = Metrics::default();
        let t = StageTimes {
            short_conv_ns: 1_000,
            modal_sweep_ns: 2_000,
            qkv_ns: 3_000,
            out_proj_ns: 4_000,
            mlp_ns: 5_000,
            lm_head_ns: 6_000,
            tokens: 4,
        };
        m.record_engine_stages(&t);
        m.record_engine_stages(&t);
        let s = m.snapshot();
        assert_eq!(s.engine_profiled, 2);
        for h in &s.engine_stages {
            assert_eq!(h.count(), 2);
        }
        // stage samples land in the microsecond range they were fed
        let p50 = s.engine_stages[5].quantile(0.5);
        assert!(p50 > 1e-6 && p50 < 1e-4, "{p50}");
    }

    #[test]
    fn export_entries_use_schema_names() {
        let m = Metrics::default();
        m.record_enqueue(1);
        m.record_done(Some(0.01), 0.05, 4);
        for (name, value) in m.export_entries() {
            let family = name.split('{').next().unwrap();
            let declared = crate::obs::registry::schema_kind(family)
                .unwrap_or_else(|| panic!("{family} missing from obs SCHEMA"));
            assert_eq!(value.kind(), declared, "{family}");
        }
    }
}
