//! Coordinator metrics: counters and latency reservoirs, shared behind a
//! mutex (the request path touches them once per token batch, not per
//! request, so contention is negligible — measured in benches/coordinator).

use std::sync::Mutex;

#[derive(Default, Debug)]
pub struct MetricsInner {
    pub requests_in: u64,
    pub requests_done: u64,
    pub tokens_generated: u64,
    pub prefills: u64,
    pub decode_steps: u64,
    pub ttft_s: Vec<f64>,
    pub total_s: Vec<f64>,
    pub queue_peak: usize,
}

/// Shared metrics handle.
#[derive(Default)]
pub struct Metrics(Mutex<MetricsInner>);

impl Metrics {
    pub fn record_enqueue(&self, queue_len: usize) {
        let mut m = self.0.lock().unwrap();
        m.requests_in += 1;
        m.queue_peak = m.queue_peak.max(queue_len);
    }

    pub fn record_prefill(&self, n: usize) {
        let mut m = self.0.lock().unwrap();
        m.prefills += n as u64;
    }

    pub fn record_decode(&self, tokens: usize) {
        let mut m = self.0.lock().unwrap();
        m.decode_steps += 1;
        m.tokens_generated += tokens as u64;
    }

    pub fn record_done(&self, ttft: Option<f64>, total: f64) {
        let mut m = self.0.lock().unwrap();
        m.requests_done += 1;
        if let Some(t) = ttft {
            m.ttft_s.push(t);
        }
        m.total_s.push(total);
    }

    pub fn snapshot(&self) -> MetricsInner {
        let m = self.0.lock().unwrap();
        MetricsInner {
            requests_in: m.requests_in,
            requests_done: m.requests_done,
            tokens_generated: m.tokens_generated,
            prefills: m.prefills,
            decode_steps: m.decode_steps,
            ttft_s: m.ttft_s.clone(),
            total_s: m.total_s.clone(),
            queue_peak: m.queue_peak,
        }
    }

    pub fn report(&self) -> String {
        let m = self.snapshot();
        let p = |v: &Vec<f64>, q| crate::util::stats::percentile(v, q);
        format!(
            "requests {}/{} | tokens {} | prefills {} | decode steps {} | \
             ttft p50 {:.1}ms p99 {:.1}ms | e2e p50 {:.1}ms p99 {:.1}ms | queue peak {}",
            m.requests_done,
            m.requests_in,
            m.tokens_generated,
            m.prefills,
            m.decode_steps,
            p(&m.ttft_s, 50.0) * 1e3,
            p(&m.ttft_s, 99.0) * 1e3,
            p(&m.total_s, 50.0) * 1e3,
            p(&m.total_s, 99.0) * 1e3,
            m.queue_peak
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.record_enqueue(3);
        m.record_enqueue(5);
        m.record_prefill(2);
        m.record_decode(8);
        m.record_done(Some(0.01), 0.05);
        let s = m.snapshot();
        assert_eq!(s.requests_in, 2);
        assert_eq!(s.queue_peak, 5);
        assert_eq!(s.tokens_generated, 8);
        assert_eq!(s.requests_done, 1);
        assert!(m.report().contains("requests 1/2"));
    }
}
