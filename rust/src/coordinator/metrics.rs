//! Coordinator metrics: counters and latency reservoirs, shared behind a
//! mutex (the request path touches them once per token batch, not per
//! request, so contention is negligible — measured in benches/coordinator).

use std::sync::Mutex;

#[derive(Default, Debug)]
pub struct MetricsInner {
    pub requests_in: u64,
    pub requests_done: u64,
    pub tokens_generated: u64,
    pub prefills: u64,
    pub decode_steps: u64,
    pub ttft_s: Vec<f64>,
    pub total_s: Vec<f64>,
    pub queue_peak: usize,
    /// Session turns resumed from a stored state (no transcript re-prefill).
    pub session_hits: u64,
    /// Session turns whose state was gone (evicted, unspilled) and had to
    /// re-prefill the full transcript.
    pub session_misses: u64,
    /// Prefill tokens skipped by resuming instead of re-prefilling.
    pub prefill_tokens_saved: u64,
    /// Bytes currently resident in the session store (gauge).
    pub session_bytes_held: u64,
    /// Sessions currently RAM-resident in the store (gauge; spilled
    /// sessions are held on disk and not counted here).
    pub sessions_resident: u64,
    /// Session-store evictions so far (gauge, mirrors the store).
    pub session_evictions: u64,
    /// Evictions persisted to the spill directory (gauge).
    pub session_spills: u64,
}

/// Shared metrics handle.
#[derive(Default)]
pub struct Metrics(Mutex<MetricsInner>);

impl Metrics {
    pub fn record_enqueue(&self, queue_len: usize) {
        let mut m = self.0.lock().unwrap();
        m.requests_in += 1;
        m.queue_peak = m.queue_peak.max(queue_len);
    }

    pub fn record_prefill(&self, n: usize) {
        let mut m = self.0.lock().unwrap();
        m.prefills += n as u64;
    }

    pub fn record_decode(&self, tokens: usize) {
        let mut m = self.0.lock().unwrap();
        m.decode_steps += 1;
        m.tokens_generated += tokens as u64;
    }

    /// A session turn resumed from a stored state; `tokens_saved` is the
    /// transcript prefill it skipped.
    pub fn record_session_hit(&self, tokens_saved: u64) {
        let mut m = self.0.lock().unwrap();
        m.session_hits += 1;
        m.prefill_tokens_saved += tokens_saved;
    }

    pub fn record_session_miss(&self) {
        let mut m = self.0.lock().unwrap();
        m.session_misses += 1;
    }

    /// Mirror the session store's gauges after a snapshot/eviction.
    pub fn set_session_store(
        &self,
        resident: u64,
        bytes_held: u64,
        evictions: u64,
        spills: u64,
    ) {
        let mut m = self.0.lock().unwrap();
        m.sessions_resident = resident;
        m.session_bytes_held = bytes_held;
        m.session_evictions = evictions;
        m.session_spills = spills;
    }

    pub fn record_done(&self, ttft: Option<f64>, total: f64) {
        let mut m = self.0.lock().unwrap();
        m.requests_done += 1;
        if let Some(t) = ttft {
            m.ttft_s.push(t);
        }
        m.total_s.push(total);
    }

    pub fn snapshot(&self) -> MetricsInner {
        let m = self.0.lock().unwrap();
        MetricsInner {
            requests_in: m.requests_in,
            requests_done: m.requests_done,
            tokens_generated: m.tokens_generated,
            prefills: m.prefills,
            decode_steps: m.decode_steps,
            ttft_s: m.ttft_s.clone(),
            total_s: m.total_s.clone(),
            queue_peak: m.queue_peak,
            session_hits: m.session_hits,
            session_misses: m.session_misses,
            prefill_tokens_saved: m.prefill_tokens_saved,
            session_bytes_held: m.session_bytes_held,
            sessions_resident: m.sessions_resident,
            session_evictions: m.session_evictions,
            session_spills: m.session_spills,
        }
    }

    pub fn report(&self) -> String {
        let m = self.snapshot();
        let p = |v: &Vec<f64>, q| crate::util::stats::percentile(v, q);
        let mut line = format!(
            "requests {}/{} | tokens {} | prefills {} | decode steps {} | \
             ttft p50 {:.1}ms p99 {:.1}ms | e2e p50 {:.1}ms p99 {:.1}ms | queue peak {}",
            m.requests_done,
            m.requests_in,
            m.tokens_generated,
            m.prefills,
            m.decode_steps,
            p(&m.ttft_s, 50.0) * 1e3,
            p(&m.ttft_s, 99.0) * 1e3,
            p(&m.total_s, 50.0) * 1e3,
            p(&m.total_s, 99.0) * 1e3,
            m.queue_peak
        );
        if m.session_hits + m.session_misses > 0 || m.session_bytes_held > 0 {
            line.push_str(&format!(
                " | sessions hit/miss {}/{} | prefill tokens saved {} | \
                 {} resident, {} session bytes (evictions {}, spills {})",
                m.session_hits,
                m.session_misses,
                m.prefill_tokens_saved,
                m.sessions_resident,
                m.session_bytes_held,
                m.session_evictions,
                m.session_spills
            ));
        }
        line
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.record_enqueue(3);
        m.record_enqueue(5);
        m.record_prefill(2);
        m.record_decode(8);
        m.record_done(Some(0.01), 0.05);
        let s = m.snapshot();
        assert_eq!(s.requests_in, 2);
        assert_eq!(s.queue_peak, 5);
        assert_eq!(s.tokens_generated, 8);
        assert_eq!(s.requests_done, 1);
        assert!(m.report().contains("requests 1/2"));
        // no session traffic -> no session segment in the report
        assert!(!m.report().contains("sessions hit/miss"));
    }

    #[test]
    fn session_counters_accumulate_and_report() {
        let m = Metrics::default();
        m.record_session_hit(120);
        m.record_session_hit(80);
        m.record_session_miss();
        m.set_session_store(5, 4096, 3, 2);
        let s = m.snapshot();
        assert_eq!(s.session_hits, 2);
        assert_eq!(s.session_misses, 1);
        assert_eq!(s.prefill_tokens_saved, 200);
        assert_eq!(s.sessions_resident, 5);
        assert_eq!(s.session_bytes_held, 4096);
        assert_eq!(s.session_evictions, 3);
        assert_eq!(s.session_spills, 2);
        let r = m.report();
        assert!(r.contains("sessions hit/miss 2/1"), "{r}");
        assert!(r.contains("prefill tokens saved 200"), "{r}");
    }
}
