//! Serving coordinator (L3): request router, dynamic batcher with
//! continuous batching over fixed engine slots, per-session state manager
//! and metrics — the deployment story the paper's throughput numbers
//! assume (recurrent models keep per-sequence state constant, so the
//! coordinator can pack far more sequences per device, Figure 1.1).
//!
//! Thread-based (std::sync::mpsc); tokio is unavailable offline.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod server;
pub mod state;

pub use request::{GenRequest, GenResponse, Refusal};
pub use server::{
    CoordinatorClosed, CoordinatorHandle, SessionCensus, SessionExport, SlotEngine,
    SubmitError,
};
