//! The coordinator event loop: a dedicated engine thread running continuous
//! batching over the slot engine, fed by an mpsc request channel.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{Batcher, Slot};
use super::metrics::Metrics;
use super::request::{GenRequest, GenResponse};
pub use super::state::SlotEngine;
use crate::config::ServeConfig;

enum Msg {
    Req(GenRequest),
    Shutdown,
}

/// Client handle: submit prompts, read metrics, shut down.
pub struct CoordinatorHandle {
    tx: Sender<Msg>,
    join: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
}

impl CoordinatorHandle {
    /// Submit a generation request; returns the response receiver.
    pub fn submit(&self, prompt: Vec<i32>, max_new_tokens: usize) -> Receiver<GenResponse> {
        let (tx, rx) = channel();
        let req = GenRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            prompt,
            max_new_tokens,
            reply: tx,
            enqueued: Instant::now(),
        };
        self.tx.send(Msg::Req(req)).expect("coordinator alive");
        rx
    }

    /// Stop the engine thread after draining in-flight work.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for CoordinatorHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Spawn the coordinator.  The engine is built *inside* the engine thread
/// via `make_engine` because PJRT executables are not `Send`.
pub fn spawn<F>(make_engine: F, cfg: ServeConfig) -> CoordinatorHandle
where
    F: FnOnce() -> Box<dyn SlotEngine> + Send + 'static,
{
    let (tx, rx) = channel::<Msg>();
    let metrics = Arc::new(Metrics::default());
    let m = metrics.clone();
    let join = std::thread::spawn(move || {
        let mut engine = make_engine();
        let n_slots = engine.n_slots();
        let mut batcher = Batcher::new(n_slots, engine.bytes_per_seq(), cfg.mem_budget);
        let mut shutdown = false;
        loop {
            // 1) intake: drain quickly; block briefly when idle
            let idle = batcher.busy_slots().is_empty() && batcher.queue_len() == 0;
            if idle && !shutdown {
                match rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(Msg::Req(r)) => {
                        m.record_enqueue(batcher.queue_len() + 1);
                        batcher.enqueue(r);
                    }
                    Ok(Msg::Shutdown) => shutdown = true,
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => shutdown = true,
                }
            }
            // opportunistic linger for batch formation
            let linger = Instant::now();
            loop {
                match rx.try_recv() {
                    Ok(Msg::Req(r)) => {
                        m.record_enqueue(batcher.queue_len() + 1);
                        batcher.enqueue(r);
                    }
                    Ok(Msg::Shutdown) => {
                        shutdown = true;
                        break;
                    }
                    Err(_) => {
                        if batcher.queue_len() == 0
                            || batcher.free_slots().is_empty()
                            || linger.elapsed() > Duration::from_millis(cfg.linger_ms)
                        {
                            break;
                        }
                        std::thread::yield_now();
                    }
                }
            }
            if shutdown && batcher.busy_slots().is_empty() && batcher.queue_len() == 0 {
                break;
            }
            // 2) admission + prefill
            let jobs = batcher.admit();
            if !jobs.is_empty() {
                m.record_prefill(jobs.len());
                let firsts = engine.prefill_slots(&jobs);
                for (slot, tok) in firsts {
                    if let Slot::Busy { req, generated, first_token_s } =
                        &mut batcher.slots[slot]
                    {
                        generated.push(tok);
                        *first_token_s = Some(req.enqueued.elapsed().as_secs_f64());
                    }
                }
            }
            // 3) decode step over active slots
            let active = batcher.busy_slots();
            if !active.is_empty() {
                let toks = engine.decode_slots(&active);
                m.record_decode(toks.len());
                for (slot, tok) in toks {
                    if let Slot::Busy { generated, .. } = &mut batcher.slots[slot] {
                        generated.push(tok);
                    }
                }
            }
            // 4) retire finished sequences
            for slot in batcher.busy_slots() {
                let done = match &batcher.slots[slot] {
                    Slot::Busy { req, generated, .. } => generated.len() >= req.max_new_tokens,
                    Slot::Free => false,
                };
                if done {
                    if let Some((req, mut generated, ttft)) = batcher.release(slot) {
                        generated.truncate(req.max_new_tokens);
                        let total = req.enqueued.elapsed().as_secs_f64();
                        m.record_done(ttft, total);
                        let _ = req.reply.send(GenResponse {
                            id: req.id,
                            tokens: generated,
                            ttft_s: ttft.unwrap_or(total),
                            total_s: total,
                        });
                    }
                    engine.clear_slot(slot);
                }
            }
        }
    });
    CoordinatorHandle { tx, join: Some(join), metrics, next_id: AtomicU64::new(0) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::recurrent::RecurrentEngine;
    use crate::engine::LmShape;

    fn handle(slots: usize) -> CoordinatorHandle {
        spawn(
            move || {
                let shape = LmShape::bench("nano").unwrap();
                Box::new(RecurrentEngine::new(&shape, slots, 11)) as Box<dyn SlotEngine>
            },
            ServeConfig { max_batch: slots, linger_ms: 1, max_new_tokens: 8, mem_budget: 1 << 30 },
        )
    }

    #[test]
    fn serves_a_single_request() {
        let h = handle(2);
        let rx = h.submit(vec![1, 2, 3], 5);
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.tokens.len(), 5);
        assert!(resp.ttft_s <= resp.total_s);
        h.shutdown();
    }

    #[test]
    fn serves_more_requests_than_slots() {
        let h = handle(2);
        let rxs: Vec<_> = (0..6).map(|i| h.submit(vec![1 + i, 2, 3], 4)).collect();
        let mut ids = vec![];
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(r.tokens.len(), 4);
            ids.push(r.id);
        }
        ids.sort();
        assert_eq!(ids, (0..6).collect::<Vec<_>>());
        let m = h.metrics.snapshot();
        assert_eq!(m.requests_done, 6);
        assert_eq!(m.tokens_generated as usize + m.prefills as usize, 6 * 4);
        h.shutdown();
    }

    #[test]
    fn identical_prompts_get_identical_tokens_regardless_of_batching() {
        // continuous batching must not leak state across slots
        let h = handle(3);
        let a = h.submit(vec![5, 6, 7], 6).recv_timeout(Duration::from_secs(30)).unwrap();
        // now saturate and resubmit the same prompt
        let rxs: Vec<_> = (0..5).map(|_| h.submit(vec![5, 6, 7], 6)).collect();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(r.tokens, a.tokens, "determinism across batch layouts");
        }
        h.shutdown();
    }
}
