//! The coordinator event loop: a dedicated engine thread running continuous
//! batching over the slot engine, fed by an mpsc request channel.
//!
//! Multi-turn sessions: `submit_in_session` tags a request with a session
//! id.  At retire the slot's O(1) recurrence state is snapshotted into the
//! LRU [`Store`]; the next turn restores it into a free slot and feeds only
//! the new tokens — skipping the re-prefill of the whole transcript while
//! producing bit-identical tokens to one uninterrupted generation (the
//! engine feeds the same token sequence through the same per-token path).
//! If the state was evicted (and not spilled), the coordinator falls back
//! to re-prefilling the transcript it keeps per session, so eviction can
//! never change tokens — only latency.

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{Batcher, Slot};
use super::metrics::Metrics;
use super::request::{GenRequest, GenResponse, Refusal};
pub use super::state::SlotEngine;
use crate::config::ServeConfig;
use crate::obs::{HopReport, TraceRecord, TraceRing};
use crate::session::{SessionError, SessionState, Store, StoreConfig};

enum Msg {
    Req(GenRequest),
    /// Drop a session's stored state and transcript.
    End(u64),
    /// Move a session *out* of this coordinator: once the session is
    /// quiescent, reply with its state + transcript and forget it locally.
    Export(u64, Sender<Option<SessionExport>>),
    /// Install a migrated session (state + transcript) into this
    /// coordinator, as if every prior turn had been served here.
    Import(u64, SessionExport, Sender<()>),
    /// Whether this coordinator holds any trace of the session (stored or
    /// spilled state, transcript, or an in-flight turn).
    Query(u64, Sender<bool>),
    /// Every session id this coordinator holds any trace of (the
    /// enumeration behind a bulk drain).
    List(Sender<Vec<u64>>),
    /// Read a session's full transcript *without* detaching anything.
    /// Deferred until the session quiesces (like Export), so the reply
    /// always reflects every completed turn — the recovery primitive a
    /// front door uses to reconcile after a token stream was severed
    /// mid-turn.
    Transcript(u64, Sender<Option<Vec<i32>>>),
    /// Exact footprint of every session this coordinator still holds.
    Census(Sender<SessionCensus>),
    Shutdown,
}

/// Exact accounting of what sessions cost this coordinator right now —
/// the observable behind the TTL guarantee that an idle session past its
/// TTL holds *zero* RAM (state, spill index, and transcript included).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SessionCensus {
    /// Sessions with a coordinator-resident transcript.
    pub transcripts: u64,
    /// Total tokens across all held transcripts.
    pub transcript_tokens: u64,
    /// Session states resident in store RAM.
    pub resident_states: u64,
    /// Bytes of store-RAM-resident states.
    pub resident_bytes: u64,
    /// Session states held by the disk spill tier.
    pub spilled_states: u64,
    /// Live bytes in the disk spill tier.
    pub spilled_bytes: u64,
    /// Session turns currently queued or occupying a slot.
    pub in_flight: u64,
}

/// Everything a session is, detached from a coordinator: the O(1)
/// recurrence state blob (when the engine supports snapshots) plus the
/// token transcript that backs the lossless re-prefill fallback.  This is
/// the unit of cross-process migration — constant-size for the recurrent
/// engine (Lemma 2.2), which is what makes shipping a live conversation to
/// another shard cheap.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionExport {
    /// Full token transcript (prompts + generated, every turn so far).
    pub transcript: Vec<i32>,
    /// Stored recurrence state; `None` when the engine cannot snapshot
    /// (the transcript alone still migrates the session losslessly).
    pub state: Option<SessionState>,
}

/// The engine thread is gone (shut down, or its construction panicked), so
/// the request could not be submitted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CoordinatorClosed;

impl std::fmt::Display for CoordinatorClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "coordinator engine thread has exited")
    }
}

impl std::error::Error for CoordinatorClosed {}

/// Why a strict session resume was refused.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The engine thread is gone.
    Closed(CoordinatorClosed),
    /// A typed session-level refusal — for a strict resume this is always
    /// [`SessionError::Unknown`], so a router can tell "migrate the session
    /// here first" apart from transport failures.
    Session(SessionError),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Closed(e) => e.fmt(f),
            SubmitError::Session(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for SubmitError {}

impl From<CoordinatorClosed> for SubmitError {
    fn from(e: CoordinatorClosed) -> SubmitError {
        SubmitError::Closed(e)
    }
}

/// Client handle: submit prompts, read metrics, shut down.
pub struct CoordinatorHandle {
    tx: Sender<Msg>,
    join: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    /// Bounded ring of per-request span trees (a "coordinator" hop with
    /// queue / prefill-or-resume / decode spans, plus an "engine" hop
    /// for profiled requests), pushed at retire.  Records are keyed by
    /// the wire trace id when the request carried one, else by the
    /// local request id.
    pub traces: Arc<TraceRing>,
    next_id: AtomicU64,
}

impl CoordinatorHandle {
    /// Submit a one-shot generation request; returns the response receiver,
    /// or [`CoordinatorClosed`] if the engine thread has exited.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
    ) -> Result<Receiver<GenResponse>, CoordinatorClosed> {
        self.submit_opt(None, prompt, max_new_tokens, None)
    }

    /// Submit one turn of a multi-turn session.  `tokens` is only this
    /// turn's new tokens; the coordinator resumes the session's stored
    /// recurrence state (or re-prefills its transcript on a store miss).
    ///
    /// Pipelining is safe: turns of one session serialize inside the
    /// batcher — a second turn submitted before the first's reply stays
    /// queued until the first retires, so it always sees the full
    /// transcript.
    pub fn submit_in_session(
        &self,
        session_id: u64,
        tokens: Vec<i32>,
        max_new_tokens: usize,
    ) -> Result<Receiver<GenResponse>, CoordinatorClosed> {
        self.submit_opt(Some(session_id), tokens, max_new_tokens, None)
    }

    /// Streaming variant of [`CoordinatorHandle::submit`]: the first
    /// receiver yields each generated token the moment the decode loop
    /// produces it (its sender is dropped at retire, ending the stream);
    /// the second delivers the buffered [`GenResponse`] whose `tokens` are
    /// always identical to the streamed sequence.
    pub fn submit_streaming(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
    ) -> Result<(Receiver<i32>, Receiver<GenResponse>), CoordinatorClosed> {
        let (tok_tx, tok_rx) = channel();
        let rx = self.submit_opt(None, prompt, max_new_tokens, Some(tok_tx))?;
        Ok((tok_rx, rx))
    }

    /// Streaming variant of [`CoordinatorHandle::submit_in_session`].
    pub fn submit_in_session_streaming(
        &self,
        session_id: u64,
        tokens: Vec<i32>,
        max_new_tokens: usize,
    ) -> Result<(Receiver<i32>, Receiver<GenResponse>), CoordinatorClosed> {
        let (tok_tx, tok_rx) = channel();
        let rx =
            self.submit_opt(Some(session_id), tokens, max_new_tokens, Some(tok_tx))?;
        Ok((tok_rx, rx))
    }

    /// Streaming variant of [`CoordinatorHandle::resume_session`]: strict
    /// (typed [`SessionError::Unknown`] refusal) plus a per-token stream.
    pub fn resume_session_streaming(
        &self,
        session_id: u64,
        tokens: Vec<i32>,
        max_new_tokens: usize,
    ) -> Result<(Receiver<i32>, Receiver<GenResponse>), SubmitError> {
        if !self.session_known(session_id)? {
            return Err(SubmitError::Session(SessionError::Unknown { id: session_id }));
        }
        let (tok_tx, tok_rx) = channel();
        let rx =
            self.submit_opt(Some(session_id), tokens, max_new_tokens, Some(tok_tx))?;
        Ok((tok_rx, rx))
    }

    fn submit_opt(
        &self,
        session: Option<u64>,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        stream: Option<Sender<i32>>,
    ) -> Result<Receiver<GenResponse>, CoordinatorClosed> {
        self.submit_full(session, prompt, max_new_tokens, stream, None)
    }

    /// The fully-general submit: session tag, per-token stream, and an
    /// absolute admission deadline.  A request still queued past its
    /// deadline is refused with a typed
    /// [`Refusal::DeadlineExceeded`][crate::coordinator::Refusal] response
    /// (empty tokens) instead of running late; `None` never sheds.
    pub fn submit_full(
        &self,
        session: Option<u64>,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        stream: Option<Sender<i32>>,
        deadline: Option<Instant>,
    ) -> Result<Receiver<GenResponse>, CoordinatorClosed> {
        self.submit_traced(session, prompt, max_new_tokens, stream, deadline, 0, false)
    }

    /// [`CoordinatorHandle::submit_full`] plus the distributed-tracing
    /// context: `trace` is the wire-propagated trace id (0 = untraced;
    /// the retire-time span record is then keyed by trace id and the
    /// response carries hop reports), `profile` turns on per-stage
    /// engine hot-path timing for this one request.
    #[allow(clippy::too_many_arguments)]
    pub fn submit_traced(
        &self,
        session: Option<u64>,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        stream: Option<Sender<i32>>,
        deadline: Option<Instant>,
        trace: u64,
        profile: bool,
    ) -> Result<Receiver<GenResponse>, CoordinatorClosed> {
        let (tx, rx) = channel();
        let req = GenRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            trace,
            profile,
            prompt,
            // a 0-token generation is meaningless and would leave a session
            // snapshot whose pending token is absent from the transcript —
            // every request produces at least the prefill token
            max_new_tokens: max_new_tokens.max(1),
            session,
            reply: tx,
            stream,
            enqueued: Instant::now(),
            deadline,
        };
        self.tx.send(Msg::Req(req)).map_err(|_| CoordinatorClosed)?;
        Ok(rx)
    }

    /// Exact per-session RAM/disk footprint of this coordinator (states,
    /// spill tier, transcripts, in-flight turns) — the fixed-size census
    /// behind the TTL zero-RAM guarantee and fleet-level leak checks.
    pub fn session_census(&self) -> Result<SessionCensus, CoordinatorClosed> {
        let (tx, rx) = channel();
        self.tx.send(Msg::Census(tx)).map_err(|_| CoordinatorClosed)?;
        rx.recv().map_err(|_| CoordinatorClosed)
    }

    /// Drop a session's stored state and transcript (RAM and spill), so
    /// long-running coordinators do not accumulate dead conversations.
    /// Takes effect once the session is quiescent: if a turn is still
    /// queued or in flight, the end is deferred until its last turn
    /// retires, so in-flight turns always see the full transcript.
    pub fn end_session(&self, session_id: u64) -> Result<(), CoordinatorClosed> {
        self.tx.send(Msg::End(session_id)).map_err(|_| CoordinatorClosed)
    }

    /// Strict variant of [`CoordinatorHandle::submit_in_session`]: refuses
    /// with [`SessionError::Unknown`] when this coordinator holds no trace
    /// of the session, instead of silently starting a fresh conversation.
    /// A router uses the typed error to decide between migrating the
    /// session here and re-prefilling from its own transcript.
    ///
    /// The existence check and the submit are two steps; a concurrent
    /// `end_session` racing between them degrades to the non-strict
    /// behaviour (a fresh session), never to an error.
    pub fn resume_session(
        &self,
        session_id: u64,
        tokens: Vec<i32>,
        max_new_tokens: usize,
    ) -> Result<Receiver<GenResponse>, SubmitError> {
        if !self.session_known(session_id)? {
            return Err(SubmitError::Session(SessionError::Unknown { id: session_id }));
        }
        Ok(self.submit_opt(Some(session_id), tokens, max_new_tokens, None)?)
    }

    /// Whether this coordinator holds any trace of the session: a stored
    /// (or spilled) state, a transcript, or a queued/in-flight turn.
    pub fn session_known(&self, session_id: u64) -> Result<bool, CoordinatorClosed> {
        let (tx, rx) = channel();
        self.tx.send(Msg::Query(session_id, tx)).map_err(|_| CoordinatorClosed)?;
        rx.recv().map_err(|_| CoordinatorClosed)
    }

    /// Every session id this coordinator holds any trace of — stored or
    /// spilled state, transcript, or a queued/in-flight turn — sorted.
    /// A bulk drain enumerates with this, then exports each id.
    pub fn session_list(&self) -> Result<Vec<u64>, CoordinatorClosed> {
        let (tx, rx) = channel();
        self.tx.send(Msg::List(tx)).map_err(|_| CoordinatorClosed)?;
        rx.recv().map_err(|_| CoordinatorClosed)
    }

    /// Quiesce and extract a session for migration: blocks until no turn
    /// of the session is queued or in flight, then returns its state +
    /// transcript and removes every local trace (store, spill, transcript)
    /// — the session now lives wherever the export is imported.  Returns
    /// `Ok(None)` when the session is unknown.
    pub fn export_session(
        &self,
        session_id: u64,
    ) -> Result<Option<SessionExport>, CoordinatorClosed> {
        let (tx, rx) = channel();
        self.tx.send(Msg::Export(session_id, tx)).map_err(|_| CoordinatorClosed)?;
        rx.recv().map_err(|_| CoordinatorClosed)
    }

    /// Read a session's full transcript without detaching it.  Blocks
    /// until the session quiesces (no turn queued or in flight), so the
    /// reply reflects every completed turn.  Returns `Ok(None)` when this
    /// coordinator holds no transcript for the session.
    pub fn transcript_of(
        &self,
        session_id: u64,
    ) -> Result<Option<Vec<i32>>, CoordinatorClosed> {
        let (tx, rx) = channel();
        self.tx
            .send(Msg::Transcript(session_id, tx))
            .map_err(|_| CoordinatorClosed)?;
        rx.recv().map_err(|_| CoordinatorClosed)
    }

    /// Install a migrated session, as if every turn of its transcript had
    /// been served here.  An existing session under the same id is
    /// replaced.  The state blob's engine tag is *not* validated here —
    /// restore-time validation plus the serve-layer handshake guarantee a
    /// foreign blob is never installed into a slot; an unusable blob only
    /// costs the re-prefill fallback.
    pub fn import_session(
        &self,
        session_id: u64,
        export: SessionExport,
    ) -> Result<(), CoordinatorClosed> {
        let (tx, rx) = channel();
        self.tx
            .send(Msg::Import(session_id, export, tx))
            .map_err(|_| CoordinatorClosed)?;
        rx.recv().map_err(|_| CoordinatorClosed)
    }

    /// Stop the engine thread after draining in-flight work.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for CoordinatorHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Record a slot's first generated token (prefill or session resume) and
/// emit it to the request's per-token stream — wire TTFB equals engine
/// TTFT because this runs the moment prefill/resume returns.
fn record_first_token(batcher: &mut Batcher, slot: usize, tok: i32) {
    if let Slot::Busy { req, generated, first_token_s } = &mut batcher.slots[slot] {
        generated.push(tok);
        req.emit(tok);
        *first_token_s = Some(req.enqueued.elapsed().as_secs_f64());
    }
}

/// Stage spans of one in-flight request, recorded as offsets from its
/// enqueue instant (clock-skew immune: only durations cross the wire).
/// A stage that did not run stays `None` and is *absent* from the hop
/// report — skipped work is never rendered as a zero-width span.
#[derive(Default)]
struct Stages {
    /// Queue wait: enqueue → slot admission, µs.
    admit_us: u64,
    /// Prefill span `(start offset, duration)` µs; absent for turns
    /// that resumed a stored state.
    prefill: Option<(u64, u64)>,
    /// Resume-feed span `(start offset, duration)` µs; present only
    /// when the turn resumed a stored state.
    resume: Option<(u64, u64)>,
    /// Whether the engine hot path is stage-profiled for this request.
    profile: bool,
}

/// Mutable scheduler state the intake path updates (grouped so the three
/// intake sites — idle block, fast drain, linger wait — share one handler).
struct Sched {
    batcher: Batcher,
    store: Store,
    /// Per-session token transcript (prompt + generated, every turn): the
    /// correctness fallback when a state was evicted without spill.
    history: HashMap<u64, Vec<i32>>,
    /// Sessions whose `end_session` arrived while a turn was queued or in
    /// flight; freed when their last turn retires.
    pending_end: HashSet<u64>,
    /// Export requests that arrived while a turn was queued or in flight;
    /// fulfilled when the session quiesces (its last turn retires) — the
    /// same deferred machinery `end_session` uses, so an exported blob
    /// always reflects the complete conversation.
    pending_export: HashMap<u64, Vec<Sender<Option<SessionExport>>>>,
    /// Transcript reads that arrived mid-turn; fulfilled (non-destructively)
    /// when the session quiesces, so the reply reflects the whole turn.
    pending_transcript: HashMap<u64, Vec<Sender<Option<Vec<i32>>>>>,
    /// Per-request stage spans captured while the request occupies a
    /// slot, drained into hop reports + the trace ring at retire —
    /// bounded by the slot count, never by traffic.
    stages: HashMap<u64, Stages>,
    /// Last time each known session was touched (turn intake, retire, or
    /// import) — drives the TTL sweep.
    last_active: HashMap<u64, Instant>,
    /// Idle-session TTL (`None` = TTL sweeping disabled).
    ttl: Option<Duration>,
    /// Queue-length admission cap (0 = unbounded): requests arriving at a
    /// full queue are refused with a typed `Overloaded` instead of queued.
    max_queue: usize,
    shutdown: bool,
}

impl Sched {
    /// Whether any turn of this session is queued or occupying a slot.
    fn session_in_flight(&self, id: u64) -> bool {
        self.batcher.slots.iter().any(|s| s.session() == Some(id))
            || self.batcher.queue.iter().any(|r| r.session == Some(id))
    }

    /// Drop a session's transcript and stored state (RAM and spill).
    fn free_session(&mut self, id: u64, m: &Metrics) {
        self.history.remove(&id);
        self.last_active.remove(&id);
        self.store.evict_session(id);
        self.mirror_store(m);
    }

    /// Mirror the store gauges into the shared metrics.
    fn mirror_store(&self, m: &Metrics) {
        m.set_session_store(
            self.store.len() as u64,
            self.store.bytes_used(),
            self.store.stats.evictions,
            self.store.stats.spills,
        );
        m.set_spill_tier(
            self.store.spill_bytes(),
            self.store.stats.spill_evictions,
            self.store.stats.compactions,
        );
    }

    /// TTL sweep: fully forget sessions idle past the TTL — transcript,
    /// stored state, and spill record all go, so an abandoned session
    /// costs zero RAM.  A session with a turn queued or in flight (or a
    /// pending export/transcript read) is deferred until it quiesces; the
    /// serve layer's transcript mirror + re-prefill path keeps a
    /// TTL-evicted session answerable without token drift.
    fn sweep_ttl(&mut self, now: Instant, m: &Metrics) {
        let ttl = match self.ttl {
            Some(t) => t,
            None => return,
        };
        let expired: Vec<u64> = self
            .last_active
            .iter()
            .filter(|(_, &at)| now.duration_since(at) >= ttl)
            .map(|(&id, _)| id)
            .collect();
        for id in expired {
            if self.session_in_flight(id)
                || self.pending_export.contains_key(&id)
                || self.pending_transcript.contains_key(&id)
            {
                continue; // mid-turn: defer until quiescent
            }
            self.pending_end.remove(&id);
            self.free_session(id, m);
            m.record_ttl_eviction();
        }
    }

    /// Refuse a request with a typed refusal response (empty tokens) and
    /// run the same quiescence bookkeeping a retire would — a shed turn
    /// may have been the last thing keeping an export or deferred end
    /// waiting.
    fn refuse(&mut self, req: GenRequest, why: Refusal, m: &Metrics, tr: &TraceRing) {
        m.record_shed(why);
        let total = req.enqueued.elapsed().as_secs_f64();
        let total_us = (total * 1e6) as u64;
        let note = match why {
            Refusal::Overloaded => "refused:overloaded",
            Refusal::DeadlineExceeded => "refused:deadline",
        };
        // a refused turn never left the queue: its whole life is one
        // queue span, annotated with the typed refusal
        let hop = HopReport::new("coordinator", total_us)
            .span("queue", 0, total_us)
            .note(note);
        tr.push(TraceRecord {
            id: if req.trace != 0 { req.trace } else { req.id },
            session: req.session,
            ok: false,
            tokens: 0,
            e2e_us: total_us,
            hops: vec![hop.clone()],
        });
        let _ = req.reply.send(GenResponse {
            id: req.id,
            trace: req.trace,
            tokens: vec![],
            ttft_s: total,
            total_s: total,
            refusal: Some(why),
            hops: if req.trace != 0 { vec![hop] } else { Vec::new() },
        });
        if let Some(id) = req.session {
            if !self.session_in_flight(id) {
                self.fulfill_transcripts(id);
                if self.pending_end.remove(&id) {
                    self.free_session(id, m);
                }
                self.fulfill_exports(id, m);
            }
        }
    }

    /// Exact session footprint (the TTL zero-RAM observable).
    fn census(&self) -> SessionCensus {
        let queued = self.batcher.queue.iter().filter(|r| r.session.is_some()).count();
        let slotted =
            self.batcher.slots.iter().filter(|s| s.session().is_some()).count();
        SessionCensus {
            transcripts: self.history.len() as u64,
            transcript_tokens: self.history.values().map(|h| h.len() as u64).sum(),
            resident_states: self.store.len() as u64,
            resident_bytes: self.store.bytes_used(),
            spilled_states: self.store.spilled_len() as u64,
            spilled_bytes: self.store.spill_bytes(),
            in_flight: (queued + slotted) as u64,
        }
    }

    /// Detach a quiescent session (state + transcript) and forget it
    /// locally.  `None` when nothing is known about the id.
    fn detach_session(&mut self, id: u64, m: &Metrics) -> Option<SessionExport> {
        let state = self.store.take(id);
        let transcript = self.history.remove(&id);
        self.last_active.remove(&id);
        self.mirror_store(m);
        if state.is_none() && transcript.is_none() {
            return None;
        }
        Some(SessionExport { transcript: transcript.unwrap_or_default(), state })
    }

    /// Fulfill every export waiting on `id` (the session must be
    /// quiescent).  The first waiter receives the session; later waiters
    /// get `None` — a session can only move to one place.
    fn fulfill_exports(&mut self, id: u64, m: &Metrics) {
        if let Some(waiters) = self.pending_export.remove(&id) {
            let mut export = self.detach_session(id, m);
            for tx in waiters {
                let _ = tx.send(export.take());
            }
        }
    }

    /// Fulfill every deferred transcript read waiting on `id` with the
    /// current (complete) transcript.  Non-destructive, so every waiter
    /// gets the same answer.
    fn fulfill_transcripts(&mut self, id: u64) {
        if let Some(waiters) = self.pending_transcript.remove(&id) {
            let transcript = self.history.get(&id).cloned();
            for tx in waiters {
                let _ = tx.send(transcript.clone());
            }
        }
    }

    /// Apply one channel message (the single intake site).
    fn apply_msg(&mut self, msg: Msg, m: &Metrics, tr: &TraceRing) {
        match msg {
            Msg::Req(r) => {
                if self.max_queue > 0 && self.batcher.queue_len() >= self.max_queue {
                    // admission cap: refuse at the door instead of letting
                    // the queue grow without bound under overload
                    self.refuse(r, Refusal::Overloaded, m, tr);
                    return;
                }
                if let Some(id) = r.session {
                    self.last_active.insert(id, Instant::now());
                }
                m.record_enqueue(self.batcher.queue_len() + 1);
                self.batcher.enqueue(r);
            }
            Msg::End(id) => {
                if self.session_in_flight(id) {
                    self.pending_end.insert(id);
                } else {
                    self.free_session(id, m);
                }
            }
            Msg::Export(id, reply) => {
                if self.session_in_flight(id) {
                    self.pending_export.entry(id).or_default().push(reply);
                } else {
                    let export = self.detach_session(id, m);
                    let _ = reply.send(export);
                }
            }
            Msg::Import(id, export, reply) => {
                self.history.insert(id, export.transcript);
                self.last_active.insert(id, Instant::now());
                if let Some(state) = export.state {
                    self.store.put(id, state);
                }
                self.mirror_store(m);
                let _ = reply.send(());
            }
            Msg::Query(id, reply) => {
                let known = self.session_in_flight(id)
                    || self.history.contains_key(&id)
                    || self.store.contains(id);
                let _ = reply.send(known);
            }
            Msg::List(reply) => {
                let mut ids = self.store.ids();
                ids.extend(self.history.keys().copied());
                ids.extend(self.batcher.queue.iter().filter_map(|r| r.session));
                ids.extend(self.batcher.slots.iter().filter_map(|s| s.session()));
                ids.sort_unstable();
                ids.dedup();
                let _ = reply.send(ids);
            }
            Msg::Transcript(id, reply) => {
                if self.session_in_flight(id) {
                    self.pending_transcript.entry(id).or_default().push(reply);
                } else {
                    let _ = reply.send(self.history.get(&id).cloned());
                }
            }
            Msg::Census(reply) => {
                let _ = reply.send(self.census());
            }
            Msg::Shutdown => self.shutdown = true,
        }
    }
}

/// Spawn the coordinator.  The engine is built *inside* the engine thread
/// via `make_engine` because PJRT executables are not `Send`.
pub fn spawn<F>(make_engine: F, cfg: ServeConfig) -> CoordinatorHandle
where
    F: FnOnce() -> Box<dyn SlotEngine> + Send + 'static,
{
    let (tx, rx) = channel::<Msg>();
    let metrics = Arc::new(Metrics::default());
    let traces = Arc::new(TraceRing::default());
    let m = metrics.clone();
    let tr = traces.clone();
    let join = std::thread::spawn(move || {
        let mut engine = make_engine();
        let n_slots = engine.n_slots();
        let mut s = Sched {
            batcher: Batcher::new(n_slots, engine.bytes_per_seq(), cfg.mem_budget),
            store: Store::new(StoreConfig {
                budget_bytes: cfg.session_budget,
                spill_dir: cfg.session_spill_dir.as_ref().map(PathBuf::from),
                spill_budget_bytes: cfg.session_spill_budget,
                ..StoreConfig::default()
            }),
            history: HashMap::new(),
            pending_end: HashSet::new(),
            pending_export: HashMap::new(),
            pending_transcript: HashMap::new(),
            stages: HashMap::new(),
            last_active: HashMap::new(),
            ttl: (cfg.session_ttl_ms > 0)
                .then(|| Duration::from_millis(cfg.session_ttl_ms)),
            max_queue: cfg.max_queue,
            shutdown: false,
        };
        let mut last_sweep = Instant::now();
        loop {
            // 1) intake: block briefly when there is nothing to run — no
            // busy slots and nothing admissible (an empty queue, or one
            // holding only ledger-blocked / held-back session turns)
            let idle = s.batcher.busy_slots().is_empty() && !s.batcher.has_admissible();
            if idle && !s.shutdown {
                match rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(msg) => s.apply_msg(msg, &m, &tr),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => s.shutdown = true,
                }
                // idle housekeeping, off any turn's critical path: compact
                // spill segments whose live ratio decayed
                if s.store.maintain() > 0 {
                    s.mirror_store(&m);
                }
            }
            // TTL sweep on a coarse cadence (the loop always spins at
            // >= 20 Hz when idle, so idle sessions are reaped promptly
            // even while other sessions keep the batch busy)
            let now = Instant::now();
            if now.duration_since(last_sweep) >= Duration::from_millis(100) {
                last_sweep = now;
                s.sweep_ttl(now, &m);
            }
            // 1b) fast drain + opportunistic linger for batch formation:
            // while an admissible request is queued and slots remain free,
            // block on the channel up to the linger deadline (hoping to
            // batch more arrivals) instead of spinning a core.  A queue of
            // only unadmissible requests must NOT linger — that would stall
            // every decode step of the active generations.
            let deadline = Instant::now() + Duration::from_millis(cfg.linger_ms);
            while !s.shutdown {
                match rx.try_recv() {
                    Ok(msg) => {
                        s.apply_msg(msg, &m, &tr);
                        continue;
                    }
                    Err(TryRecvError::Disconnected) => {
                        s.shutdown = true;
                        break;
                    }
                    Err(TryRecvError::Empty) => {}
                }
                if !s.batcher.has_admissible() {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(msg) => s.apply_msg(msg, &m, &tr),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        s.shutdown = true;
                        break;
                    }
                }
            }
            if s.shutdown && s.batcher.busy_slots().is_empty() && s.batcher.queue_len() == 0 {
                break;
            }
            // 2) admission: first shed queued work whose deadline already
            // passed (it would finish late anyway — refusing now frees the
            // slot for work that can still meet its budget), then admit.
            // Session turns with a stored state resume in O(delta);
            // everything else (one-shots, first turns, store misses) goes
            // through prefill
            for req in s.batcher.shed_expired(Instant::now()) {
                s.refuse(req, Refusal::DeadlineExceeded, &m, &tr);
            }
            let admitted = s.batcher.admit();
            if !admitted.is_empty() {
                let mut prefill_jobs: Vec<(usize, Vec<i32>)> = Vec::new();
                let mut resume_jobs: Vec<(usize, Vec<i32>)> = Vec::new();
                for (slot, delta) in admitted {
                    // queue wait ends the moment the slot is taken; the
                    // offset is remembered for the retire-time trace
                    if let Slot::Busy { req, .. } = &s.batcher.slots[slot] {
                        let wait = req.enqueued.elapsed().as_secs_f64();
                        m.record_admitted(wait, s.batcher.queue_len());
                        s.stages.insert(
                            req.id,
                            Stages {
                                admit_us: (wait * 1e6) as u64,
                                profile: req.profile,
                                ..Stages::default()
                            },
                        );
                        // arm (or disarm, for a slot a profiled request
                        // vacated) engine stage timing before any token
                        // of this request runs
                        engine.set_slot_profiling(slot, req.profile);
                    }
                    let id = match s.batcher.slots[slot].session() {
                        Some(id) => id,
                        None => {
                            prefill_jobs.push((slot, delta));
                            continue;
                        }
                    };
                    if let Some(state) = s.store.take(id) {
                        if engine.restore_slot(slot, &state).is_ok() {
                            // resume: replay the pending greedy token, then
                            // only this turn's new tokens
                            let mut feed = Vec::with_capacity(delta.len() + 1);
                            feed.push(state.last_token);
                            feed.extend_from_slice(&delta);
                            m.record_session_hit(state.tokens_seen);
                            resume_jobs.push((slot, feed));
                            continue;
                        }
                        // unusable blob (wrong engine/shape): fall through
                    }
                    // no usable state: re-prefill the transcript — slower,
                    // never wrong (a first turn has an empty transcript and
                    // is not a miss)
                    if s.history.contains_key(&id) {
                        m.record_session_miss();
                    }
                    let mut full = s.history.get(&id).cloned().unwrap_or_default();
                    full.extend_from_slice(&delta);
                    prefill_jobs.push((slot, full));
                }
                s.mirror_store(&m);
                if !resume_jobs.is_empty() {
                    // restored rows are independent: one pooled feed call
                    let t_resume = Instant::now();
                    let fed = engine.feed_slots(&resume_jobs);
                    let resume_dur_us = t_resume.elapsed().as_micros() as u64;
                    for (slot, tok) in fed {
                        record_first_token(&mut s.batcher, slot, tok);
                        if let Slot::Busy { req, .. } = &s.batcher.slots[slot] {
                            if let Some(st) = s.stages.get_mut(&req.id) {
                                let start = t_resume
                                    .saturating_duration_since(req.enqueued)
                                    .as_micros() as u64;
                                st.resume = Some((start, resume_dur_us));
                            }
                        }
                    }
                }
                if !prefill_jobs.is_empty() {
                    m.record_prefill(prefill_jobs.len());
                    let t_prefill = Instant::now();
                    let firsts = engine.prefill_slots(&prefill_jobs);
                    let prefill_s = t_prefill.elapsed().as_secs_f64();
                    let prefill_dur_us = (prefill_s * 1e6) as u64;
                    m.observe_prefill(prefill_s);
                    for (slot, tok) in firsts {
                        record_first_token(&mut s.batcher, slot, tok);
                        if let Slot::Busy { req, .. } = &s.batcher.slots[slot] {
                            if let Some(st) = s.stages.get_mut(&req.id) {
                                let start = t_prefill
                                    .saturating_duration_since(req.enqueued)
                                    .as_micros() as u64;
                                st.prefill = Some((start, prefill_dur_us));
                            }
                        }
                    }
                }
            }
            // 3) decode step over active slots that still owe tokens (slots
            // at their budget must not advance: their state would drift past
            // the transcript and break session snapshots)
            let active: Vec<usize> = s
                .batcher
                .busy_slots()
                .into_iter()
                .filter(|&sl| match &s.batcher.slots[sl] {
                    Slot::Busy { req, generated, .. } => generated.len() < req.max_new_tokens,
                    Slot::Free => false,
                })
                .collect();
            if !active.is_empty() {
                let toks = engine.decode_slots(&active);
                m.record_decode(toks.len());
                for (slot, tok) in toks {
                    if let Slot::Busy { req, generated, .. } = &mut s.batcher.slots[slot] {
                        generated.push(tok);
                        // per-token streaming: each decode step's token goes
                        // out the moment it exists, not at retire
                        req.emit(tok);
                    }
                }
            }
            // 4) retire finished sequences (snapshot session state first)
            for slot in s.batcher.busy_slots() {
                let done = match &s.batcher.slots[slot] {
                    Slot::Busy { req, generated, .. } => generated.len() >= req.max_new_tokens,
                    Slot::Free => false,
                };
                if done {
                    if let Some((req, mut generated, ttft)) = s.batcher.release(slot) {
                        generated.truncate(req.max_new_tokens);
                        if let Some(id) = req.session {
                            if s.pending_end.contains(&id) && !s.session_in_flight(id) {
                                // deferred end_session: the last turn just
                                // retired.  Transcript readers see the final
                                // transcript (this turn included) before it
                                // is dropped; any export waiting on the same
                                // session gets None (the end wins) instead
                                // of blocking forever
                                let h = s.history.entry(id).or_default();
                                h.extend_from_slice(&req.prompt);
                                h.extend_from_slice(&generated);
                                s.fulfill_transcripts(id);
                                s.pending_end.remove(&id);
                                s.free_session(id, &m);
                                s.fulfill_exports(id, &m);
                            } else {
                                let h = s.history.entry(id).or_default();
                                h.extend_from_slice(&req.prompt);
                                h.extend_from_slice(&generated);
                                let h_len = h.len();
                                s.last_active.insert(id, Instant::now());
                                if let Some(mut st) = engine.snapshot_slot(slot) {
                                    // the state has consumed everything
                                    // except the final pending greedy token
                                    st.tokens_seen = h_len.saturating_sub(1) as u64;
                                    s.store.put(id, st);
                                }
                                s.mirror_store(&m);
                                if !s.session_in_flight(id) {
                                    // the last turn just retired: deferred
                                    // transcript reads see the complete
                                    // conversation, then any deferred export
                                    // detaches and ships the session
                                    s.fulfill_transcripts(id);
                                    s.fulfill_exports(id, &m);
                                }
                            }
                        }
                        let total = req.enqueued.elapsed().as_secs_f64();
                        m.record_done(ttft, total, generated.len());
                        let total_us = (total * 1e6) as u64;
                        let ft_us = (ttft.unwrap_or(total) * 1e6) as u64;
                        let st = s.stages.remove(&req.id).unwrap_or_default();
                        let mut coord = HopReport::new("coordinator", total_us)
                            .span("queue", 0, st.admit_us);
                        if let Some((start, dur)) = st.prefill {
                            coord = coord.span("prefill", start, dur);
                        }
                        if let Some((start, dur)) = st.resume {
                            coord = coord.span("resume", start, dur);
                        }
                        coord = coord.span(
                            "decode",
                            ft_us,
                            total_us.saturating_sub(ft_us),
                        );
                        let mut hops = vec![coord];
                        if st.profile {
                            if let Some(times) = engine.take_slot_stage_times(slot) {
                                m.record_engine_stages(&times);
                                let mut eng =
                                    HopReport::new("engine", times.total_ns() / 1_000);
                                for (name, ns) in times.stages() {
                                    eng = eng.span(name, 0, ns / 1_000);
                                }
                                hops.push(eng);
                            }
                            engine.set_slot_profiling(slot, false);
                        }
                        tr.push(TraceRecord {
                            id: if req.trace != 0 { req.trace } else { req.id },
                            session: req.session,
                            ok: true,
                            tokens: generated.len() as u32,
                            e2e_us: total_us,
                            hops: hops.clone(),
                        });
                        let _ = req.reply.send(GenResponse {
                            id: req.id,
                            trace: req.trace,
                            tokens: generated,
                            ttft_s: ttft.unwrap_or(total),
                            total_s: total,
                            refusal: None,
                            hops: if req.trace != 0 { hops } else { Vec::new() },
                        });
                    }
                    engine.clear_slot(slot);
                }
            }
        }
    });
    CoordinatorHandle { tx, join: Some(join), metrics, traces, next_id: AtomicU64::new(0) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::recurrent::RecurrentEngine;
    use crate::engine::LmShape;

    fn cfg(slots: usize) -> ServeConfig {
        ServeConfig {
            max_batch: slots,
            linger_ms: 1,
            max_new_tokens: 8,
            mem_budget: 1 << 30,
            ..ServeConfig::default()
        }
    }

    fn handle_cfg(slots: usize, cfg: ServeConfig) -> CoordinatorHandle {
        spawn(
            move || {
                let shape = LmShape::bench("nano").unwrap();
                Box::new(RecurrentEngine::new(&shape, slots, 11)) as Box<dyn SlotEngine>
            },
            cfg,
        )
    }

    fn handle(slots: usize) -> CoordinatorHandle {
        handle_cfg(slots, cfg(slots))
    }

    #[test]
    fn serves_a_single_request() {
        let h = handle(2);
        let rx = h.submit(vec![1, 2, 3], 5).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.tokens.len(), 5);
        assert!(resp.ttft_s <= resp.total_s);
        h.shutdown();
    }

    #[test]
    fn traces_record_stage_spans_per_request() {
        let h = handle(2);
        let rx = h.submit(vec![1, 2, 3], 4).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        let traces = h.traces.recent();
        assert_eq!(traces.len(), 1, "retire pushes exactly one trace");
        let t = &traces[0];
        assert_eq!(t.id, resp.id, "untraced requests key by request id");
        assert_eq!(t.session, None);
        assert_eq!(t.tokens, 4);
        assert!(t.ok);
        let coord = t.hop("coordinator").expect("coordinator hop");
        let queue = coord.span_named("queue").expect("queue span");
        assert!(queue.dur_us <= t.e2e_us, "{t:?}");
        let prefill = coord
            .span_named("prefill")
            .expect("one-shot prompts go through prefill");
        assert!(prefill.start_us <= t.e2e_us, "{t:?}");
        let decode = coord.span_named("decode").expect("decode span");
        assert!(decode.start_us + decode.dur_us <= t.e2e_us + 1, "{t:?}");
        // a one-shot never resumes: the skipped stage is absent from
        // the spans, not rendered as a zero-width span
        assert!(coord.span_named("resume").is_none(), "{t:?}");
        let m = h.metrics.snapshot();
        assert_eq!(m.queue_wait.count(), 1);
        assert_eq!(m.prefill_time.count(), 1);
        assert_eq!(m.queue_depth, 0, "queue drained after admission");
        // session turn 1 prefills; turn 2 resumes the stored state and
        // its trace carries "resume" but no "prefill" — the other half
        // of the skipped-stage pin
        let _ = turn(&h, 7, vec![4, 2], 3);
        let _ = turn(&h, 7, vec![6], 3);
        let recent = h.traces.recent();
        assert_eq!(recent.len(), 3);
        let t1 = recent[1].hop("coordinator").unwrap().clone();
        let t2 = recent[2].hop("coordinator").unwrap().clone();
        assert!(t1.span_named("prefill").is_some(), "{t1:?}");
        assert!(t1.span_named("resume").is_none(), "{t1:?}");
        assert!(t2.span_named("resume").is_some(), "{t2:?}");
        assert!(t2.span_named("prefill").is_none(), "{t2:?}");
        h.shutdown();
    }

    /// The sampled-profiling contract: a traced+profiled request's trace
    /// record (keyed by the wire trace id) carries an "engine" hop with
    /// all six hot-path stage spans, the `lh_engine_*` histograms get
    /// one sample per stage, and the response echoes the trace context —
    /// while untraced requests keep empty hop reports on the wire.
    #[test]
    fn traced_profiled_request_reports_engine_stage_spans() {
        let h = handle(2);
        let rx = h
            .submit_traced(None, vec![1, 2, 3], 4, None, None, 0xBEEF, true)
            .unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.trace, 0xBEEF);
        assert_eq!(resp.tokens.len(), 4);
        assert!(!resp.hops.is_empty(), "traced response carries hop reports");
        let t = h.traces.find(0xBEEF).expect("record keyed by trace id");
        assert!(t.ok);
        assert!(t.hop("coordinator").is_some());
        let eng = t.hop("engine").expect("profiled request reports an engine hop");
        for name in ["short_conv", "modal_sweep", "qkv", "out_proj", "mlp", "lm_head"] {
            assert!(eng.span_named(name).is_some(), "missing engine stage {name}");
        }
        assert!(eng.total_us <= t.e2e_us, "engine time within wall time: {t:?}");
        let m = h.metrics.snapshot();
        assert_eq!(m.engine_profiled, 1);
        for hist in &m.engine_stages {
            assert_eq!(hist.count(), 1);
        }
        // an unprofiled follow-up reuses the slot without inheriting the
        // profiling flag, and untraced responses stay hop-free
        let resp2 = h
            .submit(vec![1, 2], 2)
            .unwrap()
            .recv_timeout(Duration::from_secs(30))
            .unwrap();
        assert_eq!(resp2.trace, 0);
        assert!(resp2.hops.is_empty());
        assert_eq!(h.metrics.snapshot().engine_profiled, 1);
        h.shutdown();
    }

    #[test]
    fn serves_more_requests_than_slots() {
        let h = handle(2);
        let rxs: Vec<_> = (0..6).map(|i| h.submit(vec![1 + i, 2, 3], 4).unwrap()).collect();
        let mut ids = vec![];
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(r.tokens.len(), 4);
            ids.push(r.id);
        }
        ids.sort();
        assert_eq!(ids, (0..6).collect::<Vec<_>>());
        let m = h.metrics.snapshot();
        assert_eq!(m.requests_done, 6);
        assert_eq!(m.tokens_generated as usize + m.prefills as usize, 6 * 4);
        h.shutdown();
    }

    #[test]
    fn identical_prompts_get_identical_tokens_regardless_of_batching() {
        // continuous batching must not leak state across slots
        let h = handle(3);
        let a = h
            .submit(vec![5, 6, 7], 6)
            .unwrap()
            .recv_timeout(Duration::from_secs(30))
            .unwrap();
        // now saturate and resubmit the same prompt
        let rxs: Vec<_> = (0..5).map(|_| h.submit(vec![5, 6, 7], 6).unwrap()).collect();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(r.tokens, a.tokens, "determinism across batch layouts");
        }
        h.shutdown();
    }

    #[test]
    fn submit_returns_err_when_engine_thread_is_gone() {
        // an engine whose construction panics kills the thread; submit must
        // surface CoordinatorClosed instead of panicking the caller
        let h = spawn(|| panic!("engine construction failed (test)"), cfg(1));
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            match h.submit(vec![1, 2], 2) {
                Err(CoordinatorClosed) => break,
                Ok(_) => {
                    assert!(Instant::now() < deadline, "submit kept succeeding");
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        }
        assert!(h.submit_in_session(1, vec![1], 1).is_err());
        // shutdown of a dead coordinator must not panic either
        h.shutdown();
    }

    /// Drive one session turn to completion.
    fn turn(h: &CoordinatorHandle, sid: u64, delta: Vec<i32>, max_new: usize) -> Vec<i32> {
        h.submit_in_session(sid, delta, max_new)
            .unwrap()
            .recv_timeout(Duration::from_secs(60))
            .unwrap()
            .tokens
    }

    /// The acceptance invariant: a conversation split across 3+ session
    /// turns — with eviction pressure forcing a spill/restore cycle — must
    /// produce bit-identical tokens to the same transcript generated in
    /// single uninterrupted requests.
    #[test]
    fn session_turns_bit_identical_to_uninterrupted_with_spill_cycle() {
        // budget fits exactly ONE nano session state, so interleaving two
        // sessions forces every stored state through disk
        let shape = LmShape::bench("nano").unwrap();
        let one_state = RecurrentEngine::new(&shape, 1, 11).snapshot_row(0).state_bytes();
        let spill = std::env::temp_dir()
            .join(format!("lh_sess_spill_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&spill);
        let h = handle_cfg(
            2,
            ServeConfig {
                session_budget: one_state,
                session_spill_dir: Some(spill.to_string_lossy().into_owned()),
                ..cfg(2)
            },
        );
        let (d1, d2, d3) = (vec![3, 1, 4, 1, 5], vec![9, 2, 6], vec![5, 3, 5]);
        let (n1, n2, n3) = (4usize, 3usize, 5usize);
        // session A turn 1, then session B turn 1 (evicts A's state to disk)
        let g1 = turn(&h, 0xA, d1.clone(), n1);
        assert_eq!(g1.len(), n1);
        let _other = turn(&h, 0xB, vec![7, 7, 7, 7, 7, 7], 4);
        // A turn 2 restores from disk; B's state now takes the RAM slot
        let g2 = turn(&h, 0xA, d2.clone(), n2);
        let _other = turn(&h, 0xB, vec![8, 8], 3);
        let g3 = turn(&h, 0xA, d3.clone(), n3);
        // uninterrupted equivalents over the growing transcript
        let mut t2 = d1.clone();
        t2.extend(&g1);
        t2.extend(&d2);
        let mut t3 = t2.clone();
        t3.extend(&g2);
        t3.extend(&d3);
        let u2 = h
            .submit(t2, n2)
            .unwrap()
            .recv_timeout(Duration::from_secs(60))
            .unwrap()
            .tokens;
        let u3 = h
            .submit(t3, n3)
            .unwrap()
            .recv_timeout(Duration::from_secs(60))
            .unwrap()
            .tokens;
        assert_eq!(g2, u2, "turn 2 != uninterrupted generation");
        assert_eq!(g3, u3, "turn 3 != uninterrupted generation");
        let m = h.metrics.snapshot();
        assert_eq!(m.session_misses, 0, "spill must make eviction lossless");
        assert!(m.session_hits >= 2, "turns 2 and 3 must resume, got {}", m.session_hits);
        assert!(m.session_spills >= 1, "eviction pressure must have spilled");
        assert!(
            m.prefill_tokens_saved as usize >= d1.len() + n1,
            "resume must skip the transcript prefill (saved {})",
            m.prefill_tokens_saved
        );
        h.shutdown();
        let _ = std::fs::remove_dir_all(&spill);
    }

    #[test]
    fn evicted_session_without_spill_reprefills_identically() {
        // zero store budget + no spill: every turn is a miss, and the
        // transcript fallback must still produce the exact same tokens
        let h_sess = handle_cfg(2, ServeConfig { session_budget: 0, ..cfg(2) });
        let h_ref = handle_cfg(
            2,
            ServeConfig { session_budget: 256 << 20, ..cfg(2) },
        );
        let (d1, d2, d3) = (vec![2, 7, 1, 8], vec![2, 8], vec![1, 8, 2, 8]);
        let mut toks_sess = vec![];
        let mut toks_ref = vec![];
        for (d, n) in [(d1, 3usize), (d2, 4), (d3, 3)] {
            toks_sess.push(turn(&h_sess, 5, d.clone(), n));
            toks_ref.push(turn(&h_ref, 5, d, n));
        }
        assert_eq!(toks_sess, toks_ref, "miss fallback changed tokens");
        let m = h_sess.metrics.snapshot();
        assert_eq!(m.session_hits, 0);
        assert_eq!(m.session_misses, 2, "turns 2 and 3 missed");
        assert_eq!(h_ref.metrics.snapshot().session_hits, 2);
        h_sess.shutdown();
        h_ref.shutdown();
    }

    #[test]
    fn pipelined_session_turns_serialize_and_match_awaited() {
        // both turns submitted before either reply is read: the batcher
        // must hold turn 2 back until turn 1 retires, so the result is
        // identical to awaiting each turn
        let h = handle(2);
        let r1 = h.submit_in_session(7, vec![4, 2, 4], 3).unwrap();
        let r2 = h.submit_in_session(7, vec![6, 1], 3).unwrap();
        let g1 = r1.recv_timeout(Duration::from_secs(60)).unwrap().tokens;
        let g2 = r2.recv_timeout(Duration::from_secs(60)).unwrap().tokens;
        let h2 = handle(2);
        let a1 = turn(&h2, 7, vec![4, 2, 4], 3);
        let a2 = turn(&h2, 7, vec![6, 1], 3);
        assert_eq!(g1, a1, "pipelined turn 1 diverged");
        assert_eq!(g2, a2, "pipelined turn 2 resumed a stale transcript");
        assert_eq!(h.metrics.snapshot().session_misses, 0);
        h.shutdown();
        h2.shutdown();
    }

    #[test]
    fn end_session_frees_state_and_transcript() {
        let h = handle(2);
        let g1 = turn(&h, 3, vec![1, 2, 3], 4);
        h.end_session(3).unwrap();
        // channel is FIFO: the End is processed before the next turn
        let g2 = turn(&h, 3, vec![1, 2, 3], 4);
        assert_eq!(g1, g2, "an ended session must behave like a fresh one");
        let m = h.metrics.snapshot();
        assert_eq!(m.session_hits, 0, "turn after end must not resume");
        assert_eq!(m.session_misses, 0, "turn after end is a first turn, not a miss");
        h.shutdown();
    }

    /// Satellite invariant: a strict resume of a session this coordinator
    /// has never seen (or has ended) fails with the *typed*
    /// [`SessionError::Unknown`] — the signal a router uses to distinguish
    /// "migrate me" from "re-prefill from transcript".
    #[test]
    fn strict_resume_refuses_unknown_sessions_with_typed_error() {
        let h = handle(2);
        match h.resume_session(0xDEAD, vec![1, 2], 3) {
            Err(SubmitError::Session(SessionError::Unknown { id })) => {
                assert_eq!(id, 0xDEAD)
            }
            other => panic!("expected typed Unknown, got {other:?}"),
        }
        // a first (non-strict) turn establishes the session...
        let g1 = turn(&h, 0xDEAD, vec![1, 2], 3);
        // ...after which the strict path resumes it and produces exactly
        // the tokens the non-strict path would
        let g2 = h
            .resume_session(0xDEAD, vec![5], 3)
            .unwrap()
            .recv_timeout(Duration::from_secs(60))
            .unwrap()
            .tokens;
        let h2 = handle(2);
        let a1 = turn(&h2, 7, vec![1, 2], 3);
        let a2 = turn(&h2, 7, vec![5], 3);
        assert_eq!(g1, a1);
        assert_eq!(g2, a2, "strict resume diverged from submit_in_session");
        // ending the session makes it unknown again (channel is FIFO, so
        // the End is processed before the resume's existence query)
        h.end_session(0xDEAD).unwrap();
        assert!(matches!(
            h.resume_session(0xDEAD, vec![1], 1),
            Err(SubmitError::Session(SessionError::Unknown { .. }))
        ));
        h.shutdown();
        h2.shutdown();
    }

    /// The migration primitive: export detaches state + transcript from
    /// coordinator A; importing into coordinator B (same engine seed)
    /// continues the conversation bit-identically to never having moved.
    #[test]
    fn exported_session_resumes_bit_identical_after_import() {
        let h_a = handle(2);
        let h_b = handle(2);
        let h_ref = handle(2);
        let (d1, d2, d3) = (vec![3, 1, 4], vec![1, 5, 9], vec![2, 6, 5]);
        let (n1, n2, n3) = (4usize, 3usize, 4usize);
        let g1 = turn(&h_a, 42, d1.clone(), n1);
        let g2 = turn(&h_a, 42, d2.clone(), n2);
        let r1 = turn(&h_ref, 42, d1.clone(), n1);
        let r2 = turn(&h_ref, 42, d2.clone(), n2);
        assert_eq!(g1, r1);
        assert_eq!(g2, r2);
        // move the session A -> B
        let export = h_a.export_session(42).unwrap().expect("session known");
        assert!(
            !h_a.session_known(42).unwrap(),
            "export must remove every local trace"
        );
        assert!(
            h_a.export_session(42).unwrap().is_none(),
            "a session can only be exported once"
        );
        assert!(export.state.is_some(), "recurrent engine snapshots O(1) state");
        let mut want_transcript = d1.clone();
        want_transcript.extend(&g1);
        want_transcript.extend(&d2);
        want_transcript.extend(&g2);
        assert_eq!(export.transcript, want_transcript);
        h_b.import_session(42, export).unwrap();
        assert!(h_b.session_known(42).unwrap());
        let g3 = turn(&h_b, 42, d3.clone(), n3);
        let r3 = turn(&h_ref, 42, d3, n3);
        assert_eq!(g3, r3, "migrated turn 3 diverged from uninterrupted run");
        let m = h_b.metrics.snapshot();
        assert!(m.session_hits >= 1, "imported turn must resume, not re-prefill");
        assert_eq!(m.session_misses, 0);
        h_a.shutdown();
        h_b.shutdown();
        h_ref.shutdown();
    }

    /// Export of a session with a turn still in flight must defer until
    /// the turn retires, so the blob always carries the full conversation.
    #[test]
    fn export_defers_until_session_quiesces() {
        let h = handle(2);
        let rx = h.submit_in_session(9, vec![1, 2, 3], 6).unwrap();
        // FIFO channel: the export arrives behind the turn, blocks until
        // it retires, and then reflects it
        let export = h.export_session(9).unwrap().expect("session exists");
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(resp.tokens.len(), 6);
        let mut want = vec![1, 2, 3];
        want.extend(&resp.tokens);
        assert_eq!(export.transcript, want, "export saw a partial conversation");
        assert!(!h.session_known(9).unwrap());
        h.shutdown();
    }

    /// The streaming contract: the per-token stream yields exactly the
    /// buffered `GenResponse.tokens`, in order, and ends (sender dropped)
    /// at retire.
    #[test]
    fn streamed_tokens_equal_buffered_response() {
        let h = handle(2);
        let (tok_rx, rx) = h.submit_streaming(vec![4, 2, 4], 6).unwrap();
        let streamed: Vec<i32> = tok_rx.iter().collect();
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(streamed, resp.tokens, "stream diverged from buffered reply");
        assert_eq!(streamed.len(), 6);
        // session variant, across two turns
        let (t1, r1) = h.submit_in_session_streaming(3, vec![1, 2], 4).unwrap();
        let s1: Vec<i32> = t1.iter().collect();
        assert_eq!(s1, r1.recv_timeout(Duration::from_secs(60)).unwrap().tokens);
        let (t2, r2) = h.resume_session_streaming(3, vec![5], 3).unwrap();
        let s2: Vec<i32> = t2.iter().collect();
        assert_eq!(s2, r2.recv_timeout(Duration::from_secs(60)).unwrap().tokens);
        // the streamed turns match a non-streamed coordinator exactly
        let h2 = handle(2);
        assert_eq!(s1, turn(&h2, 3, vec![1, 2], 4));
        assert_eq!(s2, turn(&h2, 3, vec![5], 3));
        h.shutdown();
        h2.shutdown();
    }

    /// A consumer abandoning the token stream must not stall or cancel the
    /// generation (session snapshots depend on the turn completing).
    #[test]
    fn dropped_stream_receiver_does_not_cancel_generation() {
        let h = handle(2);
        let (tok_rx, rx) = h.submit_in_session_streaming(9, vec![1, 2, 3], 5).unwrap();
        drop(tok_rx);
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(resp.tokens.len(), 5);
        // the session is intact and resumable
        assert!(h.session_known(9).unwrap());
        assert_eq!(turn(&h, 9, vec![4], 3).len(), 3);
        h.shutdown();
    }

    /// `transcript_of` defers until the in-flight turn retires and then
    /// reflects the complete conversation — without detaching the session.
    #[test]
    fn transcript_read_defers_until_quiescent_and_is_non_destructive() {
        let h = handle(2);
        assert_eq!(h.transcript_of(4).unwrap(), None, "unknown session");
        let rx = h.submit_in_session(4, vec![1, 2, 3], 5).unwrap();
        // FIFO channel: the read arrives behind the turn and must wait
        let transcript = h.transcript_of(4).unwrap().expect("session exists");
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        let mut want = vec![1, 2, 3];
        want.extend(&resp.tokens);
        assert_eq!(transcript, want, "transcript read saw a partial turn");
        // non-destructive: the session still resumes afterwards
        assert!(h.session_known(4).unwrap());
        let g2 = turn(&h, 4, vec![9], 3);
        let mut want2 = want;
        want2.push(9);
        want2.extend(&g2);
        assert_eq!(h.transcript_of(4).unwrap().unwrap(), want2);
        assert_eq!(h.metrics.snapshot().session_misses, 0);
        h.shutdown();
    }

    #[test]
    fn concurrent_sessions_do_not_cross_contaminate() {
        // two sessions with identical transcripts, interleaved with noise:
        // both must see identical tokens at every turn
        let h = handle(3);
        let mut a = vec![];
        let mut b = vec![];
        for i in 0..3 {
            let delta = vec![4 + i, 2, 9];
            let ra = h.submit_in_session(100, delta.clone(), 4).unwrap();
            let noise = h.submit(vec![13, 13, 13], 6).unwrap();
            let rb = h.submit_in_session(200, delta, 4).unwrap();
            a.push(ra.recv_timeout(Duration::from_secs(60)).unwrap().tokens);
            b.push(rb.recv_timeout(Duration::from_secs(60)).unwrap().tokens);
            let _ = noise.recv_timeout(Duration::from_secs(60)).unwrap();
        }
        assert_eq!(a, b, "sessions with equal transcripts diverged");
        h.shutdown();
    }

    /// The TTL acceptance invariant: an idle session past its TTL holds
    /// *zero* coordinator RAM — transcript, stored state, and spill index
    /// all gone — proven by the fixed-size census, and a later turn under
    /// the same id behaves exactly like a fresh session.
    #[test]
    fn ttl_sweep_frees_idle_session_to_zero_ram() {
        let h = handle_cfg(2, ServeConfig { session_ttl_ms: 50, ..cfg(2) });
        let g1 = turn(&h, 5, vec![1, 2, 3], 4);
        let c = h.session_census().unwrap();
        assert_eq!(c.transcripts, 1);
        assert!(c.transcript_tokens >= 7, "prompt + generated held: {c:?}");
        assert!(c.resident_states == 1 && c.resident_bytes > 0, "{c:?}");
        // wait out TTL + sweep cadence
        let deadline = Instant::now() + Duration::from_secs(10);
        while h.session_known(5).unwrap() {
            assert!(Instant::now() < deadline, "TTL sweep never fired");
            std::thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(
            h.session_census().unwrap(),
            SessionCensus::default(),
            "an idle session past its TTL must cost zero RAM"
        );
        assert!(h.metrics.snapshot().session_ttl_evictions >= 1);
        // the id is usable again, as a brand-new conversation
        let g2 = turn(&h, 5, vec![1, 2, 3], 4);
        assert_eq!(g1, g2, "post-TTL turn must equal a fresh first turn");
        h.shutdown();
    }

    /// Satellite edge case: a TTL shorter than a turn must not fire
    /// mid-conversation — eviction defers while any turn of the session
    /// is queued or in flight, then reaps once quiescent.
    #[test]
    fn ttl_defers_mid_turn_until_quiescent() {
        let h = handle_cfg(2, ServeConfig { session_ttl_ms: 1, ..cfg(2) });
        // two pipelined turns: the session stays in flight continuously
        // (turn 2 queued until turn 1 retires), spanning many TTL periods
        let r1 = h.submit_in_session(9, vec![1, 2], 6).unwrap();
        let r2 = h.submit_in_session(9, vec![3], 6).unwrap();
        let g1 = r1.recv_timeout(Duration::from_secs(60)).unwrap().tokens;
        let g2 = r2.recv_timeout(Duration::from_secs(60)).unwrap().tokens;
        let h_ref = handle(2);
        assert_eq!(g1, turn(&h_ref, 9, vec![1, 2], 6));
        assert_eq!(
            g2,
            turn(&h_ref, 9, vec![3], 6),
            "TTL fired mid-conversation: turn 2 lost turn 1's transcript"
        );
        // once quiescent, the sweep reaps it down to zero
        let deadline = Instant::now() + Duration::from_secs(10);
        while h.session_known(9).unwrap() {
            assert!(Instant::now() < deadline, "TTL sweep never fired");
            std::thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(h.session_census().unwrap(), SessionCensus::default());
        h.shutdown();
        h_ref.shutdown();
    }

    /// Queued work whose deadline budget ran out is shed with a typed
    /// `DeadlineExceeded` refusal — never served late, never hung.
    #[test]
    fn expired_deadline_sheds_queued_work_with_typed_refusal() {
        let h = handle_cfg(1, ServeConfig { max_batch: 1, ..cfg(1) });
        // pin the only slot (streaming first token proves it's admitted)
        let (tok_rx, busy_rx) = h.submit_streaming(vec![1, 2, 3], 64).unwrap();
        let _ = tok_rx.recv_timeout(Duration::from_secs(30)).unwrap();
        // this request's budget is already gone; it can only wait in queue
        let rx = h
            .submit_full(None, vec![4, 5], 4, None, Some(Instant::now()))
            .unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.refusal, Some(Refusal::DeadlineExceeded));
        assert!(resp.tokens.is_empty(), "a refused turn must not generate");
        assert_eq!(h.metrics.snapshot().shed_deadline, 1);
        let busy = busy_rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(busy.tokens.len(), 64, "in-slot work is never shed");
        // an ample budget is honored end-to-end
        let rx = h
            .submit_full(
                None,
                vec![4, 5],
                4,
                None,
                Some(Instant::now() + Duration::from_secs(600)),
            )
            .unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(resp.refusal, None);
        assert_eq!(resp.tokens.len(), 4);
        h.shutdown();
    }

    /// With a queue cap, arrivals past capacity get a typed `Overloaded`
    /// refusal at the door; everything accepted still completes.
    #[test]
    fn queue_cap_refuses_overflow_with_typed_overloaded() {
        let h = handle_cfg(
            1,
            ServeConfig { max_batch: 1, max_queue: 1, ..cfg(1) },
        );
        let (tok_rx, busy_rx) = h.submit_streaming(vec![1, 2, 3], 64).unwrap();
        let _ = tok_rx.recv_timeout(Duration::from_secs(30)).unwrap();
        let queued_rx = h.submit(vec![9], 2).unwrap(); // fills the queue
        let refused_rx = h.submit(vec![8], 2).unwrap(); // over capacity
        let refused = refused_rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(refused.refusal, Some(Refusal::Overloaded));
        assert!(refused.tokens.is_empty());
        assert_eq!(h.metrics.snapshot().shed_overload, 1);
        // accepted work is unaffected by the refusal
        assert_eq!(busy_rx.recv_timeout(Duration::from_secs(60)).unwrap().tokens.len(), 64);
        let queued = queued_rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(queued.refusal, None);
        assert_eq!(queued.tokens.len(), 2);
        h.shutdown();
    }
}
