//! Admission queue + slot allocator: the continuous-batching core.
//!
//! The engine exposes `B` fixed slots (the AOT artifacts have a fixed batch
//! dimension).  Sequences occupy a slot from prefill until their token
//! budget is exhausted; freed slots are immediately refilled from the
//! queue.  A memory ledger guards admission so the coordinator reproduces
//! the paper's peak-batch behaviour under a byte budget.

use std::collections::VecDeque;

use super::request::GenRequest;

/// State of one engine slot.
pub enum Slot {
    Free,
    Busy {
        req: GenRequest,
        generated: Vec<i32>,
        /// Set when the first token was produced (for TTFT).
        first_token_s: Option<f64>,
    },
}

impl Slot {
    pub fn is_free(&self) -> bool {
        matches!(self, Slot::Free)
    }

    /// Session id of the occupying request, if any.
    pub fn session(&self) -> Option<u64> {
        match self {
            Slot::Free => None,
            Slot::Busy { req, .. } => req.session,
        }
    }
}

/// FIFO admission queue with a memory ledger.
pub struct Batcher {
    pub queue: VecDeque<GenRequest>,
    pub slots: Vec<Slot>,
    /// Bytes of generation state one sequence costs (constant for the
    /// recurrent engine — the whole point of the paper).
    pub bytes_per_seq: u64,
    pub mem_budget: u64,
    pub mem_used: u64,
}

impl Batcher {
    pub fn new(n_slots: usize, bytes_per_seq: u64, mem_budget: u64) -> Batcher {
        Batcher {
            queue: VecDeque::new(),
            slots: (0..n_slots).map(|_| Slot::Free).collect(),
            bytes_per_seq,
            mem_budget,
            mem_used: 0,
        }
    }

    pub fn enqueue(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    /// Remove every queued request whose admission deadline has passed and
    /// hand them back for typed refusal — run before each admission round
    /// so overload sheds stale work instead of serving it late.  Requests
    /// already in a slot are never shed (accepted work runs to
    /// completion); relative queue order of survivors is preserved.
    pub fn shed_expired(&mut self, now: std::time::Instant) -> Vec<GenRequest> {
        let mut shed = Vec::new();
        let mut kept = VecDeque::with_capacity(self.queue.len());
        for req in self.queue.drain(..) {
            match req.deadline {
                Some(d) if d <= now => shed.push(req),
                _ => kept.push_back(req),
            }
        }
        self.queue = kept;
        shed
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn busy_slots(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&i| !self.slots[i].is_free()).collect()
    }

    pub fn free_slots(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&i| self.slots[i].is_free()).collect()
    }

    /// Admit queued requests into free slots, respecting the memory budget.
    /// Returns (slot, prompt) pairs that need prefilling.
    ///
    /// Turns of one session must serialize: a request whose session id is
    /// already occupying a slot stays queued (a pipelined second turn would
    /// otherwise resume from a transcript missing the first turn's output).
    /// Such a held-back request does not head-of-line block the rest of the
    /// queue; everything else drains strictly FIFO.
    pub fn admit(&mut self) -> Vec<(usize, Vec<i32>)> {
        let mut admitted = vec![];
        for slot_idx in self.free_slots() {
            if self.ledger_blocked() {
                break; // ledger full: leave requests queued
            }
            let pos = self.queue.iter().position(|r| self.admissible(r));
            let req = match pos.and_then(|p| self.queue.remove(p)) {
                Some(req) => req,
                None => break, // nothing admissible right now
            };
            let prompt = req.prompt.clone();
            self.slots[slot_idx] =
                Slot::Busy { req, generated: vec![], first_token_s: None };
            self.mem_used += self.bytes_per_seq;
            admitted.push((slot_idx, prompt));
        }
        admitted
    }

    /// Whether the byte ledger refuses another sequence.  One sequence is
    /// always allowed through an empty ledger (minimum progress) — a
    /// `bytes_per_seq` larger than the whole budget must not hang every
    /// request forever.  Shared by [`Batcher::admit`] and
    /// [`Batcher::has_admissible`].
    fn ledger_blocked(&self) -> bool {
        self.mem_used + self.bytes_per_seq > self.mem_budget && self.mem_used > 0
    }

    /// Whether a request may enter a slot right now: turns of a session
    /// already occupying a slot must wait for it to retire.  The single
    /// predicate behind both [`Batcher::admit`] and
    /// [`Batcher::has_admissible`].
    fn admissible(&self, r: &GenRequest) -> bool {
        match r.session {
            None => true,
            Some(id) => !self.slots.iter().any(|s| s.session() == Some(id)),
        }
    }

    /// Whether any queued request could enter a free slot right now — the
    /// server lingers for batch formation only while this holds (a queue of
    /// ledger-blocked or held-back session turns must not stall decoding).
    pub fn has_admissible(&self) -> bool {
        if self.free_slots().is_empty() || self.ledger_blocked() {
            return false;
        }
        self.queue.iter().any(|r| self.admissible(r))
    }

    /// Release a slot and return its request + generated tokens.
    pub fn release(&mut self, slot_idx: usize) -> Option<(GenRequest, Vec<i32>, Option<f64>)> {
        let slot = std::mem::replace(&mut self.slots[slot_idx], Slot::Free);
        match slot {
            Slot::Free => None,
            Slot::Busy { req, generated, first_token_s } => {
                self.mem_used = self.mem_used.saturating_sub(self.bytes_per_seq);
                Some((req, generated, first_token_s))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    fn req(id: u64, len: usize) -> (GenRequest, std::sync::mpsc::Receiver<super::super::request::GenResponse>) {
        let (tx, rx) = channel();
        (
            GenRequest {
                id,
                prompt: vec![1; len],
                max_new_tokens: 4,
                session: None,
                reply: tx,
                stream: None,
                enqueued: Instant::now(),
                deadline: None,
            },
            rx,
        )
    }

    #[test]
    fn admits_up_to_slot_count() {
        let mut b = Batcher::new(2, 100, 10_000);
        let mut rxs = vec![];
        for i in 0..5 {
            let (r, rx) = req(i, 4);
            b.enqueue(r);
            rxs.push(rx);
        }
        let admitted = b.admit();
        assert_eq!(admitted.len(), 2);
        assert_eq!(b.queue_len(), 3);
        assert_eq!(b.busy_slots().len(), 2);
        // releasing frees capacity
        b.release(admitted[0].0).unwrap();
        let more = b.admit();
        assert_eq!(more.len(), 1);
    }

    #[test]
    fn memory_ledger_blocks_admission() {
        let mut b = Batcher::new(4, 600, 1000); // only one sequence fits
        let mut rxs = vec![];
        for i in 0..3 {
            let (r, rx) = req(i, 4);
            b.enqueue(r);
            rxs.push(rx);
        }
        assert_eq!(b.admit().len(), 1);
        assert_eq!(b.mem_used, 600);
        assert_eq!(b.queue_len(), 2);
        // free it -> next can come in
        let busy = b.busy_slots();
        b.release(busy[0]);
        assert_eq!(b.mem_used, 0);
        assert_eq!(b.admit().len(), 1);
    }

    #[test]
    fn ledger_invariant_under_random_ops() {
        // property: mem_used == busy_slots * bytes_per_seq, always
        check("ledger invariant", 16, |rng| {
            let mut b = Batcher::new(4, 50, 175); // max 3 concurrent
            let mut rxs = vec![];
            let mut next_id = 0u64;
            for _ in 0..40 {
                if rng.uniform() < 0.6 {
                    let (r, rx) = req(next_id, 2);
                    next_id += 1;
                    b.enqueue(r);
                    rxs.push(rx);
                    b.admit();
                } else {
                    let busy = b.busy_slots();
                    if !busy.is_empty() {
                        let k = busy[rng.below(busy.len())];
                        b.release(k);
                        b.admit();
                    }
                }
                let want = b.busy_slots().len() as u64 * 50;
                if b.mem_used != want {
                    return Err(format!("ledger {} != busy {}", b.mem_used, want));
                }
                if b.mem_used > 175 {
                    return Err("budget exceeded".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn release_free_slot_is_none() {
        let mut b = Batcher::new(1, 10, 100);
        assert!(b.release(0).is_none());
    }

    #[test]
    fn admission_blocked_exactly_at_byte_budget() {
        // budget holds exactly two sequences; a third must stay queued even
        // though a slot is free
        let mut b = Batcher::new(3, 500, 1000);
        let mut rxs = vec![];
        for i in 0..3 {
            let (r, rx) = req(i, 2);
            b.enqueue(r);
            rxs.push(rx);
        }
        let admitted = b.admit();
        assert_eq!(admitted.len(), 2);
        assert_eq!(b.mem_used, 1000);
        assert_eq!(b.queue_len(), 1);
        assert_eq!(b.free_slots().len(), 1, "slot free but ledger full");
        // re-admit without releasing: still blocked
        assert!(b.admit().is_empty());
    }

    #[test]
    fn mem_used_returns_to_zero_after_full_release() {
        let mut b = Batcher::new(4, 250, 1000);
        let mut rxs = vec![];
        for i in 0..4 {
            let (r, rx) = req(i, 2);
            b.enqueue(r);
            rxs.push(rx);
        }
        assert_eq!(b.admit().len(), 4);
        assert_eq!(b.mem_used, 1000);
        for slot in b.busy_slots() {
            b.release(slot);
        }
        assert_eq!(b.mem_used, 0);
        assert!(b.busy_slots().is_empty());
    }

    #[test]
    fn queue_order_preserved_under_partial_admission() {
        // five requests, two slots: admission must drain strictly FIFO
        // across several partial admission rounds
        let mut b = Batcher::new(2, 100, 10_000);
        let mut rxs = vec![];
        for i in 0..5 {
            let (r, rx) = req(i, 2);
            b.enqueue(r);
            rxs.push(rx);
        }
        let mut admitted_ids = vec![];
        loop {
            let round = b.admit();
            if round.is_empty() && b.queue_len() == 0 {
                break;
            }
            for (slot, _) in &round {
                if let Slot::Busy { req, .. } = &b.slots[*slot] {
                    admitted_ids.push(req.id);
                }
            }
            for (slot, _) in &round {
                b.release(*slot);
            }
        }
        assert_eq!(admitted_ids, vec![0, 1, 2, 3, 4], "FIFO order broken");
    }

    #[test]
    fn oversized_sequence_still_makes_progress_one_at_a_time() {
        // bytes_per_seq larger than the whole budget must not deadlock:
        // exactly one sequence runs at a time
        let mut b = Batcher::new(2, 5000, 1000);
        let mut rxs = vec![];
        for i in 0..2 {
            let (r, rx) = req(i, 2);
            b.enqueue(r);
            rxs.push(rx);
        }
        assert!(b.has_admissible());
        assert_eq!(b.admit().len(), 1, "minimum-progress admission");
        assert!(!b.has_admissible(), "second must wait for the first");
        assert!(b.admit().is_empty());
        let slot = b.busy_slots()[0];
        b.release(slot);
        assert_eq!(b.admit().len(), 1);
    }

    #[test]
    fn same_session_turns_serialize_without_blocking_others() {
        // two queued turns of session 9 + one one-shot, three free slots:
        // only the first turn of 9 may enter; the one-shot must not be
        // head-of-line blocked behind the held-back second turn
        let mut b = Batcher::new(3, 10, 1000);
        for (i, sess) in [(0u64, Some(9)), (1, Some(9)), (2, None)] {
            let (mut r, _rx) = req(i, 2);
            r.session = sess;
            b.enqueue(r);
        }
        let admitted = b.admit();
        assert_eq!(admitted.len(), 2, "turn 1 of session 9 + the one-shot");
        assert_eq!(b.queue_len(), 1, "turn 2 of session 9 held back");
        let sessions: Vec<_> =
            b.busy_slots().iter().map(|&s| b.slots[s].session()).collect();
        assert_eq!(sessions.iter().filter(|s| **s == Some(9)).count(), 1);
        // retire session 9's first turn -> its second turn becomes admissible
        let slot9 = b
            .busy_slots()
            .into_iter()
            .find(|&s| b.slots[s].session() == Some(9))
            .unwrap();
        b.release(slot9);
        let next = b.admit();
        assert_eq!(next.len(), 1);
        assert_eq!(b.queue_len(), 0);
    }

    #[test]
    fn shed_expired_drops_only_stale_queued_work_preserving_order() {
        use std::time::Duration;
        let mut b = Batcher::new(1, 10, 1000);
        let now = Instant::now();
        // occupy the only slot with an expired-deadline request: admitted
        // work is never shed
        let (mut r, _rx0) = req(0, 2);
        r.deadline = Some(now - Duration::from_millis(1));
        b.enqueue(r);
        assert_eq!(b.admit().len(), 1);
        // queue: expired(1), live(2), no-deadline(3), expired(4)
        let mut rxs = vec![];
        for (id, dl) in [
            (1u64, Some(now - Duration::from_millis(1))),
            (2, Some(now + Duration::from_secs(3600))),
            (3, None),
            (4, Some(now)),
        ] {
            let (mut r, rx) = req(id, 2);
            r.deadline = dl;
            b.enqueue(r);
            rxs.push(rx);
        }
        let shed = b.shed_expired(now);
        let shed_ids: Vec<u64> = shed.iter().map(|r| r.id).collect();
        assert_eq!(shed_ids, vec![1, 4], "exactly the expired queued requests");
        let kept: Vec<u64> = b.queue.iter().map(|r| r.id).collect();
        assert_eq!(kept, vec![2, 3], "survivors keep their order");
        assert_eq!(b.busy_slots().len(), 1, "in-slot request untouched");
        assert!(b.shed_expired(now).is_empty(), "idempotent once drained");
    }

    #[test]
    fn slot_session_accessor() {
        let (mut r, _rx) = req(1, 2);
        r.session = Some(77);
        let mut b = Batcher::new(1, 10, 100);
        assert_eq!(b.slots[0].session(), None);
        b.enqueue(r);
        b.admit();
        assert_eq!(b.slots[0].session(), Some(77));
    }
}
