//! Admission queue + slot allocator: the continuous-batching core.
//!
//! The engine exposes `B` fixed slots (the AOT artifacts have a fixed batch
//! dimension).  Sequences occupy a slot from prefill until their token
//! budget is exhausted; freed slots are immediately refilled from the
//! queue.  A memory ledger guards admission so the coordinator reproduces
//! the paper's peak-batch behaviour under a byte budget.

use std::collections::VecDeque;

use super::request::GenRequest;

/// State of one engine slot.
pub enum Slot {
    Free,
    Busy {
        req: GenRequest,
        generated: Vec<i32>,
        /// Set when the first token was produced (for TTFT).
        first_token_s: Option<f64>,
    },
}

impl Slot {
    pub fn is_free(&self) -> bool {
        matches!(self, Slot::Free)
    }
}

/// FIFO admission queue with a memory ledger.
pub struct Batcher {
    pub queue: VecDeque<GenRequest>,
    pub slots: Vec<Slot>,
    /// Bytes of generation state one sequence costs (constant for the
    /// recurrent engine — the whole point of the paper).
    pub bytes_per_seq: u64,
    pub mem_budget: u64,
    pub mem_used: u64,
}

impl Batcher {
    pub fn new(n_slots: usize, bytes_per_seq: u64, mem_budget: u64) -> Batcher {
        Batcher {
            queue: VecDeque::new(),
            slots: (0..n_slots).map(|_| Slot::Free).collect(),
            bytes_per_seq,
            mem_budget,
            mem_used: 0,
        }
    }

    pub fn enqueue(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn busy_slots(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&i| !self.slots[i].is_free()).collect()
    }

    pub fn free_slots(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&i| self.slots[i].is_free()).collect()
    }

    /// Admit queued requests into free slots, respecting the memory budget.
    /// Returns (slot, prompt) pairs that need prefilling.
    pub fn admit(&mut self) -> Vec<(usize, Vec<i32>)> {
        let mut admitted = vec![];
        for slot_idx in self.free_slots() {
            if self.queue.is_empty() {
                break;
            }
            if self.mem_used + self.bytes_per_seq > self.mem_budget {
                break; // ledger full: leave requests queued
            }
            let req = self.queue.pop_front().unwrap();
            let prompt = req.prompt.clone();
            self.slots[slot_idx] =
                Slot::Busy { req, generated: vec![], first_token_s: None };
            self.mem_used += self.bytes_per_seq;
            admitted.push((slot_idx, prompt));
        }
        admitted
    }

    /// Release a slot and return its request + generated tokens.
    pub fn release(&mut self, slot_idx: usize) -> Option<(GenRequest, Vec<i32>, Option<f64>)> {
        let slot = std::mem::replace(&mut self.slots[slot_idx], Slot::Free);
        match slot {
            Slot::Free => None,
            Slot::Busy { req, generated, first_token_s } => {
                self.mem_used = self.mem_used.saturating_sub(self.bytes_per_seq);
                Some((req, generated, first_token_s))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    fn req(id: u64, len: usize) -> (GenRequest, std::sync::mpsc::Receiver<super::super::request::GenResponse>) {
        let (tx, rx) = channel();
        (
            GenRequest {
                id,
                prompt: vec![1; len],
                max_new_tokens: 4,
                reply: tx,
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn admits_up_to_slot_count() {
        let mut b = Batcher::new(2, 100, 10_000);
        let mut rxs = vec![];
        for i in 0..5 {
            let (r, rx) = req(i, 4);
            b.enqueue(r);
            rxs.push(rx);
        }
        let admitted = b.admit();
        assert_eq!(admitted.len(), 2);
        assert_eq!(b.queue_len(), 3);
        assert_eq!(b.busy_slots().len(), 2);
        // releasing frees capacity
        b.release(admitted[0].0).unwrap();
        let more = b.admit();
        assert_eq!(more.len(), 1);
    }

    #[test]
    fn memory_ledger_blocks_admission() {
        let mut b = Batcher::new(4, 600, 1000); // only one sequence fits
        let mut rxs = vec![];
        for i in 0..3 {
            let (r, rx) = req(i, 4);
            b.enqueue(r);
            rxs.push(rx);
        }
        assert_eq!(b.admit().len(), 1);
        assert_eq!(b.mem_used, 600);
        assert_eq!(b.queue_len(), 2);
        // free it -> next can come in
        let busy = b.busy_slots();
        b.release(busy[0]);
        assert_eq!(b.mem_used, 0);
        assert_eq!(b.admit().len(), 1);
    }

    #[test]
    fn ledger_invariant_under_random_ops() {
        // property: mem_used == busy_slots * bytes_per_seq, always
        check("ledger invariant", 16, |rng| {
            let mut b = Batcher::new(4, 50, 175); // max 3 concurrent
            let mut rxs = vec![];
            let mut next_id = 0u64;
            for _ in 0..40 {
                if rng.uniform() < 0.6 {
                    let (r, rx) = req(next_id, 2);
                    next_id += 1;
                    b.enqueue(r);
                    rxs.push(rx);
                    b.admit();
                } else {
                    let busy = b.busy_slots();
                    if !busy.is_empty() {
                        let k = busy[rng.below(busy.len())];
                        b.release(k);
                        b.admit();
                    }
                }
                let want = b.busy_slots().len() as u64 * 50;
                if b.mem_used != want {
                    return Err(format!("ledger {} != busy {}", b.mem_used, want));
                }
                if b.mem_used > 175 {
                    return Err("budget exceeded".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn release_free_slot_is_none() {
        let mut b = Batcher::new(1, 10, 100);
        assert!(b.release(0).is_none());
    }
}
