//! Complex double-precision arithmetic (`num-complex` is not in the offline
//! crate set; poles/residues of the modal form are inherently complex).

use std::ops::{Add, AddAssign, Div, Mul, MulAssign, Neg, Sub, SubAssign};

/// Complex number with `f64` components.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    pub const ZERO: C64 = C64 { re: 0.0, im: 0.0 };
    pub const ONE: C64 = C64 { re: 1.0, im: 0.0 };
    pub const I: C64 = C64 { re: 0.0, im: 1.0 };

    #[inline]
    pub fn new(re: f64, im: f64) -> Self {
        C64 { re, im }
    }

    #[inline]
    pub fn real(re: f64) -> Self {
        C64 { re, im: 0.0 }
    }

    /// From polar form `r e^{i theta}`.
    #[inline]
    pub fn polar(r: f64, theta: f64) -> Self {
        C64 { re: r * theta.cos(), im: r * theta.sin() }
    }

    #[inline]
    pub fn conj(self) -> Self {
        C64 { re: self.re, im: -self.im }
    }

    #[inline]
    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }

    #[inline]
    pub fn abs2(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    #[inline]
    pub fn arg(self) -> f64 {
        self.im.atan2(self.re)
    }

    #[inline]
    pub fn recip(self) -> Self {
        let d = self.abs2();
        C64 { re: self.re / d, im: -self.im / d }
    }

    /// Complex exponential.
    pub fn exp(self) -> Self {
        let r = self.re.exp();
        C64 { re: r * self.im.cos(), im: r * self.im.sin() }
    }

    /// Principal natural log.
    pub fn ln(self) -> Self {
        C64 { re: self.abs().ln(), im: self.arg() }
    }

    /// Principal square root.
    pub fn sqrt(self) -> Self {
        let r = self.abs();
        let t = self.arg() / 2.0;
        C64::polar(r.sqrt(), t)
    }

    /// Integer power by repeated squaring.
    pub fn powi(self, mut n: u64) -> Self {
        let mut base = self;
        let mut acc = C64::ONE;
        while n > 0 {
            if n & 1 == 1 {
                acc = acc * base;
            }
            base = base * base;
            n >>= 1;
        }
        acc
    }

    pub fn is_finite(self) -> bool {
        self.re.is_finite() && self.im.is_finite()
    }

    /// Scale by a real factor.
    #[inline]
    pub fn scale(self, s: f64) -> Self {
        C64 { re: self.re * s, im: self.im * s }
    }
}

impl Add for C64 {
    type Output = C64;
    #[inline]
    fn add(self, o: C64) -> C64 {
        C64 { re: self.re + o.re, im: self.im + o.im }
    }
}

impl Sub for C64 {
    type Output = C64;
    #[inline]
    fn sub(self, o: C64) -> C64 {
        C64 { re: self.re - o.re, im: self.im - o.im }
    }
}

impl Mul for C64 {
    type Output = C64;
    #[inline]
    fn mul(self, o: C64) -> C64 {
        C64 {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

impl Div for C64 {
    type Output = C64;
    #[inline]
    fn div(self, o: C64) -> C64 {
        self * o.recip()
    }
}

impl Neg for C64 {
    type Output = C64;
    #[inline]
    fn neg(self) -> C64 {
        C64 { re: -self.re, im: -self.im }
    }
}

impl AddAssign for C64 {
    #[inline]
    fn add_assign(&mut self, o: C64) {
        *self = *self + o;
    }
}

impl SubAssign for C64 {
    #[inline]
    fn sub_assign(&mut self, o: C64) {
        *self = *self - o;
    }
}

impl MulAssign for C64 {
    #[inline]
    fn mul_assign(&mut self, o: C64) {
        *self = *self * o;
    }
}

impl From<f64> for C64 {
    fn from(x: f64) -> Self {
        C64::real(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn close(a: C64, b: C64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn field_identities() {
        let z = C64::new(3.0, -4.0);
        assert_eq!(z.abs(), 5.0);
        assert!(close(z * z.recip(), C64::ONE, 1e-12));
        assert!(close(z + (-z), C64::ZERO, 1e-12));
        assert!(close(z / z, C64::ONE, 1e-12));
    }

    #[test]
    fn polar_roundtrip() {
        check("polar roundtrip", 64, |rng| {
            let r = rng.range(0.01, 10.0);
            let th = rng.range(-3.0, 3.0);
            let z = C64::polar(r, th);
            if (z.abs() - r).abs() < 1e-10 && (z.arg() - th).abs() < 1e-10 {
                Ok(())
            } else {
                Err(format!("got ({}, {})", z.abs(), z.arg()))
            }
        });
    }

    #[test]
    fn exp_ln_inverse() {
        check("exp(ln(z)) == z", 64, |rng| {
            let z = C64::new(rng.range(-2.0, 2.0), rng.range(-2.0, 2.0));
            if z.abs() < 1e-3 {
                return Ok(());
            }
            let w = z.ln().exp();
            if close(w, z, 1e-9) {
                Ok(())
            } else {
                Err(format!("{w:?} vs {z:?}"))
            }
        });
    }

    #[test]
    fn powi_matches_repeated_mul() {
        check("powi", 32, |rng| {
            let z = C64::polar(rng.range(0.5, 1.5), rng.range(-3.0, 3.0));
            let n = 1 + rng.below(12) as u64;
            let mut want = C64::ONE;
            for _ in 0..n {
                want = want * z;
            }
            if close(z.powi(n), want, 1e-9 * want.abs().max(1.0)) {
                Ok(())
            } else {
                Err(format!("n={n}"))
            }
        });
    }

    #[test]
    fn sqrt_squares_back() {
        let z = C64::new(-2.0, 0.5);
        let s = z.sqrt();
        assert!(close(s * s, z, 1e-12));
    }
}
