//! Polynomial algebra over C: construction from roots, Horner evaluation,
//! long division, and companion matrices.
//!
//! This is the machinery behind the paper's transfer-function conversions:
//! `poly(eig(A))` for ss→tf (App. A.6), companion realization for tf→ss
//! (App. A.5), and the denominator evaluation of Prop. 3.2's prefill filter.
//! Polynomials are stored low-order-first: p(x) = c[0] + c[1] x + ... .

use super::complex::C64;

/// Multiply two polynomials (coefficient convolution).
pub fn poly_mul(a: &[C64], b: &[C64]) -> Vec<C64> {
    let mut out = vec![C64::ZERO; a.len() + b.len() - 1];
    for (i, &x) in a.iter().enumerate() {
        for (j, &y) in b.iter().enumerate() {
            out[i + j] += x * y;
        }
    }
    out
}

/// Monic polynomial with the given roots: prod (x - r_i).
/// Returns d+1 coefficients, low-order-first, with c[d] == 1.
pub fn poly_from_roots(roots: &[C64]) -> Vec<C64> {
    let mut p = vec![C64::ONE];
    for &r in roots {
        p = poly_mul(&p, &[-r, C64::ONE]);
    }
    p
}

/// Horner evaluation p(x).
pub fn poly_eval(coeffs: &[C64], x: C64) -> C64 {
    let mut acc = C64::ZERO;
    for &c in coeffs.iter().rev() {
        acc = acc * x + c;
    }
    acc
}

/// Derivative coefficients.
pub fn poly_deriv(coeffs: &[C64]) -> Vec<C64> {
    if coeffs.len() <= 1 {
        return vec![C64::ZERO];
    }
    coeffs[1..]
        .iter()
        .enumerate()
        .map(|(i, &c)| c.scale((i + 1) as f64))
        .collect()
}

/// Polynomial long division: returns (quotient, remainder) of a / b.
/// Panics if b is (numerically) zero.
pub fn poly_divmod(a: &[C64], b: &[C64]) -> (Vec<C64>, Vec<C64>) {
    let deg = |p: &[C64]| p.iter().rposition(|c| c.abs() > 1e-300);
    let db = deg(b).expect("division by zero polynomial");
    let mut rem: Vec<C64> = a.to_vec();
    let da = match deg(&rem) {
        Some(d) if d >= db => d,
        _ => return (vec![C64::ZERO], rem),
    };
    let mut q = vec![C64::ZERO; da - db + 1];
    for k in (0..=da - db).rev() {
        let coeff = rem[db + k] / b[db];
        q[k] = coeff;
        for j in 0..=db {
            let sub = b[j] * coeff;
            rem[j + k] -= sub;
        }
    }
    rem.truncate(db.max(1));
    (q, rem)
}

/// Companion matrix (row-major, dense) of a *monic* polynomial
/// x^d + c[d-1] x^(d-1) + ... + c[0]; eigenvalues are the roots.
/// `coeffs` holds d+1 entries low-order-first with coeffs[d] == 1.
pub fn companion(coeffs: &[C64]) -> Vec<Vec<C64>> {
    let d = coeffs.len() - 1;
    assert!(d >= 1, "constant polynomial has no companion");
    let lead = coeffs[d];
    let mut m = vec![vec![C64::ZERO; d]; d];
    for i in 0..d {
        m[0][i] = -(coeffs[d - 1 - i] / lead);
    }
    for i in 1..d {
        m[i][i - 1] = C64::ONE;
    }
    m
}

/// All complex roots via Durand-Kerner (Weierstrass) iteration — robust for
/// the moderate degrees of distilled systems (d <= ~64) and works directly
/// on complex coefficients, unlike real-Hessenberg QR.
pub fn poly_roots(coeffs: &[C64]) -> Vec<C64> {
    // strip (numerically) zero leading coefficients
    let deg = coeffs
        .iter()
        .rposition(|c| c.abs() > 1e-12)
        .expect("zero polynomial");
    if deg == 0 {
        return vec![];
    }
    // normalize to monic
    let lead = coeffs[deg];
    let p: Vec<C64> = coeffs[..=deg].iter().map(|&c| c / lead).collect();
    let d = deg;
    // init on a spiral of radius ~ root bound
    let bound = 1.0
        + p[..d]
            .iter()
            .map(|c| c.abs())
            .fold(0.0, f64::max);
    let seed = C64::new(0.4, 0.9);
    let mut z: Vec<C64> = (0..d)
        .map(|k| seed.powi(k as u64 + 1).scale(bound.min(2.0)))
        .collect();
    for _ in 0..600 {
        let mut max_step = 0.0f64;
        for i in 0..d {
            let mut denom = C64::ONE;
            for j in 0..d {
                if i != j {
                    denom = denom * (z[i] - z[j]);
                }
            }
            if denom.abs() < 1e-300 {
                continue;
            }
            let step = poly_eval(&p, z[i]) / denom;
            z[i] -= step;
            max_step = max_step.max(step.abs());
        }
        if max_step < 1e-13 {
            break;
        }
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn from_roots_and_eval() {
        let roots = [C64::real(1.0), C64::real(2.0), C64::new(0.0, 1.0)];
        let p = poly_from_roots(&roots);
        for &r in &roots {
            assert!(poly_eval(&p, r).abs() < 1e-12);
        }
        // monic
        assert!((p[3] - C64::ONE).abs() < 1e-12);
    }

    #[test]
    fn divmod_recomposes() {
        check("a == q*b + r", 24, |rng| {
            let da = 1 + rng.below(6);
            let db = 1 + rng.below(da);
            let a: Vec<C64> =
                (0..=da).map(|_| C64::new(rng.normal(), rng.normal())).collect();
            let b: Vec<C64> =
                (0..=db).map(|_| C64::new(rng.normal(), rng.normal())).collect();
            if b.last().unwrap().abs() < 1e-3 {
                return Ok(()); // skip ill-conditioned leading coefficient
            }
            let (q, r) = poly_divmod(&a, &b);
            let mut recomposed = poly_mul(&q, &b);
            recomposed.resize(recomposed.len().max(r.len()), C64::ZERO);
            for (i, c) in r.iter().enumerate() {
                recomposed[i] += *c;
            }
            for (i, &c) in a.iter().enumerate() {
                if (recomposed[i] - c).abs() > 1e-8 * (1.0 + c.abs()) {
                    return Err(format!("coeff {i}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn deriv_power_rule() {
        // p = 1 + 2x + 3x^2 -> p' = 2 + 6x
        let p = [C64::real(1.0), C64::real(2.0), C64::real(3.0)];
        let d = poly_deriv(&p);
        assert!((d[0] - C64::real(2.0)).abs() < 1e-15);
        assert!((d[1] - C64::real(6.0)).abs() < 1e-15);
    }

    #[test]
    fn roots_recovered_from_random_polys() {
        check("poly_roots recovers roots", 16, |rng| {
            let d = 1 + rng.below(10);
            let roots: Vec<C64> = (0..d)
                .map(|_| C64::polar(rng.range(0.2, 1.2), rng.range(-3.1, 3.1)))
                .collect();
            let p = poly_from_roots(&roots);
            let got = poly_roots(&p);
            // every true root must be matched by a computed root
            for r in &roots {
                let best = got.iter().map(|g| (*g - *r).abs()).fold(f64::MAX, f64::min);
                if best > 1e-6 {
                    return Err(format!("root {r:?} unmatched (best {best:.2e}, d={d})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn roots_of_unity() {
        // x^8 - 1
        let mut p = vec![C64::ZERO; 9];
        p[0] = C64::real(-1.0);
        p[8] = C64::ONE;
        let roots = poly_roots(&p);
        assert_eq!(roots.len(), 8);
        for r in roots {
            assert!((r.abs() - 1.0).abs() < 1e-9);
            assert!(poly_eval(&p, r).abs() < 1e-9);
        }
    }

    #[test]
    fn companion_shape() {
        // x^2 - 3x + 2 = (x-1)(x-2)
        let p = [C64::real(2.0), C64::real(-3.0), C64::ONE];
        let m = companion(&p);
        assert_eq!(m.len(), 2);
        assert!((m[0][0] - C64::real(3.0)).abs() < 1e-15);
        assert!((m[0][1] - C64::real(-2.0)).abs() < 1e-15);
        assert!((m[1][0] - C64::ONE).abs() < 1e-15);
    }
}
