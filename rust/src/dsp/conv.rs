//! Causal convolution: direct (O(TL)) and FFT-based (Õ(T)) — the two
//! evaluation modes of a long-convolution layer (paper eq. 2.1).

use super::complex::C64;
use super::fft::{dft, idft, next_pow2};

/// Direct causal convolution: y_t = sum_{j=0..t} h_{t-j} u_j, truncated to
/// `u.len()` outputs. Filter shorter than the input is zero-extended.
pub fn causal_conv_direct(h: &[f64], u: &[f64]) -> Vec<f64> {
    let t = u.len();
    let mut y = vec![0.0; t];
    for i in 0..t {
        let kmax = i.min(h.len().saturating_sub(1));
        let mut acc = 0.0;
        for k in 0..=kmax {
            acc += h[k] * u[i - k];
        }
        y[i] = acc;
    }
    y
}

/// FFT causal convolution, zero-padded to avoid circular wrap.
pub fn causal_conv_fft(h: &[f64], u: &[f64]) -> Vec<f64> {
    let t = u.len();
    let n = next_pow2(t + h.len());
    let mut hb = vec![C64::ZERO; n];
    for (i, &x) in h.iter().enumerate() {
        hb[i] = C64::real(x);
    }
    let mut ub = vec![C64::ZERO; n];
    for (i, &x) in u.iter().enumerate() {
        ub[i] = C64::real(x);
    }
    let hf = dft(&hb);
    let uf = dft(&ub);
    let prod: Vec<C64> = hf.iter().zip(&uf).map(|(a, b)| *a * *b).collect();
    idft(&prod).into_iter().take(t).map(|z| z.re).collect()
}

/// One *incremental* step of cached-convolution generation (Lemma 2.1):
/// given the full history `hist` (inputs so far) compute the next output
/// y_t = sum_j h_{t-j} hist_j at t = hist.len()-1. O(t) per token.
pub fn conv_step(h: &[f64], hist: &[f64]) -> f64 {
    let t = hist.len() - 1;
    let kmax = t.min(h.len().saturating_sub(1));
    let mut acc = 0.0;
    for k in 0..=kmax {
        acc += h[k] * hist[t - k];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, check};

    #[test]
    fn fft_matches_direct() {
        check("fft conv == direct conv", 24, |rng| {
            let lh = 1 + rng.below(40);
            let lu = 1 + rng.below(60);
            let h = rng.normal_vec(lh);
            let u = rng.normal_vec(lu);
            assert_close(&causal_conv_fft(&h, &u), &causal_conv_direct(&h, &u), 1e-9, 1e-9)
        });
    }

    #[test]
    fn identity_filter() {
        let u = [1.0, -2.0, 3.0];
        let y = causal_conv_direct(&[1.0], &u);
        assert_eq!(y, u.to_vec());
    }

    #[test]
    fn delay_filter() {
        let u = [1.0, 2.0, 3.0, 4.0];
        let y = causal_conv_direct(&[0.0, 1.0], &u);
        assert_eq!(y, vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn conv_step_matches_batch() {
        check("incremental == batch conv", 16, |rng| {
            let h = rng.normal_vec(8);
            let u = rng.normal_vec(20);
            let want = causal_conv_direct(&h, &u);
            for t in 0..u.len() {
                let got = conv_step(&h, &u[..=t]);
                if (got - want[t]).abs() > 1e-10 {
                    return Err(format!("t={t}: {got} vs {}", want[t]));
                }
            }
            Ok(())
        });
    }
}
