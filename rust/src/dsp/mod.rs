//! Signal-processing substrate: complex arithmetic, FFT, convolution, and
//! polynomial algebra — everything the transfer-function machinery of the
//! paper (App. A) rests on.

pub mod complex;
pub mod conv;
pub mod fft;
pub mod poly;

pub use complex::C64;
