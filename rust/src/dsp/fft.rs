//! FFT substrate: iterative radix-2 Cooley-Tukey plus Bluestein's algorithm
//! for arbitrary lengths.
//!
//! Used by: the Õ(L) transfer-function evaluation (paper Lemma A.6), the
//! H2 distillation objective (eq. B.9), FFT-based causal convolution
//! (conv-mode generation, Lemma 2.1) and the Prop-3.2 fast prefill.

use super::complex::C64;

/// True if `n` is a power of two (and non-zero).
pub fn is_pow2(n: usize) -> bool {
    n != 0 && n & (n - 1) == 0
}

/// Smallest power of two >= n.
pub fn next_pow2(n: usize) -> usize {
    let mut p = 1;
    while p < n {
        p <<= 1;
    }
    p
}

/// In-place radix-2 DIT FFT. `data.len()` must be a power of two.
/// `inverse` applies the conjugate transform *without* the 1/n scaling.
fn fft_pow2(data: &mut [C64], inverse: bool) {
    let n = data.len();
    debug_assert!(is_pow2(n));
    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            data.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let wlen = C64::polar(1.0, ang);
        let mut i = 0;
        while i < n {
            let mut w = C64::ONE;
            for k in 0..len / 2 {
                let u = data[i + k];
                let v = data[i + k + len / 2] * w;
                data[i + k] = u + v;
                data[i + k + len / 2] = u - v;
                w = w * wlen;
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Forward DFT of arbitrary length (radix-2 when possible, Bluestein
/// otherwise). Returns a new vector.
pub fn dft(input: &[C64]) -> Vec<C64> {
    transform(input, false)
}

/// Inverse DFT (includes the 1/n scaling).
pub fn idft(input: &[C64]) -> Vec<C64> {
    let n = input.len();
    let mut out = transform(input, true);
    let s = 1.0 / n as f64;
    for z in &mut out {
        *z = z.scale(s);
    }
    out
}

fn transform(input: &[C64], inverse: bool) -> Vec<C64> {
    let n = input.len();
    assert!(n > 0, "empty DFT");
    if is_pow2(n) {
        let mut data = input.to_vec();
        fft_pow2(&mut data, inverse);
        data
    } else {
        bluestein(input, inverse)
    }
}

/// Bluestein's chirp-z algorithm: DFT of arbitrary n via a power-of-two
/// circular convolution.
fn bluestein(input: &[C64], inverse: bool) -> Vec<C64> {
    let n = input.len();
    let m = next_pow2(2 * n - 1);
    let sign = if inverse { 1.0 } else { -1.0 };
    // chirp[k] = exp(sign * i pi k^2 / n) with sign=-1 forward (from
    // k*t = (k^2 + t^2 - (k-t)^2)/2); k^2 mod 2n keeps angles small.
    let chirp: Vec<C64> = (0..n)
        .map(|k| {
            let k2 = ((k as u64 * k as u64) % (2 * n as u64)) as f64;
            C64::polar(1.0, sign * std::f64::consts::PI * k2 / n as f64)
        })
        .collect();
    let mut a = vec![C64::ZERO; m];
    for k in 0..n {
        a[k] = input[k] * chirp[k];
    }
    let mut b = vec![C64::ZERO; m];
    for k in 0..n {
        let c = chirp[k].conj();
        b[k] = c;
        if k != 0 {
            b[m - k] = c;
        }
    }
    fft_pow2(&mut a, false);
    fft_pow2(&mut b, false);
    for i in 0..m {
        a[i] = a[i] * b[i];
    }
    fft_pow2(&mut a, true);
    let s = 1.0 / m as f64;
    (0..n).map(|k| a[k].scale(s) * chirp[k]).collect()
}

/// DFT of a real sequence.
pub fn dft_real(input: &[f64]) -> Vec<C64> {
    let buf: Vec<C64> = input.iter().map(|&x| C64::real(x)).collect();
    dft(&buf)
}

/// Real part of the inverse DFT (for spectra of real signals).
pub fn idft_real(input: &[C64]) -> Vec<f64> {
    idft(input).into_iter().map(|z| z.re).collect()
}

/// Direct O(n^2) DFT — test oracle only.
pub fn dft_naive(input: &[C64]) -> Vec<C64> {
    let n = input.len();
    (0..n)
        .map(|k| {
            let mut acc = C64::ZERO;
            for (t, &x) in input.iter().enumerate() {
                let ang = -2.0 * std::f64::consts::PI * (k * t % n) as f64 / n as f64;
                acc += x * C64::polar(1.0, ang);
            }
            acc
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn rand_signal(rng: &mut crate::util::Prng, n: usize) -> Vec<C64> {
        (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect()
    }

    #[test]
    fn matches_naive_dft_pow2_and_arbitrary() {
        check("dft == naive dft", 24, |rng| {
            let n = [1, 2, 3, 4, 7, 8, 12, 16, 27, 33, 64][rng.below(11)];
            let x = rand_signal(rng, n);
            let got = dft(&x);
            let want = dft_naive(&x);
            for (g, w) in got.iter().zip(&want) {
                if (*g - *w).abs() > 1e-8 * (1.0 + w.abs()) {
                    return Err(format!("n={n}: {g:?} vs {w:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn roundtrip_identity() {
        check("idft(dft(x)) == x", 24, |rng| {
            let n = 1 + rng.below(100);
            let x = rand_signal(rng, n);
            let y = idft(&dft(&x));
            for (g, w) in y.iter().zip(&x) {
                if (*g - *w).abs() > 1e-9 * (1.0 + w.abs()) {
                    return Err(format!("n={n}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn parseval() {
        check("parseval", 16, |rng| {
            let n = 1 + rng.below(64);
            let x = rand_signal(rng, n);
            let f = dft(&x);
            let e_time: f64 = x.iter().map(|z| z.abs2()).sum();
            let e_freq: f64 = f.iter().map(|z| z.abs2()).sum::<f64>() / n as f64;
            if (e_time - e_freq).abs() < 1e-8 * e_time.max(1.0) {
                Ok(())
            } else {
                Err(format!("{e_time} vs {e_freq}"))
            }
        });
    }

    #[test]
    fn impulse_has_flat_spectrum() {
        let mut x = vec![C64::ZERO; 16];
        x[0] = C64::ONE;
        for z in dft(&x) {
            assert!((z - C64::ONE).abs() < 1e-12);
        }
    }

    #[test]
    fn real_helpers() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let back = idft_real(&dft_real(&x));
        for (g, w) in back.iter().zip(&x) {
            assert!((g - w).abs() < 1e-10);
        }
    }

    #[test]
    fn next_pow2_values() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(5), 8);
        assert_eq!(next_pow2(8), 8);
        assert_eq!(next_pow2(1000), 1024);
    }
}
