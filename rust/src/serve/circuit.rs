//! Per-shard circuit breaker: the router's defense against hammering a
//! dead or flapping shard with full connect timeouts on every request.
//!
//! Classic three-state machine:
//!
//! ```text
//!             failure >= threshold
//!   Closed ──────────────────────────▶ Open
//!     ▲                                 │ cooldown elapses
//!     │ success                         ▼
//!     └────────────────────────────  HalfOpen
//!                 ▲                     │ failure
//!                 └─────────────────────┘ (straight back to Open)
//! ```
//!
//! * **Closed** — requests flow; consecutive failures are counted and any
//!   success resets the count.
//! * **Open** — requests are refused *immediately* (the router surfaces a
//!   typed `ShardUnavailable`, not an i/o timeout) until the cooldown
//!   elapses.
//! * **HalfOpen** — after the cooldown one probe request is let through;
//!   success closes the circuit, failure re-opens it for another cooldown.
//!
//! The breaker itself is time-driven but deterministic: the only clock
//! read is in [`Breaker::allow`], and tests pin `cooldown` to zero (always
//! immediately half-open) or to hours (never half-open) so no test sleeps.

use std::time::{Duration, Instant};

/// Tuning for one shard's breaker.
#[derive(Clone, Copy, Debug)]
pub struct BreakerConfig {
    /// Consecutive failures (connect errors, i/o errors mid-call) that
    /// trip Closed → Open.
    pub failure_threshold: u32,
    /// How long an open circuit refuses requests before letting one probe
    /// through.
    pub cooldown: Duration,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig { failure_threshold: 3, cooldown: Duration::from_secs(5) }
    }
}

/// Observable breaker state (the internal Open variant also carries its
/// reopen deadline).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

#[derive(Clone, Copy, Debug)]
enum State {
    Closed,
    Open { until: Instant },
    HalfOpen,
}

/// Lifetime transition counts for one breaker — how many times each edge
/// of the state machine fired.  Observability-only: the breaker's
/// behavior never reads these.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BreakerStats {
    /// Transitions into Open (trips from Closed and re-opens from a
    /// failed HalfOpen probe; staying Open does not count).
    pub opened: u64,
    /// Transitions Open → HalfOpen (cooldown elapsed, probe admitted).
    pub half_opened: u64,
    /// Transitions into Closed from a non-Closed state (recoveries;
    /// successes while already Closed do not count).
    pub closed: u64,
}

/// One shard's circuit breaker.  Not internally synchronized — the router
/// holds it under its own lock.
#[derive(Debug)]
pub struct Breaker {
    cfg: BreakerConfig,
    state: State,
    consecutive_failures: u32,
    stats: BreakerStats,
}

impl Breaker {
    pub fn new(cfg: BreakerConfig) -> Self {
        Breaker {
            cfg,
            state: State::Closed,
            consecutive_failures: 0,
            stats: BreakerStats::default(),
        }
    }

    /// May a request be attempted right now?  An elapsed-cooldown open
    /// circuit transitions to half-open here (and admits the probe).
    pub fn allow(&mut self) -> bool {
        match self.state {
            State::Closed | State::HalfOpen => true,
            State::Open { until } => {
                if Instant::now() >= until {
                    self.state = State::HalfOpen;
                    self.stats.half_opened += 1;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// A request (or health probe) succeeded: close the circuit.
    pub fn record_success(&mut self) {
        if !matches!(self.state, State::Closed) {
            self.stats.closed += 1;
        }
        self.state = State::Closed;
        self.consecutive_failures = 0;
    }

    /// A request failed at the transport level.  A half-open probe failure
    /// re-opens immediately; `failure_threshold` consecutive closed-state
    /// failures trip the breaker.
    pub fn record_failure(&mut self) {
        self.consecutive_failures = self.consecutive_failures.saturating_add(1);
        let trip = matches!(self.state, State::HalfOpen)
            || self.consecutive_failures >= self.cfg.failure_threshold;
        if trip {
            if !matches!(self.state, State::Open { .. }) {
                self.stats.opened += 1;
            }
            self.state = State::Open { until: Instant::now() + self.cfg.cooldown };
        }
    }

    pub fn state(&self) -> BreakerState {
        match self.state {
            State::Closed => BreakerState::Closed,
            State::Open { .. } => BreakerState::Open,
            State::HalfOpen => BreakerState::HalfOpen,
        }
    }

    /// Lifetime transition counts (for the observability layer).
    pub fn stats(&self) -> BreakerStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn breaker(threshold: u32, cooldown: Duration) -> Breaker {
        Breaker::new(BreakerConfig { failure_threshold: threshold, cooldown })
    }

    #[test]
    fn trips_after_threshold_consecutive_failures() {
        let mut b = breaker(3, Duration::from_secs(3600));
        for _ in 0..2 {
            b.record_failure();
            assert_eq!(b.state(), BreakerState::Closed);
            assert!(b.allow());
        }
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.allow(), "open circuit with a future deadline must refuse");
        // and it stays open: the hour-long cooldown has not elapsed
        assert!(!b.allow());
    }

    #[test]
    fn success_resets_the_failure_count() {
        let mut b = breaker(3, Duration::from_secs(3600));
        b.record_failure();
        b.record_failure();
        b.record_success();
        b.record_failure();
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Closed, "count must reset on success");
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
    }

    #[test]
    fn elapsed_cooldown_half_opens_and_probe_decides() {
        // zero cooldown: the open circuit is immediately eligible to probe
        let mut b = breaker(1, Duration::ZERO);
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
        assert!(b.allow(), "elapsed cooldown must admit a probe");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        // probe failure: straight back to open (single failure, below any
        // threshold — half-open failures trip unconditionally)
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
        // next probe succeeds: closed again
        assert!(b.allow());
        b.record_success();
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.allow());
    }

    #[test]
    fn stats_count_each_edge_exactly_once() {
        let mut b = breaker(1, Duration::ZERO);
        assert_eq!(b.stats(), BreakerStats::default());
        // Closed successes are not "recoveries"
        b.record_success();
        assert_eq!(b.stats().closed, 0);
        // trip: one opened
        b.record_failure();
        assert_eq!(b.stats(), BreakerStats { opened: 1, half_opened: 0, closed: 0 });
        // cooldown elapsed: one half_opened (allow() again while half-open
        // must not double-count)
        assert!(b.allow());
        assert!(b.allow());
        assert_eq!(b.stats().half_opened, 1);
        // probe failure: back to open — second opened
        b.record_failure();
        assert_eq!(b.stats().opened, 2);
        // probe success after another half-open: one closed
        assert!(b.allow());
        b.record_success();
        assert_eq!(b.stats(), BreakerStats { opened: 2, half_opened: 2, closed: 1 });
    }
}
