//! The client-facing front door: consistent-hashes session ids across N
//! shard servers for affinity, forwards turns over the wire protocol, and
//! performs **live session migration** between shards.
//!
//! * **Placement.**  Session ids map onto a hash ring (every shard
//!   contributes [`VNODES`] virtual points, hashed from its address with
//!   the stable FNV so placement survives restarts); one-shot requests
//!   round-robin.  A session served once is pinned in the router's
//!   `resident` map, so affinity holds even after the ring changes — the
//!   ring decides *initial* placement, residency decides routing.
//! * **Migration.**  `migrate` quiesces the session on its source shard
//!   (the coordinator's deferred-until-quiescent export), ships the state
//!   blob + transcript over the wire, and installs it on the target.  The
//!   handshake identities (engine tag + shape fingerprint from each
//!   shard's Hello) are compared *before* the blob leaves the source —
//!   a mismatched pair is refused without shipping anything, and if the
//!   target still refuses the import, the session is re-imported into the
//!   source so it is never lost.
//! * **Admin.**  `drain` migrates every resident session off a shard and
//!   stops placing new work there; `add_shard` extends the ring;
//!   `rebalance` moves sessions whose ring target changed.
//!
//! The router is a plain struct driven by one thread (tests, the CLI
//! demo); a concurrent front door wraps it in a `Mutex` — every wire
//! conversation is a single connect/request/reply exchange, so the lock
//! scope is one call.

use std::collections::HashMap;
use std::io;
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use super::wire::{
    self, fnv1a64, splitmix64, ErrCode, Frame, HealthReport, PROTO_VERSION,
};

/// Virtual ring points per shard: enough that removing one shard moves
/// only ~1/N of the id space.
pub const VNODES: usize = 32;

/// How long the router waits for one reply frame.  Export waits for the
/// session to quiesce, so this must comfortably exceed a turn's decode
/// time.
const REPLY_TIMEOUT: Duration = Duration::from_secs(300);

/// Why a routed operation failed.
#[derive(Debug)]
pub enum RouteError {
    Io(io::Error),
    /// No live (non-draining) shard can take the work.
    NoShards,
    /// The explicit migration target is draining and takes no sessions.
    Draining(usize),
    /// The session is unknown — to the router, or to the shard a strict
    /// resume was sent to.
    UnknownSession(u64),
    /// Migration refused: source and target shards disagree on engine tag
    /// or shape fingerprint (or the target rejected the blob).  The
    /// session still lives on its source shard.
    Mismatch(String),
    /// A shard replied with an error frame.
    Shard(ErrCode, String),
    /// A shard replied out of protocol.
    Protocol(String),
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::Io(e) => write!(f, "shard i/o: {e}"),
            RouteError::NoShards => write!(f, "no live shards"),
            RouteError::Draining(i) => {
                write!(f, "shard {i} is draining and takes no sessions")
            }
            RouteError::UnknownSession(id) => write!(f, "session {id:#x} unknown"),
            RouteError::Mismatch(msg) => write!(f, "migration mismatch: {msg}"),
            RouteError::Shard(code, msg) => write!(f, "shard error {code:?}: {msg}"),
            RouteError::Protocol(msg) => write!(f, "protocol violation: {msg}"),
        }
    }
}

impl std::error::Error for RouteError {}

impl From<io::Error> for RouteError {
    fn from(e: io::Error) -> RouteError {
        RouteError::Io(e)
    }
}

/// A shard's handshake identity (from its Hello frame): the triple a
/// session blob must match end-to-end before migration ships it.
#[derive(Clone, Debug, PartialEq, Eq)]
struct Identity {
    engine: String,
    shape_fp: u64,
    weights_fp: u64,
}

/// What the router knows about one shard.
#[derive(Clone, Debug)]
struct ShardInfo {
    addr: SocketAddr,
    /// Handshake identity from the shard's Hello.
    id: Identity,
    /// Draining shards serve their resident sessions but take no new
    /// placements; `drain` empties them.
    draining: bool,
}

/// One wire conversation with a shard (connect, Hello, then
/// request/reply).  Connections are per-call: loopback connects are
/// cheap, and every connection re-validates the handshake.
struct Conn {
    stream: TcpStream,
}

impl Conn {
    fn open(addr: SocketAddr) -> Result<(Conn, Identity), RouteError> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(REPLY_TIMEOUT))?;
        match wire::read_frame(&mut stream)? {
            Frame::Hello { proto, engine, shape_fp, weights_fp } => {
                if proto != PROTO_VERSION {
                    return Err(RouteError::Mismatch(format!(
                        "shard {addr} speaks protocol {proto}, router speaks {PROTO_VERSION}"
                    )));
                }
                Ok((Conn { stream }, Identity { engine, shape_fp, weights_fp }))
            }
            other => Err(RouteError::Protocol(format!("expected Hello, got {other:?}"))),
        }
    }

    /// Send one request and read one reply frame (error frames become
    /// [`RouteError::Shard`]).
    fn request(&mut self, f: &Frame) -> Result<Frame, RouteError> {
        wire::write_frame(&mut self.stream, f)?;
        match wire::read_frame(&mut self.stream)? {
            Frame::Error { code, msg } => Err(RouteError::Shard(code, msg)),
            reply => Ok(reply),
        }
    }

    /// Send one generation request and collect the streamed tokens.
    fn generate(&mut self, f: &Frame) -> Result<Vec<i32>, RouteError> {
        wire::write_frame(&mut self.stream, f)?;
        let mut toks = Vec::new();
        loop {
            match wire::read_frame(&mut self.stream)? {
                Frame::Token { token } => toks.push(token),
                Frame::Done { .. } => return Ok(toks),
                Frame::Error { code, msg } => return Err(RouteError::Shard(code, msg)),
                other => {
                    return Err(RouteError::Protocol(format!(
                        "expected Token/Done, got {other:?}"
                    )))
                }
            }
        }
    }
}

/// The sharded front door.
pub struct Router {
    shards: Vec<ShardInfo>,
    /// Sorted (point, shard) ring over the non-draining shards.
    ring: Vec<(u64, usize)>,
    /// Which shard currently owns each session (authoritative: the router
    /// is the only front door, and migration updates it).
    resident: HashMap<u64, usize>,
    /// Round-robin cursor for one-shot requests.
    rr: usize,
}

impl Router {
    /// Connect to every shard, record its handshake identity, and build
    /// the ring.  Shards may be heterogeneous (different engines); the
    /// migration path is what insists on matching identities.
    pub fn new(addrs: &[SocketAddr]) -> Result<Router, RouteError> {
        if addrs.is_empty() {
            return Err(RouteError::NoShards);
        }
        let mut shards = Vec::with_capacity(addrs.len());
        for &addr in addrs {
            let (_conn, id) = Conn::open(addr)?;
            shards.push(ShardInfo { addr, id, draining: false });
        }
        let mut r = Router { shards, ring: Vec::new(), resident: HashMap::new(), rr: 0 };
        r.rebuild_ring();
        Ok(r)
    }

    /// Number of shards (including draining ones).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Which shard currently owns a session, if the router has seen it.
    pub fn shard_of(&self, session: u64) -> Option<usize> {
        self.resident.get(&session).copied()
    }

    /// Sessions resident on one shard (router's view).
    pub fn sessions_on(&self, shard: usize) -> Vec<u64> {
        let mut ids: Vec<u64> = self
            .resident
            .iter()
            .filter(|(_, &s)| s == shard)
            .map(|(&id, _)| id)
            .collect();
        ids.sort_unstable();
        ids
    }

    fn rebuild_ring(&mut self) {
        self.ring.clear();
        for (i, s) in self.shards.iter().enumerate() {
            if s.draining {
                continue;
            }
            for v in 0..VNODES {
                let key = format!("{}#{v}", s.addr);
                self.ring.push((fnv1a64(key.as_bytes()), i));
            }
        }
        self.ring.sort_unstable();
    }

    /// Ring lookup: first point clockwise of the session's hash.
    fn ring_target(&self, session: u64) -> Option<usize> {
        if self.ring.is_empty() {
            return None;
        }
        let h = splitmix64(session);
        let idx = self.ring.partition_point(|&(p, _)| p < h);
        let (_, shard) = self.ring[idx % self.ring.len()];
        Some(shard)
    }

    /// Shard a session turn routes to: pinned residency first, ring
    /// placement for sessions the router has not seen.
    fn route_session(&self, session: u64) -> Result<usize, RouteError> {
        if let Some(&s) = self.resident.get(&session) {
            return Ok(s);
        }
        self.ring_target(session).ok_or(RouteError::NoShards)
    }

    /// One-shot generation, round-robined over the live shards.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize) -> Result<Vec<i32>, RouteError> {
        let live: Vec<usize> = (0..self.shards.len())
            .filter(|&i| !self.shards[i].draining)
            .collect();
        if live.is_empty() {
            return Err(RouteError::NoShards);
        }
        let shard = live[self.rr % live.len()];
        self.rr = self.rr.wrapping_add(1);
        let (mut conn, _) = Conn::open(self.shards[shard].addr)?;
        conn.generate(&Frame::Submit { max_new: max_new as u32, prompt })
    }

    /// One turn of a session, routed with affinity.  Turns after the first
    /// are sent strict, so a shard that somehow lost the session surfaces
    /// the typed [`RouteError::UnknownSession`] instead of silently
    /// forking a fresh conversation.
    pub fn submit_in_session(
        &mut self,
        session: u64,
        delta: Vec<i32>,
        max_new: usize,
    ) -> Result<Vec<i32>, RouteError> {
        let shard = self.route_session(session)?;
        let strict = self.resident.contains_key(&session);
        let (mut conn, _) = Conn::open(self.shards[shard].addr)?;
        let toks = conn
            .generate(&Frame::SubmitInSession {
                session,
                strict,
                max_new: max_new as u32,
                delta,
            })
            .map_err(|e| match e {
                RouteError::Shard(ErrCode::UnknownSession, _) => {
                    RouteError::UnknownSession(session)
                }
                other => other,
            })?;
        self.resident.insert(session, shard);
        Ok(toks)
    }

    /// Drop a session everywhere the router knows about it.
    pub fn end_session(&mut self, session: u64) -> Result<(), RouteError> {
        let shard = self.route_session(session)?;
        let (mut conn, _) = Conn::open(self.shards[shard].addr)?;
        match conn.request(&Frame::EndSession { session })? {
            Frame::Ok => {
                self.resident.remove(&session);
                Ok(())
            }
            other => Err(RouteError::Protocol(format!("expected Ok, got {other:?}"))),
        }
    }

    /// Live-migrate one session to a target shard: quiesce + export on the
    /// source, ship the blob, import on the target.  Identity (engine tag
    /// + shape fingerprint, as advertised in each shard's handshake) is
    /// compared before the blob is shipped; the target connection is opened
    /// before the export, so an unreachable target fails the migration with
    /// the session untouched; on a target-side refusal the session is
    /// restored to its source.  Returns the shipped state-blob size in
    /// bytes (0 when the engine exports no state).
    ///
    /// Known limit (no two-phase commit): if the import was *applied* but
    /// its Ok reply was lost in transit, the restore-to-source leaves a
    /// stale duplicate on the target — duplicates are garbage, never lost
    /// conversations, and the router keeps routing to the source copy.
    pub fn migrate(&mut self, session: u64, to: usize) -> Result<usize, RouteError> {
        let from = *self
            .resident
            .get(&session)
            .ok_or(RouteError::UnknownSession(session))?;
        if to >= self.shards.len() {
            return Err(RouteError::Protocol(format!("no shard {to}")));
        }
        if from == to {
            return Ok(0);
        }
        if self.shards[to].draining {
            // drain's whole point is to empty the shard; explicitly
            // migrating a session onto it would pin traffic there
            return Err(RouteError::Draining(to));
        }
        // handshake check FIRST: a mismatched blob is never even exported
        let (src, dst) = (&self.shards[from], &self.shards[to]);
        if src.id.engine != dst.id.engine {
            return Err(RouteError::Mismatch(format!(
                "engine '{}' (shard {from}) != '{}' (shard {to})",
                src.id.engine, dst.id.engine
            )));
        }
        if src.id.shape_fp != dst.id.shape_fp {
            return Err(RouteError::Mismatch(format!(
                "shape fingerprint {:#x} (shard {from}) != {:#x} (shard {to})",
                src.id.shape_fp, dst.id.shape_fp
            )));
        }
        if src.id.weights_fp != dst.id.weights_fp {
            return Err(RouteError::Mismatch(format!(
                "weights fingerprint {:#x} (shard {from}) != {:#x} (shard {to}) \
                 — same shape but different weights would silently change tokens",
                src.id.weights_fp, dst.id.weights_fp
            )));
        }
        // connect to the TARGET before detaching anything from the source:
        // a down or unreachable target must fail the migration while the
        // session still lives untouched on its source shard
        let (mut dst_conn, _) = Conn::open(dst.addr)?;
        let (mut src_conn, _) = Conn::open(src.addr)?;
        let (session_id, shape_fp, weights_fp, transcript, state) =
            match src_conn.request(&Frame::Export { session }) {
                Ok(Frame::Blob { session, shape_fp, weights_fp, transcript, state }) => {
                    (session, shape_fp, weights_fp, transcript, state)
                }
                Ok(other) => {
                    return Err(RouteError::Protocol(format!("expected Blob, got {other:?}")))
                }
                Err(RouteError::Shard(ErrCode::UnknownSession, _)) => {
                    // the shard lost it (e.g. ended behind our back)
                    self.resident.remove(&session);
                    return Err(RouteError::UnknownSession(session));
                }
                Err(e) => return Err(e),
            };
        let bytes = state.as_ref().map(|b| b.len()).unwrap_or(0);
        let import =
            Frame::Import { session: session_id, shape_fp, weights_fp, transcript, state };
        match dst_conn.request(&import) {
            Ok(Frame::Ok) => {
                self.resident.insert(session, to);
                Ok(bytes)
            }
            refused => {
                // put the session back where it came from — a failed
                // migration must never lose the conversation.  If even the
                // restore fails, say so loudly instead of propagating the
                // transport error as if the session were merely unmoved.
                let restored = Conn::open(src.addr)
                    .and_then(|(mut back, _)| back.request(&import))
                    .and_then(|reply| match reply {
                        Frame::Ok => Ok(()),
                        other => Err(RouteError::Protocol(format!(
                            "restore reply was {other:?}"
                        ))),
                    });
                if let Err(e) = restored {
                    return Err(RouteError::Protocol(format!(
                        "session {session:#x} may be lost: target refused the \
                         import ({refused:?}) and restore-to-source failed: {e}"
                    )));
                }
                match refused {
                    Err(RouteError::Shard(ErrCode::Mismatch, msg)) => {
                        Err(RouteError::Mismatch(msg))
                    }
                    Err(e) => Err(e),
                    Ok(other) => Err(RouteError::Protocol(format!(
                        "expected Ok from import, got {other:?}"
                    ))),
                }
            }
        }
    }

    /// Stop placing new work on a shard and migrate every session the
    /// router has resident there to its new ring target.  Returns the
    /// migrated session ids.
    pub fn drain(&mut self, shard: usize) -> Result<Vec<u64>, RouteError> {
        if shard >= self.shards.len() {
            return Err(RouteError::Protocol(format!("no shard {shard}")));
        }
        self.shards[shard].draining = true;
        self.rebuild_ring();
        if self.ring.is_empty() {
            // nowhere to put the sessions: undo
            self.shards[shard].draining = false;
            self.rebuild_ring();
            return Err(RouteError::NoShards);
        }
        let mut moved = Vec::new();
        for sid in self.sessions_on(shard) {
            let target = self.ring_target(sid).ok_or(RouteError::NoShards)?;
            self.migrate(sid, target)?;
            moved.push(sid);
        }
        Ok(moved)
    }

    /// Add a shard to the ring (it starts taking new placements and
    /// rebalance targets immediately).
    pub fn add_shard(&mut self, addr: SocketAddr) -> Result<usize, RouteError> {
        let (_conn, id) = Conn::open(addr)?;
        self.shards.push(ShardInfo { addr, id, draining: false });
        self.rebuild_ring();
        Ok(self.shards.len() - 1)
    }

    /// Move every session whose ring target differs from where it lives
    /// (after `add_shard` changed the ring).  Returns (session, from, to)
    /// per move.  Sessions that cannot move because identities mismatch
    /// are left in place and reported untouched.
    pub fn rebalance(&mut self) -> Result<Vec<(u64, usize, usize)>, RouteError> {
        let mut moves = Vec::new();
        let plan: Vec<(u64, usize)> = self
            .resident
            .iter()
            .map(|(&sid, &cur)| (sid, cur))
            .collect();
        for (sid, cur) in plan {
            let want = match self.ring_target(sid) {
                Some(w) => w,
                None => return Err(RouteError::NoShards),
            };
            if want == cur {
                continue;
            }
            match self.migrate(sid, want) {
                Ok(_) => moves.push((sid, cur, want)),
                Err(RouteError::Mismatch(_)) => continue,
                Err(e) => return Err(e),
            }
        }
        moves.sort_unstable();
        Ok(moves)
    }

    /// Per-shard health, queried over the wire.
    pub fn health(&self) -> Result<Vec<HealthReport>, RouteError> {
        let mut out = Vec::with_capacity(self.shards.len());
        for s in &self.shards {
            let (mut conn, _) = Conn::open(s.addr)?;
            match conn.request(&Frame::Health)? {
                Frame::HealthReport(h) => out.push(h),
                other => {
                    return Err(RouteError::Protocol(format!(
                        "expected HealthReport, got {other:?}"
                    )))
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::coordinator::SlotEngine;
    use crate::engine::transformer::TransformerEngine;
    use crate::engine::LmShape;
    use crate::serve::shard::{ShardServer, ShardSpec};

    fn cfg() -> ServeConfig {
        ServeConfig { max_batch: 2, linger_ms: 1, ..ServeConfig::default() }
    }

    fn native_shards(n: usize) -> Vec<ShardServer> {
        let shape = LmShape::bench("nano").unwrap();
        (0..n)
            .map(|_| ShardServer::spawn_native(&shape, 2, 11, cfg()).unwrap())
            .collect()
    }

    fn router_over(shards: &[ShardServer]) -> Router {
        let addrs: Vec<_> = shards.iter().map(|s| s.addr()).collect();
        Router::new(&addrs).unwrap()
    }

    #[test]
    fn ring_spreads_sessions_and_is_stable() {
        let shards = native_shards(3);
        let r = router_over(&shards);
        let mut counts = [0usize; 3];
        for sid in 0..300u64 {
            let t = r.ring_target(sid).unwrap();
            assert_eq!(t, r.ring_target(sid).unwrap(), "placement must be deterministic");
            counts[t] += 1;
        }
        // with 32 vnodes each shard's expected share is ~100/300; require
        // only >5% so kernel-assigned ports can never flake the test
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 15, "shard {i} got only {c}/300 sessions — ring is lopsided");
        }
        for s in shards {
            s.shutdown();
        }
    }

    #[test]
    fn session_turns_keep_affinity_and_resume() {
        let shards = native_shards(2);
        let mut r = router_over(&shards);
        // several interleaved sessions, two turns each
        let sids: Vec<u64> = (0..6).collect();
        for &sid in &sids {
            let g = r.submit_in_session(sid, vec![1 + sid as i32, 2], 3).unwrap();
            assert_eq!(g.len(), 3);
        }
        let homes: Vec<usize> = sids.iter().map(|&s| r.shard_of(s).unwrap()).collect();
        for &sid in &sids {
            let g = r.submit_in_session(sid, vec![9], 3).unwrap();
            assert_eq!(g.len(), 3);
            assert_eq!(
                r.shard_of(sid).unwrap(),
                homes[sid as usize],
                "turn 2 must stay on the session's shard"
            );
        }
        // every second turn resumed from stored state on its home shard
        let health = r.health().unwrap();
        let hits: u64 = health.iter().map(|h| h.session_hits).sum();
        let misses: u64 = health.iter().map(|h| h.session_misses).sum();
        assert_eq!(hits, sids.len() as u64, "every turn-2 must be a store hit");
        assert_eq!(misses, 0, "a miss means a turn was routed to the wrong shard");
        for s in shards {
            s.shutdown();
        }
    }

    #[test]
    fn one_shots_round_robin_and_agree_across_shards() {
        let shards = native_shards(2);
        let mut r = router_over(&shards);
        // same prompt, same seed on both shards -> identical tokens
        let a = r.submit(vec![5, 6, 7], 4).unwrap();
        let b = r.submit(vec![5, 6, 7], 4).unwrap();
        assert_eq!(a, b, "identically-seeded shards must agree");
        let health = r.health().unwrap();
        assert_eq!(
            health.iter().map(|h| h.requests_done).collect::<Vec<_>>(),
            vec![1, 1],
            "round robin must spread one-shots"
        );
        for s in shards {
            s.shutdown();
        }
    }

    #[test]
    fn migrate_between_mismatched_engines_is_refused_at_the_handshake() {
        let shape = LmShape::bench("nano").unwrap();
        let native = ShardServer::spawn_native(&shape, 2, 11, cfg()).unwrap();
        let spec = ShardSpec::native(&shape, crate::engine::transformer::STATE_TAG, 11);
        let shape2 = shape.clone();
        let baseline = ShardServer::spawn(spec, cfg(), move || {
            Box::new(TransformerEngine::new(&shape2, 2, 11)) as Box<dyn SlotEngine>
        })
        .unwrap();
        let mut r = Router::new(&[native.addr(), baseline.addr()]).unwrap();
        // pin a session to the native shard (shard 0 may or may not be the
        // ring target, so force residency through a served turn)
        let sid = 77u64;
        let g1 = r.submit_in_session(sid, vec![1, 2, 3], 3).unwrap();
        let home = r.shard_of(sid).unwrap();
        let other = 1 - home;
        match r.migrate(sid, other) {
            Err(RouteError::Mismatch(msg)) => {
                assert!(msg.contains("engine"), "mismatch must name the engine: {msg}")
            }
            other => panic!("expected Mismatch, got {other:?}"),
        }
        // the session is untouched and continues where it lives
        assert_eq!(r.shard_of(sid), Some(home));
        let g2 = r.submit_in_session(sid, vec![4], 3).unwrap();
        assert_eq!(g2.len(), 3);
        assert!(!g1.is_empty());
        native.shutdown();
        baseline.shutdown();
    }

    /// Same engine, same shape, different seed: the shapes fingerprint
    /// identically, but the weights differ — a migrated state would decode
    /// into silently wrong tokens, so the weights fingerprint must refuse
    /// the pair before the blob is shipped.
    #[test]
    fn migrate_between_same_shape_different_seeds_is_refused() {
        let shape = LmShape::bench("nano").unwrap();
        let a = ShardServer::spawn_native(&shape, 2, 11, cfg()).unwrap();
        let b = ShardServer::spawn_native(&shape, 2, 12, cfg()).unwrap();
        let mut r = Router::new(&[a.addr(), b.addr()]).unwrap();
        let sid = 5u64;
        r.submit_in_session(sid, vec![1, 2, 3], 3).unwrap();
        let home = r.shard_of(sid).unwrap();
        match r.migrate(sid, 1 - home) {
            Err(RouteError::Mismatch(msg)) => {
                assert!(msg.contains("weights"), "must name the cause: {msg}")
            }
            other => panic!("expected weights Mismatch, got {other:?}"),
        }
        // untouched: the session keeps serving from its home shard
        assert_eq!(r.shard_of(sid), Some(home));
        assert_eq!(r.submit_in_session(sid, vec![4], 2).unwrap().len(), 2);
        a.shutdown();
        b.shutdown();
    }

    /// A draining shard must refuse to become an explicit migration
    /// target — otherwise drain's "empty this shard" invariant breaks.
    #[test]
    fn migrate_onto_a_draining_shard_is_refused() {
        let shards = native_shards(2);
        let mut r = router_over(&shards);
        let sid = 9u64;
        r.submit_in_session(sid, vec![1, 2], 2).unwrap();
        let home = r.shard_of(sid).unwrap();
        let other = 1 - home;
        // drain the other shard (it holds no sessions, so this is a no-op
        // migration-wise), then try to migrate onto it
        r.drain(other).unwrap();
        assert!(matches!(
            r.migrate(sid, other),
            Err(RouteError::Draining(i)) if i == other
        ));
        assert_eq!(r.shard_of(sid), Some(home));
        for s in shards {
            s.shutdown();
        }
    }

    #[test]
    fn migrating_an_unknown_session_is_a_typed_error() {
        let shards = native_shards(2);
        let mut r = router_over(&shards);
        assert!(matches!(
            r.migrate(0xBEEF, 1),
            Err(RouteError::UnknownSession(0xBEEF))
        ));
        for s in shards {
            s.shutdown();
        }
    }

    #[test]
    fn end_session_forgets_residency() {
        let shards = native_shards(2);
        let mut r = router_over(&shards);
        let sid = 3u64;
        r.submit_in_session(sid, vec![1, 2], 2).unwrap();
        assert!(r.shard_of(sid).is_some());
        r.end_session(sid).unwrap();
        assert_eq!(r.shard_of(sid), None);
        for s in shards {
            s.shutdown();
        }
    }
}
