//! The client-facing front door: consistent-hashes session ids across N
//! shard servers for affinity, relays token streams as they are decoded,
//! performs **two-phase live session migration**, and survives shard
//! failure by resurrecting sessions from its transcript mirror.
//!
//! * **Placement.**  Session ids map onto a hash ring (every shard
//!   contributes [`VNODES`] virtual points, hashed from its address with
//!   the stable FNV so placement survives restarts); one-shot requests
//!   round-robin.  A session served once is pinned in the router's
//!   `resident` map, so affinity holds even after the ring changes — the
//!   ring decides *initial* placement, residency decides routing.
//! * **Streaming.**  Generation requests are relayed token-by-token: the
//!   shard writes a `Token` frame per decode step and the router invokes
//!   the caller's `on_token` as each arrives, so wire time-to-first-token
//!   equals the engine's.  The buffered `submit*` wrappers collect the
//!   same stream into a `Vec`.
//! * **Circuit breaking.**  Each shard has a [`Breaker`]; transport
//!   failures trip it and an open circuit refuses requests *immediately*
//!   with the typed [`RouteError::ShardUnavailable`] instead of eating a
//!   connect timeout per call.  [`Router::probe_all`] (driven by the
//!   front server's probe thread) doubles as the half-open prober.
//! * **Migration.**  `migrate` quiesces the session on its source shard
//!   (the coordinator's deferred-until-quiescent export), which *stashes*
//!   it source-side, ships the blob + transcript, and imports it on the
//!   target.  The router then settles the stash with an explicit
//!   `ExportCommit` (landed) or `ExportAbort` (did not land); when the
//!   import's Ok is lost in transit the router probes the target's
//!   transcript and the answer decides commit vs abort — closing the
//!   lost-Ok duplicate window the old one-shot handshake documented.
//!   Settlement is idempotent, so every retry is safe.
//! * **Resurrection.**  The router mirrors every session's transcript
//!   (it sees every turn).  When a shard dies mid-conversation the next
//!   turn re-imports the mirror onto a healthy shard (transcript-only:
//!   re-prefill rebuilds the O(1) recurrence state) and strictly replays
//!   the turn — greedy decode is deterministic, so the regenerated tokens
//!   are identical and only the suffix the client has not seen is
//!   emitted.  Lossy in latency, lossless in tokens.
//! * **Durability.**  With a write-ahead journal attached
//!   ([`Router::attach_journal`]) every completed turn is appended to a
//!   checksummed log *before* it is acked, and a restarted router replays
//!   the journal to rebuild its transcript mirror — so acked turns
//!   survive a router crash, and a retried turn from the
//!   crash-after-append-before-ack window is answered from the journal
//!   exactly once instead of forking the transcript.
//! * **Fault injection.**  All shard i/o funnels through [`Conn`], whose
//!   send/recv/stream hooks consult an optional [`FaultPlan`] — the chaos
//!   tests sever, drop, delay, or corrupt frames at named protocol points
//!   deterministically.
//!
//! The router is a plain struct driven by one thread (tests, the CLI
//! demo); the concurrent front door ([`super::front`]) wraps it in a
//! `Mutex` held for the whole relayed call — which is also what makes a
//! mid-stream drain wait for the stream to finish.

use std::collections::{BTreeMap, HashMap};
use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::circuit::{Breaker, BreakerConfig, BreakerState, BreakerStats};
use super::faults::{FaultAction, FaultPlan, FrameKind, Point};
use super::wire::{
    self, fnv1a64, splitmix64, ErrCode, Frame, HealthReport, SessionBlob, MAX_FRAME_BYTES,
    PROTO_VERSION,
};
use crate::obs::{Hist, HopReport, MetricValue, Snapshot};
use crate::session::{Journal, JournalStats, Replay};

/// Virtual ring points per shard: enough that removing one shard moves
/// only ~1/N of the id space.
pub const VNODES: usize = 32;

/// How long the router waits for one reply frame.  Export waits for the
/// session to quiesce, so this must comfortably exceed a turn's decode
/// time.
const REPLY_TIMEOUT: Duration = Duration::from_secs(300);

/// How long a TCP connect to a shard may take before it counts as a
/// breaker failure.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// How long one frame write to a shard may block before it counts as a
/// transport failure (a wedged peer must not hang the router forever).
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Why a routed operation failed.
#[derive(Debug)]
pub enum RouteError {
    Io(io::Error),
    /// No live (non-draining) shard can take the work.
    NoShards,
    /// The explicit migration target is draining and takes no sessions.
    Draining(usize),
    /// The session is unknown — to the router, or to the shard a strict
    /// resume was sent to (and no transcript mirror exists to resurrect
    /// it from).
    UnknownSession(u64),
    /// Migration refused: source and target shards disagree on engine tag
    /// or shape fingerprint (or the target rejected the blob).  The
    /// session still lives on its source shard.
    Mismatch(String),
    /// The shard's circuit breaker is open: the request was refused
    /// immediately, without a connect attempt.
    ShardUnavailable { shard: usize },
    /// A shard replied with an error frame.
    Shard(ErrCode, String),
    /// A shard replied out of protocol.
    Protocol(String),
    /// Admission refused: the shard's queue is full (or every failover
    /// candidate's was).  The turn was never applied, so retrying after
    /// backoff is safe.
    Overloaded,
    /// The request's deadline budget ran out — shed from a shard's queue,
    /// or caught router-side before a send or retry.  Never retried: the
    /// client's budget is spent no matter which hop noticed first.
    DeadlineExceeded,
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::Io(e) => write!(f, "shard i/o: {e}"),
            RouteError::NoShards => write!(f, "no live shards"),
            RouteError::Draining(i) => {
                write!(f, "shard {i} is draining and takes no sessions")
            }
            RouteError::UnknownSession(id) => write!(f, "session {id:#x} unknown"),
            RouteError::Mismatch(msg) => write!(f, "migration mismatch: {msg}"),
            RouteError::ShardUnavailable { shard } => {
                write!(f, "shard {shard} unavailable: circuit open, refused without a connect")
            }
            RouteError::Shard(code, msg) => write!(f, "shard error {code:?}: {msg}"),
            RouteError::Protocol(msg) => write!(f, "protocol violation: {msg}"),
            RouteError::Overloaded => write!(f, "overloaded: admission queue full, retry later"),
            RouteError::DeadlineExceeded => write!(f, "deadline budget exhausted"),
        }
    }
}

impl std::error::Error for RouteError {}

impl From<io::Error> for RouteError {
    fn from(e: io::Error) -> RouteError {
        RouteError::Io(e)
    }
}

/// A shard's handshake identity (from its Hello frame): the triple a
/// session blob must match end-to-end before migration ships it.
#[derive(Clone, Debug, PartialEq, Eq)]
struct Identity {
    engine: String,
    shape_fp: u64,
    weights_fp: u64,
}

/// What the router knows about one shard.
#[derive(Clone, Debug)]
struct ShardInfo {
    addr: SocketAddr,
    /// Handshake identity from the shard's Hello.
    id: Identity,
    /// Draining shards serve their resident sessions but take no new
    /// placements; `drain` empties them.
    draining: bool,
}

/// One wire conversation with a shard (connect, Hello, then pipelined
/// request/reply).  Connections are per-call: loopback connects are
/// cheap, and every connection re-validates the handshake.
///
/// Every read and write passes through a fault hook: with a [`FaultPlan`]
/// attached, the plan may drop, sever, delay, or corrupt at that point;
/// without one each hook is a single `Option` check.
struct Conn {
    stream: TcpStream,
    addr: SocketAddr,
    faults: Option<Arc<FaultPlan>>,
    /// Kind of the last request written (keys the `RecvReplyTo` hook).
    last_req: Option<FrameKind>,
    /// Span report the shard streamed back for a traced generation
    /// (`Frame::Spans`, arriving between the last `Token` and `Done`).
    spans: Option<(u64, Vec<HopReport>)>,
}

impl Conn {
    fn open(
        addr: SocketAddr,
        faults: Option<Arc<FaultPlan>>,
        auth: Option<&str>,
    ) -> Result<(Conn, Identity), RouteError> {
        if let Some(plan) = &faults {
            if plan.is_killed(addr) {
                return Err(RouteError::Io(io::Error::new(
                    io::ErrorKind::ConnectionRefused,
                    format!("shard {addr} is down (injected kill)"),
                )));
            }
            if plan.fire(addr, Point::Connect).is_some() {
                return Err(RouteError::Io(io::Error::new(
                    io::ErrorKind::ConnectionRefused,
                    format!("connect to {addr} refused (injected fault)"),
                )));
            }
        }
        let mut stream = TcpStream::connect_timeout(&addr, CONNECT_TIMEOUT)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(REPLY_TIMEOUT))?;
        stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
        match wire::read_frame(&mut stream)? {
            Frame::Hello { proto, engine, shape_fp, weights_fp } => {
                if proto != PROTO_VERSION {
                    return Err(RouteError::Mismatch(format!(
                        "shard {addr} speaks protocol {proto}, router speaks {PROTO_VERSION}"
                    )));
                }
                let mut conn = Conn { stream, addr, faults, last_req: None, spans: None };
                // shared-secret handshake (fire-and-forget): success earns
                // no reply, so no round trip is spent here; a mismatch is
                // refused with the typed AuthFailed, read at the next reply
                if let Some(token) = auth {
                    wire::write_frame(&mut conn.stream, &Frame::Auth { token: token.to_string() })?;
                }
                Ok((conn, Identity { engine, shape_fp, weights_fp }))
            }
            other => Err(RouteError::Protocol(format!("expected Hello, got {other:?}"))),
        }
    }

    fn sever(&mut self) {
        let _ = self.stream.shutdown(Shutdown::Both);
    }

    /// Write one request frame through the `Send` fault hook.
    fn send(&mut self, f: &Frame) -> io::Result<()> {
        let kind = FrameKind::of(f);
        self.last_req = Some(kind);
        let action =
            self.faults.as_ref().and_then(|p| p.fire(self.addr, Point::Send(kind)));
        match action {
            None => wire::write_frame(&mut self.stream, f),
            Some(FaultAction::DropFrame) => {
                // the shard never sees the request
                self.sever();
                Err(io::Error::new(
                    io::ErrorKind::ConnectionReset,
                    "connection reset (injected: request dropped)",
                ))
            }
            Some(FaultAction::SeverAfter) => {
                // the shard sees (and acts on) the request; the reply
                // will never be read
                wire::write_frame(&mut self.stream, f)?;
                self.sever();
                Ok(())
            }
            Some(FaultAction::Delay(d)) => {
                std::thread::sleep(d);
                wire::write_frame(&mut self.stream, f)
            }
            Some(FaultAction::Corrupt) => {
                let mut framed = Vec::new();
                wire::write_frame(&mut framed, f)?;
                if let Some(b) = framed.last_mut() {
                    *b ^= 0x01;
                }
                self.stream.write_all(&framed)
            }
        }
    }

    /// Read one reply frame through the `RecvReplyTo` fault hook.
    fn recv_reply(&mut self) -> io::Result<Frame> {
        let action = match (&self.faults, self.last_req) {
            (Some(p), Some(kind)) => p.fire(self.addr, Point::RecvReplyTo(kind)),
            _ => None,
        };
        match action {
            None => wire::read_frame(&mut self.stream),
            Some(FaultAction::DropFrame) => {
                // the canonical "applied but unacknowledged" window: the
                // shard processed the request and answered; the reply is
                // consumed and discarded so the router never hears
                let _ = wire::read_frame(&mut self.stream);
                self.sever();
                Err(io::Error::new(
                    io::ErrorKind::ConnectionReset,
                    "connection reset (injected: reply dropped)",
                ))
            }
            Some(FaultAction::SeverAfter) => {
                let reply = wire::read_frame(&mut self.stream)?;
                self.sever();
                Ok(reply)
            }
            Some(FaultAction::Delay(d)) => {
                std::thread::sleep(d);
                wire::read_frame(&mut self.stream)
            }
            Some(FaultAction::Corrupt) => {
                let mut len = [0u8; 4];
                self.stream.read_exact(&mut len)?;
                let len = u32::from_le_bytes(len);
                if len as u64 > MAX_FRAME_BYTES as u64 {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        "reply frame exceeds MAX_FRAME_BYTES",
                    ));
                }
                let mut body = vec![0u8; len as usize];
                self.stream.read_exact(&mut body)?;
                if let Some(b) = body.last_mut() {
                    *b ^= 0x01;
                }
                wire::decode(&body)
            }
        }
    }

    /// Send one request and read one reply frame (error frames become
    /// [`RouteError::Shard`]).
    fn request(&mut self, f: &Frame) -> Result<Frame, RouteError> {
        self.send(f)?;
        match self.recv_reply()? {
            Frame::Error { code, msg } => Err(RouteError::Shard(code, msg)),
            reply => Ok(reply),
        }
    }

    /// Send one generation request and relay the streamed tokens:
    /// `on_token` runs per `Token` frame, as it arrives.  The collected
    /// tokens are returned when the shard's `Done` frame lands.
    fn generate_streaming(
        &mut self,
        f: &Frame,
        mut on_token: impl FnMut(i32),
    ) -> Result<Vec<i32>, RouteError> {
        self.send(f)?;
        let mut toks: Vec<i32> = Vec::new();
        loop {
            let action = self.faults.as_ref().and_then(|p| {
                p.fire(self.addr, Point::TokenStream { after: toks.len() as u32 })
            });
            if let Some(action) = action {
                match action {
                    FaultAction::Delay(d) => std::thread::sleep(d),
                    _ => {
                        self.sever();
                        return Err(RouteError::Io(io::Error::new(
                            io::ErrorKind::ConnectionReset,
                            "token stream severed (injected fault)",
                        )));
                    }
                }
            }
            match wire::read_frame(&mut self.stream)? {
                Frame::Token { token } => {
                    toks.push(token);
                    on_token(token);
                }
                Frame::Spans { trace, hops } => self.spans = Some((trace, hops)),
                Frame::Done { .. } => return Ok(toks),
                Frame::Error { code, msg } => return Err(RouteError::Shard(code, msg)),
                other => {
                    return Err(RouteError::Protocol(format!(
                        "expected Token/Done, got {other:?}"
                    )))
                }
            }
        }
    }
}

/// Lifetime counts of the router's session-movement machinery.  An
/// attempt that fails before commit/abort settlement (e.g. the export
/// itself was refused) counts only as an attempt, so
/// `attempts >= commits + aborts` always holds.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MigrationStats {
    /// Migrations that passed the identity checks and started moving.
    pub attempts: u64,
    /// Migrations whose import landed (source stash discarded).
    pub commits: u64,
    /// Migrations rolled back to the source (stash re-imported).
    pub aborts: u64,
    /// Sessions rebuilt from the transcript mirror after shard loss.
    pub resurrections: u64,
}

/// Per-request retry budget with jittered exponential backoff, applied
/// to the router's idempotent retry paths (export settlement, retry-in-
/// place after a severed stream, bulk-drain settlement).  The jitter is
/// deterministic — [`splitmix64`] over an internal counter — so replayed
/// runs pause identically and no ambient entropy leaks into tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Retries *beyond* the first attempt (0 = never retry).
    pub max_attempts: u32,
    /// Backoff before retry k is `base * 2^k`, jittered, capped below.
    pub base: Duration,
    /// Upper bound on any single backoff pause.
    pub cap: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 3,
            base: Duration::from_millis(5),
            cap: Duration::from_millis(100),
        }
    }
}

impl RetryPolicy {
    /// The pause before retry `attempt` (0-based), using `seq` as the
    /// jitter source: full exponential value, then uniformly jittered to
    /// [half, full] so synchronized retriers decorrelate without ever
    /// collapsing to zero wait.
    fn backoff(&self, attempt: u32, seq: u64) -> Duration {
        let exp_ms = (self.base.as_millis() as u64)
            .saturating_mul(1u64 << attempt.min(16))
            .min(self.cap.as_millis() as u64)
            .max(1);
        let jitter = splitmix64(seq) % (exp_ms / 2 + 1);
        Duration::from_millis(exp_ms - jitter)
    }
}

/// Is this failure worth spending retry budget on?  Transport failures
/// and open circuits may heal; `Overloaded` clears when queues drain.
/// `DeadlineExceeded` never retries — the budget is spent regardless of
/// which hop noticed.
fn retryable(e: &RouteError) -> bool {
    matches!(
        e,
        RouteError::Io(_) | RouteError::ShardUnavailable { .. } | RouteError::Overloaded
    )
}

/// Collapse typed shard error frames into the router's own typed
/// variants, so callers match on `RouteError::Overloaded` /
/// `RouteError::DeadlineExceeded` regardless of which hop refused.
fn lift_refusal(e: RouteError) -> RouteError {
    match e {
        RouteError::Shard(ErrCode::Overloaded, _) => RouteError::Overloaded,
        RouteError::Shard(ErrCode::DeadlineExceeded, _) => RouteError::DeadlineExceeded,
        other => other,
    }
}

/// Remaining deadline budget in whole milliseconds for the wire
/// (`deadline_ms`; 0 = no deadline).  A budget that has already expired
/// is refused here, before any bytes move.
fn remaining_ms(deadline: Option<Instant>) -> Result<u32, RouteError> {
    match deadline {
        None => Ok(0),
        Some(d) => {
            let left = d.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(RouteError::DeadlineExceeded);
            }
            Ok(left.as_millis().clamp(1, u32::MAX as u128) as u32)
        }
    }
}

/// Trace context for the request currently being routed.  Armed by
/// [`Router::begin_trace`] (the front door, under its router lock, just
/// before the routed call) and harvested by [`Router::take_trace`] just
/// after: the router is driven by one thread per request, so one pending
/// context is exactly enough.  All timings are durations relative to the
/// context's own `t0` — never absolute timestamps — so reports from
/// different hosts join without clock agreement.
struct TraceCtx {
    /// Wire trace id (nonzero by construction).
    trace: u64,
    /// Ask the shard's engine for per-stage hot-path timings.
    profile: bool,
    /// When the router took custody of the request.
    t0: Instant,
    /// Routing events worth surfacing on the router hop: `retry:N`,
    /// `resurrected`, `reconciled`, `journal-dedup`.
    notes: Vec<String>,
    /// Downstream span reports (shard → coordinator → engine) from the
    /// attempt that actually completed.
    hops: Vec<HopReport>,
}

/// The sharded front door.
pub struct Router {
    shards: Vec<ShardInfo>,
    /// Sorted (point, shard) ring over the non-draining shards.
    ring: Vec<(u64, usize)>,
    /// Which shard currently owns each session (authoritative: the router
    /// is the only front door, and migration updates it).
    resident: HashMap<u64, usize>,
    /// Full transcript per session, as relayed through this router: the
    /// raw material for resurrection when a shard dies.  Cheap — tokens,
    /// not state blobs.
    mirror: HashMap<u64, Vec<i32>>,
    /// One circuit breaker per shard, indexed like `shards`.
    breakers: Vec<Breaker>,
    /// Breaker tuning, kept so `add_shard` can mint matching breakers.
    breaker_cfg: BreakerConfig,
    /// Optional fault-injection plan threaded into every [`Conn`].
    faults: Option<Arc<FaultPlan>>,
    /// Round-robin cursor for one-shot requests.
    rr: usize,
    /// Router-observed round-trip latency per shard, indexed like
    /// `shards` (bounded: one fixed-bucket histogram per shard).
    route_hist: Vec<Hist>,
    /// Lifetime migration/resurrection counts.
    migrations: MigrationStats,
    /// Shards that failed to answer a metrics pull (cumulative).
    scrape_errors: u64,
    /// Retry budget + backoff tuning for the idempotent retry paths.
    retry: RetryPolicy,
    /// Monotone jitter counter: each backoff pause consumes one value.
    retry_seq: u64,
    /// Lifetime retries spent from per-request budgets (`lh_retries_total`).
    retries: u64,
    /// Optional write-ahead turn journal: every completed turn is
    /// appended (durable per the configured fsync policy) *before* the
    /// mirror is extended and the turn acked, and the mirror is rebuilt
    /// from it on cold start ([`Router::attach_journal`]).
    journal: Option<Journal>,
    /// Per-session duplicate-turn window rebuilt from journal replay:
    /// the last journaled (delta, gen) per session.  A post-restart turn
    /// whose delta matches is a client retry of a turn that was appended
    /// but never acked (the crash landed between the two); it is answered
    /// from here without re-applying to any shard.
    replay_dedup: HashMap<u64, (Vec<i32>, Vec<i32>)>,
    /// Shared-secret token presented on every shard connection.
    auth: Option<Arc<String>>,
    /// Trace context for the in-flight routed request, if traced.
    trace_ctx: Option<TraceCtx>,
}

impl Router {
    /// Connect to every shard, record its handshake identity, and build
    /// the ring.  Shards may be heterogeneous (different engines); the
    /// migration path is what insists on matching identities.
    pub fn new(addrs: &[SocketAddr]) -> Result<Router, RouteError> {
        Router::new_with(addrs, BreakerConfig::default(), None)
    }

    /// [`Router::new`] with explicit breaker tuning and an optional fault
    /// plan (chaos tests pin cooldowns and stage faults through these).
    pub fn new_with(
        addrs: &[SocketAddr],
        breaker_cfg: BreakerConfig,
        faults: Option<Arc<FaultPlan>>,
    ) -> Result<Router, RouteError> {
        Router::new_with_auth(addrs, breaker_cfg, faults, None)
    }

    /// [`Router::new_with`] plus a shared-secret token presented to every
    /// shard right after its Hello (see [`super::shard`] for the server
    /// side of the v5 handshake).
    pub fn new_with_auth(
        addrs: &[SocketAddr],
        breaker_cfg: BreakerConfig,
        faults: Option<Arc<FaultPlan>>,
        auth: Option<String>,
    ) -> Result<Router, RouteError> {
        let auth: Option<Arc<String>> = auth.map(Arc::new);
        if addrs.is_empty() {
            return Err(RouteError::NoShards);
        }
        let mut shards = Vec::with_capacity(addrs.len());
        for &addr in addrs {
            let (_conn, id) = Conn::open(addr, faults.clone(), auth.as_ref().map(|a| a.as_str()))?;
            shards.push(ShardInfo { addr, id, draining: false });
        }
        let breakers = addrs.iter().map(|_| Breaker::new(breaker_cfg)).collect();
        let route_hist = addrs.iter().map(|_| Hist::new()).collect();
        let mut r = Router {
            shards,
            ring: Vec::new(),
            resident: HashMap::new(),
            mirror: HashMap::new(),
            breakers,
            breaker_cfg,
            faults,
            rr: 0,
            route_hist,
            migrations: MigrationStats::default(),
            scrape_errors: 0,
            retry: RetryPolicy::default(),
            retry_seq: 0,
            retries: 0,
            journal: None,
            replay_dedup: HashMap::new(),
            auth,
            trace_ctx: None,
        };
        r.rebuild_ring();
        Ok(r)
    }

    /// Attach a write-ahead journal together with the replay of whatever
    /// it already holds: the transcript mirror is seeded from the replayed
    /// sessions (so strict routing and resurrection work across a process
    /// restart with zero acked turns lost), and each session's last
    /// journaled turn arms the duplicate-turn window that closes the
    /// crash-after-append-before-ack gap.  The router's fault plan is
    /// threaded into the journal so chaos tests drive its crash points.
    pub fn attach_journal(&mut self, mut journal: Journal, replay: Replay) {
        journal.set_faults(self.faults.clone());
        self.replay_dedup = replay.last_turn;
        for (sid, transcript) in replay.sessions {
            self.mirror.insert(sid, transcript);
        }
        self.journal = Some(journal);
    }

    /// Lifetime journal counters (`None` when no journal is attached).
    pub fn journal_stats(&self) -> Option<JournalStats> {
        self.journal.as_ref().map(|j| j.stats())
    }

    /// Force any batched-but-unsynced journal bytes to disk (shutdown
    /// path; with `FsyncPolicy::PerRecord` this is a no-op).
    pub fn flush_journal(&mut self) -> io::Result<()> {
        if let Some(j) = self.journal.as_mut() {
            j.flush().map_err(|e| io::Error::new(io::ErrorKind::BrokenPipe, e.to_string()))?;
        }
        Ok(())
    }

    /// Number of shards (including draining ones).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Which shard currently owns a session, if the router has seen it.
    pub fn shard_of(&self, session: u64) -> Option<usize> {
        self.resident.get(&session).copied()
    }

    /// Sessions resident on one shard (router's view).
    pub fn sessions_on(&self, shard: usize) -> Vec<u64> {
        let mut ids: Vec<u64> = self
            .resident
            .iter()
            .filter(|(_, &s)| s == shard)
            .map(|(&id, _)| id)
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Observable circuit state of one shard's breaker.
    pub fn breaker_state(&self, shard: usize) -> Option<BreakerState> {
        self.breakers.get(shard).map(|b| b.state())
    }

    /// The Hello the front door greets clients with: the cluster launcher
    /// seeds every shard identically, so shard 0's identity speaks for
    /// the cluster (heterogeneous clusters advertise their first shard).
    pub(crate) fn front_hello(&self) -> Frame {
        let id = &self.shards[0].id;
        Frame::Hello {
            proto: PROTO_VERSION,
            engine: id.engine.clone(),
            shape_fp: id.shape_fp,
            weights_fp: id.weights_fp,
        }
    }

    /// The router's transcript mirror for a session (what resurrection
    /// would rebuild from).
    pub fn mirror_of(&self, session: u64) -> Option<&[i32]> {
        self.mirror.get(&session).map(|v| v.as_slice())
    }

    /// Is the session pinned to a shard (served at least once and not
    /// ended)?  The front door's two-priority admission gate prefers
    /// resident sessions: their next turn is a cheap state resume, while
    /// a cold session costs a full prefill.
    pub fn is_resident(&self, session: u64) -> bool {
        self.resident.contains_key(&session)
    }

    /// Replace the retry budget / backoff tuning (tests pin this to
    /// zero-wait or zero-budget policies).
    pub fn set_retry_policy(&mut self, p: RetryPolicy) {
        self.retry = p;
    }

    /// Lifetime retries spent from per-request retry budgets.
    pub fn retries_spent(&self) -> u64 {
        self.retries
    }

    /// Arm tracing for the next routed call: the Submit/SubmitInSession
    /// frames it sends will carry `trace` (and `profile`), the shard's
    /// `Spans` report is captured, and routing events (retries,
    /// resurrection) are noted.  `trace == 0` disarms (untraced requests
    /// pay nothing beyond this `Option` store).  The front door calls
    /// this under its router lock immediately before the routed call and
    /// harvests with [`Router::take_trace`] immediately after.
    pub fn begin_trace(&mut self, trace: u64, profile: bool) {
        self.trace_ctx = (trace != 0).then(|| TraceCtx {
            trace,
            profile,
            t0: Instant::now(),
            notes: Vec::new(),
            hops: Vec::new(),
        });
    }

    /// Harvest the armed trace: a "router" hop (total custody time plus
    /// any routing notes) followed by the downstream span reports from
    /// the attempt that completed.  Empty when tracing was not armed.
    pub fn take_trace(&mut self) -> Vec<HopReport> {
        match self.trace_ctx.take() {
            None => Vec::new(),
            Some(ctx) => {
                let mut hop = HopReport::new("router", ctx.t0.elapsed().as_micros() as u64);
                hop.notes = ctx.notes;
                let mut hops = vec![hop];
                hops.extend(ctx.hops);
                hops
            }
        }
    }

    /// (trace, profile) to stamp into the next generation frame.
    fn trace_req(&self) -> (u64, bool) {
        self.trace_ctx.as_ref().map(|c| (c.trace, c.profile)).unwrap_or((0, false))
    }

    /// Note a routing event on the armed trace (no-op when untraced).
    fn trace_note(&mut self, note: String) {
        if let Some(ctx) = self.trace_ctx.as_mut() {
            ctx.notes.push(note);
        }
    }

    /// Absorb the `Spans` report a connection captured into the armed
    /// trace.  The id must match: a stale report from a half-dead retry
    /// must not masquerade as the completed attempt's timeline.
    fn trace_absorb(&mut self, conn: &mut Conn) {
        if let Some((t, hops)) = conn.spans.take() {
            if let Some(ctx) = self.trace_ctx.as_mut() {
                if ctx.trace == t {
                    ctx.hops = hops;
                }
            }
        }
    }

    /// Spend one unit of retry budget: pause for the jittered backoff
    /// (deterministic: the jitter source is an internal counter) and
    /// count the retry.  Refuses with [`RouteError::DeadlineExceeded`]
    /// instead of pausing across the caller's deadline.
    fn backoff_pause(&mut self, attempt: u32, deadline: Option<Instant>) -> Result<(), RouteError> {
        let pause = self.retry.backoff(attempt, self.retry_seq);
        self.retry_seq = self.retry_seq.wrapping_add(1);
        if let Some(d) = deadline {
            if Instant::now() + pause >= d {
                return Err(RouteError::DeadlineExceeded);
            }
        }
        self.retries += 1;
        std::thread::sleep(pause);
        Ok(())
    }

    fn rebuild_ring(&mut self) {
        self.ring.clear();
        for (i, s) in self.shards.iter().enumerate() {
            if s.draining {
                continue;
            }
            for v in 0..VNODES {
                let key = format!("{}#{v}", s.addr);
                self.ring.push((fnv1a64(key.as_bytes()), i));
            }
        }
        self.ring.sort_unstable();
    }

    /// Ring lookup: first point clockwise of the session's hash.
    fn ring_target(&self, session: u64) -> Option<usize> {
        if self.ring.is_empty() {
            return None;
        }
        let h = splitmix64(session);
        let idx = self.ring.partition_point(|&(p, _)| p < h);
        let (_, shard) = self.ring[idx % self.ring.len()];
        Some(shard)
    }

    /// Shard a session turn routes to: pinned residency first, ring
    /// placement for sessions the router has not seen.
    fn route_session(&self, session: u64) -> Result<usize, RouteError> {
        if let Some(&s) = self.resident.get(&session) {
            return Ok(s);
        }
        self.ring_target(session).ok_or(RouteError::NoShards)
    }

    /// Open a breaker-guarded connection to a shard.  An open circuit
    /// refuses immediately with the typed error; connect failures are the
    /// caller's to record (exactly once per logical attempt).
    fn open_shard(&mut self, shard: usize) -> Result<Conn, RouteError> {
        if !self.breakers[shard].allow() {
            return Err(RouteError::ShardUnavailable { shard });
        }
        let (conn, _id) = Conn::open(
            self.shards[shard].addr,
            self.faults.clone(),
            self.auth.as_ref().map(|a| a.as_str()),
        )?;
        Ok(conn)
    }

    /// Record the outcome of one attempt against a shard on its breaker.
    /// Only transport-level failures count — a typed shard error (e.g.
    /// `UnknownSession`) means the shard is alive and answering.
    fn note_outcome(&mut self, shard: usize, err: Option<&RouteError>) {
        match err {
            None => self.breakers[shard].record_success(),
            Some(RouteError::Io(_)) => self.breakers[shard].record_failure(),
            Some(_) => {}
        }
    }

    /// Record a completed turn: journal it, extend the transcript mirror,
    /// and pin residency.  The mirror tracks exactly what the shard's
    /// store holds: prompt ++ generated, per turn.
    ///
    /// Ordering is the durability contract: the journal append (durable
    /// per the configured fsync policy) happens *before* this method
    /// returns and the turn is acked to the caller.  A crash after the
    /// append replays the turn on restart; a crash before it means the
    /// caller never saw an ack — at-least-once either way, and the
    /// replayed dedup window upgrades the append-but-no-ack case to
    /// exactly-once.  An append *error* is absorbed (counted in
    /// `lh_journal_append_errors_total`): the turn already happened on the
    /// shard, so refusing the ack would only manufacture a divergence.
    fn note_turn(&mut self, session: u64, shard: usize, delta: &[i32], toks: &[i32]) {
        if let Some(j) = self.journal.as_mut() {
            let prior = self.mirror.get(&session).map(|m| m.len()).unwrap_or(0);
            let _ = j.append_turn(session, prior as u32, delta, toks);
        }
        let m = self.mirror.entry(session).or_default();
        m.extend_from_slice(delta);
        m.extend_from_slice(toks);
        self.resident.insert(session, shard);
        self.replay_dedup.remove(&session);
        if let Some(mut j) = self.journal.take() {
            let _ = j.maybe_compact(&self.mirror);
            self.journal = Some(j);
        }
    }

    /// Journal the mirror's current transcript as an absolute `Set`
    /// record — used wherever the mirror is *replaced* rather than
    /// extended by a turn (migration landing, recovery reconcile, drain).
    fn journal_set(&mut self, session: u64) {
        self.replay_dedup.remove(&session);
        if let Some(mut j) = self.journal.take() {
            if let Some(m) = self.mirror.get(&session) {
                let _ = j.append_set(session, m);
            }
            self.journal = Some(j);
        }
    }

    /// One-shot generation, round-robined over the live shards.  Fails
    /// over to the next live shard only while zero tokens have been
    /// emitted (a half-streamed one-shot cannot be transparently retried).
    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize) -> Result<Vec<i32>, RouteError> {
        self.submit_streaming(prompt, max_new, |_| {})
    }

    /// Streaming one-shot: `on_token` runs per relayed token.
    pub fn submit_streaming(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
        on_token: impl FnMut(i32),
    ) -> Result<Vec<i32>, RouteError> {
        self.submit_streaming_deadline(prompt, max_new, None, on_token)
    }

    /// [`Router::submit_streaming`] under a deadline: the remaining
    /// budget is re-derived immediately before each attempt and travels
    /// as `deadline_ms` so the shard's admission queue can shed the work
    /// if it goes stale there.  An `Overloaded` shard is failed over like
    /// a dead one (the turn was never applied); `DeadlineExceeded` is
    /// surfaced immediately — the budget is spent wherever we'd go next.
    pub fn submit_streaming_deadline(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
        deadline: Option<Instant>,
        mut on_token: impl FnMut(i32),
    ) -> Result<Vec<i32>, RouteError> {
        let live: Vec<usize> = (0..self.shards.len())
            .filter(|&i| !self.shards[i].draining)
            .collect();
        if live.is_empty() {
            return Err(RouteError::NoShards);
        }
        let base = self.rr;
        self.rr = self.rr.wrapping_add(1);
        let mut last = RouteError::NoShards;
        for k in 0..live.len() {
            let deadline_ms = remaining_ms(deadline)?;
            let shard = live[(base + k) % live.len()];
            if k > 0 {
                self.trace_note(format!("retry:{k}"));
            }
            let mut conn = match self.open_shard(shard) {
                Ok(c) => c,
                Err(e) => {
                    self.note_outcome(shard, Some(&e));
                    last = e;
                    continue;
                }
            };
            let mut emitted = 0usize;
            let (trace, profile) = self.trace_req();
            let req = Frame::Submit {
                max_new: max_new as u32,
                deadline_ms,
                trace,
                profile,
                prompt: prompt.clone(),
            };
            let t0 = Instant::now();
            match conn.generate_streaming(&req, |t| {
                emitted += 1;
                on_token(t);
            }) {
                Ok(toks) => {
                    self.trace_absorb(&mut conn);
                    self.route_hist[shard].record(t0.elapsed().as_secs_f64());
                    self.note_outcome(shard, None);
                    return Ok(toks);
                }
                Err(e) if emitted == 0 => {
                    let e = lift_refusal(e);
                    self.note_outcome(shard, Some(&e));
                    if matches!(e, RouteError::DeadlineExceeded) {
                        return Err(e);
                    }
                    if !retryable(&e) {
                        return Err(e);
                    }
                    last = e;
                }
                Err(e) => {
                    let e = lift_refusal(e);
                    self.note_outcome(shard, Some(&e));
                    return Err(e);
                }
            }
        }
        Err(last)
    }

    /// One turn of a session, routed with affinity.  Turns after the first
    /// are sent strict, so a shard that somehow lost the session surfaces
    /// the typed [`RouteError::UnknownSession`] instead of silently
    /// forking a fresh conversation — unless the router holds a transcript
    /// mirror, in which case the session is resurrected and the turn
    /// replayed (token-identical: greedy decode is deterministic).
    pub fn submit_in_session(
        &mut self,
        session: u64,
        delta: Vec<i32>,
        max_new: usize,
    ) -> Result<Vec<i32>, RouteError> {
        self.submit_in_session_streaming(session, delta, max_new, |_| {})
    }

    /// Streaming session turn: `on_token` runs per relayed token.  Across
    /// a mid-stream failure + recovery, each token is emitted exactly
    /// once (replays skip the prefix the caller already saw).
    pub fn submit_in_session_streaming(
        &mut self,
        session: u64,
        delta: Vec<i32>,
        max_new: usize,
        on_token: impl FnMut(i32),
    ) -> Result<Vec<i32>, RouteError> {
        self.submit_in_session_streaming_deadline(session, delta, max_new, None, on_token)
    }

    /// [`Router::submit_in_session_streaming`] under a deadline.  The
    /// remaining budget is re-derived before each attempt and shipped as
    /// `deadline_ms`; a shard-side `Overloaded` refusal (the turn was
    /// never applied — the session is intact) is retried in place against
    /// the session's own shard, spending the per-request retry budget
    /// with jittered backoff.  `DeadlineExceeded` is never retried.
    pub fn submit_in_session_streaming_deadline(
        &mut self,
        session: u64,
        delta: Vec<i32>,
        max_new: usize,
        deadline: Option<Instant>,
        mut on_token: impl FnMut(i32),
    ) -> Result<Vec<i32>, RouteError> {
        // crash-window closure: when the last journaled turn for this
        // session was appended but the process died before the ack reached
        // the client, the client retries the identical turn after restart.
        // Re-applying it would fork the transcript (the shard — or the
        // replayed mirror — already holds its effect), so a matching delta
        // is answered from the journal's own record.  The window is one
        // turn deep and disarms on any other activity for the session.
        if let Some((last_delta, gen)) = self.replay_dedup.remove(&session) {
            if last_delta == delta {
                if let Some(j) = self.journal.as_mut() {
                    j.note_dedup();
                }
                self.trace_note("journal-dedup".to_string());
                for &t in &gen {
                    on_token(t);
                }
                return Ok(gen);
            }
        }
        let shard = self.route_session(session)?;
        // strict when the router knows the session — resident on a shard,
        // or mirrored (e.g. rebuilt by journal replay after a restart,
        // when `resident` is empty).  A mirrored-only session must NOT be
        // sent lax: the shard would silently fork a fresh conversation
        // instead of surfacing UnknownSession for the resurrection path.
        let strict =
            self.resident.contains_key(&session) || self.mirror.contains_key(&session);
        let mut attempt_no = 0u32;
        loop {
            let deadline_ms = remaining_ms(deadline)?;
            let mut emitted = 0usize;
            let (trace, profile) = self.trace_req();
            let req = Frame::SubmitInSession {
                session,
                strict,
                max_new: max_new as u32,
                deadline_ms,
                trace,
                profile,
                delta: delta.clone(),
            };
            let t0 = Instant::now();
            let attempt = match self.open_shard(shard) {
                Ok(mut conn) => {
                    let r = conn.generate_streaming(&req, |t| {
                        emitted += 1;
                        on_token(t);
                    });
                    if r.is_ok() {
                        self.trace_absorb(&mut conn);
                    }
                    r
                }
                Err(e) => Err(e),
            };
            return match attempt {
                Ok(toks) => {
                    self.route_hist[shard].record(t0.elapsed().as_secs_f64());
                    self.note_outcome(shard, None);
                    self.note_turn(session, shard, &delta, &toks);
                    Ok(toks)
                }
                Err(RouteError::Shard(ErrCode::UnknownSession, _)) => {
                    // a strict resume the shard refused: resurrect from the
                    // mirror if we hold one, else surface the typed error
                    if strict && self.mirror.contains_key(&session) {
                        self.resurrect_turn(
                            session, &delta, max_new, deadline, emitted, &mut on_token,
                        )
                    } else {
                        Err(RouteError::UnknownSession(session))
                    }
                }
                Err(RouteError::Shard(ErrCode::Overloaded, _)) if emitted == 0 => {
                    // admission refused: the session is untouched on its
                    // shard, so an in-place retry after backoff is safe
                    if attempt_no < self.retry.max_attempts {
                        self.backoff_pause(attempt_no, deadline)?;
                        attempt_no += 1;
                        self.trace_note(format!("retry:{attempt_no}"));
                        continue;
                    }
                    Err(RouteError::Overloaded)
                }
                Err(e)
                    if strict
                        && matches!(
                            e,
                            RouteError::Io(_) | RouteError::ShardUnavailable { .. }
                        ) =>
                {
                    self.note_outcome(shard, Some(&e));
                    self.recover_turn(
                        session, shard, &delta, max_new, deadline, emitted, &mut on_token, e,
                    )
                }
                Err(e) => {
                    let e = lift_refusal(e);
                    self.note_outcome(shard, Some(&e));
                    Err(e)
                }
            };
        }
    }

    /// A strict turn died at the transport level.  Three escalating
    /// recoveries:
    ///
    /// 1. **Reconcile** — the shard may have finished the turn even though
    ///    our stream died (the coordinator keeps decoding when the relay
    ///    drops).  The transcript probe defers until the session is
    ///    quiescent, so it reflects the finished turn; if it lines up,
    ///    emit the unseen suffix and accept without replaying.
    /// 2. **Retry in place** — the transcript is exactly the pre-turn
    ///    mirror, so the request never reached the coordinator and the
    ///    session is intact: send the turn again (up to the per-request
    ///    retry budget, with jittered backoff between attempts).
    /// 3. **Resurrect** — the shard is gone (or inconsistent): rebuild
    ///    the session elsewhere from the mirror and replay.
    #[allow(clippy::too_many_arguments)]
    fn recover_turn(
        &mut self,
        session: u64,
        shard: usize,
        delta: &[i32],
        max_new: usize,
        deadline: Option<Instant>,
        emitted: usize,
        on_token: &mut dyn FnMut(i32),
        cause: RouteError,
    ) -> Result<Vec<i32>, RouteError> {
        let pre_len = self.mirror.get(&session).map(|m| m.len()).unwrap_or(0);
        let mut want = self.mirror.get(&session).cloned().unwrap_or_default();
        want.extend_from_slice(delta);
        if let Ok(Some(tokens)) = self.fetch_transcript(shard, session) {
            if tokens.len() == want.len() + max_new && tokens.starts_with(&want) {
                // the turn completed server-side; deliver what the client
                // has not yet seen
                let generated = tokens[want.len()..].to_vec();
                for &t in &generated[emitted..] {
                    on_token(t);
                }
                self.trace_note("reconciled".to_string());
                self.note_outcome(shard, None);
                self.mirror.insert(session, tokens);
                self.resident.insert(session, shard);
                self.journal_set(session);
                return Ok(generated);
            }
            if emitted == 0 && tokens.len() == pre_len && tokens[..] == want[..pre_len] {
                // the turn never reached the coordinator: the session is
                // intact in place, so retry there — budgeted, backed off.
                // Greedy decode is deterministic, so a replay regenerates
                // the identical tokens and only the unseen suffix is
                // forwarded — a retry that died mid-stream never causes a
                // duplicate emission.
                let mut seen = 0usize;
                for attempt in 0..=self.retry.max_attempts {
                    if attempt > 0 && self.backoff_pause(attempt - 1, deadline).is_err() {
                        return Err(RouteError::DeadlineExceeded);
                    }
                    let deadline_ms = remaining_ms(deadline)?;
                    let Ok(mut conn) = self.open_shard(shard) else { continue };
                    self.trace_note(format!("retry:{}", attempt + 1));
                    let (trace, profile) = self.trace_req();
                    let req = Frame::SubmitInSession {
                        session,
                        strict: true,
                        max_new: max_new as u32,
                        deadline_ms,
                        trace,
                        profile,
                        delta: delta.to_vec(),
                    };
                    let mut streamed = 0usize;
                    match conn.generate_streaming(&req, |t| {
                        streamed += 1;
                        if streamed > seen {
                            on_token(t);
                        }
                    }) {
                        Ok(toks) => {
                            self.trace_absorb(&mut conn);
                            self.note_outcome(shard, None);
                            self.note_turn(session, shard, delta, &toks);
                            return Ok(toks);
                        }
                        Err(_) => seen = seen.max(streamed),
                    }
                }
                // the in-place retries themselves half-streamed: the
                // resurrection replay below must skip what the caller saw
                if seen > 0 {
                    let toks = match self
                        .resurrect_turn(session, delta, max_new, deadline, seen, on_token)
                    {
                        Ok(t) => t,
                        Err(RouteError::NoShards) => return Err(cause),
                        Err(e) => return Err(e),
                    };
                    if self.resident.get(&session) != Some(&shard) {
                        if let Ok(mut conn) = self.open_shard(shard) {
                            let _ = conn.request(&Frame::EndSession { session });
                        }
                    }
                    return Ok(toks);
                }
            }
        }
        let toks =
            match self.resurrect_turn(session, delta, max_new, deadline, emitted, on_token) {
                Ok(t) => t,
                Err(RouteError::NoShards) => return Err(cause),
                Err(e) => return Err(e),
            };
        // the old shard may still hold a now-superseded copy (e.g. the
        // request never arrived but its transcript probe also failed);
        // best-effort end it so the session lives in exactly one place
        if self.resident.get(&session) != Some(&shard) {
            if let Ok(mut conn) = self.open_shard(shard) {
                let _ = conn.request(&Frame::EndSession { session });
            }
        }
        Ok(toks)
    }

    /// Rebuild a lost session from the transcript mirror on a healthy
    /// shard and strictly replay the interrupted turn, emitting only the
    /// tokens the client has not already seen.  Candidates: the ring
    /// target first (where the session would naturally land), then every
    /// other live shard.
    fn resurrect_turn(
        &mut self,
        session: u64,
        delta: &[i32],
        max_new: usize,
        deadline: Option<Instant>,
        emitted: usize,
        on_token: &mut dyn FnMut(i32),
    ) -> Result<Vec<i32>, RouteError> {
        let pre = self.mirror.get(&session).cloned().unwrap_or_default();
        let mut candidates: Vec<usize> = Vec::new();
        if let Some(t) = self.ring_target(session) {
            candidates.push(t);
        }
        for i in 0..self.shards.len() {
            if !self.shards[i].draining && !candidates.contains(&i) {
                candidates.push(i);
            }
        }
        let mut last = RouteError::NoShards;
        for target in candidates {
            let mut conn = match self.open_shard(target) {
                Ok(c) => c,
                Err(e) => {
                    self.note_outcome(target, Some(&e));
                    last = e;
                    continue;
                }
            };
            // transcript-only import: replay re-prefills on the target's
            // own weights, so the target's advertised fingerprints are the
            // right ones to claim (no state blob carries provenance here)
            let id = self.shards[target].id.clone();
            let import = Frame::Import {
                session,
                shape_fp: id.shape_fp,
                weights_fp: id.weights_fp,
                transcript: pre.clone(),
                state: None,
            };
            match conn.request(&import) {
                Ok(Frame::Ok) => {}
                Ok(other) => {
                    last = RouteError::Protocol(format!("expected Ok from import, got {other:?}"));
                    continue;
                }
                Err(e) => {
                    self.note_outcome(target, Some(&e));
                    last = e;
                    continue;
                }
            }
            // strict replay: deterministic greedy decode regenerates the
            // identical tokens; emit only the unseen suffix
            let deadline_ms = remaining_ms(deadline)?;
            let (trace, profile) = self.trace_req();
            let req = Frame::SubmitInSession {
                session,
                strict: true,
                max_new: max_new as u32,
                deadline_ms,
                trace,
                profile,
                delta: delta.to_vec(),
            };
            let mut replayed = 0usize;
            let t0 = Instant::now();
            match conn.generate_streaming(&req, |t| {
                replayed += 1;
                if replayed > emitted {
                    on_token(t);
                }
            }) {
                Ok(toks) => {
                    self.trace_absorb(&mut conn);
                    self.trace_note("resurrected".to_string());
                    self.route_hist[target].record(t0.elapsed().as_secs_f64());
                    self.migrations.resurrections += 1;
                    self.note_outcome(target, None);
                    self.note_turn(session, target, delta, &toks);
                    return Ok(toks);
                }
                Err(e) => {
                    self.note_outcome(target, Some(&e));
                    last = e;
                    continue;
                }
            }
        }
        Err(last)
    }

    /// Ask a shard for a session's transcript (`Ok(None)` = shard answers
    /// but does not know the session).  The shard defers the read until
    /// the session is quiescent, so an in-flight turn is reflected fully
    /// or not at all — never half.
    fn fetch_transcript(
        &mut self,
        shard: usize,
        session: u64,
    ) -> Result<Option<Vec<i32>>, RouteError> {
        let mut conn = self.open_shard(shard)?;
        match conn.request(&Frame::Transcript { session }) {
            Ok(Frame::TranscriptIs { tokens }) => Ok(Some(tokens)),
            Ok(other) => Err(RouteError::Protocol(format!(
                "expected TranscriptIs, got {other:?}"
            ))),
            Err(RouteError::Shard(ErrCode::UnknownSession, _)) => Ok(None),
            Err(e) => {
                self.note_outcome(shard, Some(&e));
                Err(e)
            }
        }
    }

    /// Does a shard hold this session?  (Transcript probe, presence only.)
    fn probe_session(&mut self, shard: usize, session: u64) -> Result<bool, RouteError> {
        self.fetch_transcript(shard, session).map(|t| t.is_some())
    }

    /// Settle a source shard's export stash: `ExportCommit` (discard) or
    /// `ExportAbort` (re-import).  Settlement is idempotent server-side —
    /// an absent stash answers Ok — so the blind retry is safe.
    fn settle_export(
        &mut self,
        shard: usize,
        session: u64,
        commit: bool,
    ) -> Result<(), RouteError> {
        let frame = if commit {
            Frame::ExportCommit { session }
        } else {
            Frame::ExportAbort { session }
        };
        let mut last: Option<RouteError> = None;
        // settlement is idempotent, so every retry in the budget is safe;
        // backoff gives a restarting shard a beat to come back
        for attempt in 0..=self.retry.max_attempts {
            if attempt > 0 {
                let _ = self.backoff_pause(attempt - 1, None);
            }
            match self.open_shard(shard) {
                Ok(mut conn) => match conn.request(&frame) {
                    Ok(Frame::Ok) => {
                        self.note_outcome(shard, None);
                        return Ok(());
                    }
                    Ok(other) => {
                        last = Some(RouteError::Protocol(format!(
                            "expected Ok from settlement, got {other:?}"
                        )));
                    }
                    Err(e) => {
                        self.note_outcome(shard, Some(&e));
                        let give_up = !retryable(&e);
                        last = Some(e);
                        if give_up {
                            break;
                        }
                    }
                },
                Err(e) => {
                    self.note_outcome(shard, Some(&e));
                    last = Some(e);
                }
            }
        }
        Err(last.unwrap_or(RouteError::NoShards))
    }

    /// Abort a migration: settle the source's stash back into its
    /// coordinator, then surface `cause`.  If even the abort fails the
    /// session may be stranded (stashed on an unreachable source) — say
    /// so loudly instead of pretending it is merely unmoved.
    fn abort_and<T>(
        &mut self,
        from: usize,
        session: u64,
        cause: RouteError,
    ) -> Result<T, RouteError> {
        self.migrations.aborts += 1;
        match self.settle_export(from, session, false) {
            Ok(()) => Err(cause),
            Err(abort_err) => Err(RouteError::Protocol(format!(
                "session {session:#x} may be stranded in shard {from}'s export stash: \
                 import did not land ({cause}) and the abort also failed: {abort_err}"
            ))),
        }
    }

    fn finish_migration(
        &mut self,
        from: usize,
        to: usize,
        session: u64,
        bytes: usize,
    ) -> Result<usize, RouteError> {
        self.migrations.commits += 1;
        self.resident.insert(session, to);
        // commit releases the source's inactive stash.  Best-effort: a
        // failed commit leaves a stale stash entry, never a live duplicate
        // (the stash is invisible to the coordinator), and settlement is
        // idempotent so any later retry is safe.
        let _ = self.settle_export(from, session, true);
        Ok(bytes)
    }

    /// Drop a session everywhere the router knows about it.
    pub fn end_session(&mut self, session: u64) -> Result<(), RouteError> {
        let shard = self.route_session(session)?;
        let mut conn = self.open_shard(shard)?;
        match conn.request(&Frame::EndSession { session })? {
            Frame::Ok => {
                self.resident.remove(&session);
                self.mirror.remove(&session);
                self.replay_dedup.remove(&session);
                if let Some(j) = self.journal.as_mut() {
                    let _ = j.append_end(session);
                }
                Ok(())
            }
            other => Err(RouteError::Protocol(format!("expected Ok, got {other:?}"))),
        }
    }

    /// Live-migrate one session to a target shard, two-phase: quiesce +
    /// export on the source (which stashes the session source-side), ship
    /// the blob, import on the target, then settle the stash with an
    /// explicit commit (landed) or abort (did not land).  Identity (engine
    /// tag + shape + weights fingerprints, as advertised in each shard's
    /// handshake) is compared before the blob is shipped; the target
    /// connection is opened before the export, so an unreachable target
    /// fails the migration with the session untouched.  Returns the
    /// shipped state-blob size in bytes (0 when the engine exports no
    /// state).
    ///
    /// When the import's Ok is lost in transit the router probes the
    /// target's transcript: present → the import landed, commit; absent
    /// or unreachable → abort, restoring the source.  Either way the
    /// session lives in exactly one coordinator — the lost-Ok duplicate
    /// the pre-2PC handshake documented cannot happen.
    pub fn migrate(&mut self, session: u64, to: usize) -> Result<usize, RouteError> {
        let from = *self
            .resident
            .get(&session)
            .ok_or(RouteError::UnknownSession(session))?;
        if to >= self.shards.len() {
            return Err(RouteError::Protocol(format!("no shard {to}")));
        }
        if from == to {
            return Ok(0);
        }
        if self.shards[to].draining {
            // drain's whole point is to empty the shard; explicitly
            // migrating a session onto it would pin traffic there
            return Err(RouteError::Draining(to));
        }
        // handshake check FIRST: a mismatched blob is never even exported
        let (src, dst) = (&self.shards[from], &self.shards[to]);
        if src.id.engine != dst.id.engine {
            return Err(RouteError::Mismatch(format!(
                "engine '{}' (shard {from}) != '{}' (shard {to})",
                src.id.engine, dst.id.engine
            )));
        }
        if src.id.shape_fp != dst.id.shape_fp {
            return Err(RouteError::Mismatch(format!(
                "shape fingerprint {:#x} (shard {from}) != {:#x} (shard {to})",
                src.id.shape_fp, dst.id.shape_fp
            )));
        }
        if src.id.weights_fp != dst.id.weights_fp {
            return Err(RouteError::Mismatch(format!(
                "weights fingerprint {:#x} (shard {from}) != {:#x} (shard {to}) \
                 — same shape but different weights would silently change tokens",
                src.id.weights_fp, dst.id.weights_fp
            )));
        }
        // identity checks passed: the move is actually starting
        self.migrations.attempts += 1;
        // connect to the TARGET before detaching anything from the source:
        // a down or unreachable target must fail the migration while the
        // session still lives untouched on its source shard
        let mut dst_conn = self.open_shard(to)?;
        let mut src_conn = self.open_shard(from)?;
        let (session_id, shape_fp, weights_fp, transcript, state) =
            match src_conn.request(&Frame::Export { session }) {
                Ok(Frame::Blob { session, shape_fp, weights_fp, transcript, state }) => {
                    (session, shape_fp, weights_fp, transcript, state)
                }
                Ok(other) => {
                    return Err(RouteError::Protocol(format!("expected Blob, got {other:?}")))
                }
                Err(RouteError::Shard(ErrCode::UnknownSession, _)) => {
                    // the shard lost it (e.g. ended behind our back)
                    self.resident.remove(&session);
                    return Err(RouteError::UnknownSession(session));
                }
                Err(e) => {
                    // the export reply was lost: the source holds the
                    // session either live in its coordinator or detached
                    // in its stash.  Abort settles both cases (idempotent:
                    // stashed → re-imported, live → no-op Ok).
                    self.note_outcome(from, Some(&e));
                    return self.abort_and(from, session, e);
                }
            };
        let bytes = state.as_ref().map(|b| b.len()).unwrap_or(0);
        // the exported transcript is authoritative — refresh the mirror
        self.mirror.insert(session, transcript.clone());
        self.journal_set(session);
        let import =
            Frame::Import { session: session_id, shape_fp, weights_fp, transcript, state };
        match dst_conn.request(&import) {
            Ok(Frame::Ok) => self.finish_migration(from, to, session, bytes),
            Ok(other) => self.abort_and(
                from,
                session,
                RouteError::Protocol(format!("expected Ok from import, got {other:?}")),
            ),
            Err(RouteError::Shard(ErrCode::Mismatch, msg)) => {
                self.abort_and(from, session, RouteError::Mismatch(msg))
            }
            Err(e @ RouteError::Io(_)) => {
                // ambiguous: the import may have been applied with its Ok
                // lost in transit.  Probe the target; the answer decides
                // commit vs abort.
                self.note_outcome(to, Some(&e));
                if matches!(self.probe_session(to, session), Ok(true)) {
                    self.finish_migration(from, to, session, bytes)
                } else {
                    self.abort_and(from, session, e)
                }
            }
            Err(e) => self.abort_and(from, session, e),
        }
    }

    /// Settle a batch of export stashes in one round trip:
    /// `BulkCommit` (discard) or `BulkAbort` (re-import).  An *empty* id
    /// list on abort means "restore every stash" — the recovery for a
    /// lost `BulkBlob` reply, where the router cannot name what was
    /// stashed.  Idempotent per id, retried on the same budget as
    /// [`Router::settle_export`].
    fn settle_bulk(
        &mut self,
        shard: usize,
        sessions: &[u64],
        commit: bool,
    ) -> Result<(), RouteError> {
        let frame = if commit {
            Frame::BulkCommit { sessions: sessions.to_vec() }
        } else {
            Frame::BulkAbort { sessions: sessions.to_vec() }
        };
        let mut last: Option<RouteError> = None;
        for attempt in 0..=self.retry.max_attempts {
            if attempt > 0 {
                let _ = self.backoff_pause(attempt - 1, None);
            }
            match self.open_shard(shard) {
                Ok(mut conn) => match conn.request(&frame) {
                    Ok(Frame::Ok) => {
                        self.note_outcome(shard, None);
                        return Ok(());
                    }
                    Ok(other) => {
                        last = Some(RouteError::Protocol(format!(
                            "expected Ok from bulk settlement, got {other:?}"
                        )));
                    }
                    Err(e) => {
                        self.note_outcome(shard, Some(&e));
                        let give_up = !retryable(&e);
                        last = Some(e);
                        if give_up {
                            break;
                        }
                    }
                },
                Err(e) => {
                    self.note_outcome(shard, Some(&e));
                    last = Some(e);
                }
            }
        }
        Err(last.unwrap_or(RouteError::NoShards))
    }

    /// Stop placing new work on a shard and move every session it holds
    /// to its new ring target — **bulk**: one `BulkExport` round trip on
    /// the source, one `BulkImport` per target shard, then batched 2PC
    /// settlement, instead of a per-session quiesce/ship/settle cycle.
    /// Sessions whose target's identity mismatches the source's are
    /// aborted back in place (the drain moves what it can and reports
    /// only the moved ids).  Returns the moved session ids, sorted.
    pub fn drain(&mut self, shard: usize) -> Result<Vec<u64>, RouteError> {
        if shard >= self.shards.len() {
            return Err(RouteError::Protocol(format!("no shard {shard}")));
        }
        self.shards[shard].draining = true;
        self.rebuild_ring();
        if self.ring.is_empty() {
            // nowhere to put the sessions: undo
            self.shards[shard].draining = false;
            self.rebuild_ring();
            return Err(RouteError::NoShards);
        }
        // phase 1, one round trip: quiesce + detach + stash everything
        // the shard holds and ship it all back
        let undo = |r: &mut Router, e: RouteError| {
            r.shards[shard].draining = false;
            r.rebuild_ring();
            Err(e)
        };
        let mut conn = match self.open_shard(shard) {
            Ok(c) => c,
            Err(e) => return undo(self, e),
        };
        let (shape_fp, weights_fp, blobs) = match conn.request(&Frame::BulkExport) {
            Ok(Frame::BulkBlob { shape_fp, weights_fp, sessions }) => {
                self.note_outcome(shard, None);
                (shape_fp, weights_fp, sessions)
            }
            Ok(other) => {
                return undo(
                    self,
                    RouteError::Protocol(format!("expected BulkBlob, got {other:?}")),
                )
            }
            Err(e) => {
                // the reply may be lost with every session stashed: an
                // empty BulkAbort restores all stashes (idempotent, so a
                // reply lost *before* the stash is also fine)
                self.note_outcome(shard, Some(&e));
                let _ = self.settle_bulk(shard, &[], false);
                return undo(self, e);
            }
        };
        drop(conn);
        if blobs.is_empty() {
            return Ok(Vec::new());
        }
        // phase 2: partition by ring target, one BulkImport per peer
        let mut groups: BTreeMap<usize, Vec<SessionBlob>> = BTreeMap::new();
        for b in blobs {
            let t = self.ring_target(b.session).ok_or(RouteError::NoShards)?;
            groups.entry(t).or_default().push(b);
        }
        let src_engine = self.shards[shard].id.engine.clone();
        let mut moved = Vec::new();
        for (target, group) in groups {
            let ids: Vec<u64> = group.iter().map(|b| b.session).collect();
            self.migrations.attempts += ids.len() as u64;
            let tgt = &self.shards[target].id;
            if tgt.engine != src_engine
                || tgt.shape_fp != shape_fp
                || tgt.weights_fp != weights_fp
            {
                // mismatched peer: these sessions stay on the draining
                // source rather than decode into silently wrong tokens
                self.migrations.aborts += ids.len() as u64;
                self.settle_bulk(shard, &ids, false)?;
                continue;
            }
            let import =
                Frame::BulkImport { shape_fp, weights_fp, sessions: group.clone() };
            let landed = match self.open_shard(target).and_then(|mut c| c.request(&import)) {
                Ok(Frame::Ok) => {
                    self.note_outcome(target, None);
                    true
                }
                Ok(_) => false,
                Err(e @ RouteError::Io(_)) => {
                    // ambiguous lost-Ok: the bulk install is atomic
                    // server-side (validate everything, then install
                    // everything), so one session's presence answers for
                    // the whole batch
                    self.note_outcome(target, Some(&e));
                    matches!(self.probe_session(target, ids[0]), Ok(true))
                }
                Err(e) => {
                    self.note_outcome(target, Some(&e));
                    false
                }
            };
            if landed {
                self.migrations.commits += ids.len() as u64;
                for b in &group {
                    self.resident.insert(b.session, target);
                    self.mirror.insert(b.session, b.transcript.clone());
                    self.journal_set(b.session);
                }
                // best-effort, like finish_migration: a failed commit
                // leaves a stale (invisible, idempotent) stash, never a
                // live duplicate
                let _ = self.settle_bulk(shard, &ids, true);
                moved.extend(ids);
            } else {
                self.migrations.aborts += ids.len() as u64;
                if let Err(abort_err) = self.settle_bulk(shard, &ids, false) {
                    return Err(RouteError::Protocol(format!(
                        "{} session(s) may be stranded in shard {shard}'s export stash: \
                         bulk import did not land and the abort also failed: {abort_err}",
                        ids.len()
                    )));
                }
            }
        }
        moved.sort_unstable();
        Ok(moved)
    }

    /// Add a shard to the ring (it starts taking new placements and
    /// rebalance targets immediately).
    pub fn add_shard(&mut self, addr: SocketAddr) -> Result<usize, RouteError> {
        let (_conn, id) =
            Conn::open(addr, self.faults.clone(), self.auth.as_ref().map(|a| a.as_str()))?;
        self.shards.push(ShardInfo { addr, id, draining: false });
        self.breakers.push(Breaker::new(self.breaker_cfg));
        self.route_hist.push(Hist::new());
        self.rebuild_ring();
        Ok(self.shards.len() - 1)
    }

    /// Move every session whose ring target differs from where it lives
    /// (after `add_shard` changed the ring).  Returns (session, from, to)
    /// per move.  Sessions that cannot move because identities mismatch
    /// are left in place and reported untouched.
    pub fn rebalance(&mut self) -> Result<Vec<(u64, usize, usize)>, RouteError> {
        let mut moves = Vec::new();
        let plan: Vec<(u64, usize)> = self
            .resident
            .iter()
            .map(|(&sid, &cur)| (sid, cur))
            .collect();
        for (sid, cur) in plan {
            let want = match self.ring_target(sid) {
                Some(w) => w,
                None => return Err(RouteError::NoShards),
            };
            if want == cur {
                continue;
            }
            match self.migrate(sid, want) {
                Ok(_) => moves.push((sid, cur, want)),
                Err(RouteError::Mismatch(_)) => continue,
                Err(e) => return Err(e),
            }
        }
        moves.sort_unstable();
        Ok(moves)
    }

    /// Per-shard health, queried over the wire.  Fails on the first shard
    /// that cannot answer (including a typed refusal for an open circuit).
    pub fn health(&mut self) -> Result<Vec<HealthReport>, RouteError> {
        let mut out = Vec::with_capacity(self.shards.len());
        for i in 0..self.shards.len() {
            let mut conn = self.open_shard(i)?;
            match conn.request(&Frame::Health)? {
                Frame::HealthReport(h) => {
                    self.note_outcome(i, None);
                    out.push(h);
                }
                other => {
                    return Err(RouteError::Protocol(format!(
                        "expected HealthReport, got {other:?}"
                    )))
                }
            }
        }
        Ok(out)
    }

    /// Probe every shard once and feed the result to its breaker; returns
    /// the post-probe circuit states.  Open circuits whose cooldown has
    /// not elapsed are skipped (no hammering); an elapsed one half-opens
    /// and this probe decides whether it closes — so a periodic
    /// `probe_all` (the front server's probe thread) is the mechanism by
    /// which a recovered shard rejoins service.
    pub fn probe_all(&mut self) -> Vec<BreakerState> {
        for i in 0..self.shards.len() {
            if !self.breakers[i].allow() {
                continue;
            }
            let ok = Conn::open(
                self.shards[i].addr,
                self.faults.clone(),
                self.auth.as_ref().map(|a| a.as_str()),
            )
            .and_then(|(mut c, _)| c.request(&Frame::Health))
                .map(|f| matches!(f, Frame::HealthReport(_)))
                .unwrap_or(false);
            if ok {
                self.breakers[i].record_success();
            } else {
                self.breakers[i].record_failure();
            }
        }
        self.breakers.iter().map(|b| b.state()).collect()
    }

    /// Lifetime migration/resurrection counts.
    pub fn migration_stats(&self) -> MigrationStats {
        self.migrations
    }

    /// Observable circuit state of every shard's breaker, indexed like
    /// the shards.
    pub fn breaker_states(&self) -> Vec<BreakerState> {
        self.breakers.iter().map(|b| b.state()).collect()
    }

    /// Pull every shard's metric snapshot over the wire, merge them
    /// exactly (counters/gauges sum, histograms merge bucket-wise), and
    /// fold in the router's own routing/breaker/migration metrics.
    ///
    /// Scrape-tolerant: a shard that cannot answer is skipped — its
    /// numbers are simply absent from this scrape — and counted in
    /// `lh_scrape_errors_total`, so a dead shard degrades the scrape
    /// instead of failing it.
    pub fn cluster_metrics(&mut self) -> Snapshot {
        let mut snap = Snapshot::default();
        for i in 0..self.shards.len() {
            let pulled = self
                .open_shard(i)
                .and_then(|mut conn| conn.request(&Frame::Metrics));
            match pulled {
                Ok(Frame::MetricsReport { entries }) => {
                    self.note_outcome(i, None);
                    for (name, v) in entries {
                        snap.merge_entry(&name, v);
                    }
                }
                Ok(_) => self.scrape_errors += 1,
                Err(e) => {
                    self.note_outcome(i, Some(&e));
                    self.scrape_errors += 1;
                }
            }
        }
        let mut transitions = BreakerStats::default();
        for (i, b) in self.breakers.iter().enumerate() {
            let level = match b.state() {
                BreakerState::Closed => 0,
                BreakerState::HalfOpen => 1,
                BreakerState::Open => 2,
            };
            snap.merge_entry(
                &format!("lh_breaker_state{{shard=\"{i}\"}}"),
                MetricValue::Gauge(level),
            );
            let st = b.stats();
            transitions.opened += st.opened;
            transitions.half_opened += st.half_opened;
            transitions.closed += st.closed;
        }
        for (i, h) in self.route_hist.iter().enumerate() {
            if h.count() > 0 {
                snap.merge_entry(
                    &format!("lh_route_seconds{{shard=\"{i}\"}}"),
                    MetricValue::Hist(h.clone()),
                );
            }
        }
        let m = self.migrations;
        let fault_hits =
            self.faults.as_ref().map(|p| p.hits().len() as u64).unwrap_or(0);
        let js = self.journal_stats().unwrap_or_default();
        for (name, v) in [
            ("lh_breaker_opened_total", transitions.opened),
            ("lh_breaker_half_opened_total", transitions.half_opened),
            ("lh_breaker_closed_total", transitions.closed),
            ("lh_migration_attempts_total", m.attempts),
            ("lh_migration_commits_total", m.commits),
            ("lh_migration_aborts_total", m.aborts),
            ("lh_resurrections_total", m.resurrections),
            ("lh_retries_total", self.retries),
            ("lh_fault_hits_total", fault_hits),
            ("lh_scrape_errors_total", self.scrape_errors),
            ("lh_journal_appended_total", js.appended),
            ("lh_journal_replayed_total", js.replayed),
            ("lh_journal_deduped_total", js.deduped),
            ("lh_journal_truncated_tails_total", js.truncated_tails),
            ("lh_journal_compactions_total", js.compactions),
            ("lh_journal_append_errors_total", js.append_errors),
        ] {
            snap.merge_entry(name, MetricValue::Counter(v));
        }
        snap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::coordinator::SlotEngine;
    use crate::engine::transformer::TransformerEngine;
    use crate::engine::LmShape;
    use crate::serve::shard::{ShardServer, ShardSpec};

    fn cfg() -> ServeConfig {
        ServeConfig { max_batch: 2, linger_ms: 1, ..ServeConfig::default() }
    }

    fn native_shards(n: usize) -> Vec<ShardServer> {
        let shape = LmShape::bench("nano").unwrap();
        (0..n)
            .map(|_| ShardServer::spawn_native(&shape, 2, 11, cfg()).unwrap())
            .collect()
    }

    fn router_over(shards: &[ShardServer]) -> Router {
        let addrs: Vec<_> = shards.iter().map(|s| s.addr()).collect();
        Router::new(&addrs).unwrap()
    }

    fn router_with_faults(
        shards: &[ShardServer],
        cfg: BreakerConfig,
    ) -> (Router, Arc<FaultPlan>) {
        let addrs: Vec<_> = shards.iter().map(|s| s.addr()).collect();
        let faults = Arc::new(FaultPlan::new());
        let r = Router::new_with(&addrs, cfg, Some(faults.clone())).unwrap();
        (r, faults)
    }

    #[test]
    fn ring_spreads_sessions_and_is_stable() {
        let shards = native_shards(3);
        let r = router_over(&shards);
        let mut counts = [0usize; 3];
        for sid in 0..300u64 {
            let t = r.ring_target(sid).unwrap();
            assert_eq!(t, r.ring_target(sid).unwrap(), "placement must be deterministic");
            counts[t] += 1;
        }
        // with 32 vnodes each shard's expected share is ~100/300; require
        // only >5% so kernel-assigned ports can never flake the test
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 15, "shard {i} got only {c}/300 sessions — ring is lopsided");
        }
        for s in shards {
            s.shutdown();
        }
    }

    #[test]
    fn session_turns_keep_affinity_and_resume() {
        let shards = native_shards(2);
        let mut r = router_over(&shards);
        // several interleaved sessions, two turns each
        let sids: Vec<u64> = (0..6).collect();
        for &sid in &sids {
            let g = r.submit_in_session(sid, vec![1 + sid as i32, 2], 3).unwrap();
            assert_eq!(g.len(), 3);
        }
        let homes: Vec<usize> = sids.iter().map(|&s| r.shard_of(s).unwrap()).collect();
        for &sid in &sids {
            let g = r.submit_in_session(sid, vec![9], 3).unwrap();
            assert_eq!(g.len(), 3);
            assert_eq!(
                r.shard_of(sid).unwrap(),
                homes[sid as usize],
                "turn 2 must stay on the session's shard"
            );
        }
        // every second turn resumed from stored state on its home shard
        let health = r.health().unwrap();
        let hits: u64 = health.iter().map(|h| h.session_hits).sum();
        let misses: u64 = health.iter().map(|h| h.session_misses).sum();
        assert_eq!(hits, sids.len() as u64, "every turn-2 must be a store hit");
        assert_eq!(misses, 0, "a miss means a turn was routed to the wrong shard");
        for s in shards {
            s.shutdown();
        }
    }

    #[test]
    fn one_shots_round_robin_and_agree_across_shards() {
        let shards = native_shards(2);
        let mut r = router_over(&shards);
        // same prompt, same seed on both shards -> identical tokens
        let a = r.submit(vec![5, 6, 7], 4).unwrap();
        let b = r.submit(vec![5, 6, 7], 4).unwrap();
        assert_eq!(a, b, "identically-seeded shards must agree");
        let health = r.health().unwrap();
        assert_eq!(
            health.iter().map(|h| h.requests_done).collect::<Vec<_>>(),
            vec![1, 1],
            "round robin must spread one-shots"
        );
        for s in shards {
            s.shutdown();
        }
    }

    #[test]
    fn migrate_between_mismatched_engines_is_refused_at_the_handshake() {
        let shape = LmShape::bench("nano").unwrap();
        let native = ShardServer::spawn_native(&shape, 2, 11, cfg()).unwrap();
        let spec = ShardSpec::native(&shape, crate::engine::transformer::STATE_TAG, 11);
        let shape2 = shape.clone();
        let baseline = ShardServer::spawn(spec, cfg(), move || {
            Box::new(TransformerEngine::new(&shape2, 2, 11)) as Box<dyn SlotEngine>
        })
        .unwrap();
        let mut r = Router::new(&[native.addr(), baseline.addr()]).unwrap();
        // pin a session to the native shard (shard 0 may or may not be the
        // ring target, so force residency through a served turn)
        let sid = 77u64;
        let g1 = r.submit_in_session(sid, vec![1, 2, 3], 3).unwrap();
        let home = r.shard_of(sid).unwrap();
        let other = 1 - home;
        match r.migrate(sid, other) {
            Err(RouteError::Mismatch(msg)) => {
                assert!(msg.contains("engine"), "mismatch must name the engine: {msg}")
            }
            other => panic!("expected Mismatch, got {other:?}"),
        }
        // the session is untouched and continues where it lives
        assert_eq!(r.shard_of(sid), Some(home));
        let g2 = r.submit_in_session(sid, vec![4], 3).unwrap();
        assert_eq!(g2.len(), 3);
        assert!(!g1.is_empty());
        native.shutdown();
        baseline.shutdown();
    }

    /// Same engine, same shape, different seed: the shapes fingerprint
    /// identically, but the weights differ — a migrated state would decode
    /// into silently wrong tokens, so the weights fingerprint must refuse
    /// the pair before the blob is shipped.
    #[test]
    fn migrate_between_same_shape_different_seeds_is_refused() {
        let shape = LmShape::bench("nano").unwrap();
        let a = ShardServer::spawn_native(&shape, 2, 11, cfg()).unwrap();
        let b = ShardServer::spawn_native(&shape, 2, 12, cfg()).unwrap();
        let mut r = Router::new(&[a.addr(), b.addr()]).unwrap();
        let sid = 5u64;
        r.submit_in_session(sid, vec![1, 2, 3], 3).unwrap();
        let home = r.shard_of(sid).unwrap();
        match r.migrate(sid, 1 - home) {
            Err(RouteError::Mismatch(msg)) => {
                assert!(msg.contains("weights"), "must name the cause: {msg}")
            }
            other => panic!("expected weights Mismatch, got {other:?}"),
        }
        // untouched: the session keeps serving from its home shard
        assert_eq!(r.shard_of(sid), Some(home));
        assert_eq!(r.submit_in_session(sid, vec![4], 2).unwrap().len(), 2);
        a.shutdown();
        b.shutdown();
    }

    /// A draining shard must refuse to become an explicit migration
    /// target — otherwise drain's "empty this shard" invariant breaks.
    #[test]
    fn migrate_onto_a_draining_shard_is_refused() {
        let shards = native_shards(2);
        let mut r = router_over(&shards);
        let sid = 9u64;
        r.submit_in_session(sid, vec![1, 2], 2).unwrap();
        let home = r.shard_of(sid).unwrap();
        let other = 1 - home;
        // drain the other shard (it holds no sessions, so this is a no-op
        // migration-wise), then try to migrate onto it
        r.drain(other).unwrap();
        assert!(matches!(
            r.migrate(sid, other),
            Err(RouteError::Draining(i)) if i == other
        ));
        assert_eq!(r.shard_of(sid), Some(home));
        for s in shards {
            s.shutdown();
        }
    }

    #[test]
    fn migrating_an_unknown_session_is_a_typed_error() {
        let shards = native_shards(2);
        let mut r = router_over(&shards);
        assert!(matches!(
            r.migrate(0xBEEF, 1),
            Err(RouteError::UnknownSession(0xBEEF))
        ));
        for s in shards {
            s.shutdown();
        }
    }

    #[test]
    fn end_session_forgets_residency() {
        let shards = native_shards(2);
        let mut r = router_over(&shards);
        let sid = 3u64;
        r.submit_in_session(sid, vec![1, 2], 2).unwrap();
        assert!(r.shard_of(sid).is_some());
        assert!(r.mirror_of(sid).is_some());
        r.end_session(sid).unwrap();
        assert_eq!(r.shard_of(sid), None);
        assert_eq!(r.mirror_of(sid), None, "end_session must drop the mirror too");
        for s in shards {
            s.shutdown();
        }
    }

    /// The streamed tokens (via `on_token`) must be exactly the buffered
    /// return value, in order, for both one-shots and session turns.
    #[test]
    fn streamed_tokens_match_the_buffered_return() {
        let shards = native_shards(1);
        let mut r = router_over(&shards);
        let mut seen = Vec::new();
        let toks = r.submit_streaming(vec![3, 4, 5], 4, |t| seen.push(t)).unwrap();
        assert_eq!(seen, toks, "one-shot stream must equal the return value");
        seen.clear();
        let t1 = r
            .submit_in_session_streaming(7, vec![1, 2], 4, |t| seen.push(t))
            .unwrap();
        assert_eq!(seen, t1, "session stream must equal the return value");
        // and the mirror tracks prompt ++ generated
        let mut want = vec![1, 2];
        want.extend_from_slice(&t1);
        assert_eq!(r.mirror_of(7).unwrap(), &want[..]);
        for s in shards {
            s.shutdown();
        }
    }

    /// A traced turn must come back with the full cross-hop timeline —
    /// router, shard, coordinator, and (profiled) engine reports joined
    /// under one id — while an untraced turn collects nothing.
    #[test]
    fn traced_turn_collects_cross_hop_spans() {
        let shards = native_shards(1);
        let mut r = router_over(&shards);
        r.begin_trace(0x5EED, true);
        let toks = r.submit_in_session(11, vec![1, 2], 3).unwrap();
        assert_eq!(toks.len(), 3);
        let hops = r.take_trace();
        let names: Vec<&str> = hops.iter().map(|h| h.hop.as_str()).collect();
        assert_eq!(names.first(), Some(&"router"), "router hop must lead the report");
        for want in ["shard", "coordinator", "engine"] {
            assert!(names.contains(&want), "missing {want} hop in {names:?}");
        }
        let shard_hop = hops.iter().find(|h| h.hop == "shard").unwrap();
        assert!(shard_hop.span_named("to_first_token").is_some());
        assert!(shard_hop.span_named("stream").is_some());
        // hop totals are durations on each hop's own clock: every inner
        // hop fits inside the router's custody window (no clock skew)
        for h in &hops[1..] {
            assert!(
                h.total_us <= hops[0].total_us,
                "{} hop ({}us) exceeds router custody ({}us)",
                h.hop,
                h.total_us,
                hops[0].total_us
            );
        }
        // a second take is empty, and an untraced turn collects nothing
        assert!(r.take_trace().is_empty());
        assert_eq!(r.submit_in_session(11, vec![4], 2).unwrap().len(), 2);
        assert!(r.take_trace().is_empty());
        for s in shards {
            s.shutdown();
        }
    }

    /// Three connect failures trip the breaker; the fourth request is
    /// refused with the typed `ShardUnavailable`, not a raw i/o error —
    /// and without touching the network (the hour-long cooldown means no
    /// half-open probe can sneak through).
    #[test]
    fn open_circuit_refuses_with_typed_shard_unavailable() {
        let shards = native_shards(1);
        let bc = BreakerConfig {
            failure_threshold: 3,
            cooldown: Duration::from_secs(3600),
        };
        let (mut r, faults) = router_with_faults(&shards, bc);
        faults.kill(shards[0].addr());
        for i in 0..3 {
            match r.submit(vec![1, 2], 2) {
                Err(RouteError::Io(_)) => {}
                other => panic!("attempt {i}: expected Io while closed, got {other:?}"),
            }
        }
        assert_eq!(r.breaker_state(0), Some(BreakerState::Open));
        match r.submit(vec![1, 2], 2) {
            Err(RouteError::ShardUnavailable { shard: 0 }) => {}
            other => panic!("expected typed ShardUnavailable, got {other:?}"),
        }
        // revive + probe: the breaker is the only gate, and probe_all with
        // an unelapsed cooldown must not reset it behind the clock's back
        faults.revive(shards[0].addr());
        assert_eq!(r.probe_all()[0], BreakerState::Open, "cooldown has not elapsed");
        for s in shards {
            s.shutdown();
        }
    }

    /// A zero cooldown lets `probe_all` half-open and close the circuit as
    /// soon as the shard is reachable again.
    #[test]
    fn probe_all_recovers_a_revived_shard() {
        let shards = native_shards(1);
        let bc = BreakerConfig { failure_threshold: 1, cooldown: Duration::ZERO };
        let (mut r, faults) = router_with_faults(&shards, bc);
        faults.kill(shards[0].addr());
        assert!(r.submit(vec![1], 1).is_err());
        assert_eq!(r.breaker_state(0), Some(BreakerState::Open));
        // still dead: the probe re-opens
        assert_eq!(r.probe_all()[0], BreakerState::Open);
        faults.revive(shards[0].addr());
        assert_eq!(r.probe_all()[0], BreakerState::Closed, "probe must close the circuit");
        assert_eq!(r.submit(vec![1, 2], 2).unwrap().len(), 2);
        for s in shards {
            s.shutdown();
        }
    }

    /// Kill a session's home shard between turns: the next turn must be
    /// served anyway — resurrected from the router's transcript mirror on
    /// the surviving shard — with tokens identical to a never-interrupted
    /// run of the same conversation.
    #[test]
    fn killed_home_shard_resurrects_the_session_token_identically() {
        let shards = native_shards(2);
        let (mut r, faults) = router_with_faults(&shards, BreakerConfig::default());
        let sid = 42u64;
        let t1 = r.submit_in_session(sid, vec![1, 2, 3], 4).unwrap();
        let home = r.shard_of(sid).unwrap();
        // reference: the same two turns, uninterrupted, on a fresh
        // identically-seeded shard
        let reference = {
            let ref_shards = native_shards(1);
            let mut rr = router_over(&ref_shards);
            let a = rr.submit_in_session(sid, vec![1, 2, 3], 4).unwrap();
            assert_eq!(a, t1, "identically-seeded turn 1 must agree");
            let b = rr.submit_in_session(sid, vec![9, 9], 4).unwrap();
            for s in ref_shards {
                s.shutdown();
            }
            b
        };
        faults.kill(shards[home].addr());
        let mut streamed = Vec::new();
        let t2 = r
            .submit_in_session_streaming(sid, vec![9, 9], 4, |t| streamed.push(t))
            .unwrap();
        assert_eq!(t2, reference, "resurrected turn must be token-identical");
        assert_eq!(streamed, reference, "and streamed exactly once each");
        let new_home = r.shard_of(sid).unwrap();
        assert_ne!(new_home, home, "the session must have moved off the dead shard");
        // the resurrected session is a first-class resident: another turn
        // keeps working without any further recovery
        assert_eq!(r.submit_in_session(sid, vec![4], 2).unwrap().len(), 2);
        assert!(shards[new_home].handle.session_known(sid).unwrap());
        for s in shards {
            s.shutdown();
        }
    }

    /// A clean 2PC migration must leave the source with an empty stash
    /// (commit settled it) and the session live in exactly one
    /// coordinator.
    #[test]
    fn migrate_commits_the_source_stash_and_keeps_one_copy() {
        let shards = native_shards(2);
        let mut r = router_over(&shards);
        let sid = 21u64;
        r.submit_in_session(sid, vec![1, 2, 3], 3).unwrap();
        let home = r.shard_of(sid).unwrap();
        let other = 1 - home;
        r.migrate(sid, other).unwrap();
        assert_eq!(r.shard_of(sid), Some(other));
        assert_eq!(shards[home].pending_exports(), 0, "commit must drain the stash");
        assert!(
            !shards[home].handle.session_known(sid).unwrap(),
            "source coordinator must have let go"
        );
        assert!(
            shards[other].handle.session_known(sid).unwrap(),
            "target coordinator must hold the session"
        );
        assert_eq!(r.submit_in_session(sid, vec![4], 3).unwrap().len(), 3);
        for s in shards {
            s.shutdown();
        }
    }

    /// The cluster scrape merges per-shard snapshots exactly and carries
    /// the router's own routing/breaker/migration metrics; migrations
    /// and resurrections are counted on the stats struct.
    #[test]
    fn cluster_metrics_merge_shards_and_count_migrations() {
        let shards = native_shards(2);
        let mut r = router_over(&shards);
        let sid = 21u64;
        r.submit_in_session(sid, vec![1, 2, 3], 3).unwrap();
        r.submit_in_session(sid, vec![4], 3).unwrap();
        let home = r.shard_of(sid).unwrap();
        r.migrate(sid, 1 - home).unwrap();
        assert_eq!(
            r.migration_stats(),
            MigrationStats { attempts: 1, commits: 1, aborts: 0, resurrections: 0 }
        );
        let snap = r.cluster_metrics();
        let e = &snap.entries;
        // shard-side counters merged across both shards
        assert_eq!(e.get("lh_requests_done_total"), Some(&MetricValue::Counter(2)));
        match e.get("lh_ttft_seconds") {
            Some(MetricValue::Hist(h)) => assert_eq!(h.count(), 2),
            other => panic!("expected merged ttft hist, got {other:?}"),
        }
        // router-side: both turns landed on the home shard's route hist
        match e.get(&format!("lh_route_seconds{{shard=\"{home}\"}}")) {
            Some(MetricValue::Hist(h)) => assert_eq!(h.count(), 2),
            other => panic!("expected route hist for shard {home}, got {other:?}"),
        }
        assert_eq!(
            e.get("lh_breaker_state{shard=\"0\"}"),
            Some(&MetricValue::Gauge(0))
        );
        assert_eq!(e.get("lh_migration_commits_total"), Some(&MetricValue::Counter(1)));
        assert_eq!(e.get("lh_scrape_errors_total"), Some(&MetricValue::Counter(0)));
        for s in shards {
            s.shutdown();
        }
    }

    /// A "restarted" router (fresh instance, same journal dir) must
    /// rebuild its transcript mirror by replay, serve the next turn of an
    /// old session bit-identically to an uninterrupted run, and answer a
    /// client retry of the last pre-crash turn from the journal's dedup
    /// window without re-applying it to any shard.
    #[test]
    fn journal_replay_restores_sessions_and_dedups_the_retried_turn() {
        use crate::session::JournalConfig;
        let dir = std::env::temp_dir()
            .join(format!("lh_router_journal_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let shards = native_shards(2);
        let addrs: Vec<_> = shards.iter().map(|s| s.addr()).collect();
        let sid = 63u64;
        // uninterrupted reference for the same three-turn conversation
        let reference = {
            let ref_shards = native_shards(1);
            let mut rr = router_over(&ref_shards);
            rr.submit_in_session(sid, vec![1, 2, 3], 4).unwrap();
            rr.submit_in_session(sid, vec![9], 4).unwrap();
            let t3 = rr.submit_in_session(sid, vec![5, 5], 4).unwrap();
            for s in ref_shards {
                s.shutdown();
            }
            t3
        };
        // journaled router serves two turns, then "crashes" (is dropped)
        let (t2, mirror_before) = {
            let mut r = Router::new(&addrs).unwrap();
            let (j, replay) = Journal::open(JournalConfig::new(&dir)).unwrap();
            assert!(replay.sessions.is_empty(), "fresh dir must replay empty");
            r.attach_journal(j, replay);
            r.submit_in_session(sid, vec![1, 2, 3], 4).unwrap();
            let t2 = r.submit_in_session(sid, vec![9], 4).unwrap();
            (t2, r.mirror_of(sid).unwrap().to_vec())
        };
        // restart: a fresh router over the same shards + journal dir
        let mut r = Router::new(&addrs).unwrap();
        let (j, replay) = Journal::open(JournalConfig::new(&dir)).unwrap();
        r.attach_journal(j, replay);
        assert_eq!(
            r.mirror_of(sid),
            Some(&mirror_before[..]),
            "replay must rebuild the mirror byte-for-byte"
        );
        assert!(r.journal_stats().unwrap().replayed >= 2);
        // the client never saw turn 2's ack and retries it: answered from
        // the dedup window, bit-identically, without touching a shard
        let requests_before: u64 =
            r.health().unwrap().iter().map(|h| h.requests_done).sum();
        let mut streamed = Vec::new();
        let retried = r
            .submit_in_session_streaming(sid, vec![9], 4, |t| streamed.push(t))
            .unwrap();
        assert_eq!(retried, t2, "deduped retry must return the journaled tokens");
        assert_eq!(streamed, t2, "and stream them exactly once each");
        let requests_after: u64 =
            r.health().unwrap().iter().map(|h| h.requests_done).sum();
        assert_eq!(requests_after, requests_before, "dedup must not touch a shard");
        assert_eq!(r.journal_stats().unwrap().deduped, 1);
        // a genuinely new turn continues the conversation bit-identically
        // to the uninterrupted reference (strict + mirror resurrection)
        let t3 = r.submit_in_session(sid, vec![5, 5], 4).unwrap();
        assert_eq!(t3, reference, "post-restart turn must match the reference");
        for s in shards {
            s.shutdown();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A dead shard degrades the scrape (its numbers are absent, the
    /// error is counted) instead of failing it.
    #[test]
    fn cluster_metrics_tolerate_a_dead_shard() {
        let shards = native_shards(2);
        let (mut r, faults) = router_with_faults(&shards, BreakerConfig::default());
        r.submit(vec![1, 2], 2).unwrap();
        r.submit(vec![1, 2], 2).unwrap();
        faults.kill(shards[0].addr());
        let snap = r.cluster_metrics();
        let e = &snap.entries;
        // exactly one shard answered
        assert_eq!(e.get("lh_requests_done_total"), Some(&MetricValue::Counter(1)));
        assert_eq!(e.get("lh_scrape_errors_total"), Some(&MetricValue::Counter(1)));
        // the failed pull fed the breaker, and a second scrape still works
        let again = r.cluster_metrics();
        assert_eq!(
            again.entries.get("lh_requests_done_total"),
            Some(&MetricValue::Counter(1))
        );
        for s in shards {
            s.shutdown();
        }
    }
}
