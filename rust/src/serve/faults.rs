//! Deterministic fault injection for the serve layer.
//!
//! Every failure path the router claims to survive — a shard dying
//! mid-token-stream, a migration severed after the export but before its
//! Ok, an import that never lands — is exercised by *injecting* the fault
//! at a named protocol point rather than hoping a test can race a real
//! crash.  The router threads an optional [`FaultPlan`] through its
//! shard connections; at each hook point it asks the plan whether a rule
//! fires and applies the returned [`FaultAction`].  With no plan (or no
//! matching rule) the hooks are no-ops, so production builds pay one
//! `Option` check per frame.
//!
//! Rules are consumed (`times` countdown) and logged, so a test can
//! assert not only that the conversation survived, but that the fault it
//! staged actually fired (a fault that never fires is a test of nothing).
//!
//! Semantics of the actions at a [`Point::Send`] / [`Point::RecvReplyTo`]
//! hook, chosen so each distinct protocol window is reachable:
//!
//! | action         | at `Send(k)`                          | at `RecvReplyTo(k)`                   |
//! |----------------|---------------------------------------|---------------------------------------|
//! | `DropFrame`    | request never written; conn severed — the shard never saw it | request processed by the shard; its reply read and *discarded*; conn severed |
//! | `SeverAfter`   | request written, conn severed before the reply is read | reply read and returned, then conn severed |
//! | `Delay(d)`     | sleep `d`, then write normally        | sleep `d`, then read normally         |
//! | `Corrupt`      | a byte of the encoded frame is flipped before writing (the shard's bounded decoder must reject it) | reply read, a byte flipped before decoding on the router side |
//!
//! `Point::Connect` refuses the TCP connect (any action), and
//! [`FaultPlan::kill`] makes a shard address unreachable until
//! [`FaultPlan::revive`] — the serve-layer stand-in for a crashed
//! process, without un-listening the socket.

use std::collections::HashSet;
use std::net::SocketAddr;
use std::sync::Mutex;
use std::time::Duration;

use super::wire::Frame;

/// Which protocol frame a rule keys on (one variant per wire tag that a
/// router ever sends or awaits).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    Hello,
    Auth,
    Submit,
    SubmitInSession,
    EndSession,
    Export,
    Import,
    Health,
    Metrics,
    MetricsReport,
    ExportCommit,
    ExportAbort,
    Transcript,
    BulkExport,
    BulkImport,
    BulkCommit,
    BulkAbort,
    Token,
    Done,
    Spans,
    Blob,
    Ok,
    HealthReport,
    TranscriptIs,
    BulkBlob,
    Error,
}

impl FrameKind {
    /// The kind of a concrete frame (for request-tracking in the conn).
    pub fn of(f: &Frame) -> FrameKind {
        match f {
            Frame::Hello { .. } => FrameKind::Hello,
            Frame::Auth { .. } => FrameKind::Auth,
            Frame::Submit { .. } => FrameKind::Submit,
            Frame::SubmitInSession { .. } => FrameKind::SubmitInSession,
            Frame::EndSession { .. } => FrameKind::EndSession,
            Frame::Export { .. } => FrameKind::Export,
            Frame::Import { .. } => FrameKind::Import,
            Frame::Health => FrameKind::Health,
            Frame::Metrics => FrameKind::Metrics,
            Frame::MetricsReport { .. } => FrameKind::MetricsReport,
            Frame::ExportCommit { .. } => FrameKind::ExportCommit,
            Frame::ExportAbort { .. } => FrameKind::ExportAbort,
            Frame::Transcript { .. } => FrameKind::Transcript,
            Frame::BulkExport => FrameKind::BulkExport,
            Frame::BulkImport { .. } => FrameKind::BulkImport,
            Frame::BulkCommit { .. } => FrameKind::BulkCommit,
            Frame::BulkAbort { .. } => FrameKind::BulkAbort,
            Frame::BulkBlob { .. } => FrameKind::BulkBlob,
            Frame::Token { .. } => FrameKind::Token,
            Frame::Done { .. } => FrameKind::Done,
            Frame::Spans { .. } => FrameKind::Spans,
            Frame::Blob { .. } => FrameKind::Blob,
            Frame::Ok => FrameKind::Ok,
            Frame::HealthReport(_) => FrameKind::HealthReport,
            Frame::TranscriptIs { .. } => FrameKind::TranscriptIs,
            Frame::Error { .. } => FrameKind::Error,
        }
    }
}

/// A named protocol point a rule can fire at.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Point {
    /// Establishing the TCP connection to the shard.
    Connect,
    /// Just before the router writes this request frame.
    Send(FrameKind),
    /// Just before the router reads the reply to this request kind
    /// (`RecvReplyTo(Export)` is the canonical "after-export-before-ok"
    /// window: the shard performed the export, the router never hears).
    RecvReplyTo(FrameKind),
    /// After exactly `after` streamed `Token` frames of one generation
    /// have been relayed ("mid-token-stream").
    TokenStream { after: u32 },
    /// The write-ahead turn journal is about to append a record.  Any
    /// action = the process dies *before* the record reaches the file:
    /// the shard applied the turn, the journal never heard (the residual
    /// at-least-once window the crash-window table documents).
    JournalBeforeAppend,
    /// The journal finished (and synced) an append but the process dies
    /// before the turn is acked — the window replay-dedup closes.  Any
    /// action = the append succeeds, then errors out of the caller.
    JournalAfterAppend,
    /// The append is torn mid-record: only a prefix of the encoded record
    /// reaches the file before the process dies.  Replay must truncate
    /// the tail at the last complete record.
    JournalTornWrite,
    /// The fsync the policy called for is silently skipped (a lying disk
    /// / power-loss model): the record is written but its durability is
    /// not forced.
    JournalLostFsync,
}

/// What happens when a rule fires; see the module-level table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    DropFrame,
    SeverAfter,
    Delay(Duration),
    Corrupt,
}

/// One injection rule: fires `times` times at `point` (optionally only
/// toward `shard`), then goes inert.
#[derive(Clone, Copy, Debug)]
pub struct Rule {
    /// `None` matches any shard.
    pub shard: Option<SocketAddr>,
    pub point: Point,
    pub action: FaultAction,
    pub times: u32,
}

impl Rule {
    /// A single-shot rule matching any shard.
    pub fn once(point: Point, action: FaultAction) -> Rule {
        Rule { shard: None, point, action, times: 1 }
    }

    /// A single-shot rule pinned to one shard address.
    pub fn once_at(shard: SocketAddr, point: Point, action: FaultAction) -> Rule {
        Rule { shard: Some(shard), point, action, times: 1 }
    }
}

/// A fault that fired (for test assertions: staged faults must be hit).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Hit {
    pub shard: SocketAddr,
    pub point: Point,
    pub action: FaultAction,
}

#[derive(Default)]
struct Inner {
    rules: Vec<Rule>,
    killed: HashSet<SocketAddr>,
    hits: Vec<Hit>,
}

/// The shared fault plan; internally synchronized so the router's
/// per-connection threads consult it concurrently.
#[derive(Default)]
pub struct FaultPlan {
    inner: Mutex<Inner>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn add_rule(&self, rule: Rule) {
        self.inner.lock().unwrap().rules.push(rule);
    }

    /// Make a shard address unreachable (every connect refused) until
    /// [`FaultPlan::revive`].
    pub fn kill(&self, addr: SocketAddr) {
        self.inner.lock().unwrap().killed.insert(addr);
    }

    pub fn revive(&self, addr: SocketAddr) {
        self.inner.lock().unwrap().killed.remove(&addr);
    }

    pub fn is_killed(&self, addr: SocketAddr) -> bool {
        self.inner.lock().unwrap().killed.contains(&addr)
    }

    /// Consult the plan at a protocol point: consumes and returns the
    /// first matching live rule's action, recording a [`Hit`].
    pub fn fire(&self, shard: SocketAddr, point: Point) -> Option<FaultAction> {
        let mut inner = self.inner.lock().unwrap();
        let idx = inner.rules.iter().position(|r| {
            r.times > 0 && (r.shard.is_none() || r.shard == Some(shard)) && r.point == point
        })?;
        inner.rules[idx].times -= 1;
        let action = inner.rules[idx].action;
        inner.hits.push(Hit { shard, point, action });
        Some(action)
    }

    /// [`FaultPlan::fire`] for process-local points (the journal's crash
    /// hooks) that have no shard address: rules match via `shard: None`,
    /// and hits record the sentinel unspecified address.
    pub fn fire_local(&self, point: Point) -> Option<FaultAction> {
        let local: SocketAddr = ([0, 0, 0, 0], 0).into();
        let mut inner = self.inner.lock().unwrap();
        let idx = inner
            .rules
            .iter()
            .position(|r| r.times > 0 && r.shard.is_none() && r.point == point)?;
        inner.rules[idx].times -= 1;
        let action = inner.rules[idx].action;
        inner.hits.push(Hit { shard: local, point, action });
        Some(action)
    }

    /// Every fault that fired so far, in order.
    pub fn hits(&self) -> Vec<Hit> {
        self.inner.lock().unwrap().hits.clone()
    }

    /// How many staged rules have not (fully) fired yet.
    pub fn rules_pending(&self) -> usize {
        self.inner.lock().unwrap().rules.iter().filter(|r| r.times > 0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    #[test]
    fn rule_fires_exactly_times_then_goes_inert() {
        let plan = FaultPlan::new();
        plan.add_rule(Rule {
            shard: None,
            point: Point::Send(FrameKind::Export),
            action: FaultAction::DropFrame,
            times: 2,
        });
        assert_eq!(plan.rules_pending(), 1);
        let p = Point::Send(FrameKind::Export);
        assert_eq!(plan.fire(addr(1), p), Some(FaultAction::DropFrame));
        assert_eq!(plan.fire(addr(2), p), Some(FaultAction::DropFrame));
        assert_eq!(plan.fire(addr(1), p), None, "rule must be consumed");
        assert_eq!(plan.rules_pending(), 0);
        assert_eq!(plan.hits().len(), 2);
        assert_eq!(plan.hits()[0], Hit { shard: addr(1), point: p, action: FaultAction::DropFrame });
    }

    #[test]
    fn shard_filter_and_point_matching_are_exact() {
        let plan = FaultPlan::new();
        plan.add_rule(Rule::once_at(
            addr(9),
            Point::RecvReplyTo(FrameKind::Import),
            FaultAction::SeverAfter,
        ));
        plan.add_rule(Rule::once(Point::TokenStream { after: 3 }, FaultAction::SeverAfter));
        // wrong shard, wrong point, wrong token count: no fire
        assert_eq!(plan.fire(addr(8), Point::RecvReplyTo(FrameKind::Import)), None);
        assert_eq!(plan.fire(addr(9), Point::RecvReplyTo(FrameKind::Export)), None);
        assert_eq!(plan.fire(addr(9), Point::TokenStream { after: 2 }), None);
        // exact matches fire
        assert_eq!(
            plan.fire(addr(9), Point::RecvReplyTo(FrameKind::Import)),
            Some(FaultAction::SeverAfter)
        );
        assert_eq!(
            plan.fire(addr(1), Point::TokenStream { after: 3 }),
            Some(FaultAction::SeverAfter)
        );
        assert_eq!(plan.hits().len(), 2);
    }

    #[test]
    fn kill_and_revive_toggle_reachability() {
        let plan = FaultPlan::new();
        assert!(!plan.is_killed(addr(5)));
        plan.kill(addr(5));
        assert!(plan.is_killed(addr(5)));
        assert!(!plan.is_killed(addr(6)), "kill is per-address");
        plan.revive(addr(5));
        assert!(!plan.is_killed(addr(5)));
    }
}
