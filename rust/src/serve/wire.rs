//! The serve-layer wire protocol: length-prefixed, versioned binary frames
//! over a byte stream (TCP between router and shards; loopback in tests).
//!
//! Framing: every frame is `[u32 len LE][u8 tag][payload][u64 fnv1a64]`,
//! where `len` counts the tag byte plus the payload (not the trailing
//! checksum) and is capped at [`MAX_FRAME_BYTES`] so a corrupt stream
//! fails fast instead of allocating unboundedly.  The trailing checksum
//! is the fnv1a64 of the tag + payload bytes, verified by the bounded
//! reader before any decoding, so a frame corrupted on the wire is a
//! typed `InvalidData` error rather than a silently mis-decoded command.
//! Integers are little-endian; strings are `u32 len + UTF-8`; token
//! vectors are `u32 count + i32 LE` each.
//!
//! Handshake: a shard greets every connection with [`Frame::Hello`]
//! carrying the protocol version, its engine's state tag, its
//! [`crate::engine::LmShape::fingerprint`], and a weights fingerprint
//! (shape alone is not identity — same shape + different weights would
//! decode a migrated state into silently wrong tokens).  The router
//! refuses a shard whose protocol version differs, and refuses to *ship*
//! a session blob toward a shard whose engine tag, shape fingerprint or
//! weights fingerprint differs from the blob's source — a mismatched
//! blob is rejected at the handshake, never restored (the shard
//! re-validates on [`Frame::Import`] as defense in depth, and slot
//! restore validates plane shapes a third time).
//!
//! One connection carries one command at a time: the client writes a
//! request frame and reads reply frames until [`Frame::Done`],
//! [`Frame::Blob`], [`Frame::BulkBlob`], [`Frame::Ok`],
//! [`Frame::HealthReport`] or [`Frame::Error`].  Generation replies
//! stream one [`Frame::Token`] per generated token before the closing
//! [`Frame::Done`].
//!
//! Deadlines: generation requests carry a `deadline_ms` budget — the
//! milliseconds the *client* is still willing to wait when the frame is
//! written (0 = no deadline).  Every hop re-derives its own absolute
//! deadline from the budget on receipt, so clock skew between peers
//! never matters; work whose budget expires in a queue is shed with a
//! typed [`ErrCode::DeadlineExceeded`] instead of being silently served
//! to a client that already gave up.

use std::io::{self, Read, Write};

use crate::obs::{Hist, HopReport, MetricValue, Span, BUCKETS};
use crate::util::bytes::{ByteReader, ReadErr};

/// Protocol version; bump on any frame-layout change so mixed-version
/// router/shard pairs refuse each other at the handshake.  v2 added the
/// commit/abort migration pair ([`Frame::ExportCommit`] /
/// [`Frame::ExportAbort`]), the transcript probe ([`Frame::Transcript`] /
/// [`Frame::TranscriptIs`]) and [`ErrCode::Unavailable`].  v3 added the
/// observability pull ([`Frame::Metrics`] / [`Frame::MetricsReport`]) and
/// the `queue_depth` field of [`HealthReport`].  v4 added the trailing
/// fnv1a64 frame checksum, the `deadline_ms` budget on [`Frame::Submit`]
/// / [`Frame::SubmitInSession`], the typed [`ErrCode::Overloaded`] /
/// [`ErrCode::DeadlineExceeded`] refusals, and the bulk-drain family
/// ([`Frame::BulkExport`], [`Frame::BulkImport`], [`Frame::BulkCommit`],
/// [`Frame::BulkAbort`], [`Frame::BulkBlob`]).  v5 added the optional
/// shared-secret handshake ([`Frame::Auth`], sent by the client right
/// after validating the server's [`Frame::Hello`]) and the typed
/// [`ErrCode::AuthFailed`] refusal.  v6 added the distributed-tracing
/// context: a 64-bit `trace` id + a `profile` flag on [`Frame::Submit`]
/// / [`Frame::SubmitInSession`] (0 = untraced; the flag requests
/// engine hot-path stage profiling), the `trace` echo on
/// [`Frame::Done`], and the [`Frame::Spans`] reply carrying a hop's
/// span report (durations + hop-relative offsets — clock-skew-immune
/// like `deadline_ms`) back toward the front door.
pub const PROTO_VERSION: u32 = 6;

/// Upper bound on one frame's encoded size (tag + payload).
pub const MAX_FRAME_BYTES: u32 = 64 << 20;

/// Typed error codes carried by [`Frame::Error`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrCode {
    /// The shard holds no trace of the session (the router should migrate
    /// or re-prefill).
    UnknownSession,
    /// Engine tag / shape / blob version mismatch: the payload can never
    /// be restored here.
    Mismatch,
    /// The shard's coordinator is gone.
    Closed,
    /// Malformed or out-of-order frame.
    Protocol,
    /// Anything else.
    Internal,
    /// The target cannot take the request right now (open circuit breaker,
    /// in-flight cap, draining).  Retryable — unlike [`ErrCode::Closed`],
    /// nothing is wrong with the request itself.
    Unavailable,
    /// Admission refused under load: the request waited out its deadline
    /// budget (or the bounded queue was full) without reaching a slot.
    /// The request was never applied — session state is untouched.
    Overloaded,
    /// The request's deadline budget expired while it was queued, so it
    /// was shed before running.  Like [`ErrCode::Overloaded`], the
    /// session state is untouched.
    DeadlineExceeded,
    /// The connection did not present the server's shared-secret token
    /// (missing, wrong, or a non-[`Frame::Auth`] first frame) before its
    /// first command.  The connection is closed after this refusal.
    AuthFailed,
}

impl ErrCode {
    fn to_u16(self) -> u16 {
        match self {
            ErrCode::UnknownSession => 1,
            ErrCode::Mismatch => 2,
            ErrCode::Closed => 3,
            ErrCode::Protocol => 4,
            ErrCode::Internal => 5,
            ErrCode::Unavailable => 6,
            ErrCode::Overloaded => 7,
            ErrCode::DeadlineExceeded => 8,
            ErrCode::AuthFailed => 9,
        }
    }

    fn from_u16(v: u16) -> ErrCode {
        match v {
            1 => ErrCode::UnknownSession,
            2 => ErrCode::Mismatch,
            3 => ErrCode::Closed,
            4 => ErrCode::Protocol,
            6 => ErrCode::Unavailable,
            7 => ErrCode::Overloaded,
            8 => ErrCode::DeadlineExceeded,
            9 => ErrCode::AuthFailed,
            _ => ErrCode::Internal,
        }
    }
}

/// Per-shard health snapshot (the serve-layer view of the coordinator
/// metrics), aggregated across shards by `serve::admin`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HealthReport {
    /// Sessions RAM-resident in the shard's store.
    pub sessions_resident: u64,
    /// Bytes those sessions occupy.
    pub session_bytes: u64,
    /// Session turns resumed from stored state.
    pub session_hits: u64,
    /// Session turns that had to re-prefill their transcript.
    pub session_misses: u64,
    /// Requests accepted but not yet finished.
    pub in_flight: u64,
    pub requests_done: u64,
    pub tokens_generated: u64,
    /// Prefill tokens skipped by resuming stored state.
    pub prefill_tokens_saved: u64,
    /// Requests waiting for a slot right now.
    pub queue_depth: u64,
}

/// One exported session inside a bulk drain frame: the same payload a
/// per-session [`Frame::Blob`] carries, minus the fingerprints (they are
/// per-shard and travel once per bulk frame).
#[derive(Clone, Debug, PartialEq)]
pub struct SessionBlob {
    pub session: u64,
    pub transcript: Vec<i32>,
    pub state: Option<Vec<u8>>,
}

/// One protocol frame.  Client-to-shard requests first, then shard
/// replies; see the module docs for the conversation shape.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Server greeting: protocol version + engine tag + shape fingerprint
    /// + weights fingerprint.  Shape alone is not identity: two
    /// identically-shaped engines with different weights would decode a
    /// migrated state into silently wrong tokens, so the weights
    /// fingerprint participates in every migration check.
    Hello { proto: u32, engine: String, shape_fp: u64, weights_fp: u64 },
    /// Client credential: the shared-secret token, sent as the first
    /// client frame when the server requires one.  The server compares
    /// it in constant time ([`crate::util::bytes::ct_eq`]) and answers
    /// any mismatch — or any other first frame — with a typed
    /// [`ErrCode::AuthFailed`] before processing commands.
    Auth { token: String },
    /// One-shot generation.  `deadline_ms` is the client's remaining
    /// deadline budget in milliseconds at send time (0 = none).
    /// `trace` is the request's 64-bit trace id (0 = untraced; the
    /// front door mints one for every admitted request and propagates
    /// it on this field, so the reply's span reports join across
    /// hops).  `profile` asks the serving engine to record per-stage
    /// hot-path timings for this request.
    Submit { max_new: u32, deadline_ms: u32, trace: u64, profile: bool, prompt: Vec<i32> },
    /// One turn of a session.  `strict` asks for a typed
    /// [`ErrCode::UnknownSession`] instead of silently starting a fresh
    /// conversation when the shard does not hold the session.
    /// `deadline_ms`, `trace` and `profile` as on [`Frame::Submit`].
    SubmitInSession {
        session: u64,
        strict: bool,
        max_new: u32,
        deadline_ms: u32,
        trace: u64,
        profile: bool,
        delta: Vec<i32>,
    },
    /// Drop the session's state + transcript (deferred until quiescent).
    EndSession { session: u64 },
    /// Quiesce the session, detach it, and reply with [`Frame::Blob`].
    Export { session: u64 },
    /// Install a migrated session.  `shape_fp`/`weights_fp` are the
    /// *source* shard's fingerprints; the receiving shard refuses any
    /// mismatch before decoding the state bytes.
    Import {
        session: u64,
        shape_fp: u64,
        weights_fp: u64,
        transcript: Vec<i32>,
        state: Option<Vec<u8>>,
    },
    /// Ask for a [`Frame::HealthReport`].
    Health,
    /// Ask for a [`Frame::MetricsReport`]: the shard's full named-metric
    /// snapshot (counters, gauges, latency histograms).
    Metrics,
    /// Second phase of a migration: the export landed on the target, so
    /// the source shard may discard its stashed copy of the session.  The
    /// session survives on exactly one shard at every point of this
    /// protocol because [`Frame::Export`] only *stashes* the detached
    /// session at the source (inactive, unable to serve turns) — commit
    /// discards the stash, [`Frame::ExportAbort`] restores it.  Both are
    /// idempotent: committing or aborting an absent stash is [`Frame::Ok`],
    /// so the router can retry either after a severed connection.
    ExportCommit { session: u64 },
    /// Roll back an export: re-install the stashed session at the source
    /// (the import never landed on the target).  Idempotent, see
    /// [`Frame::ExportCommit`].
    ExportAbort { session: u64 },
    /// Ask for the session's full transcript (prompt + generated tokens,
    /// deferred until the session is quiescent).  Replies
    /// [`Frame::TranscriptIs`], or [`ErrCode::UnknownSession`] — which is
    /// how the router probes "did my severed import land?" without side
    /// effects, and how it reconciles its transcript mirror after a
    /// severed token stream.
    Transcript { session: u64 },
    /// Export *every* session the shard holds (resident, spilled, and
    /// transcript-only) in one round trip: each is detached and stashed
    /// exactly like a per-session [`Frame::Export`], and the reply is one
    /// [`Frame::BulkBlob`].  Settlement is [`Frame::BulkCommit`] /
    /// [`Frame::BulkAbort`] over the stashed ids.
    BulkExport,
    /// Install a batch of migrated sessions in one round trip (the
    /// receiving side of a bulk drain).  Fingerprint validation is
    /// identical to [`Frame::Import`] and happens before any session in
    /// the batch is installed, so a mismatched batch installs nothing.
    BulkImport { shape_fp: u64, weights_fp: u64, sessions: Vec<SessionBlob> },
    /// Discard the listed export stashes (idempotent per id, like
    /// [`Frame::ExportCommit`] but one round trip for the whole batch).
    BulkCommit { sessions: Vec<u64> },
    /// Restore the listed export stashes (idempotent per id, like
    /// [`Frame::ExportAbort`] but one round trip for the whole batch).
    /// An EMPTY id list means "restore every stash" — the recovery a
    /// router uses when the [`Frame::BulkBlob`] reply was lost and it
    /// cannot name what was stashed.
    BulkAbort { sessions: Vec<u64> },
    /// One generated token of the current request.
    Token { token: i32 },
    /// End of a generation reply.  `trace` echoes the request's trace
    /// id (0 when the request was untraced) so every client learns the
    /// id it can look up at `GET /trace/<id>`.
    Done { trace: u64, ttft_us: u64, total_us: u64 },
    /// Span report for one traced generation, sent immediately before
    /// [`Frame::Done`] when the request carried a non-zero `trace`.
    /// Each hop's spans are durations + offsets relative to that hop's
    /// own start (clock-skew-immune); a replying layer *prepends* its
    /// own hop to the reports it gathered downstream, so the front
    /// door receives the hops in traversal order.
    Spans { trace: u64, hops: Vec<HopReport> },
    /// Export reply: the detached session (wire-encoded
    /// [`crate::session::SessionState`] bytes, when the engine snapshots),
    /// stamped with the exporting shard's fingerprints.
    Blob {
        session: u64,
        shape_fp: u64,
        weights_fp: u64,
        transcript: Vec<i32>,
        state: Option<Vec<u8>>,
    },
    /// Generic success ack (EndSession / Import / ExportCommit /
    /// ExportAbort / BulkImport / BulkCommit / BulkAbort).
    Ok,
    HealthReport(HealthReport),
    /// Reply to [`Frame::Metrics`]: the shard's named-metric snapshot.
    /// Histograms ship sparsely (only non-zero buckets) over the shared
    /// fixed bucket grid, so the router can merge shard histograms
    /// exactly into cluster histograms.
    MetricsReport { entries: Vec<(String, MetricValue)> },
    /// Reply to [`Frame::Transcript`]: the session's complete token
    /// history in order.
    TranscriptIs { tokens: Vec<i32> },
    /// Reply to [`Frame::BulkExport`]: every stashed session, stamped
    /// with the exporting shard's fingerprints.
    BulkBlob { shape_fp: u64, weights_fp: u64, sessions: Vec<SessionBlob> },
    Error { code: ErrCode, msg: String },
}

// Frame tag bytes (requests low, replies from 16 up).
const TAG_HELLO: u8 = 1;
const TAG_SUBMIT: u8 = 2;
const TAG_SUBMIT_IN_SESSION: u8 = 3;
const TAG_END_SESSION: u8 = 4;
const TAG_EXPORT: u8 = 5;
const TAG_IMPORT: u8 = 6;
const TAG_HEALTH: u8 = 7;
const TAG_EXPORT_COMMIT: u8 = 8;
const TAG_EXPORT_ABORT: u8 = 9;
const TAG_TRANSCRIPT: u8 = 10;
const TAG_METRICS: u8 = 11;
const TAG_BULK_EXPORT: u8 = 12;
const TAG_BULK_IMPORT: u8 = 13;
const TAG_BULK_COMMIT: u8 = 14;
const TAG_BULK_ABORT: u8 = 15;
const TAG_TOKEN: u8 = 16;
const TAG_DONE: u8 = 17;
const TAG_BLOB: u8 = 18;
const TAG_OK: u8 = 19;
const TAG_HEALTH_REPORT: u8 = 20;
const TAG_ERROR: u8 = 21;
const TAG_TRANSCRIPT_IS: u8 = 22;
const TAG_METRICS_REPORT: u8 = 23;
const TAG_BULK_BLOB: u8 = 24;
const TAG_AUTH: u8 = 25;
const TAG_SPANS: u8 = 26;

fn bad_data(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

/// Payload encoder: appends little-endian primitives to a byte buffer.
struct Enc(Vec<u8>);

impl Enc {
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }

    fn u16(&mut self, v: u16) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn i32(&mut self, v: i32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.0.extend_from_slice(s.as_bytes());
    }

    fn tokens(&mut self, toks: &[i32]) {
        self.u32(toks.len() as u32);
        for &t in toks {
            self.i32(t);
        }
    }

    fn opt_bytes(&mut self, b: &Option<Vec<u8>>) {
        match b {
            None => self.u8(0),
            Some(v) => {
                self.u8(1);
                self.u32(v.len() as u32);
                self.0.extend_from_slice(v);
            }
        }
    }

    fn session_blobs(&mut self, blobs: &[SessionBlob]) {
        self.u32(blobs.len() as u32);
        for b in blobs {
            self.u64(b.session);
            self.tokens(&b.transcript);
            self.opt_bytes(&b.state);
        }
    }

    /// Sparse histogram: only non-zero buckets travel (the grid is a
    /// compile-time constant shared by both ends), then total count and
    /// the sum's raw bits.
    fn hist(&mut self, h: &Hist) {
        let nonzero: Vec<(usize, u64)> = h
            .bucket_counts()
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (i, c))
            .collect();
        self.u8(nonzero.len() as u8);
        for (i, c) in nonzero {
            self.u8(i as u8);
            self.u64(c);
        }
        self.u64(h.count());
        self.u64(h.sum().to_bits());
    }

    fn hops(&mut self, hops: &[HopReport]) {
        self.u32(hops.len() as u32);
        for h in hops {
            self.str(&h.hop);
            self.u64(h.total_us);
            self.u32(h.spans.len() as u32);
            for s in &h.spans {
                self.str(&s.name);
                self.u64(s.start_us);
                self.u64(s.dur_us);
            }
            self.u32(h.notes.len() as u32);
            for n in &h.notes {
                self.str(n);
            }
        }
    }

    fn metric(&mut self, v: &MetricValue) {
        match v {
            MetricValue::Counter(c) => {
                self.u8(0);
                self.u64(*c);
            }
            MetricValue::Gauge(g) => {
                self.u8(1);
                self.u64(*g);
            }
            MetricValue::Hist(h) => {
                self.u8(2);
                self.hist(h);
            }
        }
    }
}

/// Maps the shared reader's typed errors into frame-decode `InvalidData`.
fn read_err(e: ReadErr) -> io::Error {
    bad_data(match e {
        ReadErr::Truncated => "truncated frame",
        ReadErr::Utf8 => "non-utf8 string in frame",
    })
}

/// Payload decoder: thin io-error wrapper over the shared bounded reader
/// ([`crate::util::bytes::ByteReader`] — one bounds-check implementation
/// for every untrusted-bytes decoder in the crate), plus the wire-specific
/// composites (token vectors, optional byte blobs).
struct Dec<'a>(ByteReader<'a>);

impl Dec<'_> {
    fn u8(&mut self) -> io::Result<u8> {
        self.0.u8().map_err(read_err)
    }

    fn u16(&mut self) -> io::Result<u16> {
        self.0.u16().map_err(read_err)
    }

    fn u32(&mut self) -> io::Result<u32> {
        self.0.u32().map_err(read_err)
    }

    fn u64(&mut self) -> io::Result<u64> {
        self.0.u64().map_err(read_err)
    }

    fn i32(&mut self) -> io::Result<i32> {
        self.0.i32().map_err(read_err)
    }

    fn str(&mut self) -> io::Result<String> {
        self.0.string().map_err(read_err)
    }

    fn tokens(&mut self) -> io::Result<Vec<i32>> {
        let n = self.u32()? as usize;
        let raw = self.0.take(4 * n).map_err(read_err)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn opt_bytes(&mut self) -> io::Result<Option<Vec<u8>>> {
        match self.u8()? {
            0 => Ok(None),
            1 => {
                let len = self.u32()? as usize;
                Ok(Some(self.0.take(len).map_err(read_err)?.to_vec()))
            }
            _ => Err(bad_data("bad option tag")),
        }
    }

    fn session_blobs(&mut self) -> io::Result<Vec<SessionBlob>> {
        let n = self.u32()? as usize;
        let mut blobs = Vec::new();
        for _ in 0..n {
            blobs.push(SessionBlob {
                session: self.u64()?,
                transcript: self.tokens()?,
                state: self.opt_bytes()?,
            });
        }
        Ok(blobs)
    }

    fn sessions(&mut self) -> io::Result<Vec<u64>> {
        let n = self.u32()? as usize;
        let raw = self.0.take(8 * n).map_err(read_err)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| {
                u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
            })
            .collect())
    }

    fn hist(&mut self) -> io::Result<Hist> {
        let n = self.u8()? as usize;
        let mut counts = [0u64; BUCKETS];
        for _ in 0..n {
            let idx = self.u8()? as usize;
            if idx >= BUCKETS {
                return Err(bad_data("histogram bucket index out of range"));
            }
            // wrapping: corrupt duplicate pairs must not panic the decoder
            counts[idx] = counts[idx].wrapping_add(self.u64()?);
        }
        let count = self.u64()?;
        let sum = f64::from_bits(self.u64()?);
        Ok(Hist::from_raw(counts, count, sum))
    }

    fn hops(&mut self) -> io::Result<Vec<HopReport>> {
        let n = self.u32()? as usize;
        let mut hops = Vec::new();
        for _ in 0..n {
            let hop = self.str()?;
            let total_us = self.u64()?;
            let n_spans = self.u32()? as usize;
            let mut spans = Vec::new();
            for _ in 0..n_spans {
                spans.push(Span {
                    name: self.str()?,
                    start_us: self.u64()?,
                    dur_us: self.u64()?,
                });
            }
            let n_notes = self.u32()? as usize;
            let mut notes = Vec::new();
            for _ in 0..n_notes {
                notes.push(self.str()?);
            }
            hops.push(HopReport { hop, total_us, spans, notes });
        }
        Ok(hops)
    }

    fn metric(&mut self) -> io::Result<MetricValue> {
        match self.u8()? {
            0 => Ok(MetricValue::Counter(self.u64()?)),
            1 => Ok(MetricValue::Gauge(self.u64()?)),
            2 => Ok(MetricValue::Hist(self.hist()?)),
            _ => Err(bad_data("bad metric kind tag")),
        }
    }

    fn finish(&self) -> io::Result<()> {
        if self.0.is_exhausted() {
            Ok(())
        } else {
            Err(bad_data("trailing bytes in frame"))
        }
    }
}

/// Encode one frame (tag + payload, without the length prefix).
fn encode(frame: &Frame) -> Vec<u8> {
    let mut e = Enc(Vec::with_capacity(32));
    match frame {
        Frame::Hello { proto, engine, shape_fp, weights_fp } => {
            e.u8(TAG_HELLO);
            e.u32(*proto);
            e.str(engine);
            e.u64(*shape_fp);
            e.u64(*weights_fp);
        }
        Frame::Auth { token } => {
            e.u8(TAG_AUTH);
            e.str(token);
        }
        Frame::Submit { max_new, deadline_ms, trace, profile, prompt } => {
            e.u8(TAG_SUBMIT);
            e.u32(*max_new);
            e.u32(*deadline_ms);
            e.u64(*trace);
            e.u8(*profile as u8);
            e.tokens(prompt);
        }
        Frame::SubmitInSession {
            session,
            strict,
            max_new,
            deadline_ms,
            trace,
            profile,
            delta,
        } => {
            e.u8(TAG_SUBMIT_IN_SESSION);
            e.u64(*session);
            e.u8(*strict as u8);
            e.u32(*max_new);
            e.u32(*deadline_ms);
            e.u64(*trace);
            e.u8(*profile as u8);
            e.tokens(delta);
        }
        Frame::EndSession { session } => {
            e.u8(TAG_END_SESSION);
            e.u64(*session);
        }
        Frame::Export { session } => {
            e.u8(TAG_EXPORT);
            e.u64(*session);
        }
        Frame::Import { session, shape_fp, weights_fp, transcript, state } => {
            e.u8(TAG_IMPORT);
            e.u64(*session);
            e.u64(*shape_fp);
            e.u64(*weights_fp);
            e.tokens(transcript);
            e.opt_bytes(state);
        }
        Frame::Health => e.u8(TAG_HEALTH),
        Frame::Metrics => e.u8(TAG_METRICS),
        Frame::MetricsReport { entries } => {
            e.u8(TAG_METRICS_REPORT);
            e.u32(entries.len() as u32);
            for (name, v) in entries {
                e.str(name);
                e.metric(v);
            }
        }
        Frame::ExportCommit { session } => {
            e.u8(TAG_EXPORT_COMMIT);
            e.u64(*session);
        }
        Frame::ExportAbort { session } => {
            e.u8(TAG_EXPORT_ABORT);
            e.u64(*session);
        }
        Frame::Transcript { session } => {
            e.u8(TAG_TRANSCRIPT);
            e.u64(*session);
        }
        Frame::BulkExport => e.u8(TAG_BULK_EXPORT),
        Frame::BulkImport { shape_fp, weights_fp, sessions } => {
            e.u8(TAG_BULK_IMPORT);
            e.u64(*shape_fp);
            e.u64(*weights_fp);
            e.session_blobs(sessions);
        }
        Frame::BulkCommit { sessions } => {
            e.u8(TAG_BULK_COMMIT);
            e.u32(sessions.len() as u32);
            for &s in sessions {
                e.u64(s);
            }
        }
        Frame::BulkAbort { sessions } => {
            e.u8(TAG_BULK_ABORT);
            e.u32(sessions.len() as u32);
            for &s in sessions {
                e.u64(s);
            }
        }
        Frame::Token { token } => {
            e.u8(TAG_TOKEN);
            e.i32(*token);
        }
        Frame::Done { trace, ttft_us, total_us } => {
            e.u8(TAG_DONE);
            e.u64(*trace);
            e.u64(*ttft_us);
            e.u64(*total_us);
        }
        Frame::Spans { trace, hops } => {
            e.u8(TAG_SPANS);
            e.u64(*trace);
            e.hops(hops);
        }
        Frame::Blob { session, shape_fp, weights_fp, transcript, state } => {
            e.u8(TAG_BLOB);
            e.u64(*session);
            e.u64(*shape_fp);
            e.u64(*weights_fp);
            e.tokens(transcript);
            e.opt_bytes(state);
        }
        Frame::Ok => e.u8(TAG_OK),
        Frame::TranscriptIs { tokens } => {
            e.u8(TAG_TRANSCRIPT_IS);
            e.tokens(tokens);
        }
        Frame::BulkBlob { shape_fp, weights_fp, sessions } => {
            e.u8(TAG_BULK_BLOB);
            e.u64(*shape_fp);
            e.u64(*weights_fp);
            e.session_blobs(sessions);
        }
        Frame::HealthReport(h) => {
            e.u8(TAG_HEALTH_REPORT);
            e.u64(h.sessions_resident);
            e.u64(h.session_bytes);
            e.u64(h.session_hits);
            e.u64(h.session_misses);
            e.u64(h.in_flight);
            e.u64(h.requests_done);
            e.u64(h.tokens_generated);
            e.u64(h.prefill_tokens_saved);
            e.u64(h.queue_depth);
        }
        Frame::Error { code, msg } => {
            e.u8(TAG_ERROR);
            e.u16(code.to_u16());
            e.str(msg);
        }
    }
    e.0
}

/// Decode one frame body (tag + payload, without the length prefix).
/// `pub(crate)` so the shard's stop-aware reader can reuse it.
pub(crate) fn decode(body: &[u8]) -> io::Result<Frame> {
    let mut d = Dec(ByteReader::new(body));
    let tag = d.u8()?;
    let frame = match tag {
        TAG_HELLO => Frame::Hello {
            proto: d.u32()?,
            engine: d.str()?,
            shape_fp: d.u64()?,
            weights_fp: d.u64()?,
        },
        TAG_AUTH => Frame::Auth { token: d.str()? },
        TAG_SUBMIT => Frame::Submit {
            max_new: d.u32()?,
            deadline_ms: d.u32()?,
            trace: d.u64()?,
            profile: d.u8()? != 0,
            prompt: d.tokens()?,
        },
        TAG_SUBMIT_IN_SESSION => Frame::SubmitInSession {
            session: d.u64()?,
            strict: d.u8()? != 0,
            max_new: d.u32()?,
            deadline_ms: d.u32()?,
            trace: d.u64()?,
            profile: d.u8()? != 0,
            delta: d.tokens()?,
        },
        TAG_END_SESSION => Frame::EndSession { session: d.u64()? },
        TAG_EXPORT => Frame::Export { session: d.u64()? },
        TAG_IMPORT => Frame::Import {
            session: d.u64()?,
            shape_fp: d.u64()?,
            weights_fp: d.u64()?,
            transcript: d.tokens()?,
            state: d.opt_bytes()?,
        },
        TAG_HEALTH => Frame::Health,
        TAG_METRICS => Frame::Metrics,
        TAG_METRICS_REPORT => {
            let n = d.u32()? as usize;
            let mut entries = Vec::new();
            for _ in 0..n {
                let name = d.str()?;
                let v = d.metric()?;
                entries.push((name, v));
            }
            Frame::MetricsReport { entries }
        }
        TAG_EXPORT_COMMIT => Frame::ExportCommit { session: d.u64()? },
        TAG_EXPORT_ABORT => Frame::ExportAbort { session: d.u64()? },
        TAG_TRANSCRIPT => Frame::Transcript { session: d.u64()? },
        TAG_BULK_EXPORT => Frame::BulkExport,
        TAG_BULK_IMPORT => Frame::BulkImport {
            shape_fp: d.u64()?,
            weights_fp: d.u64()?,
            sessions: d.session_blobs()?,
        },
        TAG_BULK_COMMIT => Frame::BulkCommit { sessions: d.sessions()? },
        TAG_BULK_ABORT => Frame::BulkAbort { sessions: d.sessions()? },
        TAG_TOKEN => Frame::Token { token: d.i32()? },
        TAG_DONE => Frame::Done { trace: d.u64()?, ttft_us: d.u64()?, total_us: d.u64()? },
        TAG_SPANS => Frame::Spans { trace: d.u64()?, hops: d.hops()? },
        TAG_BLOB => Frame::Blob {
            session: d.u64()?,
            shape_fp: d.u64()?,
            weights_fp: d.u64()?,
            transcript: d.tokens()?,
            state: d.opt_bytes()?,
        },
        TAG_OK => Frame::Ok,
        TAG_TRANSCRIPT_IS => Frame::TranscriptIs { tokens: d.tokens()? },
        TAG_BULK_BLOB => Frame::BulkBlob {
            shape_fp: d.u64()?,
            weights_fp: d.u64()?,
            sessions: d.session_blobs()?,
        },
        TAG_HEALTH_REPORT => Frame::HealthReport(HealthReport {
            sessions_resident: d.u64()?,
            session_bytes: d.u64()?,
            session_hits: d.u64()?,
            session_misses: d.u64()?,
            in_flight: d.u64()?,
            requests_done: d.u64()?,
            tokens_generated: d.u64()?,
            prefill_tokens_saved: d.u64()?,
            queue_depth: d.u64()?,
        }),
        TAG_ERROR => Frame::Error { code: ErrCode::from_u16(d.u16()?), msg: d.str()? },
        other => return Err(bad_data(&format!("unknown frame tag {other}"))),
    };
    d.finish()?;
    Ok(frame)
}

/// Write one length-prefixed, checksummed frame and flush it.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> io::Result<()> {
    let body = encode(frame);
    if body.len() as u64 > MAX_FRAME_BYTES as u64 {
        return Err(bad_data("frame exceeds MAX_FRAME_BYTES"));
    }
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(&body)?;
    w.write_all(&fnv1a64(&body).to_le_bytes())?;
    w.flush()
}

/// Read one length-prefixed frame; blocks until a whole frame arrives.
/// The trailing fnv1a64 checksum is verified before decoding, so a
/// frame corrupted in transit fails as `InvalidData` instead of
/// mis-decoding.  Errors with `UnexpectedEof` on a cleanly closed stream
/// and `InvalidData` on an oversized, corrupted or malformed frame.
pub fn read_frame(r: &mut impl Read) -> io::Result<Frame> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len);
    if len == 0 || len > MAX_FRAME_BYTES {
        return Err(bad_data("bad frame length"));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    let mut sum = [0u8; 8];
    r.read_exact(&mut sum)?;
    if u64::from_le_bytes(sum) != fnv1a64(&body) {
        return Err(bad_data("frame checksum mismatch"));
    }
    decode(&body)
}

// The stable hashes the router builds its ring from; one implementation,
// shared with the shape/weights fingerprints (see `util::bytes`).
pub use crate::util::bytes::{fnv1a64, splitmix64};

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(f: Frame) {
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        let got = read_frame(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(got, f);
    }

    #[test]
    fn every_frame_roundtrips() {
        roundtrip(Frame::Hello {
            proto: PROTO_VERSION,
            engine: "laughing-hyena".into(),
            shape_fp: 0xDEAD_BEEF_1234_5678,
            weights_fp: 0x0123_4567_89AB_CDEF,
        });
        roundtrip(Frame::Auth { token: "".into() });
        roundtrip(Frame::Auth { token: "hunter2".into() });
        roundtrip(Frame::Submit {
            max_new: 16,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            prompt: vec![1, -2, 3],
        });
        roundtrip(Frame::Submit {
            max_new: 16,
            deadline_ms: 2500,
            trace: u64::MAX,
            profile: true,
            prompt: vec![],
        });
        roundtrip(Frame::SubmitInSession {
            session: u64::MAX,
            strict: true,
            max_new: 0,
            deadline_ms: u32::MAX,
            trace: 0,
            profile: false,
            delta: vec![],
        });
        roundtrip(Frame::SubmitInSession {
            session: 7,
            strict: false,
            max_new: 3,
            deadline_ms: 0,
            trace: 99,
            profile: true,
            delta: vec![i32::MIN, i32::MAX],
        });
        roundtrip(Frame::EndSession { session: 9 });
        roundtrip(Frame::Export { session: 0 });
        roundtrip(Frame::Import {
            session: 3,
            shape_fp: 42,
            weights_fp: 43,
            transcript: vec![5, 6, 7],
            state: Some(vec![0, 255, 128]),
        });
        roundtrip(Frame::Import {
            session: 3,
            shape_fp: 42,
            weights_fp: 43,
            transcript: vec![],
            state: None,
        });
        roundtrip(Frame::Health);
        roundtrip(Frame::Metrics);
        roundtrip(Frame::MetricsReport { entries: vec![] });
        let mut h = Hist::new();
        h.record(0.001);
        h.record(0.002);
        h.record(1e9); // overflow bucket must survive the sparse encoding
        roundtrip(Frame::MetricsReport {
            entries: vec![
                ("lh_requests_total".into(), MetricValue::Counter(7)),
                ("lh_queue_depth".into(), MetricValue::Gauge(0)),
                ("lh_ttft_seconds".into(), MetricValue::Hist(h)),
            ],
        });
        roundtrip(Frame::ExportCommit { session: 21 });
        roundtrip(Frame::ExportAbort { session: u64::MAX });
        roundtrip(Frame::Transcript { session: 0 });
        roundtrip(Frame::TranscriptIs { tokens: vec![] });
        roundtrip(Frame::TranscriptIs { tokens: vec![1, -2, i32::MAX] });
        roundtrip(Frame::BulkExport);
        roundtrip(Frame::BulkImport { shape_fp: 1, weights_fp: 2, sessions: vec![] });
        roundtrip(Frame::BulkImport {
            shape_fp: 1,
            weights_fp: 2,
            sessions: vec![
                SessionBlob { session: 5, transcript: vec![1, 2], state: Some(vec![7; 9]) },
                SessionBlob { session: u64::MAX, transcript: vec![], state: None },
            ],
        });
        roundtrip(Frame::BulkCommit { sessions: vec![] });
        roundtrip(Frame::BulkCommit { sessions: vec![1, u64::MAX, 0] });
        roundtrip(Frame::BulkAbort { sessions: vec![3, 1, 4] });
        roundtrip(Frame::BulkBlob {
            shape_fp: 9,
            weights_fp: 10,
            sessions: vec![SessionBlob {
                session: 2,
                transcript: vec![-1],
                state: Some(vec![0, 1]),
            }],
        });
        roundtrip(Frame::Token { token: -1 });
        roundtrip(Frame::Done { trace: 0, ttft_us: 1, total_us: 2 });
        roundtrip(Frame::Done { trace: u64::MAX, ttft_us: 1, total_us: 2 });
        roundtrip(Frame::Spans { trace: 7, hops: vec![] });
        roundtrip(Frame::Spans {
            trace: u64::MAX,
            hops: vec![
                HopReport::new("shard", 1234)
                    .span("to_first_token", 0, 200)
                    .span("stream", 200, 1034),
                HopReport::new("coordinator", 1100)
                    .span("queue", 0, 5)
                    .span("decode", 5, 1095)
                    .note("retry:2")
                    .note("resurrected"),
                HopReport::new("engine", 900),
            ],
        });
        roundtrip(Frame::Blob {
            session: 11,
            shape_fp: 13,
            weights_fp: 17,
            transcript: vec![1],
            state: Some(vec![9; 33]),
        });
        roundtrip(Frame::Ok);
        roundtrip(Frame::HealthReport(HealthReport {
            sessions_resident: 1,
            session_bytes: 2,
            session_hits: 3,
            session_misses: 4,
            in_flight: 5,
            requests_done: 6,
            tokens_generated: 7,
            prefill_tokens_saved: 8,
            queue_depth: 9,
        }));
        for code in [
            ErrCode::UnknownSession,
            ErrCode::Mismatch,
            ErrCode::Closed,
            ErrCode::Protocol,
            ErrCode::Internal,
            ErrCode::Unavailable,
            ErrCode::Overloaded,
            ErrCode::DeadlineExceeded,
            ErrCode::AuthFailed,
        ] {
            roundtrip(Frame::Error { code, msg: "why".into() });
        }
    }

    #[test]
    fn multiple_frames_stream_in_order() {
        let mut buf = Vec::new();
        let frames = [
            Frame::Token { token: 4 },
            Frame::Token { token: 5 },
            Frame::Done { trace: 0, ttft_us: 10, total_us: 20 },
        ];
        for f in &frames {
            write_frame(&mut buf, f).unwrap();
        }
        let mut cur = Cursor::new(&buf);
        for f in &frames {
            assert_eq!(&read_frame(&mut cur).unwrap(), f);
        }
        // stream exhausted: clean EOF
        assert_eq!(
            read_frame(&mut cur).unwrap_err().kind(),
            io::ErrorKind::UnexpectedEof
        );
    }

    #[test]
    fn corrupt_frames_are_rejected_not_panicked() {
        // oversized length prefix
        let mut huge = Vec::new();
        huge.extend_from_slice(&(MAX_FRAME_BYTES + 1).to_le_bytes());
        assert_eq!(
            read_frame(&mut Cursor::new(&huge)).unwrap_err().kind(),
            io::ErrorKind::InvalidData
        );
        // zero-length frame
        let zero = 0u32.to_le_bytes().to_vec();
        assert!(read_frame(&mut Cursor::new(&zero)).is_err());
        // unknown tag (checksummed correctly, so the tag itself is what
        // gets rejected)
        let mut unk = Vec::new();
        unk.extend_from_slice(&1u32.to_le_bytes());
        unk.push(250);
        unk.extend_from_slice(&fnv1a64(&[250]).to_le_bytes());
        assert_eq!(
            read_frame(&mut Cursor::new(&unk)).unwrap_err().kind(),
            io::ErrorKind::InvalidData
        );
        // truncation at every cut of a real frame
        let mut good = Vec::new();
        write_frame(
            &mut good,
            &Frame::SubmitInSession {
                session: 1,
                strict: true,
                max_new: 4,
                deadline_ms: 0,
                trace: 3,
                profile: false,
                delta: vec![1, 2],
            },
        )
        .unwrap();
        for cut in 0..good.len() {
            assert!(
                read_frame(&mut Cursor::new(&good[..cut])).is_err(),
                "cut at {cut} must error"
            );
        }
        // a single flipped payload bit is caught by the checksum
        let mut flipped = good.clone();
        flipped[5] ^= 0x40;
        let err = read_frame(&mut Cursor::new(&flipped)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("checksum"), "{err}");
        // trailing garbage inside the declared frame body: the checksum
        // no longer matches the (shifted) body bytes
        let mut long = good.clone();
        let body_len = u32::from_le_bytes([long[0], long[1], long[2], long[3]]);
        long.push(7);
        long[0..4].copy_from_slice(&(body_len + 1).to_le_bytes());
        assert_eq!(
            read_frame(&mut Cursor::new(&long)).unwrap_err().kind(),
            io::ErrorKind::InvalidData
        );
    }

    /// Trailing bytes *inside* a correctly-checksummed body are still a
    /// decode error: the checksum authenticates transport, `finish()`
    /// still rejects over-long payloads.
    #[test]
    fn trailing_bytes_in_checksummed_body_rejected() {
        let mut body = encode(&Frame::Ok);
        body.push(9); // garbage past the frame's own payload
        let mut buf = Vec::new();
        buf.extend_from_slice(&(body.len() as u32).to_le_bytes());
        buf.extend_from_slice(&body);
        buf.extend_from_slice(&fnv1a64(&body).to_le_bytes());
        let err = read_frame(&mut Cursor::new(&buf)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("trailing"), "{err}");
    }

    use crate::util::prop::check;
    use crate::util::Prng;

    fn arb_tokens(rng: &mut Prng, max: usize) -> Vec<i32> {
        let n = rng.below(max + 1);
        (0..n).map(|_| rng.next_u64() as i32).collect()
    }

    fn arb_bytes(rng: &mut Prng, max: usize) -> Option<Vec<u8>> {
        match rng.below(3) {
            0 => None,
            _ => {
                let n = rng.below(max + 1);
                Some((0..n).map(|_| rng.next_u64() as u8).collect())
            }
        }
    }

    fn arb_hist(rng: &mut Prng) -> Hist {
        let mut h = Hist::new();
        for _ in 0..rng.below(32) {
            h.record(rng.uniform() * 100.0);
        }
        h
    }

    fn arb_metric(rng: &mut Prng) -> MetricValue {
        match rng.below(3) {
            0 => MetricValue::Counter(rng.next_u64()),
            1 => MetricValue::Gauge(rng.next_u64()),
            _ => MetricValue::Hist(arb_hist(rng)),
        }
    }

    fn arb_hops(rng: &mut Prng) -> Vec<HopReport> {
        (0..rng.below(4))
            .map(|i| {
                let mut h = HopReport::new(["front", "router", "shard"][i % 3], rng.next_u64());
                for _ in 0..rng.below(4) {
                    h = h.span("stage", rng.next_u64(), rng.next_u64());
                }
                for _ in 0..rng.below(3) {
                    h = h.note(&"n".repeat(rng.below(6)));
                }
                h
            })
            .collect()
    }

    fn arb_session_blobs(rng: &mut Prng) -> Vec<SessionBlob> {
        (0..rng.below(4))
            .map(|_| SessionBlob {
                session: rng.next_u64(),
                transcript: arb_tokens(rng, 6),
                state: arb_bytes(rng, 24),
            })
            .collect()
    }

    /// A random instance of every frame kind — the generator behind the
    /// wire property tests, so fuzzing covers each tag's payload layout.
    fn arb_frame(rng: &mut Prng) -> Frame {
        match rng.below(26) {
            0 => Frame::Hello {
                proto: rng.next_u64() as u32,
                engine: "hyena".into(),
                shape_fp: rng.next_u64(),
                weights_fp: rng.next_u64(),
            },
            1 => Frame::Submit {
                max_new: rng.below(64) as u32,
                deadline_ms: rng.next_u64() as u32,
                trace: rng.next_u64(),
                profile: rng.below(2) == 1,
                prompt: arb_tokens(rng, 8),
            },
            2 => Frame::SubmitInSession {
                session: rng.next_u64(),
                strict: rng.below(2) == 1,
                max_new: rng.below(64) as u32,
                deadline_ms: rng.next_u64() as u32,
                trace: rng.next_u64(),
                profile: rng.below(2) == 1,
                delta: arb_tokens(rng, 8),
            },
            3 => Frame::EndSession { session: rng.next_u64() },
            4 => Frame::Export { session: rng.next_u64() },
            5 => Frame::Import {
                session: rng.next_u64(),
                shape_fp: rng.next_u64(),
                weights_fp: rng.next_u64(),
                transcript: arb_tokens(rng, 8),
                state: arb_bytes(rng, 48),
            },
            6 => Frame::Health,
            7 => Frame::ExportCommit { session: rng.next_u64() },
            8 => Frame::ExportAbort { session: rng.next_u64() },
            9 => Frame::Transcript { session: rng.next_u64() },
            10 => Frame::Token { token: rng.next_u64() as i32 },
            11 => Frame::Done {
                trace: rng.next_u64(),
                ttft_us: rng.next_u64(),
                total_us: rng.next_u64(),
            },
            12 => Frame::Blob {
                session: rng.next_u64(),
                shape_fp: rng.next_u64(),
                weights_fp: rng.next_u64(),
                transcript: arb_tokens(rng, 8),
                state: arb_bytes(rng, 48),
            },
            13 => Frame::Ok,
            14 => Frame::TranscriptIs { tokens: arb_tokens(rng, 12) },
            15 => Frame::HealthReport(HealthReport {
                sessions_resident: rng.next_u64(),
                session_bytes: rng.next_u64(),
                session_hits: rng.next_u64(),
                session_misses: rng.next_u64(),
                in_flight: rng.next_u64(),
                requests_done: rng.next_u64(),
                tokens_generated: rng.next_u64(),
                prefill_tokens_saved: rng.next_u64(),
                queue_depth: rng.next_u64(),
            }),
            16 => Frame::Metrics,
            17 => Frame::MetricsReport {
                entries: (0..rng.below(5))
                    .map(|i| (format!("lh_arb_{i}"), arb_metric(rng)))
                    .collect(),
            },
            18 => Frame::BulkExport,
            19 => Frame::BulkImport {
                shape_fp: rng.next_u64(),
                weights_fp: rng.next_u64(),
                sessions: arb_session_blobs(rng),
            },
            20 => Frame::BulkCommit {
                sessions: (0..rng.below(6)).map(|_| rng.next_u64()).collect(),
            },
            21 => Frame::BulkAbort {
                sessions: (0..rng.below(6)).map(|_| rng.next_u64()).collect(),
            },
            22 => Frame::BulkBlob {
                shape_fp: rng.next_u64(),
                weights_fp: rng.next_u64(),
                sessions: arb_session_blobs(rng),
            },
            23 => Frame::Auth { token: "t".repeat(rng.below(8)) },
            24 => Frame::Spans { trace: rng.next_u64(), hops: arb_hops(rng) },
            _ => Frame::Error {
                code: ErrCode::from_u16(rng.below(10) as u16),
                msg: "m".repeat(rng.below(16)),
            },
        }
    }

    /// Property: every generatable frame survives encode → decode intact.
    #[test]
    fn prop_every_arbitrary_frame_roundtrips() {
        check("frame roundtrip", 256, |rng| {
            let f = arb_frame(rng);
            let mut buf = Vec::new();
            write_frame(&mut buf, &f).unwrap();
            match read_frame(&mut Cursor::new(&buf)) {
                Ok(got) if got == f => Ok(()),
                Ok(got) => Err(format!("{got:?} != {f:?}")),
                Err(e) => Err(format!("decode failed: {e}")),
            }
        });
    }

    /// Property: a strict prefix of any encoded frame is always a typed
    /// error (`UnexpectedEof` mid-header / mid-body / mid-checksum,
    /// `InvalidData` on a mangled body) — never a panic, never a bogus
    /// decode.
    #[test]
    fn prop_truncation_of_every_frame_kind_is_typed_error() {
        check("truncation is typed", 256, |rng| {
            let f = arb_frame(rng);
            let mut buf = Vec::new();
            write_frame(&mut buf, &f).unwrap();
            let cut = rng.below(buf.len());
            match read_frame(&mut Cursor::new(&buf[..cut])) {
                Ok(got) => Err(format!("cut {cut}/{} decoded {got:?}", buf.len())),
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::UnexpectedEof | io::ErrorKind::InvalidData
                    ) =>
                {
                    Ok(())
                }
                Err(e) => Err(format!("untyped error kind {:?}", e.kind())),
            }
        });
    }

    /// Property: flipping random bytes anywhere in the framed bytes
    /// (length prefix and checksum included) either decodes as *some*
    /// frame or fails with a typed error — the bounded reader never
    /// panics and never allocates past [`MAX_FRAME_BYTES`].
    #[test]
    fn prop_corruption_of_every_frame_kind_never_panics() {
        check("corruption is contained", 256, |rng| {
            let f = arb_frame(rng);
            let mut buf = Vec::new();
            write_frame(&mut buf, &f).unwrap();
            for _ in 0..1 + rng.below(4) {
                let i = rng.below(buf.len());
                buf[i] ^= (1 + rng.below(255)) as u8;
            }
            match read_frame(&mut Cursor::new(&buf)) {
                Ok(_) => Ok(()), // mutated into another valid frame: fine
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::UnexpectedEof | io::ErrorKind::InvalidData
                    ) =>
                {
                    Ok(())
                }
                Err(e) => Err(format!("untyped error kind {:?}", e.kind())),
            }
        });
    }

    /// Property: any corruption confined to the frame *body* (length
    /// prefix intact) is caught — either by the checksum or, for the
    /// astronomically unlikely collision, by the decoder — never served
    /// as a silently different frame of the same kind and length.
    #[test]
    fn prop_body_corruption_is_caught_by_checksum() {
        check("body corruption detected", 256, |rng| {
            let f = arb_frame(rng);
            let mut buf = Vec::new();
            write_frame(&mut buf, &f).unwrap();
            let body_end = buf.len() - 8; // trailing checksum
            if body_end <= 4 {
                return Ok(()); // no body bytes to corrupt
            }
            let i = 4 + rng.below(body_end - 4);
            buf[i] ^= (1 + rng.below(255)) as u8;
            match read_frame(&mut Cursor::new(&buf)) {
                Err(e) if e.kind() == io::ErrorKind::InvalidData => Ok(()),
                Ok(got) => Err(format!("corrupted body decoded as {got:?}")),
                Err(e) => Err(format!("untyped error kind {:?}", e.kind())),
            }
        });
    }

    /// Property: a declared length past the cap is refused before any
    /// body allocation, whatever tag byte follows.
    #[test]
    fn prop_oversize_declared_length_is_rejected() {
        check("oversize is rejected", 64, |rng| {
            let mut buf = Vec::new();
            let over = MAX_FRAME_BYTES + 1 + (rng.next_u64() as u32 % 0x10000);
            buf.extend_from_slice(&over.to_le_bytes());
            buf.push(rng.next_u64() as u8);
            match read_frame(&mut Cursor::new(&buf)) {
                Err(e) if e.kind() == io::ErrorKind::InvalidData => Ok(()),
                other => Err(format!("expected InvalidData, got {other:?}")),
            }
        });
    }

    #[test]
    fn hashes_are_stable_and_spread() {
        // pinned values: the ring layout must not move between builds
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        // splitmix spreads consecutive ids apart
        let a = splitmix64(1);
        let b = splitmix64(2);
        assert_ne!(a, b);
        assert!(a.count_ones() > 8 && b.count_ones() > 8);
    }
}
