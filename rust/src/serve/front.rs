//! The concurrent, streaming front door: a wire server wrapped around the
//! [`Router`].
//!
//! The router itself is a plain single-threaded struct; this module is
//! what makes it a *server*.  A loopback listener accepts client
//! connections (one thread each, same pattern as the shard server), greets
//! them with the cluster's Hello, and dispatches request frames into the
//! shared `Arc<Mutex<Router>>`:
//!
//! * **Streaming relay.**  Generation requests hold the router lock for
//!   the whole call and write one [`Frame::Token`] to the client per
//!   relayed token, as the shard decodes it — the client's
//!   time-to-first-token is the engine's, not the turn's.  The closing
//!   [`Frame::Done`] carries the front door's own ttft/total timings.
//! * **Serialized admin.**  Because every routed call holds the same
//!   lock, admin operations (drain, rebalance, migrate — driven through
//!   [`FrontServer::router`]) interleave *between* calls, never inside
//!   one: a drain issued mid-stream waits for the stream to finish.  This
//!   is a deliberate throughput-for-correctness trade at the front door;
//!   the shards themselves stay concurrent.
//! * **Admission.**  At most `max_inflight` generation requests run
//!   concurrently.  A request carrying a `deadline_ms` budget queues in
//!   a two-priority admission gate — turns for RAM-resident sessions
//!   are admitted strictly before the rest, since their state is
//!   already paid for — for up to its budget, then is shed with a typed
//!   [`ErrCode::Overloaded`].  A request without a budget keeps the
//!   legacy contract: refused immediately with a typed
//!   [`ErrCode::Unavailable`] error frame (retryable) instead of
//!   queueing unboundedly on the lock.
//! * **Health probing.**  A background thread calls
//!   [`Router::probe_all`] every `probe_interval`, which is what lets an
//!   open circuit half-open and a recovered shard rejoin service without
//!   waiting for client traffic to find it.
//! * **Observability.**  A second loopback listener speaks just enough
//!   GET-only HTTP/1.1 for a scraper: `/metrics` renders the merged
//!   cluster snapshot ([`Router::cluster_metrics`] plus the front door's
//!   own registry) as Prometheus text, `/admin` a human-readable
//!   dashboard, `/traces` recent per-request timelines as JSON lines.
//!   Anything else gets a typed status (400 malformed, 404 unknown path,
//!   405 non-GET, 431 oversized head) — never a panic, never a hang.
//!   `/metrics` serves the cluster portion from a cached snapshot no
//!   older than `metrics_max_age` (the probe thread refreshes it in the
//!   background), so a scrape storm never piles up on the router lock;
//!   only a stale-or-empty cache makes a scrape wait out an in-flight
//!   turn.  Front-door-local metrics bypass the cache and are always
//!   live.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::admin::AdminReport;
use super::circuit::BreakerState;
use super::router::{RouteError, Router};
use super::wire::{self, ErrCode, Frame, MAX_FRAME_BYTES};
use crate::obs::{
    render_prometheus, HopReport, MetricValue, Registry, Snapshot, TraceRecord, TraceRing,
};

/// How often blocked reads wake to check the stop flag.
const STOP_POLL: Duration = Duration::from_millis(50);

/// Tuning for the front server.
#[derive(Clone, Copy, Debug)]
pub struct FrontConfig {
    /// Generation requests admitted concurrently; excess requests queue
    /// within their deadline budget, or get a typed refusal.
    pub max_inflight: usize,
    /// Health-probe cadence (`None` disables the probe thread — tests
    /// that drive [`Router::probe_all`] by hand use this).
    pub probe_interval: Option<Duration>,
    /// Staleness bound on the `/metrics` cluster snapshot: scrapes are
    /// served from cache up to this age instead of taking the router
    /// lock per scrape.
    pub metrics_max_age: Duration,
    /// Head-sample 1-in-N requests for engine hot-path profiling (their
    /// trace gains an "engine" hop with per-stage spans, and the
    /// `lh_engine_*` histograms accumulate).  0 disables sampling; a
    /// client-traced request is always profiled regardless.
    pub profile_sample: u64,
}

impl Default for FrontConfig {
    fn default() -> Self {
        FrontConfig {
            max_inflight: 32,
            probe_interval: Some(Duration::from_millis(500)),
            metrics_max_age: Duration::from_secs(2),
            profile_sample: 0,
        }
    }
}

/// Two-priority admission gate for in-flight generation requests.
///
/// [`Gate::try_enter`] is the immediate path for requests without a
/// deadline budget: full means refused, nothing queues.
/// [`Gate::enter_within`] queues the caller until a slot frees or its
/// budget runs out; high-priority waiters (turns for RAM-resident
/// sessions, whose state is already paid for) are admitted strictly
/// before low-priority ones, and the immediate path never jumps a
/// waiting high-priority turn.
struct Gate {
    state: Mutex<GateState>,
    cv: Condvar,
    max: usize,
}

#[derive(Default)]
struct GateState {
    cur: usize,
    hi_waiting: usize,
    lo_waiting: usize,
}

impl Gate {
    fn new(max: usize) -> Gate {
        Gate { state: Mutex::new(GateState::default()), cv: Condvar::new(), max }
    }

    /// Immediate admission (no queueing); refused while any resident
    /// turn waits.
    fn try_enter(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.cur < self.max && st.hi_waiting == 0 {
            st.cur += 1;
            true
        } else {
            false
        }
    }

    /// Queue for a slot until `deadline`; `false` means the budget ran
    /// out first and nothing was admitted.
    fn enter_within(&self, deadline: Instant, hi: bool) -> bool {
        let mut st = self.state.lock().unwrap();
        if hi {
            st.hi_waiting += 1;
        } else {
            st.lo_waiting += 1;
        }
        loop {
            if st.cur < self.max && (hi || st.hi_waiting == 0) {
                if hi {
                    st.hi_waiting -= 1;
                } else {
                    st.lo_waiting -= 1;
                }
                st.cur += 1;
                return true;
            }
            let now = Instant::now();
            if now >= deadline {
                if hi {
                    st.hi_waiting -= 1;
                } else {
                    st.lo_waiting -= 1;
                }
                drop(st);
                // a departing hi waiter may unblock lo waiters
                self.cv.notify_all();
                return false;
            }
            st = self.cv.wait_timeout(st, deadline - now).unwrap().0;
        }
    }

    fn leave(&self) {
        let mut st = self.state.lock().unwrap();
        st.cur -= 1;
        drop(st);
        self.cv.notify_all();
    }

    fn in_flight(&self) -> usize {
        self.state.lock().unwrap().cur
    }

    /// `(hi, lo)` waiter counts — test introspection.
    #[cfg(test)]
    fn waiting(&self) -> (usize, usize) {
        let st = self.state.lock().unwrap();
        (st.hi_waiting, st.lo_waiting)
    }
}

/// Observability state shared by every front-door connection: the front
/// door's own metric registry, the per-request trace ring, and the
/// request-id counter that names traces.
struct FrontShared {
    reg: Registry,
    traces: TraceRing,
    next_req: AtomicU64,
    /// Head-sampling rate for engine profiling (see
    /// [`FrontConfig::profile_sample`]).
    profile_sample: u64,
    /// Cached cluster snapshot and when it was pulled — what lets
    /// `/metrics` answer inside the freshness bound without the router
    /// lock.
    metrics_cache: Mutex<Option<(Instant, Snapshot)>>,
}

/// The router, served over the wire protocol on a loopback socket, with
/// a sibling HTTP listener for `/metrics`, `/admin` and `/traces`.
pub struct FrontServer {
    addr: SocketAddr,
    http_addr: SocketAddr,
    router: Arc<Mutex<Router>>,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    http_accept: Option<JoinHandle<()>>,
    prober: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    gate: Arc<Gate>,
    shared: Arc<FrontShared>,
}

impl FrontServer {
    /// Bind a loopback listener and serve the router on it.
    pub fn spawn(router: Router, cfg: FrontConfig) -> io::Result<FrontServer> {
        FrontServer::spawn_on(router, cfg, "127.0.0.1")
    }

    /// [`FrontServer::spawn`] with an explicit bind host for both the
    /// wire and HTTP listeners.  Loopback is the default everywhere;
    /// binding wider is an explicit opt-in (`ServeConfig::bind_addr`) and
    /// belongs behind the shared-secret handshake.
    pub fn spawn_on(router: Router, cfg: FrontConfig, bind_host: &str) -> io::Result<FrontServer> {
        let hello = router.front_hello();
        let router = Arc::new(Mutex::new(router));
        let listener = TcpListener::bind((bind_host, 0))?;
        let addr = listener.local_addr()?;
        let http_listener = TcpListener::bind((bind_host, 0))?;
        let http_addr = http_listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let gate = Arc::new(Gate::new(cfg.max_inflight.max(1)));
        let shared = Arc::new(FrontShared {
            reg: Registry::new(),
            traces: TraceRing::default(),
            next_req: AtomicU64::new(1),
            profile_sample: cfg.profile_sample,
            metrics_cache: Mutex::new(None),
        });
        let accept = {
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            let router = Arc::clone(&router);
            let gate = Arc::clone(&gate);
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let stream = match stream {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    let stop = Arc::clone(&stop);
                    let router = Arc::clone(&router);
                    let gate = Arc::clone(&gate);
                    let shared = Arc::clone(&shared);
                    let hello = hello.clone();
                    let join = std::thread::spawn(move || {
                        let _ = serve_conn(stream, &router, &hello, &gate, &shared, &stop);
                    });
                    let mut conns = conns.lock().unwrap();
                    conns.retain(|j| !j.is_finished());
                    conns.push(join);
                }
            })
        };
        let http_accept = {
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            let router = Arc::clone(&router);
            let gate = Arc::clone(&gate);
            let shared = Arc::clone(&shared);
            let max_age = cfg.metrics_max_age;
            std::thread::spawn(move || {
                for stream in http_listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let stream = match stream {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    let stop = Arc::clone(&stop);
                    let router = Arc::clone(&router);
                    let gate = Arc::clone(&gate);
                    let shared = Arc::clone(&shared);
                    let join = std::thread::spawn(move || {
                        let _ = serve_http_conn(stream, &router, &shared, &gate, max_age, &stop);
                    });
                    let mut conns = conns.lock().unwrap();
                    conns.retain(|j| !j.is_finished());
                    conns.push(join);
                }
            })
        };
        let prober = cfg.probe_interval.map(|interval| {
            let stop = Arc::clone(&stop);
            let router = Arc::clone(&router);
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    std::thread::sleep(interval);
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    // probe, and refresh the metrics cache while the
                    // lock is held anyway — steady-state scrapes then
                    // never touch the router at all
                    let snap = {
                        let mut r = router.lock().unwrap();
                        r.probe_all();
                        r.cluster_metrics()
                    };
                    *shared.metrics_cache.lock().unwrap() = Some((Instant::now(), snap));
                }
            })
        });
        Ok(FrontServer {
            addr,
            http_addr,
            router,
            stop,
            accept: Some(accept),
            http_accept: Some(http_accept),
            prober,
            conns,
            gate,
            shared,
        })
    }

    /// The bound loopback address clients connect to.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The bound loopback address of the HTTP observability endpoint
    /// (`/metrics`, `/admin`, `/traces`).
    pub fn http_addr(&self) -> SocketAddr {
        self.http_addr
    }

    /// Snapshot of the front door's own registry (requests, refusals,
    /// relay errors, inter-token gaps) — shard and router metrics are
    /// served via `/metrics`, not here.
    pub fn front_metrics(&self) -> Snapshot {
        self.shared.reg.snapshot()
    }

    /// The shared router, for admin operations (drain, migrate, health).
    /// Taking this lock serializes with in-flight client calls — an admin
    /// action never interrupts a stream halfway.
    pub fn router(&self) -> Arc<Mutex<Router>> {
        Arc::clone(&self.router)
    }

    /// Generation requests currently admitted past the gate.
    pub fn in_flight(&self) -> usize {
        self.gate.in_flight()
    }

    /// Stop accepting, join every connection thread (in-flight streams
    /// finish first), then the probe thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // unblock both accept loops
        let _ = TcpStream::connect(self.addr);
        let _ = TcpStream::connect(self.http_addr);
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
        if let Some(j) = self.http_accept.take() {
            let _ = j.join();
        }
        for j in self.conns.lock().unwrap().drain(..) {
            let _ = j.join();
        }
        if let Some(j) = self.prober.take() {
            let _ = j.join();
        }
        // a clean shutdown leaves no batched-but-unsynced journal bytes
        // behind (per-record and off policies make this a no-op)
        if let Ok(mut r) = self.router.lock() {
            let _ = r.flush_journal();
        }
    }
}

impl Drop for FrontServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Map a routing failure onto the wire's typed error codes.
fn err_frame(e: &RouteError) -> Frame {
    let code = match e {
        RouteError::UnknownSession(_) => ErrCode::UnknownSession,
        RouteError::Mismatch(_) => ErrCode::Mismatch,
        RouteError::ShardUnavailable { .. }
        | RouteError::NoShards
        | RouteError::Draining(_) => ErrCode::Unavailable,
        RouteError::Overloaded => ErrCode::Overloaded,
        RouteError::DeadlineExceeded => ErrCode::DeadlineExceeded,
        RouteError::Shard(code, _) => *code,
        RouteError::Io(_) | RouteError::Protocol(_) => ErrCode::Internal,
    };
    Frame::Error { code, msg: e.to_string() }
}

/// The client's remaining budget, as an absolute deadline on this hop's
/// clock (0 on the wire = no budget).
fn wire_deadline(deadline_ms: u32) -> Option<Instant> {
    (deadline_ms > 0).then(|| Instant::now() + Duration::from_millis(deadline_ms as u64))
}

/// Pass the admission gate, or write the typed refusal and report
/// `Ok(false)`.  A deadline-carrying request queues (two-priority) until
/// its budget runs out → [`ErrCode::Overloaded`]; a request without a
/// budget keeps the legacy immediate [`ErrCode::Unavailable`].
fn admit_or_refuse(
    stream: &mut TcpStream,
    gate: &Gate,
    shared: &FrontShared,
    deadline: Option<Instant>,
    hi: bool,
) -> io::Result<bool> {
    let Some(d) = deadline else {
        if gate.try_enter() {
            return Ok(true);
        }
        shared.reg.inc("lh_front_over_capacity_total", 1);
        write_over_capacity(stream, gate.max)?;
        return Ok(false);
    };
    let t0 = Instant::now();
    let admitted = gate.enter_within(d, hi);
    shared.reg.observe("lh_front_queue_wait_seconds", t0.elapsed().as_secs_f64());
    if admitted {
        return Ok(true);
    }
    shared.reg.inc("lh_front_shed_deadline_total", 1);
    wire::write_frame(
        stream,
        &Frame::Error {
            code: ErrCode::Overloaded,
            msg: format!(
                "front door at capacity ({} in flight) and the deadline budget ran out \
                 queueing — shed",
                gate.max
            ),
        },
    )?;
    Ok(false)
}

/// Run one generation under the router lock, relaying each token to the
/// client as it arrives.  A relay write failure (client went away) aborts
/// the connection but never the generation — the router still completes
/// the turn and keeps its mirror consistent.
///
/// Every relay leaves a [`TraceRecord`] in the front door's ring: a
/// "front" hop (queue wait + relayed stream, clocked from `t_req` — the
/// moment the request frame arrived) joined with the router / shard /
/// coordinator / engine hop reports the trace context collected
/// downstream.  The wire trace id is the client's when nonzero, else
/// minted here from the request counter, and is echoed on `Done` either
/// way — so every caller can `GET /trace/<id>` afterwards.  The span
/// report itself is streamed back (`Frame::Spans`, before `Done`) only
/// to clients that traced explicitly; everyone else pays no extra
/// frames.  The registry feeds stay as before: inter-token gaps into
/// `lh_stream_token_seconds`, failures into `lh_front_errors_total`.
#[allow(clippy::too_many_arguments)]
fn relay_generation<F>(
    stream: &mut TcpStream,
    router: &Mutex<Router>,
    shared: &FrontShared,
    session: Option<u64>,
    t_req: Instant,
    client_trace: u64,
    client_profile: bool,
    run: F,
) -> io::Result<()>
where
    F: FnOnce(&mut Router, &mut dyn FnMut(i32)) -> Result<Vec<i32>, RouteError>,
{
    let id = shared.next_req.fetch_add(1, Ordering::Relaxed);
    let trace = if client_trace != 0 { client_trace } else { id };
    let profile = client_profile
        || client_trace != 0
        || (shared.profile_sample > 0 && id % shared.profile_sample == 0);
    let start = Instant::now();
    let queue_us = start.saturating_duration_since(t_req).as_micros() as u64;
    let mut first: Option<Duration> = None;
    let mut prev_tok: Option<Instant> = None;
    let mut n_tokens: u32 = 0;
    let mut relay_err: Option<io::Error> = None;
    let (result, router_hops) = {
        let mut r = router.lock().unwrap();
        r.begin_trace(trace, profile);
        let res = run(&mut r, &mut |t| {
            let now = Instant::now();
            if first.is_none() {
                first = Some(start.elapsed());
            } else if let Some(prev) = prev_tok {
                shared
                    .reg
                    .observe("lh_stream_token_seconds", (now - prev).as_secs_f64());
            }
            prev_tok = Some(now);
            n_tokens += 1;
            if relay_err.is_none() {
                if let Err(e) = wire::write_frame(stream, &Frame::Token { token: t }) {
                    relay_err = Some(e);
                }
            }
        });
        let hops = r.take_trace();
        (res, hops)
    };
    let total = start.elapsed();
    let ttft = first.unwrap_or(total);
    let e2e_us = t_req.elapsed().as_micros() as u64;
    let front_hop = HopReport::new("front", e2e_us)
        .span("queue", 0, queue_us)
        .span("relay", queue_us, total.as_micros() as u64);
    let mut hops = vec![front_hop];
    hops.extend(router_hops);
    shared.traces.push(TraceRecord {
        id: trace,
        session,
        ok: result.is_ok(),
        tokens: n_tokens,
        e2e_us,
        hops: hops.clone(),
    });
    if result.is_err() {
        shared.reg.inc("lh_front_errors_total", 1);
    }
    if let Some(e) = relay_err {
        return Err(e);
    }
    match result {
        Ok(_) => {
            if client_trace != 0 {
                wire::write_frame(stream, &Frame::Spans { trace, hops })?;
            }
            wire::write_frame(
                stream,
                &Frame::Done {
                    trace,
                    ttft_us: ttft.as_micros() as u64,
                    total_us: total.as_micros() as u64,
                },
            )
        }
        Err(e) => wire::write_frame(stream, &err_frame(&e)),
    }
}

/// Serve one client connection until it disconnects or the front stops.
fn serve_conn(
    mut stream: TcpStream,
    router: &Mutex<Router>,
    hello: &Frame,
    gate: &Gate,
    shared: &FrontShared,
    stop: &AtomicBool,
) -> io::Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(STOP_POLL))?;
    wire::write_frame(&mut stream, hello)?;
    loop {
        let frame = match read_frame_stoppable(&mut stream, stop)? {
            Some(f) => f,
            None => return Ok(()),
        };
        match frame {
            Frame::Submit { max_new, deadline_ms, trace, profile, prompt } => {
                shared.reg.inc("lh_front_requests_total", 1);
                let t_req = Instant::now();
                let deadline = wire_deadline(deadline_ms);
                if !admit_or_refuse(&mut stream, gate, shared, deadline, false)? {
                    continue;
                }
                let res = relay_generation(
                    &mut stream,
                    router,
                    shared,
                    None,
                    t_req,
                    trace,
                    profile,
                    |r, on_tok| {
                        r.submit_streaming_deadline(prompt, max_new as usize, deadline, |t| {
                            on_tok(t)
                        })
                    },
                );
                gate.leave();
                res?;
            }
            Frame::SubmitInSession {
                session,
                strict: _,
                max_new,
                deadline_ms,
                trace,
                profile,
                delta,
            } => {
                // the front door decides strictness itself: residency in
                // the router is what distinguishes turn 1 from a resume
                shared.reg.inc("lh_front_requests_total", 1);
                let t_req = Instant::now();
                let deadline = wire_deadline(deadline_ms);
                // resident turns queue at high priority — their state is
                // already paid for, so serving them first frees RAM
                // soonest.  A router busy mid-stream can't be asked;
                // bias toward affinity rather than wait to classify.
                let hi = match router.try_lock() {
                    Ok(r) => r.is_resident(session),
                    Err(_) => true,
                };
                if !admit_or_refuse(&mut stream, gate, shared, deadline, hi)? {
                    continue;
                }
                let res = relay_generation(
                    &mut stream,
                    router,
                    shared,
                    Some(session),
                    t_req,
                    trace,
                    profile,
                    |r, on_tok| {
                        r.submit_in_session_streaming_deadline(
                            session,
                            delta,
                            max_new as usize,
                            deadline,
                            |t| on_tok(t),
                        )
                    },
                );
                gate.leave();
                res?;
            }
            Frame::EndSession { session } => {
                let reply = match router.lock().unwrap().end_session(session) {
                    Ok(()) => Frame::Ok,
                    Err(e) => err_frame(&e),
                };
                wire::write_frame(&mut stream, &reply)?;
            }
            Frame::Health => {
                // cluster totals: the per-shard reports summed
                let reply = match router.lock().unwrap().health() {
                    Ok(reports) => {
                        let mut total = wire::HealthReport::default();
                        for h in &reports {
                            total.sessions_resident += h.sessions_resident;
                            total.session_bytes += h.session_bytes;
                            total.session_hits += h.session_hits;
                            total.session_misses += h.session_misses;
                            total.in_flight += h.in_flight;
                            total.requests_done += h.requests_done;
                            total.tokens_generated += h.tokens_generated;
                            total.prefill_tokens_saved += h.prefill_tokens_saved;
                            total.queue_depth += h.queue_depth;
                        }
                        Frame::HealthReport(total)
                    }
                    Err(e) => err_frame(&e),
                };
                wire::write_frame(&mut stream, &reply)?;
            }
            other => {
                wire::write_frame(
                    &mut stream,
                    &Frame::Error {
                        code: ErrCode::Protocol,
                        msg: format!("front door does not serve {other:?}"),
                    },
                )?;
            }
        }
    }
}

fn write_over_capacity(stream: &mut TcpStream, max: usize) -> io::Result<()> {
    wire::write_frame(
        stream,
        &Frame::Error {
            code: ErrCode::Unavailable,
            msg: format!("front door at capacity ({max} in flight) — retry"),
        },
    )
}

/// Largest HTTP request head the observability endpoint accepts; more
/// than enough for any scraper and a hard cap on per-connection memory.
const MAX_HTTP_HEAD: usize = 8 * 1024;

/// Typed verdict on one HTTP request head.  Everything a peer can throw
/// at the endpoint maps onto one of these — the handler never panics.
#[derive(Debug, PartialEq, Eq)]
enum HttpParse {
    /// A well-formed `GET`: the request target, query string preserved
    /// (the responder splits it — `/traces?session=7` filters).
    Get(String),
    /// Well-formed HTTP but a method other than GET → 405.
    NotGet,
    /// The head never terminated within [`MAX_HTTP_HEAD`] → 431.
    TooLarge,
    /// Not parseable as an HTTP/1.x request → 400.
    Malformed,
}

/// Byte offset just past the head terminator (`\r\n\r\n` or bare
/// `\n\n`), if present.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4)
        .position(|w| w == b"\r\n\r\n")
        .map(|i| i + 4)
        .or_else(|| buf.windows(2).position(|w| w == b"\n\n").map(|i| i + 2))
}

/// Parse a complete request head down to a typed verdict.  Pure — the
/// unit tests drive it directly with malformed and hostile inputs.
fn parse_http_head(head: &[u8]) -> HttpParse {
    let text = match std::str::from_utf8(head) {
        Ok(t) => t,
        Err(_) => return HttpParse::Malformed,
    };
    let line = text.lines().next().unwrap_or("");
    let mut parts = line.split(' ');
    match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(method), Some(path), Some(version), None)
            if !method.is_empty() && version.starts_with("HTTP/1.") =>
        {
            if method != "GET" {
                HttpParse::NotGet
            } else if !path.starts_with('/') {
                HttpParse::Malformed
            } else {
                HttpParse::Get(path.to_string())
            }
        }
        _ => HttpParse::Malformed,
    }
}

/// A complete HTTP/1.1 response with the body framed by content-length
/// (the connection closes after one exchange).
fn http_response(status: u16, reason: &str, content_type: &str, body: &str) -> Vec<u8> {
    format!(
        "HTTP/1.1 {status} {reason}\r\n\
         content-type: {content_type}\r\n\
         content-length: {}\r\n\
         connection: close\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// The cluster snapshot, served from the cache when it is no older than
/// `max_age` (the probe thread refreshes it in the background).  A stale
/// or absent cache falls back to pulling under the router lock — the
/// freshness bound holds either way.
fn cluster_snapshot(
    router: &Mutex<Router>,
    shared: &FrontShared,
    max_age: Duration,
) -> Snapshot {
    if let Some((at, snap)) = &*shared.metrics_cache.lock().unwrap() {
        if at.elapsed() <= max_age {
            return snap.clone();
        }
    }
    let snap = router.lock().unwrap().cluster_metrics();
    *shared.metrics_cache.lock().unwrap() = Some((Instant::now(), snap.clone()));
    snap
}

/// One `key=value` query parameter parsed as a `u64`, if present.
fn query_u64(query: Option<&str>, key: &str) -> Option<u64> {
    query?
        .split('&')
        .find_map(|kv| kv.strip_prefix(key).and_then(|v| v.strip_prefix('=')))
        .and_then(|v| v.parse().ok())
}

/// Route one GET.  `/metrics` merges the (cached, freshness-bounded)
/// cluster snapshot with the front door's own live registry; `/admin`
/// renders the aggregated dashboard; `/traces` dumps the recent
/// per-request timelines as JSON lines (`?session=<id>` filters);
/// `/trace/<id>` looks up one request's joined multi-hop span tree;
/// `/healthz` answers 200 whenever the listener serves at all, and
/// `/readyz` 200 only while at least one shard breaker is closed (or
/// the router is busy relaying — serving traffic *is* readiness).
fn respond_get(
    target: &str,
    router: &Mutex<Router>,
    shared: &FrontShared,
    gate: &Gate,
    max_age: Duration,
) -> Vec<u8> {
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, Some(q)),
        None => (target, None),
    };
    if let Some(id) = path.strip_prefix("/trace/") {
        return match id.parse::<u64>().ok().and_then(|id| shared.traces.find(id)) {
            Some(rec) => http_response(200, "OK", "application/json", &rec.to_json()),
            None => http_response(
                404,
                "Not Found",
                "text/plain",
                "no such trace (evicted from the ring, or never seen)\n",
            ),
        };
    }
    match path {
        "/metrics" => {
            let mut snap = cluster_snapshot(router, shared, max_age);
            snap.merge(&shared.reg.snapshot());
            snap.merge_entry(
                "lh_front_in_flight",
                MetricValue::Gauge(gate.in_flight() as u64),
            );
            http_response(
                200,
                "OK",
                "text/plain; version=0.0.4",
                &render_prometheus(&snap),
            )
        }
        "/admin" => {
            let mut r = router.lock().unwrap();
            let body = match AdminReport::collect(&mut r) {
                Ok(rep) => format!("{rep}"),
                Err(e) => format!("admin report unavailable: {e}\n"),
            };
            http_response(200, "OK", "text/plain; charset=utf-8", &body)
        }
        "/traces" => http_response(
            200,
            "OK",
            "application/x-ndjson",
            &shared.traces.to_json_lines(query_u64(query, "session")),
        ),
        "/healthz" => http_response(200, "OK", "text/plain", "ok\n"),
        "/readyz" => {
            // try_lock: a router busy relaying a stream is serving, which
            // is the strongest possible readiness signal — don't queue a
            // probe behind it
            let ready = match router.try_lock() {
                Err(_) => true,
                Ok(r) => r.breaker_states().iter().any(|s| *s == BreakerState::Closed),
            };
            if ready {
                http_response(200, "OK", "text/plain", "ready\n")
            } else {
                http_response(
                    503,
                    "Service Unavailable",
                    "text/plain",
                    "not ready: no shard breaker is closed\n",
                )
            }
        }
        _ => http_response(
            404,
            "Not Found",
            "text/plain",
            "try /metrics, /admin, /traces, /trace/<id>, /healthz or /readyz\n",
        ),
    }
}

/// Serve one HTTP connection: read a bounded request head, answer once,
/// close.  Malformed, oversized and non-GET requests get their typed
/// status instead of a panic or a hang.
fn serve_http_conn(
    mut stream: TcpStream,
    router: &Mutex<Router>,
    shared: &FrontShared,
    gate: &Gate,
    max_age: Duration,
    stop: &AtomicBool,
) -> io::Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(STOP_POLL))?;
    let mut head: Vec<u8> = Vec::new();
    let mut buf = [0u8; 1024];
    let verdict = loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        let n = match stream.read(&mut buf) {
            // EOF before the head terminator: whatever arrived, it is
            // not a complete HTTP request
            Ok(0) => break HttpParse::Malformed,
            Ok(n) => n,
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock
                        | io::ErrorKind::TimedOut
                        | io::ErrorKind::Interrupted
                ) =>
            {
                continue
            }
            Err(e) => return Err(e),
        };
        head.extend_from_slice(&buf[..n]);
        if let Some(end) = find_head_end(&head) {
            break parse_http_head(&head[..end]);
        }
        if head.len() > MAX_HTTP_HEAD {
            break HttpParse::TooLarge;
        }
    };
    let response = match verdict {
        HttpParse::Get(path) => respond_get(&path, router, shared, gate, max_age),
        HttpParse::NotGet => http_response(
            405,
            "Method Not Allowed",
            "text/plain",
            "only GET is served here\n",
        ),
        HttpParse::TooLarge => http_response(
            431,
            "Request Header Fields Too Large",
            "text/plain",
            "request head exceeds 8 KiB\n",
        ),
        HttpParse::Malformed => {
            http_response(400, "Bad Request", "text/plain", "malformed HTTP request\n")
        }
    };
    stream.write_all(&response)?;
    // Closing with unread request bytes still queued makes TCP reset the
    // connection, which can discard the queued response before the client
    // reads it (the oversized-head path always leaves unread bytes).
    // Drain, bounded, until the client shuts its half down.
    let deadline = Instant::now() + Duration::from_secs(1);
    let mut drained = 0usize;
    while Instant::now() < deadline && drained < 256 * 1024 && !stop.load(Ordering::SeqCst) {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => drained += n,
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock
                        | io::ErrorKind::TimedOut
                        | io::ErrorKind::Interrupted
                ) => {}
            Err(_) => break,
        }
    }
    Ok(())
}

/// Fill `buf` completely, waking every [`STOP_POLL`] to honor `stop`.
/// `Ok(false)` = clean EOF before the first byte (only when `idle_ok`).
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    idle_ok: bool,
) -> io::Result<bool> {
    let mut got = 0;
    while got < buf.len() {
        if stop.load(Ordering::SeqCst) {
            return Err(io::ErrorKind::ConnectionAborted.into());
        }
        match stream.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 && idle_ok {
                    return Ok(false);
                }
                return Err(io::ErrorKind::UnexpectedEof.into());
            }
            Ok(n) => got += n,
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock
                        | io::ErrorKind::TimedOut
                        | io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Stop-aware frame read; `Ok(None)` on clean disconnect or shutdown
/// between frames.
fn read_frame_stoppable(
    stream: &mut TcpStream,
    stop: &AtomicBool,
) -> io::Result<Option<Frame>> {
    let mut len = [0u8; 4];
    if !read_full(stream, &mut len, stop, true)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(len);
    if len == 0 || len > MAX_FRAME_BYTES {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad frame length"));
    }
    let mut body = vec![0u8; len as usize];
    read_full(stream, &mut body, stop, false)?;
    wire::decode(&body).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::engine::LmShape;
    use crate::serve::shard::ShardServer;
    use crate::serve::wire::PROTO_VERSION;

    fn cfg() -> ServeConfig {
        ServeConfig { max_batch: 2, linger_ms: 1, ..ServeConfig::default() }
    }

    fn front_over(n: usize, fc: FrontConfig) -> (Vec<ShardServer>, FrontServer) {
        let shape = LmShape::bench("nano").unwrap();
        let shards: Vec<ShardServer> = (0..n)
            .map(|_| ShardServer::spawn_native(&shape, 2, 11, cfg()).unwrap())
            .collect();
        let addrs: Vec<_> = shards.iter().map(|s| s.addr()).collect();
        let router = Router::new(&addrs).unwrap();
        let front = FrontServer::spawn(router, fc).unwrap();
        (shards, front)
    }

    struct Client {
        stream: TcpStream,
    }

    impl Client {
        fn connect(addr: SocketAddr) -> Client {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream
                .set_read_timeout(Some(Duration::from_secs(120)))
                .unwrap();
            match wire::read_frame(&mut stream).unwrap() {
                Frame::Hello { proto, .. } => assert_eq!(proto, PROTO_VERSION),
                other => panic!("expected Hello, got {other:?}"),
            }
            Client { stream }
        }

        fn send(&mut self, f: &Frame) {
            wire::write_frame(&mut self.stream, f).unwrap();
        }

        fn recv(&mut self) -> Frame {
            wire::read_frame(&mut self.stream).unwrap()
        }

        /// Collect one generation: (tokens, saw_done).
        fn collect(&mut self) -> (Vec<i32>, bool) {
            let mut toks = Vec::new();
            loop {
                match self.recv() {
                    Frame::Token { token } => toks.push(token),
                    Frame::Done { .. } => return (toks, true),
                    Frame::Error { code, msg } => panic!("shard error {code:?}: {msg}"),
                    other => panic!("expected Token/Done, got {other:?}"),
                }
            }
        }

        /// Collect one traced generation: (tokens, span report, Done's
        /// echoed trace id).
        fn collect_traced(&mut self) -> (Vec<i32>, Vec<HopReport>, u64) {
            let mut toks = Vec::new();
            let mut spans = Vec::new();
            loop {
                match self.recv() {
                    Frame::Token { token } => toks.push(token),
                    Frame::Spans { hops, .. } => spans = hops,
                    Frame::Done { trace, .. } => return (toks, spans, trace),
                    Frame::Error { code, msg } => panic!("shard error {code:?}: {msg}"),
                    other => panic!("expected Token/Spans/Done, got {other:?}"),
                }
            }
        }
    }

    #[test]
    fn front_serves_streamed_sessions_end_to_end() {
        let (shards, front) = front_over(2, FrontConfig::default());
        let mut c = Client::connect(front.addr());
        c.send(&Frame::SubmitInSession {
            session: 5,
            strict: false,
            max_new: 4,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            delta: vec![1, 2, 3],
        });
        let (t1, done) = c.collect();
        assert_eq!(t1.len(), 4);
        assert!(done);
        // second turn on the same connection resumes the same session
        c.send(&Frame::SubmitInSession {
            session: 5,
            strict: true,
            max_new: 3,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            delta: vec![7],
        });
        let (t2, _) = c.collect();
        assert_eq!(t2.len(), 3);
        // health aggregates across both shards
        c.send(&Frame::Health);
        match c.recv() {
            Frame::HealthReport(h) => {
                assert_eq!(h.requests_done, 2);
                assert_eq!(h.sessions_resident, 1);
            }
            other => panic!("expected HealthReport, got {other:?}"),
        }
        // end the session through the front
        c.send(&Frame::EndSession { session: 5 });
        assert!(matches!(c.recv(), Frame::Ok));
        front.shutdown();
        for s in shards {
            s.shutdown();
        }
    }

    #[test]
    fn over_capacity_requests_get_a_typed_unavailable() {
        // a zero-size gate (clamped to 1) refuses the second concurrent
        // request; with one slot and a held lock the refusal path is
        // easiest to pin by just filling the gate ourselves
        let (shards, front) = front_over(
            1,
            FrontConfig { max_inflight: 1, probe_interval: None, ..FrontConfig::default() },
        );
        assert!(front.gate.try_enter(), "gate must admit the first request");
        let mut c = Client::connect(front.addr());
        c.send(&Frame::Submit { max_new: 2, deadline_ms: 0, trace: 0, profile: false, prompt: vec![1, 2] });
        match c.recv() {
            Frame::Error { code, msg } => {
                assert_eq!(code, ErrCode::Unavailable, "{msg}");
                assert!(msg.contains("capacity"), "{msg}");
            }
            other => panic!("expected Unavailable, got {other:?}"),
        }
        front.gate.leave();
        // with the gate free the same request is served
        c.send(&Frame::Submit { max_new: 2, deadline_ms: 0, trace: 0, profile: false, prompt: vec![1, 2] });
        let (toks, _) = c.collect();
        assert_eq!(toks.len(), 2);
        front.shutdown();
        for s in shards {
            s.shutdown();
        }
    }

    #[test]
    fn unserved_frames_are_refused_in_protocol() {
        let (shards, front) = front_over(1, FrontConfig { probe_interval: None, ..FrontConfig::default() });
        let mut c = Client::connect(front.addr());
        // Export is a shard-internal frame; the front must refuse it with
        // a typed error, not hang or die
        c.send(&Frame::Export { session: 1 });
        match c.recv() {
            Frame::Error { code, .. } => assert_eq!(code, ErrCode::Protocol),
            other => panic!("expected Error, got {other:?}"),
        }
        // the connection survives the refusal
        c.send(&Frame::Submit { max_new: 1, deadline_ms: 0, trace: 0, profile: false, prompt: vec![3] });
        let (toks, _) = c.collect();
        assert_eq!(toks.len(), 1);
        front.shutdown();
        for s in shards {
            s.shutdown();
        }
    }

    #[test]
    fn http_head_parser_is_typed_and_total() {
        use HttpParse::*;
        assert_eq!(
            parse_http_head(b"GET /metrics HTTP/1.1\r\nhost: x\r\n\r\n"),
            Get("/metrics".into())
        );
        // query strings survive for the responder to parse, HTTP/1.0 is
        // accepted
        assert_eq!(
            parse_http_head(b"GET /traces?n=5 HTTP/1.0\r\n\r\n"),
            Get("/traces?n=5".into())
        );
        assert_eq!(parse_http_head(b"POST /metrics HTTP/1.1\r\n\r\n"), NotGet);
        assert_eq!(parse_http_head(b"DELETE / HTTP/1.1\r\n\r\n"), NotGet);
        assert_eq!(parse_http_head(b"this is not http\r\n\r\n"), Malformed);
        assert_eq!(parse_http_head(b"GET relative-path HTTP/1.1\r\n\r\n"), Malformed);
        assert_eq!(parse_http_head(b"GET /x SMTP/1.1\r\n\r\n"), Malformed);
        assert_eq!(parse_http_head(b"GET /x HTTP/1.1 extra\r\n\r\n"), Malformed);
        assert_eq!(parse_http_head(b"\xff\xfe\r\n\r\n"), Malformed);
        assert_eq!(parse_http_head(b""), Malformed);
    }

    /// Raw one-shot HTTP exchange against the observability listener.
    /// Half-closes after writing so a truncated request is seen as EOF,
    /// not a stalled read.
    fn http_exchange(addr: SocketAddr, raw: &[u8]) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        s.write_all(raw).unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut out = Vec::new();
        s.read_to_end(&mut out).unwrap();
        String::from_utf8_lossy(&out).into_owned()
    }

    #[test]
    fn http_metrics_admin_and_traces_serve_the_cluster_view() {
        let (shards, front) =
            front_over(1, FrontConfig { probe_interval: None, ..FrontConfig::default() });
        let mut c = Client::connect(front.addr());
        c.send(&Frame::SubmitInSession {
            session: 5,
            strict: false,
            max_new: 4,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            delta: vec![1, 2, 3],
        });
        let (toks, _) = c.collect();
        assert_eq!(toks.len(), 4);
        let metrics =
            http_exchange(front.http_addr(), b"GET /metrics HTTP/1.1\r\nhost: x\r\n\r\n");
        assert!(metrics.starts_with("HTTP/1.1 200 OK\r\n"), "{metrics}");
        // shard-side histogram, router-side breaker gauge, front-side
        // counters — all in one exposition
        assert!(metrics.contains("# TYPE lh_ttft_seconds histogram"), "{metrics}");
        assert!(metrics.contains("lh_ttft_seconds_count 1\n"), "{metrics}");
        assert!(metrics.contains("lh_breaker_state{shard=\"0\"} 0\n"), "{metrics}");
        assert!(metrics.contains("lh_front_requests_total 1\n"), "{metrics}");
        assert!(metrics.contains("lh_front_in_flight 0\n"), "{metrics}");
        assert!(metrics.contains("lh_requests_done_total 1\n"), "{metrics}");
        let admin = http_exchange(front.http_addr(), b"GET /admin HTTP/1.1\r\n\r\n");
        assert!(admin.starts_with("HTTP/1.1 200 OK\r\n"), "{admin}");
        assert!(admin.contains("shard"), "{admin}");
        let traces = http_exchange(front.http_addr(), b"GET /traces HTTP/1.1\r\n\r\n");
        assert!(traces.starts_with("HTTP/1.1 200 OK\r\n"), "{traces}");
        assert!(traces.contains("\"session\":5"), "{traces}");
        assert!(traces.contains("\"ok\":true"), "{traces}");
        front.shutdown();
        for s in shards {
            s.shutdown();
        }
    }

    /// Hostile HTTP input gets its typed status — 400/404/405/431 — and
    /// the endpoint keeps serving afterwards.
    #[test]
    fn http_errors_are_typed_and_never_kill_the_endpoint() {
        let (shards, front) =
            front_over(1, FrontConfig { probe_interval: None, ..FrontConfig::default() });
        let addr = front.http_addr();
        let bad = http_exchange(addr, b"complete garbage\r\n\r\n");
        assert!(bad.starts_with("HTTP/1.1 400 "), "{bad}");
        let post = http_exchange(addr, b"POST /metrics HTTP/1.1\r\n\r\n");
        assert!(post.starts_with("HTTP/1.1 405 "), "{post}");
        let lost = http_exchange(addr, b"GET /nope HTTP/1.1\r\n\r\n");
        assert!(lost.starts_with("HTTP/1.1 404 "), "{lost}");
        // a head that never terminates within the cap
        let mut huge = b"GET /metrics HTTP/1.1\r\n".to_vec();
        huge.extend(vec![b'a'; MAX_HTTP_HEAD + 1024]);
        let big = http_exchange(addr, &huge);
        assert!(big.starts_with("HTTP/1.1 431 "), "{big}");
        // EOF mid-head (no terminator at all) is malformed, not a hang
        let cut = http_exchange(addr, b"GET /metr");
        assert!(cut.starts_with("HTTP/1.1 400 "), "{cut}");
        // and a well-formed scrape still works after all of that
        let ok = http_exchange(addr, b"GET /metrics HTTP/1.1\r\n\r\n");
        assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"), "{ok}");
        front.shutdown();
        for s in shards {
            s.shutdown();
        }
    }

    /// A client-traced request gets the full joined timeline on the wire
    /// (Spans before Done, Done echoing the trace id) and the same tree
    /// from `GET /trace/<id>`; `/traces?session=` filters; an unknown
    /// trace id is a 404.
    #[test]
    fn traced_request_streams_spans_and_serves_trace_lookup() {
        let (shards, front) =
            front_over(1, FrontConfig { probe_interval: None, ..FrontConfig::default() });
        let mut c = Client::connect(front.addr());
        c.send(&Frame::SubmitInSession {
            session: 9,
            strict: false,
            max_new: 3,
            deadline_ms: 0,
            trace: 777,
            profile: true,
            delta: vec![1, 2],
        });
        let (toks, spans, done_trace) = c.collect_traced();
        assert_eq!(toks.len(), 3);
        assert_eq!(done_trace, 777, "Done must echo the client's trace id");
        let names: Vec<&str> = spans.iter().map(|h| h.hop.as_str()).collect();
        for want in ["front", "router", "shard", "coordinator", "engine"] {
            assert!(names.contains(&want), "missing {want} hop in {names:?}");
        }
        // the hop reports account for the front-observed end-to-end time:
        // the front hop leads and every inner hop fits inside it
        assert_eq!(names.first(), Some(&"front"));
        for h in &spans[1..] {
            assert!(h.total_us <= spans[0].total_us, "{} hop exceeds front e2e", h.hop);
        }
        let engine = spans.iter().find(|h| h.hop == "engine").unwrap();
        assert!(engine.span_named("modal_sweep").is_some(), "profiled stages missing");
        // the HTTP lookup joins the same tree under the same id
        let looked = http_exchange(front.http_addr(), b"GET /trace/777 HTTP/1.1\r\n\r\n");
        assert!(looked.starts_with("HTTP/1.1 200 OK\r\n"), "{looked}");
        assert!(looked.contains("\"id\":777"), "{looked}");
        for want in ["\"hop\":\"front\"", "\"hop\":\"shard\"", "\"hop\":\"engine\""] {
            assert!(looked.contains(want), "{looked}");
        }
        let miss = http_exchange(front.http_addr(), b"GET /trace/123456789 HTTP/1.1\r\n\r\n");
        assert!(miss.starts_with("HTTP/1.1 404 "), "{miss}");
        // session filtering: an untraced one-shot lands in the ring too,
        // but ?session=9 keeps only the session's turns
        c.send(&Frame::Submit {
            max_new: 1,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            prompt: vec![4],
        });
        let (one, _) = c.collect();
        assert_eq!(one.len(), 1);
        let all = http_exchange(front.http_addr(), b"GET /traces HTTP/1.1\r\n\r\n");
        assert!(all.contains("\"session\":null"), "{all}");
        let filtered =
            http_exchange(front.http_addr(), b"GET /traces?session=9 HTTP/1.1\r\n\r\n");
        assert!(filtered.contains("\"session\":9"), "{filtered}");
        assert!(!filtered.contains("\"session\":null"), "{filtered}");
        front.shutdown();
        for s in shards {
            s.shutdown();
        }
    }

    /// `/healthz` answers 200 whenever the listener serves; `/readyz`
    /// answers 200 while a shard breaker is closed and 503 once every
    /// breaker has opened — both over real sockets.
    #[test]
    fn healthz_is_liveness_and_readyz_tracks_breakers() {
        let (shards, front) =
            front_over(1, FrontConfig { probe_interval: None, ..FrontConfig::default() });
        let hz = http_exchange(front.http_addr(), b"GET /healthz HTTP/1.1\r\n\r\n");
        assert!(hz.starts_with("HTTP/1.1 200 OK\r\n"), "{hz}");
        assert!(hz.contains("ok"), "{hz}");
        let rz = http_exchange(front.http_addr(), b"GET /readyz HTTP/1.1\r\n\r\n");
        assert!(rz.starts_with("HTTP/1.1 200 OK\r\n"), "{rz}");
        // kill the only shard and let probes trip its breaker
        for s in shards {
            s.shutdown();
        }
        {
            let router = front.router();
            let mut r = router.lock().unwrap();
            let t0 = Instant::now();
            while r.breaker_states()[0] == BreakerState::Closed {
                assert!(t0.elapsed() < Duration::from_secs(30), "breaker never opened");
                r.probe_all();
            }
        }
        let rz = http_exchange(front.http_addr(), b"GET /readyz HTTP/1.1\r\n\r\n");
        assert!(rz.starts_with("HTTP/1.1 503 "), "{rz}");
        // liveness is about the listener, not the cluster
        let hz = http_exchange(front.http_addr(), b"GET /healthz HTTP/1.1\r\n\r\n");
        assert!(hz.starts_with("HTTP/1.1 200 OK\r\n"), "{hz}");
        front.shutdown();
    }

    /// The gate's two-priority contract, driven deterministically: a
    /// high-priority (resident-session) waiter is admitted strictly
    /// before a low-priority one that queued first, and the immediate
    /// path never jumps a waiting resident turn.
    #[test]
    fn gate_admits_resident_waiters_before_one_shots() {
        let gate = Arc::new(Gate::new(1));
        assert!(gate.try_enter());
        let order = Arc::new(Mutex::new(Vec::new()));
        let deadline = Instant::now() + Duration::from_secs(30);
        let lo = {
            let (gate, order) = (Arc::clone(&gate), Arc::clone(&order));
            std::thread::spawn(move || {
                assert!(gate.enter_within(deadline, false));
                order.lock().unwrap().push("lo");
                gate.leave();
            })
        };
        let t0 = Instant::now();
        while gate.waiting() != (0, 1) {
            assert!(t0.elapsed() < Duration::from_secs(10), "lo never queued");
            std::thread::sleep(Duration::from_millis(1));
        }
        let hi = {
            let (gate, order) = (Arc::clone(&gate), Arc::clone(&order));
            std::thread::spawn(move || {
                assert!(gate.enter_within(deadline, true));
                order.lock().unwrap().push("hi");
                gate.leave();
            })
        };
        while gate.waiting() != (1, 1) {
            assert!(t0.elapsed() < Duration::from_secs(10), "hi never queued");
            std::thread::sleep(Duration::from_millis(1));
        }
        // the immediate path must not jump the waiting resident turn
        assert!(!gate.try_enter());
        gate.leave();
        hi.join().unwrap();
        lo.join().unwrap();
        assert_eq!(*order.lock().unwrap(), vec!["hi", "lo"]);
        assert_eq!(gate.in_flight(), 0);
    }

    /// A request carrying a deadline budget queues at a full gate
    /// instead of being refused, and is served once a slot frees.
    #[test]
    fn deadline_budget_waits_out_a_full_gate_then_succeeds() {
        let (shards, front) = front_over(
            1,
            FrontConfig { max_inflight: 1, probe_interval: None, ..FrontConfig::default() },
        );
        assert!(front.gate.try_enter(), "fill the only slot");
        let freer = {
            let gate = Arc::clone(&front.gate);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(100));
                gate.leave();
            })
        };
        let mut c = Client::connect(front.addr());
        c.send(&Frame::Submit { max_new: 2, deadline_ms: 30_000, trace: 0, profile: false, prompt: vec![1, 2] });
        let (toks, done) = c.collect();
        assert_eq!(toks.len(), 2);
        assert!(done);
        freer.join().unwrap();
        front.shutdown();
        for s in shards {
            s.shutdown();
        }
    }

    /// When the budget runs out still queued, the shed is the typed
    /// `Overloaded` — and the connection survives to try again.
    #[test]
    fn exhausted_deadline_budget_in_the_queue_is_a_typed_overloaded() {
        let (shards, front) = front_over(
            1,
            FrontConfig { max_inflight: 1, probe_interval: None, ..FrontConfig::default() },
        );
        assert!(front.gate.try_enter(), "fill the only slot");
        let mut c = Client::connect(front.addr());
        c.send(&Frame::Submit { max_new: 2, deadline_ms: 50, trace: 0, profile: false, prompt: vec![1, 2] });
        match c.recv() {
            Frame::Error { code, msg } => assert_eq!(code, ErrCode::Overloaded, "{msg}"),
            other => panic!("expected Overloaded, got {other:?}"),
        }
        let shed = render_prometheus(&front.front_metrics());
        assert!(shed.contains("lh_front_shed_deadline_total 1\n"), "{shed}");
        front.gate.leave();
        c.send(&Frame::Submit { max_new: 2, deadline_ms: 5_000, trace: 0, profile: false, prompt: vec![1, 2] });
        let (toks, _) = c.collect();
        assert_eq!(toks.len(), 2);
        front.shutdown();
        for s in shards {
            s.shutdown();
        }
    }

    /// Scrapes inside the freshness bound serve the cached cluster
    /// snapshot (no router lock); front-door-local metrics stay live.
    #[test]
    fn metrics_scrapes_within_the_freshness_bound_reuse_the_cache() {
        let (shards, front) = front_over(
            1,
            FrontConfig {
                probe_interval: None,
                metrics_max_age: Duration::from_secs(600),
                ..FrontConfig::default()
            },
        );
        let mut c = Client::connect(front.addr());
        c.send(&Frame::Submit { max_new: 2, deadline_ms: 0, trace: 0, profile: false, prompt: vec![1, 2] });
        assert_eq!(c.collect().0.len(), 2);
        // first scrape pulls under the router lock and fills the cache
        let first = http_exchange(front.http_addr(), b"GET /metrics HTTP/1.1\r\n\r\n");
        assert!(first.contains("lh_requests_done_total 1\n"), "{first}");
        // another turn lands on the cluster...
        c.send(&Frame::Submit { max_new: 2, deadline_ms: 0, trace: 0, profile: false, prompt: vec![3] });
        assert_eq!(c.collect().0.len(), 2);
        // ...but a scrape inside the bound serves the cached cluster
        // view, while the front door's own counters are live
        let second = http_exchange(front.http_addr(), b"GET /metrics HTTP/1.1\r\n\r\n");
        assert!(second.contains("lh_requests_done_total 1\n"), "{second}");
        assert!(second.contains("lh_front_requests_total 2\n"), "{second}");
        front.shutdown();
        for s in shards {
            s.shutdown();
        }
    }
}
