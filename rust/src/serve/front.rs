//! The concurrent, streaming front door: a wire server wrapped around the
//! [`Router`].
//!
//! The router itself is a plain single-threaded struct; this module is
//! what makes it a *server*.  A loopback listener accepts client
//! connections (one thread each, same pattern as the shard server), greets
//! them with the cluster's Hello, and dispatches request frames into the
//! shared `Arc<Mutex<Router>>`:
//!
//! * **Streaming relay.**  Generation requests hold the router lock for
//!   the whole call and write one [`Frame::Token`] to the client per
//!   relayed token, as the shard decodes it — the client's
//!   time-to-first-token is the engine's, not the turn's.  The closing
//!   [`Frame::Done`] carries the front door's own ttft/total timings.
//! * **Serialized admin.**  Because every routed call holds the same
//!   lock, admin operations (drain, rebalance, migrate — driven through
//!   [`FrontServer::router`]) interleave *between* calls, never inside
//!   one: a drain issued mid-stream waits for the stream to finish.  This
//!   is a deliberate throughput-for-correctness trade at the front door;
//!   the shards themselves stay concurrent.
//! * **Backpressure.**  At most `max_inflight` generation requests are
//!   admitted; the rest are refused immediately with a typed
//!   [`ErrCode::Unavailable`] error frame (retryable) instead of queueing
//!   unboundedly on the lock.
//! * **Health probing.**  A background thread calls
//!   [`Router::probe_all`] every `probe_interval`, which is what lets an
//!   open circuit half-open and a recovered shard rejoin service without
//!   waiting for client traffic to find it.

use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::router::{RouteError, Router};
use super::wire::{self, ErrCode, Frame, MAX_FRAME_BYTES};

/// How often blocked reads wake to check the stop flag.
const STOP_POLL: Duration = Duration::from_millis(50);

/// Tuning for the front server.
#[derive(Clone, Copy, Debug)]
pub struct FrontConfig {
    /// Generation requests admitted concurrently; excess requests get a
    /// typed `Unavailable` refusal instead of queueing without bound.
    pub max_inflight: usize,
    /// Health-probe cadence (`None` disables the probe thread — tests
    /// that drive [`Router::probe_all`] by hand use this).
    pub probe_interval: Option<Duration>,
}

impl Default for FrontConfig {
    fn default() -> Self {
        FrontConfig { max_inflight: 32, probe_interval: Some(Duration::from_millis(500)) }
    }
}

/// Counting gate for in-flight generation requests.
struct Gate {
    cur: AtomicUsize,
    max: usize,
}

impl Gate {
    fn try_enter(&self) -> bool {
        loop {
            let c = self.cur.load(Ordering::Acquire);
            if c >= self.max {
                return false;
            }
            if self
                .cur
                .compare_exchange(c, c + 1, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return true;
            }
        }
    }

    fn leave(&self) {
        self.cur.fetch_sub(1, Ordering::AcqRel);
    }
}

/// The router, served over the wire protocol on a loopback socket.
pub struct FrontServer {
    addr: SocketAddr,
    router: Arc<Mutex<Router>>,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    prober: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    gate: Arc<Gate>,
}

impl FrontServer {
    /// Bind a loopback listener and serve the router on it.
    pub fn spawn(router: Router, cfg: FrontConfig) -> io::Result<FrontServer> {
        let hello = router.front_hello();
        let router = Arc::new(Mutex::new(router));
        let listener = TcpListener::bind(("127.0.0.1", 0))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let gate = Arc::new(Gate { cur: AtomicUsize::new(0), max: cfg.max_inflight.max(1) });
        let accept = {
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            let router = Arc::clone(&router);
            let gate = Arc::clone(&gate);
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let stream = match stream {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    let stop = Arc::clone(&stop);
                    let router = Arc::clone(&router);
                    let gate = Arc::clone(&gate);
                    let hello = hello.clone();
                    let join = std::thread::spawn(move || {
                        let _ = serve_conn(stream, &router, &hello, &gate, &stop);
                    });
                    let mut conns = conns.lock().unwrap();
                    conns.retain(|j| !j.is_finished());
                    conns.push(join);
                }
            })
        };
        let prober = cfg.probe_interval.map(|interval| {
            let stop = Arc::clone(&stop);
            let router = Arc::clone(&router);
            std::thread::spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    std::thread::sleep(interval);
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    router.lock().unwrap().probe_all();
                }
            })
        });
        Ok(FrontServer { addr, router, stop, accept: Some(accept), prober, conns, gate })
    }

    /// The bound loopback address clients connect to.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The shared router, for admin operations (drain, migrate, health).
    /// Taking this lock serializes with in-flight client calls — an admin
    /// action never interrupts a stream halfway.
    pub fn router(&self) -> Arc<Mutex<Router>> {
        Arc::clone(&self.router)
    }

    /// Generation requests currently admitted past the gate.
    pub fn in_flight(&self) -> usize {
        self.gate.cur.load(Ordering::Acquire)
    }

    /// Stop accepting, join every connection thread (in-flight streams
    /// finish first), then the probe thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // unblock the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
        for j in self.conns.lock().unwrap().drain(..) {
            let _ = j.join();
        }
        if let Some(j) = self.prober.take() {
            let _ = j.join();
        }
    }
}

impl Drop for FrontServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Map a routing failure onto the wire's typed error codes.
fn err_frame(e: &RouteError) -> Frame {
    let code = match e {
        RouteError::UnknownSession(_) => ErrCode::UnknownSession,
        RouteError::Mismatch(_) => ErrCode::Mismatch,
        RouteError::ShardUnavailable { .. }
        | RouteError::NoShards
        | RouteError::Draining(_) => ErrCode::Unavailable,
        RouteError::Shard(code, _) => *code,
        RouteError::Io(_) | RouteError::Protocol(_) => ErrCode::Internal,
    };
    Frame::Error { code, msg: e.to_string() }
}

/// Run one generation under the router lock, relaying each token to the
/// client as it arrives.  A relay write failure (client went away) aborts
/// the connection but never the generation — the router still completes
/// the turn and keeps its mirror consistent.
fn relay_generation<F>(
    stream: &mut TcpStream,
    router: &Mutex<Router>,
    run: F,
) -> io::Result<()>
where
    F: FnOnce(&mut Router, &mut dyn FnMut(i32)) -> Result<Vec<i32>, RouteError>,
{
    let start = Instant::now();
    let mut first: Option<Duration> = None;
    let mut relay_err: Option<io::Error> = None;
    let result = {
        let mut r = router.lock().unwrap();
        run(&mut r, &mut |t| {
            if first.is_none() {
                first = Some(start.elapsed());
            }
            if relay_err.is_none() {
                if let Err(e) = wire::write_frame(stream, &Frame::Token { token: t }) {
                    relay_err = Some(e);
                }
            }
        })
    };
    if let Some(e) = relay_err {
        return Err(e);
    }
    match result {
        Ok(_) => {
            let total = start.elapsed();
            let ttft = first.unwrap_or(total);
            wire::write_frame(
                stream,
                &Frame::Done {
                    ttft_us: ttft.as_micros() as u64,
                    total_us: total.as_micros() as u64,
                },
            )
        }
        Err(e) => wire::write_frame(stream, &err_frame(&e)),
    }
}

/// Serve one client connection until it disconnects or the front stops.
fn serve_conn(
    mut stream: TcpStream,
    router: &Mutex<Router>,
    hello: &Frame,
    gate: &Gate,
    stop: &AtomicBool,
) -> io::Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(STOP_POLL))?;
    wire::write_frame(&mut stream, hello)?;
    loop {
        let frame = match read_frame_stoppable(&mut stream, stop)? {
            Some(f) => f,
            None => return Ok(()),
        };
        match frame {
            Frame::Submit { max_new, prompt } => {
                if !gate.try_enter() {
                    write_over_capacity(&mut stream, gate.max)?;
                    continue;
                }
                let res = relay_generation(&mut stream, router, |r, on_tok| {
                    r.submit_streaming(prompt, max_new as usize, |t| on_tok(t))
                });
                gate.leave();
                res?;
            }
            Frame::SubmitInSession { session, strict: _, max_new, delta } => {
                // the front door decides strictness itself: residency in
                // the router is what distinguishes turn 1 from a resume
                if !gate.try_enter() {
                    write_over_capacity(&mut stream, gate.max)?;
                    continue;
                }
                let res = relay_generation(&mut stream, router, |r, on_tok| {
                    r.submit_in_session_streaming(session, delta, max_new as usize, |t| {
                        on_tok(t)
                    })
                });
                gate.leave();
                res?;
            }
            Frame::EndSession { session } => {
                let reply = match router.lock().unwrap().end_session(session) {
                    Ok(()) => Frame::Ok,
                    Err(e) => err_frame(&e),
                };
                wire::write_frame(&mut stream, &reply)?;
            }
            Frame::Health => {
                // cluster totals: the per-shard reports summed
                let reply = match router.lock().unwrap().health() {
                    Ok(reports) => {
                        let mut total = wire::HealthReport::default();
                        for h in &reports {
                            total.sessions_resident += h.sessions_resident;
                            total.session_bytes += h.session_bytes;
                            total.session_hits += h.session_hits;
                            total.session_misses += h.session_misses;
                            total.in_flight += h.in_flight;
                            total.requests_done += h.requests_done;
                            total.tokens_generated += h.tokens_generated;
                            total.prefill_tokens_saved += h.prefill_tokens_saved;
                        }
                        Frame::HealthReport(total)
                    }
                    Err(e) => err_frame(&e),
                };
                wire::write_frame(&mut stream, &reply)?;
            }
            other => {
                wire::write_frame(
                    &mut stream,
                    &Frame::Error {
                        code: ErrCode::Protocol,
                        msg: format!("front door does not serve {other:?}"),
                    },
                )?;
            }
        }
    }
}

fn write_over_capacity(stream: &mut TcpStream, max: usize) -> io::Result<()> {
    wire::write_frame(
        stream,
        &Frame::Error {
            code: ErrCode::Unavailable,
            msg: format!("front door at capacity ({max} in flight) — retry"),
        },
    )
}

/// Fill `buf` completely, waking every [`STOP_POLL`] to honor `stop`.
/// `Ok(false)` = clean EOF before the first byte (only when `idle_ok`).
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    idle_ok: bool,
) -> io::Result<bool> {
    use std::io::Read;
    let mut got = 0;
    while got < buf.len() {
        if stop.load(Ordering::SeqCst) {
            return Err(io::ErrorKind::ConnectionAborted.into());
        }
        match stream.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 && idle_ok {
                    return Ok(false);
                }
                return Err(io::ErrorKind::UnexpectedEof.into());
            }
            Ok(n) => got += n,
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock
                        | io::ErrorKind::TimedOut
                        | io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Stop-aware frame read; `Ok(None)` on clean disconnect or shutdown
/// between frames.
fn read_frame_stoppable(
    stream: &mut TcpStream,
    stop: &AtomicBool,
) -> io::Result<Option<Frame>> {
    let mut len = [0u8; 4];
    if !read_full(stream, &mut len, stop, true)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(len);
    if len == 0 || len > MAX_FRAME_BYTES {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad frame length"));
    }
    let mut body = vec![0u8; len as usize];
    read_full(stream, &mut body, stop, false)?;
    wire::decode(&body).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::engine::LmShape;
    use crate::serve::shard::ShardServer;
    use crate::serve::wire::PROTO_VERSION;

    fn cfg() -> ServeConfig {
        ServeConfig { max_batch: 2, linger_ms: 1, ..ServeConfig::default() }
    }

    fn front_over(n: usize, fc: FrontConfig) -> (Vec<ShardServer>, FrontServer) {
        let shape = LmShape::bench("nano").unwrap();
        let shards: Vec<ShardServer> = (0..n)
            .map(|_| ShardServer::spawn_native(&shape, 2, 11, cfg()).unwrap())
            .collect();
        let addrs: Vec<_> = shards.iter().map(|s| s.addr()).collect();
        let router = Router::new(&addrs).unwrap();
        let front = FrontServer::spawn(router, fc).unwrap();
        (shards, front)
    }

    struct Client {
        stream: TcpStream,
    }

    impl Client {
        fn connect(addr: SocketAddr) -> Client {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream
                .set_read_timeout(Some(Duration::from_secs(120)))
                .unwrap();
            match wire::read_frame(&mut stream).unwrap() {
                Frame::Hello { proto, .. } => assert_eq!(proto, PROTO_VERSION),
                other => panic!("expected Hello, got {other:?}"),
            }
            Client { stream }
        }

        fn send(&mut self, f: &Frame) {
            wire::write_frame(&mut self.stream, f).unwrap();
        }

        fn recv(&mut self) -> Frame {
            wire::read_frame(&mut self.stream).unwrap()
        }

        /// Collect one generation: (tokens, saw_done).
        fn collect(&mut self) -> (Vec<i32>, bool) {
            let mut toks = Vec::new();
            loop {
                match self.recv() {
                    Frame::Token { token } => toks.push(token),
                    Frame::Done { .. } => return (toks, true),
                    Frame::Error { code, msg } => panic!("shard error {code:?}: {msg}"),
                    other => panic!("expected Token/Done, got {other:?}"),
                }
            }
        }
    }

    #[test]
    fn front_serves_streamed_sessions_end_to_end() {
        let (shards, front) = front_over(2, FrontConfig::default());
        let mut c = Client::connect(front.addr());
        c.send(&Frame::SubmitInSession { session: 5, strict: false, max_new: 4, delta: vec![1, 2, 3] });
        let (t1, done) = c.collect();
        assert_eq!(t1.len(), 4);
        assert!(done);
        // second turn on the same connection resumes the same session
        c.send(&Frame::SubmitInSession { session: 5, strict: true, max_new: 3, delta: vec![7] });
        let (t2, _) = c.collect();
        assert_eq!(t2.len(), 3);
        // health aggregates across both shards
        c.send(&Frame::Health);
        match c.recv() {
            Frame::HealthReport(h) => {
                assert_eq!(h.requests_done, 2);
                assert_eq!(h.sessions_resident, 1);
            }
            other => panic!("expected HealthReport, got {other:?}"),
        }
        // end the session through the front
        c.send(&Frame::EndSession { session: 5 });
        assert!(matches!(c.recv(), Frame::Ok));
        front.shutdown();
        for s in shards {
            s.shutdown();
        }
    }

    #[test]
    fn over_capacity_requests_get_a_typed_unavailable() {
        // a zero-size gate (clamped to 1) refuses the second concurrent
        // request; with one slot and a held lock the refusal path is
        // easiest to pin by just filling the gate ourselves
        let (shards, front) = front_over(1, FrontConfig { max_inflight: 1, probe_interval: None });
        assert!(front.gate.try_enter(), "gate must admit the first request");
        let mut c = Client::connect(front.addr());
        c.send(&Frame::Submit { max_new: 2, prompt: vec![1, 2] });
        match c.recv() {
            Frame::Error { code, msg } => {
                assert_eq!(code, ErrCode::Unavailable, "{msg}");
                assert!(msg.contains("capacity"), "{msg}");
            }
            other => panic!("expected Unavailable, got {other:?}"),
        }
        front.gate.leave();
        // with the gate free the same request is served
        c.send(&Frame::Submit { max_new: 2, prompt: vec![1, 2] });
        let (toks, _) = c.collect();
        assert_eq!(toks.len(), 2);
        front.shutdown();
        for s in shards {
            s.shutdown();
        }
    }

    #[test]
    fn unserved_frames_are_refused_in_protocol() {
        let (shards, front) = front_over(1, FrontConfig { probe_interval: None, ..FrontConfig::default() });
        let mut c = Client::connect(front.addr());
        // Export is a shard-internal frame; the front must refuse it with
        // a typed error, not hang or die
        c.send(&Frame::Export { session: 1 });
        match c.recv() {
            Frame::Error { code, .. } => assert_eq!(code, ErrCode::Protocol),
            other => panic!("expected Error, got {other:?}"),
        }
        // the connection survives the refusal
        c.send(&Frame::Submit { max_new: 1, prompt: vec![3] });
        let (toks, _) = c.collect();
        assert_eq!(toks.len(), 1);
        front.shutdown();
        for s in shards {
            s.shutdown();
        }
    }
}
