//! The serve layer: horizontal sharding of the single-process coordinator.
//!
//! The paper's deployment claim (Lemma 2.2 / Prop. 3.2) is that a
//! distilled model's per-sequence generation state is *constant-size* —
//! which PR 2 materialized as a versioned, engine-tagged, byte-exact
//! [`crate::session::SessionState`] blob.  A live conversation is
//! therefore cheap to move between processes: ship O(state) bytes, not an
//! O(t)-growing KV cache.  This module turns that property into a
//! horizontally sharded service:
//!
//! * [`wire`] — a length-prefixed, versioned binary frame protocol over
//!   TCP, with an engine-tag + shape- and weights-fingerprint handshake
//!   so a session blob is never restored into a mismatched engine (or
//!   into an identically-shaped engine carrying different weights).
//! * [`shard`] — a shard server owning one
//!   [`crate::coordinator::CoordinatorHandle`] + session store, serving
//!   the protocol on a loopback socket and streaming generated tokens
//!   back frame-by-frame.
//! * [`router`] — the client-facing front door: consistent-hash session
//!   affinity across N shards, plus **live session migration** (quiesce +
//!   export on the source, wire transfer, import on the target,
//!   bit-identical continuation).
//! * [`admin`] — drain / add-shard / rebalance, per-shard health and
//!   aggregated metrics, and the in-process cluster launcher behind
//!   `repro serve --shards N`.

pub mod admin;
pub mod router;
pub mod shard;
pub mod wire;

pub use admin::{AdminReport, Cluster};
pub use router::{RouteError, Router};
pub use shard::{ShardServer, ShardSpec};
pub use wire::{ErrCode, Frame, HealthReport, PROTO_VERSION};
