//! The serve layer: horizontal sharding of the single-process coordinator.
//!
//! The paper's deployment claim (Lemma 2.2 / Prop. 3.2) is that a
//! distilled model's per-sequence generation state is *constant-size* —
//! which PR 2 materialized as a versioned, engine-tagged, byte-exact
//! [`crate::session::SessionState`] blob.  A live conversation is
//! therefore cheap to move between processes: ship O(state) bytes, not an
//! O(t)-growing KV cache.  This module turns that property into a
//! horizontally sharded service:
//!
//! * [`wire`] — a length-prefixed, versioned binary frame protocol over
//!   TCP, with an engine-tag + shape- and weights-fingerprint handshake
//!   so a session blob is never restored into a mismatched engine (or
//!   into an identically-shaped engine carrying different weights).
//! * [`shard`] — a shard server owning one
//!   [`crate::coordinator::CoordinatorHandle`] + session store, serving
//!   the protocol on a loopback socket and streaming generated tokens
//!   back frame-by-frame.
//! * [`router`] — the routing core: consistent-hash session affinity
//!   across N shards, token-stream relay, **two-phase live session
//!   migration** (export stash + commit/abort settlement), per-shard
//!   circuit breaking, and transcript-mirror **resurrection** of sessions
//!   whose shard died.
//! * [`front`] — the router as a concurrent wire server: per-connection
//!   threads, streamed `Token` relay, deadline-budgeted two-priority
//!   admission (resident sessions first; budget exhaustion is a typed
//!   shed, capacity without a budget a typed refusal), a background
//!   health-probe thread, and a GET-only HTTP sibling listener serving
//!   `/metrics` (Prometheus text of the merged cluster snapshot, served
//!   from a freshness-bounded cache), `/admin` (dashboard) and
//!   `/traces` (JSON lines).
//! * [`circuit`] — the three-state (closed/open/half-open) breaker the
//!   router keeps per shard.
//! * [`faults`] — deterministic fault injection at named protocol points
//!   (drop/sever/delay/corrupt), the machinery behind the chaos tests.
//! * [`admin`] — drain / add-shard / rebalance, per-shard health and
//!   aggregated metrics, and the in-process cluster launcher behind
//!   `repro serve --shards N`.

pub mod admin;
pub mod circuit;
pub mod faults;
pub mod front;
pub mod router;
pub mod shard;
pub mod wire;

pub use admin::{AdminReport, Cluster};
pub use circuit::{Breaker, BreakerConfig, BreakerState, BreakerStats};
pub use faults::{FaultAction, FaultPlan, FrameKind, Point, Rule};
pub use front::{FrontConfig, FrontServer};
pub use router::{MigrationStats, RetryPolicy, RouteError, Router};
pub use shard::{ShardServer, ShardSpec};
pub use wire::{ErrCode, Frame, HealthReport, SessionBlob, PROTO_VERSION};
