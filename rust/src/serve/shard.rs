//! A shard: one [`CoordinatorHandle`] (engine thread + session store)
//! served over the wire protocol on a TCP socket.
//!
//! The listener binds `127.0.0.1:0` by default (kernel-assigned port —
//! sandbox-safe); a non-loopback bind is opt-in via
//! [`ServeConfig::bind_addr`].  When [`ServeConfig::auth_token`] is set,
//! every connection must present that shared secret in a [`Frame::Auth`]
//! as its first frame (compared in constant time) or its first command is
//! refused with a typed [`ErrCode::AuthFailed`] and the connection is
//! closed.  The shard greets every connection with [`Frame::Hello`]
//! carrying the protocol version, engine state tag and shape fingerprint,
//! then handles one request frame at a time per connection.  Generation
//! replies stream one [`Frame::Token`] per token before the closing
//! [`Frame::Done`].
//!
//! Import safety: a [`Frame::Import`] whose shape fingerprint, weights
//! fingerprint, blob format version or engine tag does not match this
//! shard is refused with [`ErrCode::Mismatch`] *before* anything reaches
//! the coordinator — a mismatched blob is rejected at the handshake,
//! never restored (and slot restore re-validates plane shapes as the
//! last line of defense).
//!
//! Migration is two-phase on the source side: [`Frame::Export`] detaches
//! the session from the coordinator but *stashes* it shard-locally
//! (inactive — it cannot serve turns) until the router settles the move
//! with [`Frame::ExportCommit`] (discard the stash; the target has it) or
//! [`Frame::ExportAbort`] (re-import the stash; the move failed).  Both
//! are idempotent, so a router whose connection was severed mid-protocol
//! can probe the target ([`Frame::Transcript`]) and retry whichever
//! settlement is correct — at every severed point the session is live on
//! exactly one shard, never zero, never two.

use std::collections::HashMap;
use std::io::{self, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::wire::{
    self, ErrCode, Frame, HealthReport, SessionBlob, MAX_FRAME_BYTES, PROTO_VERSION,
};
use crate::config::ServeConfig;
use crate::coordinator::server::{spawn, SessionExport};
use crate::coordinator::{CoordinatorHandle, GenResponse, Refusal, SlotEngine};
use crate::engine::recurrent::{RecurrentEngine, STATE_TAG};
use crate::engine::LmShape;
use crate::obs::HopReport;
use crate::session::{SessionError, SessionState};

/// How often a blocked read wakes to check the stop flag.
const STOP_POLL: Duration = Duration::from_millis(100);

/// How long one frame write may stall before the connection is declared
/// dead.  A client that stops draining its socket mid-stream otherwise
/// parks the connection thread forever; the generation itself is never
/// aborted — the coordinator finishes the turn regardless.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Re-derive the absolute admission deadline from the wire's *relative*
/// budget (0 = none).  Each hop anchors the budget to its own clock, so
/// clock skew between peers never compounds into the deadline.
fn wire_deadline(deadline_ms: u32) -> Option<Instant> {
    (deadline_ms > 0).then(|| Instant::now() + Duration::from_millis(deadline_ms as u64))
}

/// What a shard announces about its engine — the handshake identity a
/// session blob must match before it is ever shipped here.  Shape alone
/// is not identity: two identically-shaped engines built from different
/// weights would decode a migrated state into silently wrong tokens, so
/// the weights fingerprint participates in every check.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// Engine state tag ([`crate::coordinator::state::SlotEngine::state_tag`]).
    pub engine: String,
    /// [`LmShape::fingerprint`] of the engine's shape.
    pub shape_fp: u64,
    /// Fingerprint of the engine's *weights*.  For the native engines
    /// (deterministically initialized from a seed) this is derived from
    /// (shape, seed) via [`ShardSpec::native`]; engines with loaded
    /// checkpoints should fingerprint the checkpoint instead.
    pub weights_fp: u64,
}

impl ShardSpec {
    /// Identity of a native engine: weights are fully determined by
    /// (shape, seed), so the weights fingerprint hashes exactly those.
    pub fn native(shape: &LmShape, engine: &str, seed: u64) -> ShardSpec {
        let shape_fp = shape.fingerprint();
        let mut id = shape_fp.to_le_bytes().to_vec();
        id.extend_from_slice(&seed.to_le_bytes());
        ShardSpec {
            engine: engine.to_string(),
            shape_fp,
            weights_fp: crate::util::bytes::fnv1a64(&id),
        }
    }
}

/// A running shard server; dropping it (or calling
/// [`ShardServer::shutdown`]) stops the listener, joins every connection
/// thread, and shuts the coordinator down after draining in-flight work.
pub struct ShardServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    /// Kept so tests and the demo can read shard metrics in-process.
    pub handle: Arc<CoordinatorHandle>,
    /// Sessions exported but not yet committed/aborted (shared with every
    /// connection thread — the commit may arrive on a different connection
    /// than the export after a router reconnect).
    pending: Arc<Mutex<HashMap<u64, SessionExport>>>,
    spec: ShardSpec,
}

impl ShardServer {
    /// Bind a loopback listener and serve `make_engine`'s coordinator on
    /// it.  `spec` must describe the engine `make_engine` builds — it is
    /// what the handshake advertises.
    pub fn spawn<F>(spec: ShardSpec, cfg: ServeConfig, make_engine: F) -> io::Result<ShardServer>
    where
        F: FnOnce() -> Box<dyn SlotEngine> + Send + 'static,
    {
        // cfg moves into the coordinator; keep the transport settings out
        let bind_host = cfg.bind_addr.clone().unwrap_or_else(|| "127.0.0.1".to_string());
        let auth: Option<Arc<String>> = cfg.auth_token.clone().map(Arc::new);
        let handle = Arc::new(spawn(make_engine, cfg));
        let listener = TcpListener::bind((bind_host.as_str(), 0))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let pending: Arc<Mutex<HashMap<u64, SessionExport>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let accept = {
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            let handle = Arc::clone(&handle);
            let pending = Arc::clone(&pending);
            let spec = spec.clone();
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let stream = match stream {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    let stop = Arc::clone(&stop);
                    let handle = Arc::clone(&handle);
                    let pending = Arc::clone(&pending);
                    let spec = spec.clone();
                    let auth = auth.clone();
                    let join = std::thread::spawn(move || {
                        let _ = serve_conn(
                            stream,
                            &handle,
                            &pending,
                            &spec,
                            auth.as_ref().map(|a| a.as_str()),
                            &stop,
                        );
                    });
                    // reap finished connection threads so a long-running
                    // shard (per-call router connections) does not grow an
                    // unbounded handle list; live ones are joined at stop
                    let mut conns = conns.lock().unwrap();
                    conns.retain(|j| !j.is_finished());
                    conns.push(join);
                }
            })
        };
        Ok(ShardServer { addr, stop, accept: Some(accept), conns, handle, pending, spec })
    }

    /// Convenience: a shard over the native recurrent engine (the O(1)
    /// state path the serve layer exists for).
    pub fn spawn_native(
        shape: &LmShape,
        slots: usize,
        seed: u64,
        cfg: ServeConfig,
    ) -> io::Result<ShardServer> {
        let spec = ShardSpec::native(shape, STATE_TAG, seed);
        let shape = shape.clone();
        ShardServer::spawn(spec, cfg, move || {
            Box::new(RecurrentEngine::new(&shape, slots, seed)) as Box<dyn SlotEngine>
        })
    }

    /// The bound loopback address clients connect to.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The identity the handshake advertises.
    pub fn spec(&self) -> &ShardSpec {
        &self.spec
    }

    /// How many exported sessions await commit/abort (tests assert the
    /// stash never leaks across a settled migration).
    pub fn pending_exports(&self) -> usize {
        self.pending.lock().unwrap().len()
    }

    /// Stop accepting, join every connection thread (in-flight generations
    /// finish first — they are bounded by their token budgets), then shut
    /// the coordinator down.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // unblock the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
        for j in self.conns.lock().unwrap().drain(..) {
            let _ = j.join();
        }
        // the coordinator itself shuts down when the last Arc drops
    }
}

impl Drop for ShardServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Fill `buf` completely, waking every [`STOP_POLL`] to honor `stop`.
/// `Ok(false)` = clean EOF before the first byte (only when `idle_ok`).
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    idle_ok: bool,
) -> io::Result<bool> {
    let mut got = 0;
    while got < buf.len() {
        if stop.load(Ordering::SeqCst) {
            // the conn thread is being torn down; any mid-frame read aborts
            return Err(io::ErrorKind::ConnectionAborted.into());
        }
        match stream.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 && idle_ok {
                    return Ok(false);
                }
                return Err(io::ErrorKind::UnexpectedEof.into());
            }
            Ok(n) => got += n,
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock
                        | io::ErrorKind::TimedOut
                        | io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Stop-aware frame read; `Ok(None)` on clean disconnect or shutdown
/// between frames.
fn read_frame_stoppable(
    stream: &mut TcpStream,
    stop: &AtomicBool,
) -> io::Result<Option<Frame>> {
    let mut len = [0u8; 4];
    if !read_full(stream, &mut len, stop, true)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(len);
    if len == 0 || len > MAX_FRAME_BYTES {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad frame length"));
    }
    let mut body = vec![0u8; len as usize];
    read_full(stream, &mut body, stop, false)?;
    wire::decode(&body).map(Some)
}

/// Serve one connection until the peer disconnects or the shard stops.
/// When `auth` is set, the first client frame must be a matching
/// [`Frame::Auth`] (constant-time compare) or the connection gets one
/// typed [`ErrCode::AuthFailed`] and is closed.
fn serve_conn(
    mut stream: TcpStream,
    h: &CoordinatorHandle,
    pending: &Mutex<HashMap<u64, SessionExport>>,
    spec: &ShardSpec,
    auth: Option<&str>,
    stop: &AtomicBool,
) -> io::Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(STOP_POLL))?;
    stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
    wire::write_frame(
        &mut stream,
        &Frame::Hello {
            proto: PROTO_VERSION,
            engine: spec.engine.clone(),
            shape_fp: spec.shape_fp,
            weights_fp: spec.weights_fp,
        },
    )?;
    if let Some(token) = auth {
        match read_frame_stoppable(&mut stream, stop)? {
            None => return Ok(()),
            Some(Frame::Auth { token: presented })
                if crate::util::bytes::ct_eq(presented.as_bytes(), token.as_bytes()) => {}
            Some(_) => {
                // never say whether the token or the frame kind was wrong
                send_err(&mut stream, ErrCode::AuthFailed, "shared-secret token required")?;
                return Ok(());
            }
        }
    }
    loop {
        let frame = match read_frame_stoppable(&mut stream, stop)? {
            Some(f) => f,
            None => return Ok(()),
        };
        match frame {
            Frame::Submit { max_new, deadline_ms, trace, profile, prompt } => {
                // the shard hop's clock starts at frame receipt — spans
                // are offsets from here, never absolute timestamps
                let t0 = Instant::now();
                let deadline = wire_deadline(deadline_ms);
                let (tok_tx, tok_rx) = channel();
                match h.submit_traced(
                    None,
                    prompt,
                    max_new as usize,
                    Some(tok_tx),
                    deadline,
                    trace,
                    profile,
                ) {
                    Ok(rx) => stream_generation(&mut stream, tok_rx, rx, t0)?,
                    Err(_) => send_err(&mut stream, ErrCode::Closed, "coordinator closed")?,
                }
            }
            Frame::SubmitInSession {
                session,
                strict,
                max_new,
                deadline_ms,
                trace,
                profile,
                delta,
            } => {
                let t0 = Instant::now();
                let deadline = wire_deadline(deadline_ms);
                // strict resume: refuse with the typed UnknownSession
                // instead of silently forking a fresh conversation.  (The
                // check and the submit are two steps; a concurrent end
                // racing between them degrades to a fresh session, never
                // to an error — same contract as resume_session.)
                if strict && !h.session_known(session).unwrap_or(false) {
                    send_err(
                        &mut stream,
                        ErrCode::UnknownSession,
                        &SessionError::Unknown { id: session }.to_string(),
                    )?;
                    continue;
                }
                let (tok_tx, tok_rx) = channel();
                match h.submit_traced(
                    Some(session),
                    delta,
                    max_new as usize,
                    Some(tok_tx),
                    deadline,
                    trace,
                    profile,
                ) {
                    Ok(rx) => stream_generation(&mut stream, tok_rx, rx, t0)?,
                    Err(_) => send_err(&mut stream, ErrCode::Closed, "coordinator closed")?,
                }
            }
            Frame::EndSession { session } => match h.end_session(session) {
                Ok(()) => wire::write_frame(&mut stream, &Frame::Ok)?,
                Err(_) => send_err(&mut stream, ErrCode::Closed, "coordinator closed")?,
            },
            Frame::Export { session } => match h.export_session(session) {
                Ok(Some(exp)) => {
                    // the export DETACHED the session; stash it (inactive)
                    // until the router settles with commit or abort, so a
                    // connection severed anywhere past this point can be
                    // recovered: the session is here, just not serving.
                    let blob = Frame::Blob {
                        session,
                        shape_fp: spec.shape_fp,
                        weights_fp: spec.weights_fp,
                        transcript: exp.transcript.clone(),
                        state: exp.state.as_ref().map(|s| s.to_wire_bytes()),
                    };
                    pending.lock().unwrap().insert(session, exp);
                    if let Err(e) = wire::write_frame(&mut stream, &blob) {
                        // the peer never saw the blob and this conn is dead:
                        // roll back eagerly rather than await an abort that
                        // may never come (a failed export must never destroy
                        // the conversation)
                        if let Some(exp) = pending.lock().unwrap().remove(&session) {
                            let _ = h.import_session(session, exp);
                        }
                        return Err(e);
                    }
                }
                Ok(None) => send_err(
                    &mut stream,
                    ErrCode::UnknownSession,
                    &SessionError::Unknown { id: session }.to_string(),
                )?,
                Err(_) => send_err(&mut stream, ErrCode::Closed, "coordinator closed")?,
            },
            Frame::ExportCommit { session } => {
                // the move landed on the target: discard the stash.  An
                // absent stash (duplicate commit after a retry) is still Ok
                // — idempotence is what makes retry-after-sever safe.
                pending.lock().unwrap().remove(&session);
                wire::write_frame(&mut stream, &Frame::Ok)?
            }
            Frame::ExportAbort { session } => {
                // the move failed before the target had the session:
                // re-import the stash so the conversation lives on here.
                // An absent stash (duplicate abort, or the eager rollback
                // above already ran) is likewise Ok.
                let stashed = pending.lock().unwrap().remove(&session);
                match stashed {
                    Some(exp) => match h.import_session(session, exp) {
                        Ok(()) => wire::write_frame(&mut stream, &Frame::Ok)?,
                        Err(_) => {
                            send_err(&mut stream, ErrCode::Closed, "coordinator closed")?
                        }
                    },
                    None => wire::write_frame(&mut stream, &Frame::Ok)?,
                }
            }
            Frame::Transcript { session } => match h.transcript_of(session) {
                Ok(Some(tokens)) => {
                    wire::write_frame(&mut stream, &Frame::TranscriptIs { tokens })?
                }
                Ok(None) => send_err(
                    &mut stream,
                    ErrCode::UnknownSession,
                    &SessionError::Unknown { id: session }.to_string(),
                )?,
                Err(_) => send_err(&mut stream, ErrCode::Closed, "coordinator closed")?,
            },
            Frame::Import { session, shape_fp, weights_fp, transcript, state } => {
                match check_import(spec, shape_fp, weights_fp, state) {
                    Err(msg) => send_err(&mut stream, ErrCode::Mismatch, &msg)?,
                    Ok(state) => {
                        match h.import_session(session, SessionExport { transcript, state }) {
                            Ok(()) => wire::write_frame(&mut stream, &Frame::Ok)?,
                            Err(_) => {
                                send_err(&mut stream, ErrCode::Closed, "coordinator closed")?
                            }
                        }
                    }
                }
            }
            Frame::Health => {
                let m = h.metrics.snapshot();
                wire::write_frame(
                    &mut stream,
                    &Frame::HealthReport(HealthReport {
                        sessions_resident: m.sessions_resident,
                        session_bytes: m.session_bytes_held,
                        session_hits: m.session_hits,
                        session_misses: m.session_misses,
                        in_flight: m.requests_in.saturating_sub(m.requests_done),
                        requests_done: m.requests_done,
                        tokens_generated: m.tokens_generated,
                        prefill_tokens_saved: m.prefill_tokens_saved,
                        queue_depth: m.queue_depth,
                    }),
                )?
            }
            Frame::Metrics => {
                // full snapshot under stable schema names — the router
                // merges these exactly across shards (hist merge is exact,
                // counters/gauges sum)
                wire::write_frame(
                    &mut stream,
                    &Frame::MetricsReport { entries: h.metrics.export_entries() },
                )?
            }
            Frame::BulkExport => {
                // quiesce + detach + stash EVERY session this shard holds
                // (resident, spilled, transcript-only), reply with one
                // BulkBlob — the source half of a one-round-trip drain
                let ids = match h.session_list() {
                    Ok(ids) => ids,
                    Err(_) => {
                        send_err(&mut stream, ErrCode::Closed, "coordinator closed")?;
                        continue;
                    }
                };
                let mut blobs = Vec::with_capacity(ids.len());
                let mut stashed: Vec<u64> = Vec::new();
                for id in ids {
                    match h.export_session(id) {
                        Ok(Some(exp)) => {
                            blobs.push(SessionBlob {
                                session: id,
                                transcript: exp.transcript.clone(),
                                state: exp.state.as_ref().map(|s| s.to_wire_bytes()),
                            });
                            pending.lock().unwrap().insert(id, exp);
                            stashed.push(id);
                        }
                        // ended between the list and the export: fine
                        Ok(None) => {}
                        Err(_) => break,
                    }
                }
                let reply = Frame::BulkBlob {
                    shape_fp: spec.shape_fp,
                    weights_fp: spec.weights_fp,
                    sessions: blobs,
                };
                if let Err(e) = wire::write_frame(&mut stream, &reply) {
                    // the peer never saw the blob and this conn is dead:
                    // roll every stash back eagerly (same reasoning as the
                    // per-session export — a failed export must never
                    // destroy conversations)
                    let mut p = pending.lock().unwrap();
                    for id in stashed {
                        if let Some(exp) = p.remove(&id) {
                            let _ = h.import_session(id, exp);
                        }
                    }
                    return Err(e);
                }
            }
            Frame::BulkImport { shape_fp, weights_fp, sessions } => {
                // atomic: validate every blob before installing any, so a
                // mismatched batch installs nothing — and the router's
                // lost-Ok probe of one session answers for the whole batch
                let mut checked = Vec::with_capacity(sessions.len());
                let mut bad: Option<String> = None;
                for b in sessions {
                    match check_import(spec, shape_fp, weights_fp, b.state) {
                        Ok(st) => checked.push((
                            b.session,
                            SessionExport { transcript: b.transcript, state: st },
                        )),
                        Err(msg) => {
                            bad = Some(msg);
                            break;
                        }
                    }
                }
                if let Some(msg) = bad {
                    send_err(&mut stream, ErrCode::Mismatch, &msg)?;
                    continue;
                }
                let mut closed = false;
                for (id, exp) in checked {
                    if h.import_session(id, exp).is_err() {
                        closed = true;
                        break;
                    }
                }
                if closed {
                    send_err(&mut stream, ErrCode::Closed, "coordinator closed")?
                } else {
                    wire::write_frame(&mut stream, &Frame::Ok)?
                }
            }
            Frame::BulkCommit { sessions } => {
                // idempotent per id, exactly like ExportCommit
                let mut p = pending.lock().unwrap();
                for id in sessions {
                    p.remove(&id);
                }
                drop(p);
                wire::write_frame(&mut stream, &Frame::Ok)?
            }
            Frame::BulkAbort { sessions } => {
                // an EMPTY id list restores every stash — the recovery for
                // a lost BulkBlob reply, where the peer cannot name what
                // was stashed.  Idempotent per id, like ExportAbort.
                let victims: Vec<u64> = if sessions.is_empty() {
                    pending.lock().unwrap().keys().copied().collect()
                } else {
                    sessions
                };
                let mut closed = false;
                for id in victims {
                    let stashed = pending.lock().unwrap().remove(&id);
                    if let Some(exp) = stashed {
                        if h.import_session(id, exp).is_err() {
                            closed = true;
                            break;
                        }
                    }
                }
                if closed {
                    send_err(&mut stream, ErrCode::Closed, "coordinator closed")?
                } else {
                    wire::write_frame(&mut stream, &Frame::Ok)?
                }
            }
            // a credential presented to an open shard is accepted silently
            // (a token-configured client may talk to a token-less shard)
            Frame::Auth { .. } => {}
            // reply frames (or a client Hello) are not valid requests
            _ => send_err(&mut stream, ErrCode::Protocol, "unexpected frame")?,
        }
    }
}

/// Validate an import against this shard's identity *before* the
/// coordinator sees it: shape fingerprint, weights fingerprint, blob
/// magic + format version, and engine tag all have to match.
fn check_import(
    spec: &ShardSpec,
    shape_fp: u64,
    weights_fp: u64,
    state: Option<Vec<u8>>,
) -> Result<Option<SessionState>, String> {
    if shape_fp != spec.shape_fp {
        return Err(format!(
            "shape fingerprint {shape_fp:#x} != shard {:#x}",
            spec.shape_fp
        ));
    }
    if weights_fp != spec.weights_fp {
        return Err(format!(
            "weights fingerprint {weights_fp:#x} != shard {:#x} \
             (same shape, different weights/seed?)",
            spec.weights_fp
        ));
    }
    match state {
        None => Ok(None),
        Some(bytes) => {
            let st = SessionState::from_wire_bytes(&bytes).map_err(|e| e.to_string())?;
            st.check_engine(&spec.engine).map_err(|e| e.to_string())?;
            Ok(Some(st))
        }
    }
}

/// Stream one generation *live*: each Token frame is written the moment
/// the decode loop emits it (wire TTFB = engine TTFT), then the buffered
/// response closes the reply with Done.  A write error (peer gone
/// mid-stream) aborts the relay but never the generation — the
/// coordinator finishes the turn regardless, so the session snapshot and
/// transcript stay complete and a front door can reconcile from them.
fn stream_generation(
    stream: &mut TcpStream,
    tokens: Receiver<i32>,
    resp: Receiver<GenResponse>,
    t0: Instant,
) -> io::Result<()> {
    for t in tokens.iter() {
        wire::write_frame(stream, &Frame::Token { token: t })?;
    }
    // the token sender dropped: the request retired and the response is
    // already (or imminently) in the reply channel
    match resp.recv() {
        // a refused turn was never applied (no tokens, session untouched):
        // surface the coordinator's typed refusal as a typed wire error so
        // the client can back off / respect the spent budget — never a
        // silent hang, never a half-reply
        Ok(mut resp) => match resp.refusal {
            Some(Refusal::Overloaded) => {
                send_err(stream, ErrCode::Overloaded, "admission queue full")
            }
            Some(Refusal::DeadlineExceeded) => send_err(
                stream,
                ErrCode::DeadlineExceeded,
                "deadline budget exhausted before admission",
            ),
            None => {
                let ttft_us = (resp.ttft_s * 1e6) as u64;
                let total_us = (resp.total_s * 1e6) as u64;
                if resp.trace != 0 {
                    // span report first, Done last — the closing frame
                    // stays the closing frame for every client
                    let hop = HopReport::new("shard", t0.elapsed().as_micros() as u64)
                        .span("to_first_token", 0, ttft_us)
                        .span("stream", ttft_us, total_us.saturating_sub(ttft_us));
                    let mut hops = vec![hop];
                    hops.append(&mut resp.hops);
                    wire::write_frame(
                        stream,
                        &Frame::Spans { trace: resp.trace, hops },
                    )?;
                }
                wire::write_frame(
                    stream,
                    &Frame::Done { trace: resp.trace, ttft_us, total_us },
                )
            }
        },
        Err(_) => send_err(stream, ErrCode::Closed, "generation reply lost"),
    }
}

fn send_err(stream: &mut TcpStream, code: ErrCode, msg: &str) -> io::Result<()> {
    wire::write_frame(stream, &Frame::Error { code, msg: msg.to_string() })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ServeConfig {
        ServeConfig { max_batch: 2, linger_ms: 1, ..ServeConfig::default() }
    }

    fn native_shard() -> ShardServer {
        let shape = LmShape::bench("nano").unwrap();
        ShardServer::spawn_native(&shape, 2, 11, cfg()).unwrap()
    }

    /// Minimal raw client for the tests: connect, swallow the Hello,
    /// exchange frames directly.
    struct RawClient {
        stream: TcpStream,
        /// (proto, engine, shape_fp, weights_fp) from the Hello.
        hello: (u32, String, u64, u64),
    }

    impl RawClient {
        fn connect(addr: SocketAddr) -> RawClient {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream
                .set_read_timeout(Some(Duration::from_secs(120)))
                .unwrap();
            let hello = match wire::read_frame(&mut stream).unwrap() {
                Frame::Hello { proto, engine, shape_fp, weights_fp } => {
                    (proto, engine, shape_fp, weights_fp)
                }
                other => panic!("expected Hello, got {other:?}"),
            };
            RawClient { stream, hello }
        }

        fn send(&mut self, f: &Frame) {
            wire::write_frame(&mut self.stream, f).unwrap();
        }

        fn recv(&mut self) -> Frame {
            wire::read_frame(&mut self.stream).unwrap()
        }

        /// Read Token* + Done and return the tokens.
        fn collect_generation(&mut self) -> Vec<i32> {
            let mut toks = Vec::new();
            loop {
                match self.recv() {
                    Frame::Token { token } => toks.push(token),
                    Frame::Done { ttft_us, total_us, .. } => {
                        assert!(ttft_us <= total_us);
                        return toks;
                    }
                    other => panic!("expected Token/Done, got {other:?}"),
                }
            }
        }
    }

    #[test]
    fn handshake_advertises_proto_engine_and_shape() {
        let shard = native_shard();
        let client = RawClient::connect(shard.addr());
        let shape = LmShape::bench("nano").unwrap();
        assert_eq!(client.hello.0, PROTO_VERSION);
        assert_eq!(client.hello.1, STATE_TAG);
        assert_eq!(client.hello.2, shape.fingerprint());
        let spec = ShardSpec::native(&shape, STATE_TAG, 11);
        assert_eq!(client.hello.3, spec.weights_fp);
        // a different seed means different weights, and a different identity
        assert_ne!(spec.weights_fp, ShardSpec::native(&shape, STATE_TAG, 12).weights_fp);
        shard.shutdown();
    }

    #[test]
    fn submit_streams_the_same_tokens_the_coordinator_produces() {
        let shard = native_shard();
        // reference coordinator with the same seed -> identical weights
        let shape = LmShape::bench("nano").unwrap();
        let h_ref = spawn(
            move || Box::new(RecurrentEngine::new(&shape, 2, 11)) as Box<dyn SlotEngine>,
            cfg(),
        );
        let want = h_ref
            .submit(vec![4, 2, 4], 5)
            .unwrap()
            .recv_timeout(Duration::from_secs(60))
            .unwrap()
            .tokens;
        let mut client = RawClient::connect(shard.addr());
        client.send(&Frame::Submit { max_new: 5, deadline_ms: 0, trace: 0, profile: false, prompt: vec![4, 2, 4] });
        assert_eq!(client.collect_generation(), want);
        // a second command reuses the same connection
        client.send(&Frame::Submit { max_new: 5, deadline_ms: 0, trace: 0, profile: false, prompt: vec![4, 2, 4] });
        assert_eq!(client.collect_generation(), want);
        h_ref.shutdown();
        shard.shutdown();
    }

    #[test]
    fn strict_resume_of_unknown_session_is_a_typed_wire_error() {
        let shard = native_shard();
        let mut client = RawClient::connect(shard.addr());
        client.send(&Frame::SubmitInSession {
            session: 99,
            strict: true,
            max_new: 3,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            delta: vec![1, 2],
        });
        match client.recv() {
            Frame::Error { code, .. } => assert_eq!(code, ErrCode::UnknownSession),
            other => panic!("expected UnknownSession, got {other:?}"),
        }
        // non-strict starts the session; strict then succeeds
        client.send(&Frame::SubmitInSession {
            session: 99,
            strict: false,
            max_new: 3,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            delta: vec![1, 2],
        });
        let g1 = client.collect_generation();
        assert_eq!(g1.len(), 3);
        client.send(&Frame::SubmitInSession {
            session: 99,
            strict: true,
            max_new: 3,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            delta: vec![3],
        });
        assert_eq!(client.collect_generation().len(), 3);
        shard.shutdown();
    }

    #[test]
    fn mismatched_imports_are_refused_before_restore() {
        let shard = native_shard();
        let mut client = RawClient::connect(shard.addr());
        let (fp, wfp) = (client.hello.2, client.hello.3);
        // wrong shape fingerprint: refused outright
        client.send(&Frame::Import {
            session: 1,
            shape_fp: fp ^ 1,
            weights_fp: wfp,
            transcript: vec![1],
            state: None,
        });
        assert!(matches!(
            client.recv(),
            Frame::Error { code: ErrCode::Mismatch, .. }
        ));
        // same shape but different weights (e.g. another seed): refused too
        client.send(&Frame::Import {
            session: 1,
            shape_fp: fp,
            weights_fp: wfp ^ 1,
            transcript: vec![1],
            state: None,
        });
        match client.recv() {
            Frame::Error { code, msg } => {
                assert_eq!(code, ErrCode::Mismatch);
                assert!(msg.contains("weights"), "must name the cause: {msg}");
            }
            other => panic!("expected Mismatch, got {other:?}"),
        }
        // garbage state bytes: refused at blob validation
        client.send(&Frame::Import {
            session: 1,
            shape_fp: fp,
            weights_fp: wfp,
            transcript: vec![1],
            state: Some(vec![1, 2, 3, 4]),
        });
        assert!(matches!(
            client.recv(),
            Frame::Error { code: ErrCode::Mismatch, .. }
        ));
        // foreign engine tag: refused at the tag check
        let foreign = SessionState::new("some-other-engine", 7);
        client.send(&Frame::Import {
            session: 1,
            shape_fp: fp,
            weights_fp: wfp,
            transcript: vec![1],
            state: Some(foreign.to_wire_bytes()),
        });
        assert!(matches!(
            client.recv(),
            Frame::Error { code: ErrCode::Mismatch, .. }
        ));
        // none of those refusals may have created the session
        client.send(&Frame::SubmitInSession {
            session: 1,
            strict: true,
            max_new: 1,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            delta: vec![5],
        });
        assert!(matches!(
            client.recv(),
            Frame::Error { code: ErrCode::UnknownSession, .. }
        ));
        shard.shutdown();
    }

    #[test]
    fn export_import_roundtrip_over_the_wire_continues_bit_identical() {
        let shard_a = native_shard();
        let shard_b = native_shard();
        let shape = LmShape::bench("nano").unwrap();
        let h_ref = spawn(
            move || Box::new(RecurrentEngine::new(&shape, 2, 11)) as Box<dyn SlotEngine>,
            cfg(),
        );
        let sid = 0xC0FFEE;
        let turn_ref = |delta: Vec<i32>, n: usize| {
            h_ref
                .submit_in_session(sid, delta, n)
                .unwrap()
                .recv_timeout(Duration::from_secs(60))
                .unwrap()
                .tokens
        };
        let mut a = RawClient::connect(shard_a.addr());
        let mut b = RawClient::connect(shard_b.addr());
        // turn 1 on shard A
        a.send(&Frame::SubmitInSession {
            session: sid,
            strict: false,
            max_new: 4,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            delta: vec![3, 1, 4],
        });
        let g1 = a.collect_generation();
        assert_eq!(g1, turn_ref(vec![3, 1, 4], 4));
        // migrate A -> B over the wire
        a.send(&Frame::Export { session: sid });
        let (fp, wfp, transcript, state) = match a.recv() {
            Frame::Blob { session, shape_fp, weights_fp, transcript, state } => {
                assert_eq!(session, sid);
                (shape_fp, weights_fp, transcript, state)
            }
            other => panic!("expected Blob, got {other:?}"),
        };
        assert!(state.is_some(), "recurrent engine exports O(1) state");
        b.send(&Frame::Import {
            session: sid,
            shape_fp: fp,
            weights_fp: wfp,
            transcript,
            state,
        });
        assert_eq!(b.recv(), Frame::Ok);
        // turn 2 on shard B must match the uninterrupted reference
        b.send(&Frame::SubmitInSession {
            session: sid,
            strict: true,
            max_new: 3,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            delta: vec![1, 5],
        });
        assert_eq!(b.collect_generation(), turn_ref(vec![1, 5], 3));
        // the session no longer exists on A
        a.send(&Frame::Export { session: sid });
        assert!(matches!(
            a.recv(),
            Frame::Error { code: ErrCode::UnknownSession, .. }
        ));
        h_ref.shutdown();
        shard_a.shutdown();
        shard_b.shutdown();
    }

    /// The source-side two-phase export: a stashed session is inactive
    /// but recoverable; abort restores it bit-identically, commit discards
    /// it, and both settlements are idempotent across reconnects.
    #[test]
    fn export_stash_abort_restores_and_commit_discards() {
        let shard = native_shard();
        let shape = LmShape::bench("nano").unwrap();
        let h_ref = spawn(
            move || Box::new(RecurrentEngine::new(&shape, 2, 11)) as Box<dyn SlotEngine>,
            cfg(),
        );
        let sid = 7;
        let turn_ref = |delta: Vec<i32>, n: usize| {
            h_ref
                .submit_in_session(sid, delta, n)
                .unwrap()
                .recv_timeout(Duration::from_secs(60))
                .unwrap()
                .tokens
        };
        let mut c = RawClient::connect(shard.addr());
        c.send(&Frame::SubmitInSession {
            session: sid,
            strict: false,
            max_new: 4,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            delta: vec![2, 7, 1],
        });
        assert_eq!(c.collect_generation(), turn_ref(vec![2, 7, 1], 4));
        // export: the session leaves the coordinator and sits in the stash
        c.send(&Frame::Export { session: sid });
        assert!(matches!(c.recv(), Frame::Blob { .. }));
        assert_eq!(shard.pending_exports(), 1);
        assert!(
            !shard.handle.session_known(sid).unwrap(),
            "a stashed session must not be able to serve turns"
        );
        c.send(&Frame::SubmitInSession { session: sid, strict: true, max_new: 1, deadline_ms: 0, trace: 0, profile: false, delta: vec![9] });
        assert!(matches!(c.recv(), Frame::Error { code: ErrCode::UnknownSession, .. }));
        // abort on a NEW connection: settlement survives a reconnect
        let mut c2 = RawClient::connect(shard.addr());
        c2.send(&Frame::ExportAbort { session: sid });
        assert_eq!(c2.recv(), Frame::Ok);
        assert_eq!(shard.pending_exports(), 0);
        assert!(shard.handle.session_known(sid).unwrap());
        // duplicate abort: idempotent Ok, session still exactly once
        c2.send(&Frame::ExportAbort { session: sid });
        assert_eq!(c2.recv(), Frame::Ok);
        // continuation after the rollback is bit-identical to uninterrupted
        c2.send(&Frame::SubmitInSession {
            session: sid,
            strict: true,
            max_new: 3,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            delta: vec![5, 5],
        });
        assert_eq!(c2.collect_generation(), turn_ref(vec![5, 5], 3));
        // export again, commit this time: gone for good
        c2.send(&Frame::Export { session: sid });
        assert!(matches!(c2.recv(), Frame::Blob { .. }));
        c2.send(&Frame::ExportCommit { session: sid });
        assert_eq!(c2.recv(), Frame::Ok);
        assert_eq!(shard.pending_exports(), 0);
        c2.send(&Frame::ExportCommit { session: sid }); // duplicate commit
        assert_eq!(c2.recv(), Frame::Ok);
        c2.send(&Frame::SubmitInSession { session: sid, strict: true, max_new: 1, deadline_ms: 0, trace: 0, profile: false, delta: vec![1] });
        assert!(matches!(c2.recv(), Frame::Error { code: ErrCode::UnknownSession, .. }));
        h_ref.shutdown();
        shard.shutdown();
    }

    /// The transcript probe: typed UnknownSession for an absent session,
    /// the full prompt+generated history for a live one — and reading it
    /// never detaches anything.
    #[test]
    fn transcript_probe_is_nondestructive_and_typed_for_unknown() {
        let shard = native_shard();
        let mut c = RawClient::connect(shard.addr());
        c.send(&Frame::Transcript { session: 42 });
        assert!(matches!(c.recv(), Frame::Error { code: ErrCode::UnknownSession, .. }));
        c.send(&Frame::SubmitInSession {
            session: 42,
            strict: false,
            max_new: 3,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            delta: vec![1, 2],
        });
        let g = c.collect_generation();
        c.send(&Frame::Transcript { session: 42 });
        match c.recv() {
            Frame::TranscriptIs { tokens } => {
                let mut want = vec![1, 2];
                want.extend(&g);
                assert_eq!(tokens, want, "transcript must be prompt + generated, in order");
            }
            other => panic!("expected TranscriptIs, got {other:?}"),
        }
        c.send(&Frame::SubmitInSession { session: 42, strict: true, max_new: 2, deadline_ms: 0, trace: 0, profile: false, delta: vec![3] });
        assert_eq!(c.collect_generation().len(), 2);
        shard.shutdown();
    }

    #[test]
    fn health_reports_sessions_and_traffic() {
        let shard = native_shard();
        let mut client = RawClient::connect(shard.addr());
        client.send(&Frame::SubmitInSession {
            session: 5,
            strict: false,
            max_new: 4,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            delta: vec![2, 7],
        });
        let _ = client.collect_generation();
        client.send(&Frame::Health);
        match client.recv() {
            Frame::HealthReport(h) => {
                assert_eq!(h.sessions_resident, 1);
                assert!(h.session_bytes > 0);
                assert_eq!(h.requests_done, 1);
                assert_eq!(h.tokens_generated as usize + 1, 4);
                assert_eq!(h.in_flight, 0);
            }
            other => panic!("expected HealthReport, got {other:?}"),
        }
        shard.shutdown();
    }

    #[test]
    fn metrics_frame_returns_schema_named_snapshot() {
        use crate::obs::MetricValue;
        let shard = native_shard();
        let mut client = RawClient::connect(shard.addr());
        client.send(&Frame::SubmitInSession {
            session: 5,
            strict: false,
            max_new: 4,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            delta: vec![2, 7],
        });
        let _ = client.collect_generation();
        client.send(&Frame::Metrics);
        match client.recv() {
            Frame::MetricsReport { entries } => {
                let get = |name: &str| {
                    entries
                        .iter()
                        .find(|(n, _)| n == name)
                        .map(|(_, v)| v.clone())
                        .unwrap_or_else(|| panic!("missing metric {name}"))
                };
                assert_eq!(get("lh_requests_done_total"), MetricValue::Counter(1));
                match get("lh_ttft_seconds") {
                    MetricValue::Hist(h) => assert_eq!(h.count(), 1),
                    other => panic!("lh_ttft_seconds must be a hist, got {other:?}"),
                }
                match get("lh_queue_depth") {
                    MetricValue::Gauge(0) => {}
                    other => panic!("queue must be drained, got {other:?}"),
                }
            }
            other => panic!("expected MetricsReport, got {other:?}"),
        }
        shard.shutdown();
    }

    /// The bulk drain path: one BulkExport stashes every session and
    /// ships them all; BulkImport installs the batch atomically on the
    /// peer; BulkCommit settles the source stash.  Conversations continue
    /// on the peer bit-identically to an uninterrupted run.
    #[test]
    fn bulk_export_import_commit_moves_every_session_in_one_round_trip() {
        let shard_a = native_shard();
        let shard_b = native_shard();
        let shape = LmShape::bench("nano").unwrap();
        let h_ref = spawn(
            move || Box::new(RecurrentEngine::new(&shape, 2, 11)) as Box<dyn SlotEngine>,
            cfg(),
        );
        let sids = [3u64, 7, 9];
        let mut a = RawClient::connect(shard_a.addr());
        for &sid in &sids {
            a.send(&Frame::SubmitInSession {
                session: sid,
                strict: false,
                max_new: 3,
                deadline_ms: 0,
                trace: 0,
                profile: false,
                delta: vec![1 + sid as i32, 2],
            });
            let got = a.collect_generation();
            let want = h_ref
                .submit_in_session(sid, vec![1 + sid as i32, 2], 3)
                .unwrap()
                .recv_timeout(Duration::from_secs(60))
                .unwrap()
                .tokens;
            assert_eq!(got, want, "turn 1 of session {sid} must agree with reference");
        }
        // one round trip detaches and ships everything
        a.send(&Frame::BulkExport);
        let (fp, wfp, blobs) = match a.recv() {
            Frame::BulkBlob { shape_fp, weights_fp, sessions } => {
                (shape_fp, weights_fp, sessions)
            }
            other => panic!("expected BulkBlob, got {other:?}"),
        };
        assert_eq!(blobs.len(), sids.len());
        assert_eq!(shard_a.pending_exports(), sids.len());
        for &sid in &sids {
            assert!(
                !shard_a.handle.session_known(sid).unwrap(),
                "a stashed session must not be able to serve turns"
            );
        }
        // install the batch on the peer, then settle the source stash
        let mut b = RawClient::connect(shard_b.addr());
        b.send(&Frame::BulkImport { shape_fp: fp, weights_fp: wfp, sessions: blobs });
        assert_eq!(b.recv(), Frame::Ok);
        a.send(&Frame::BulkCommit { sessions: sids.to_vec() });
        assert_eq!(a.recv(), Frame::Ok);
        assert_eq!(shard_a.pending_exports(), 0, "commit must drain the stash");
        // turn 2 on the peer matches the uninterrupted reference
        for &sid in &sids {
            b.send(&Frame::SubmitInSession {
                session: sid,
                strict: true,
                max_new: 3,
                deadline_ms: 0,
                trace: 0,
                profile: false,
                delta: vec![9],
            });
            let got = b.collect_generation();
            let want = h_ref
                .submit_in_session(sid, vec![9], 3)
                .unwrap()
                .recv_timeout(Duration::from_secs(60))
                .unwrap()
                .tokens;
            assert_eq!(got, want, "post-drain turn of session {sid} must be bit-identical");
        }
        h_ref.shutdown();
        shard_a.shutdown();
        shard_b.shutdown();
    }

    /// A BulkAbort with an EMPTY id list restores every stash — the
    /// recovery a router uses when the BulkBlob reply was lost and it
    /// cannot name what was stashed.
    #[test]
    fn bulk_abort_with_empty_list_restores_every_stash() {
        let shard = native_shard();
        let mut c = RawClient::connect(shard.addr());
        for sid in [1u64, 2] {
            c.send(&Frame::SubmitInSession {
                session: sid,
                strict: false,
                max_new: 2,
                deadline_ms: 0,
                trace: 0,
                profile: false,
                delta: vec![sid as i32],
            });
            let _ = c.collect_generation();
        }
        c.send(&Frame::BulkExport);
        assert!(matches!(c.recv(), Frame::BulkBlob { .. }));
        assert_eq!(shard.pending_exports(), 2);
        c.send(&Frame::BulkAbort { sessions: vec![] });
        assert_eq!(c.recv(), Frame::Ok);
        assert_eq!(shard.pending_exports(), 0);
        for sid in [1u64, 2] {
            assert!(shard.handle.session_known(sid).unwrap(), "session {sid} must be back");
        }
        // and they still serve strict turns
        c.send(&Frame::SubmitInSession {
            session: 1,
            strict: true,
            max_new: 2,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            delta: vec![5],
        });
        assert_eq!(c.collect_generation().len(), 2);
        shard.shutdown();
    }

    /// A queued request whose wire deadline budget expires is refused
    /// with the typed DeadlineExceeded error frame — never a silent hang,
    /// never a late generation.
    #[test]
    fn expired_wire_deadline_is_a_typed_error_frame() {
        let shape = LmShape::bench("nano").unwrap();
        let shard = ShardServer::spawn_native(
            &shape,
            1,
            11,
            ServeConfig { max_batch: 1, linger_ms: 1, ..ServeConfig::default() },
        )
        .unwrap();
        // pin the single slot with a long generation: read the first token
        // to prove admission, leaving the rest of the stream in flight
        let mut busy = RawClient::connect(shard.addr());
        busy.send(&Frame::Submit { max_new: 20_000, deadline_ms: 0, trace: 0, profile: false, prompt: vec![1, 2] });
        match busy.recv() {
            Frame::Token { .. } => {}
            other => panic!("expected first token, got {other:?}"),
        }
        // a 1ms budget expires in the queue behind the busy slot
        let mut late = RawClient::connect(shard.addr());
        late.send(&Frame::Submit { max_new: 4, deadline_ms: 1, trace: 0, profile: false, prompt: vec![3] });
        match late.recv() {
            Frame::Error { code, .. } => assert_eq!(code, ErrCode::DeadlineExceeded),
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        // the pinned request still completes in full
        let mut toks = 1;
        loop {
            match busy.recv() {
                Frame::Token { .. } => toks += 1,
                Frame::Done { .. } => break,
                other => panic!("expected Token/Done, got {other:?}"),
            }
        }
        assert_eq!(toks, 20_000, "accepted work always runs to completion");
        shard.shutdown();
    }

    /// Arrivals past the admission-queue cap get the typed Overloaded
    /// error frame immediately.
    #[test]
    fn queue_cap_overflow_is_a_typed_overloaded_frame() {
        let shape = LmShape::bench("nano").unwrap();
        let shard = ShardServer::spawn_native(
            &shape,
            1,
            11,
            ServeConfig { max_batch: 1, linger_ms: 1, max_queue: 1, ..ServeConfig::default() },
        )
        .unwrap();
        // a long session turn pins the single slot
        let mut busy = RawClient::connect(shard.addr());
        busy.send(&Frame::SubmitInSession {
            session: 6,
            strict: false,
            max_new: 20_000,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            delta: vec![1, 2],
        });
        match busy.recv() {
            Frame::Token { .. } => {}
            other => panic!("expected first token, got {other:?}"),
        }
        // a second session turn fills the queue (no deadline: it will
        // simply wait its turn); the census counts session turns that are
        // queued or slotted, so in_flight == 2 proves the queue is full
        let mut queued = RawClient::connect(shard.addr());
        queued.send(&Frame::SubmitInSession {
            session: 7,
            strict: false,
            max_new: 2,
            deadline_ms: 0,
            trace: 0,
            profile: false,
            delta: vec![3],
        });
        let t0 = Instant::now();
        while shard.handle.session_census().unwrap().in_flight < 2 {
            assert!(t0.elapsed() < Duration::from_secs(30), "turn never queued");
            std::thread::sleep(Duration::from_millis(1));
        }
        // past the cap: typed refusal, immediately
        let mut extra = RawClient::connect(shard.addr());
        extra.send(&Frame::Submit { max_new: 2, deadline_ms: 0, trace: 0, profile: false, prompt: vec![4] });
        match extra.recv() {
            Frame::Error { code, .. } => assert_eq!(code, ErrCode::Overloaded),
            other => panic!("expected Overloaded, got {other:?}"),
        }
        // drain the pinned stream; the queued request then completes
        loop {
            if matches!(busy.recv(), Frame::Done { .. }) {
                break;
            }
        }
        assert_eq!(queued.collect_generation().len(), 2);
        shard.shutdown();
    }

    /// The shared-secret handshake: a token-configured shard refuses the
    /// first command of any connection that did not present the exact
    /// token, and an open shard silently accepts a presented credential.
    #[test]
    fn auth_token_gates_every_command() {
        let shape = LmShape::bench("nano").unwrap();
        let shard = ShardServer::spawn_native(
            &shape,
            2,
            11,
            ServeConfig {
                max_batch: 2,
                linger_ms: 1,
                auth_token: Some("hunter2".into()),
                ..ServeConfig::default()
            },
        )
        .unwrap();
        // no token: the first command is refused, typed
        let mut c = RawClient::connect(shard.addr());
        c.send(&Frame::Submit { max_new: 2, deadline_ms: 0, trace: 0, profile: false, prompt: vec![1] });
        assert!(matches!(c.recv(), Frame::Error { code: ErrCode::AuthFailed, .. }));
        // wrong token: refused too
        let mut c = RawClient::connect(shard.addr());
        c.send(&Frame::Auth { token: "hunter3".into() });
        c.send(&Frame::Submit { max_new: 2, deadline_ms: 0, trace: 0, profile: false, prompt: vec![1] });
        assert!(matches!(c.recv(), Frame::Error { code: ErrCode::AuthFailed, .. }));
        // the right token admits the connection for all further commands
        let mut c = RawClient::connect(shard.addr());
        c.send(&Frame::Auth { token: "hunter2".into() });
        c.send(&Frame::Submit { max_new: 2, deadline_ms: 0, trace: 0, profile: false, prompt: vec![1] });
        assert_eq!(c.collect_generation().len(), 2);
        shard.shutdown();
        // an open (token-less) shard ignores a presented credential
        let open = native_shard();
        let mut c = RawClient::connect(open.addr());
        c.send(&Frame::Auth { token: "whatever".into() });
        c.send(&Frame::Submit { max_new: 2, deadline_ms: 0, trace: 0, profile: false, prompt: vec![1] });
        assert_eq!(c.collect_generation().len(), 2);
        open.shutdown();
    }

    /// The wire tracing contract at the shard boundary: a traced submit
    /// gets one Spans frame — shard + coordinator + engine hops joined
    /// under the client's trace id — after the last token and before the
    /// Done, and the Done echoes the trace id.  Untraced submits (all
    /// the other tests here) never see a Spans frame.
    #[test]
    fn traced_submit_streams_spans_before_done() {
        let shard = native_shard();
        let mut c = RawClient::connect(shard.addr());
        c.send(&Frame::Submit {
            max_new: 3,
            deadline_ms: 0,
            trace: 0xABCD,
            profile: true,
            prompt: vec![1, 2],
        });
        let mut toks = 0;
        let mut spans: Option<(u64, Vec<HopReport>)> = None;
        loop {
            match c.recv() {
                Frame::Token { .. } => {
                    assert!(spans.is_none(), "Spans must come after the last token");
                    toks += 1;
                }
                Frame::Spans { trace, hops } => spans = Some((trace, hops)),
                Frame::Done { trace, ttft_us, total_us } => {
                    assert_eq!(trace, 0xABCD, "Done must echo the trace id");
                    assert!(ttft_us <= total_us);
                    break;
                }
                other => panic!("unexpected frame {other:?}"),
            }
        }
        assert_eq!(toks, 3);
        let (trace, hops) = spans.expect("traced submit must ship a span report");
        assert_eq!(trace, 0xABCD);
        let names: Vec<&str> = hops.iter().map(|h| h.hop.as_str()).collect();
        assert!(names.contains(&"shard"), "{names:?}");
        assert!(names.contains(&"coordinator"), "{names:?}");
        assert!(
            names.contains(&"engine"),
            "profiled request must report engine stages: {names:?}"
        );
        let eng = hops.iter().find(|h| h.hop == "engine").unwrap();
        assert!(eng.span_named("modal_sweep").is_some(), "{eng:?}");
        shard.shutdown();
    }

    #[test]
    fn protocol_violations_get_a_typed_error_and_shutdown_is_clean() {
        let shard = native_shard();
        let mut client = RawClient::connect(shard.addr());
        client.send(&Frame::Ok); // replies are not requests
        assert!(matches!(
            client.recv(),
            Frame::Error { code: ErrCode::Protocol, .. }
        ));
        // dropping the client mid-connection must not wedge shutdown
        drop(client);
        let _idle = RawClient::connect(shard.addr());
        shard.shutdown();
    }
}
