//! Admin surface of the serve layer: aggregated health across shards and
//! a convenience launcher that runs N in-process shard servers plus a
//! router over loopback sockets (the CLI demo and the integration tests
//! both drive this).

use std::fmt;
use std::sync::Arc;

use super::circuit::{BreakerConfig, BreakerState};
use super::faults::FaultPlan;
use super::router::{MigrationStats, RouteError, Router};
use super::shard::ShardServer;
use super::wire::HealthReport;
use crate::config::ServeConfig;
use crate::engine::LmShape;
use crate::session::{Journal, JournalConfig, JournalError};

/// Per-shard health plus cluster totals, with the router-side view
/// (circuit states, migration counters) alongside the shard-side sums.
#[derive(Clone, Debug, Default)]
pub struct AdminReport {
    pub per_shard: Vec<HealthReport>,
    pub total: HealthReport,
    /// Circuit state per shard, indexed like `per_shard`.  Empty when
    /// the report was built by [`AdminReport::aggregate`] alone (no
    /// router at hand).
    pub breakers: Vec<BreakerState>,
    /// Lifetime migration/resurrection counts from the router.
    pub migrations: MigrationStats,
}

impl AdminReport {
    /// Sum the per-shard reports into cluster totals.  Shard-side only —
    /// [`AdminReport::collect`] is what fills the router-side fields.
    pub fn aggregate(per_shard: Vec<HealthReport>) -> AdminReport {
        let mut total = HealthReport::default();
        for h in &per_shard {
            total.sessions_resident += h.sessions_resident;
            total.session_bytes += h.session_bytes;
            total.session_hits += h.session_hits;
            total.session_misses += h.session_misses;
            total.in_flight += h.in_flight;
            total.requests_done += h.requests_done;
            total.tokens_generated += h.tokens_generated;
            total.prefill_tokens_saved += h.prefill_tokens_saved;
            total.queue_depth += h.queue_depth;
        }
        AdminReport { per_shard, total, ..AdminReport::default() }
    }

    /// Full cluster report: per-shard health over the wire plus the
    /// router's breaker states and migration counters.
    pub fn collect(router: &mut Router) -> Result<AdminReport, RouteError> {
        let mut rep = AdminReport::aggregate(router.health()?);
        rep.breakers = router.breaker_states();
        rep.migrations = router.migration_stats();
        Ok(rep)
    }
}

impl fmt::Display for AdminReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "{:>6} {:>9} {:>12} {:>10} {:>10} {:>9} {:>8} {:>12} {:>6} {:>9}",
            "shard",
            "sessions",
            "state bytes",
            "hits",
            "misses",
            "done",
            "tokens",
            "saved-toks",
            "queue",
            "breaker"
        )?;
        let row = |f: &mut fmt::Formatter<'_>, name: &str, h: &HealthReport, brk: &str| {
            writeln!(
                f,
                "{:>6} {:>9} {:>12} {:>10} {:>10} {:>9} {:>8} {:>12} {:>6} {:>9}",
                name,
                h.sessions_resident,
                h.session_bytes,
                h.session_hits,
                h.session_misses,
                h.requests_done,
                h.tokens_generated,
                h.prefill_tokens_saved,
                h.queue_depth,
                brk
            )
        };
        for (i, h) in self.per_shard.iter().enumerate() {
            let brk = match self.breakers.get(i) {
                Some(BreakerState::Closed) => "closed",
                Some(BreakerState::HalfOpen) => "half-open",
                Some(BreakerState::Open) => "open",
                None => "-",
            };
            row(f, &i.to_string(), h, brk)?;
        }
        row(f, "total", &self.total, "-")?;
        let m = self.migrations;
        writeln!(
            f,
            "migrations: {} attempted, {} committed, {} aborted; {} resurrections",
            m.attempts, m.commits, m.aborts, m.resurrections
        )
    }
}

/// N in-process shards (native recurrent engine, shared seed so every
/// shard carries identical weights) behind one router on loopback sockets.
pub struct Cluster {
    pub shards: Vec<ShardServer>,
    pub router: Router,
}

impl Cluster {
    /// Launch `n` native shards + a router.  Every shard gets `slots`
    /// engine slots and the same `seed` (identically-seeded shards are
    /// what make cross-shard migration bit-identical).  When
    /// `cfg.session_spill_dir` is set, each shard spills into its own
    /// `shard<i>` subdirectory so shards never clobber each other.  When
    /// `cfg.journal_dir` is set, the router opens (and replays) the
    /// write-ahead turn journal there — the cold-restart path.  When
    /// `cfg.auth_token` is set, every shard requires it and the router
    /// presents it.
    pub fn launch_native(
        n: usize,
        shape: &LmShape,
        slots: usize,
        seed: u64,
        cfg: &ServeConfig,
    ) -> Result<Cluster, RouteError> {
        Cluster::launch_native_with(n, shape, slots, seed, cfg, BreakerConfig::default(), None)
    }

    /// [`Cluster::launch_native`] with explicit breaker tuning and an
    /// optional fault plan threaded into the router (the chaos tests
    /// stage shard kills and protocol-point faults through the plan).
    #[allow(clippy::too_many_arguments)]
    pub fn launch_native_with(
        n: usize,
        shape: &LmShape,
        slots: usize,
        seed: u64,
        cfg: &ServeConfig,
        breaker_cfg: BreakerConfig,
        faults: Option<Arc<FaultPlan>>,
    ) -> Result<Cluster, RouteError> {
        let mut shards = Vec::with_capacity(n);
        for i in 0..n {
            let mut shard_cfg = cfg.clone();
            if let Some(dir) = &cfg.session_spill_dir {
                shard_cfg.session_spill_dir = Some(format!("{dir}/shard{i}"));
            }
            shards.push(ShardServer::spawn_native(shape, slots, seed, shard_cfg)?);
        }
        let addrs: Vec<_> = shards.iter().map(|s| s.addr()).collect();
        let mut router =
            Router::new_with_auth(&addrs, breaker_cfg, faults, cfg.auth_token.clone())?;
        if let Some(dir) = &cfg.journal_dir {
            let mut jcfg = JournalConfig::new(dir.as_str());
            jcfg.fsync = cfg.journal_fsync;
            let (journal, replay) = Journal::open(jcfg).map_err(|e| match e {
                JournalError::Io(io) => RouteError::Io(io),
                corrupt => RouteError::Protocol(corrupt.to_string()),
            })?;
            router.attach_journal(journal, replay);
        }
        Ok(Cluster { shards, router })
    }

    /// Aggregated health over the wire, including the router-side view.
    pub fn report(&mut self) -> Result<AdminReport, RouteError> {
        AdminReport::collect(&mut self.router)
    }

    /// Split the cluster into its shards and router — what the CLI does
    /// to hand the router to a [`super::front::FrontServer`] while
    /// keeping ownership of the shard servers for shutdown.
    pub fn into_parts(self) -> (Vec<ShardServer>, Router) {
        (self.shards, self.router)
    }

    /// Shut every shard down (in-flight work drains first).
    pub fn shutdown(self) {
        for s in self.shards {
            s.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_sums_and_renders() {
        let a = HealthReport {
            sessions_resident: 1,
            session_bytes: 100,
            session_hits: 2,
            session_misses: 1,
            in_flight: 0,
            requests_done: 3,
            tokens_generated: 12,
            prefill_tokens_saved: 40,
            queue_depth: 2,
        };
        let mut b = a.clone();
        b.sessions_resident = 4;
        let rep = AdminReport::aggregate(vec![a, b]);
        assert_eq!(rep.total.sessions_resident, 5);
        assert_eq!(rep.total.requests_done, 6);
        assert_eq!(rep.total.tokens_generated, 24);
        assert_eq!(rep.total.queue_depth, 4);
        let text = format!("{rep}");
        assert!(text.contains("total"), "{text}");
        assert!(text.contains("queue"), "{text}");
        assert!(text.contains("migrations:"), "{text}");
        assert!(text.lines().count() >= 5, "{text}");
    }

    /// Aggregation is exact field-by-field — every u64 is the sum of the
    /// inputs, nothing sampled or approximated — and the same holds for
    /// metric snapshots: merged histograms carry exactly the union of
    /// the per-shard samples.
    #[test]
    fn aggregation_is_exact_including_histogram_merge() {
        use crate::obs::{MetricValue, Snapshot};
        let mk = |k: u64| HealthReport {
            sessions_resident: k,
            session_bytes: 10 * k,
            session_hits: 100 * k,
            session_misses: k + 1,
            in_flight: k,
            requests_done: 7 * k,
            tokens_generated: 13 * k,
            prefill_tokens_saved: 17 * k,
            queue_depth: 3 * k,
        };
        let rep = AdminReport::aggregate(vec![mk(1), mk(2), mk(4)]);
        let want = mk(7); // sums are exact: 1 + 2 + 4, field by field
        assert_eq!(rep.total.sessions_resident, want.sessions_resident);
        assert_eq!(rep.total.session_bytes, want.session_bytes);
        assert_eq!(rep.total.session_hits, want.session_hits);
        assert_eq!(rep.total.session_misses, 2 + 3 + 5);
        assert_eq!(rep.total.in_flight, want.in_flight);
        assert_eq!(rep.total.requests_done, want.requests_done);
        assert_eq!(rep.total.tokens_generated, want.tokens_generated);
        assert_eq!(rep.total.prefill_tokens_saved, want.prefill_tokens_saved);
        assert_eq!(rep.total.queue_depth, want.queue_depth);
        // the metric-side analogue: two per-shard snapshots merge into
        // bucket-exact cluster histograms alongside summed counters
        let mut shard_a = Snapshot::default();
        shard_a.add_counter("lh_requests_done_total", 3);
        for v in [0.001, 0.01, 0.1] {
            shard_a.observe("lh_ttft_seconds", v);
        }
        let mut shard_b = Snapshot::default();
        shard_b.add_counter("lh_requests_done_total", 4);
        for v in [0.001, 1.0] {
            shard_b.observe("lh_ttft_seconds", v);
        }
        let mut cluster = Snapshot::default();
        assert!(cluster.merge(&shard_a).is_empty());
        assert!(cluster.merge(&shard_b).is_empty());
        assert_eq!(
            cluster.entries.get("lh_requests_done_total"),
            Some(&MetricValue::Counter(7))
        );
        match (
            cluster.entries.get("lh_ttft_seconds"),
            shard_a.entries.get("lh_ttft_seconds"),
            shard_b.entries.get("lh_ttft_seconds"),
        ) {
            (
                Some(MetricValue::Hist(merged)),
                Some(MetricValue::Hist(ha)),
                Some(MetricValue::Hist(hb)),
            ) => {
                assert_eq!(merged.count(), 5);
                for i in 0..crate::obs::BUCKETS {
                    assert_eq!(
                        merged.bucket_counts()[i],
                        ha.bucket_counts()[i] + hb.bucket_counts()[i],
                        "bucket {i} must be the exact sum"
                    );
                }
            }
            other => panic!("expected three histograms, got {other:?}"),
        }
    }

    #[test]
    fn cluster_launches_serves_and_reports() {
        let shape = LmShape::bench("nano").unwrap();
        let cfg = ServeConfig { max_batch: 2, linger_ms: 1, ..ServeConfig::default() };
        let mut cluster = Cluster::launch_native(2, &shape, 2, 11, &cfg).unwrap();
        let g = cluster.router.submit_in_session(1, vec![1, 2, 3], 3).unwrap();
        assert_eq!(g.len(), 3);
        let rep = cluster.report().unwrap();
        assert_eq!(rep.per_shard.len(), 2);
        assert_eq!(rep.total.requests_done, 1);
        assert_eq!(rep.total.sessions_resident, 1);
        // report() goes through collect(): the router-side view rides along
        assert_eq!(rep.breakers, vec![BreakerState::Closed, BreakerState::Closed]);
        assert_eq!(rep.migrations, MigrationStats::default());
        cluster.shutdown();
    }
}
