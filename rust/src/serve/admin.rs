//! Admin surface of the serve layer: aggregated health across shards and
//! a convenience launcher that runs N in-process shard servers plus a
//! router over loopback sockets (the CLI demo and the integration tests
//! both drive this).

use std::fmt;
use std::sync::Arc;

use super::circuit::BreakerConfig;
use super::faults::FaultPlan;
use super::router::{RouteError, Router};
use super::shard::ShardServer;
use super::wire::HealthReport;
use crate::config::ServeConfig;
use crate::engine::LmShape;

/// Per-shard health plus cluster totals.
#[derive(Clone, Debug, Default)]
pub struct AdminReport {
    pub per_shard: Vec<HealthReport>,
    pub total: HealthReport,
}

impl AdminReport {
    /// Sum the per-shard reports into cluster totals.
    pub fn aggregate(per_shard: Vec<HealthReport>) -> AdminReport {
        let mut total = HealthReport::default();
        for h in &per_shard {
            total.sessions_resident += h.sessions_resident;
            total.session_bytes += h.session_bytes;
            total.session_hits += h.session_hits;
            total.session_misses += h.session_misses;
            total.in_flight += h.in_flight;
            total.requests_done += h.requests_done;
            total.tokens_generated += h.tokens_generated;
            total.prefill_tokens_saved += h.prefill_tokens_saved;
        }
        AdminReport { per_shard, total }
    }
}

impl fmt::Display for AdminReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "{:>6} {:>9} {:>12} {:>10} {:>10} {:>9} {:>8} {:>12}",
            "shard", "sessions", "state bytes", "hits", "misses", "done", "tokens", "saved-toks"
        )?;
        let row = |f: &mut fmt::Formatter<'_>, name: &str, h: &HealthReport| {
            writeln!(
                f,
                "{:>6} {:>9} {:>12} {:>10} {:>10} {:>9} {:>8} {:>12}",
                name,
                h.sessions_resident,
                h.session_bytes,
                h.session_hits,
                h.session_misses,
                h.requests_done,
                h.tokens_generated,
                h.prefill_tokens_saved
            )
        };
        for (i, h) in self.per_shard.iter().enumerate() {
            row(f, &i.to_string(), h)?;
        }
        row(f, "total", &self.total)
    }
}

/// N in-process shards (native recurrent engine, shared seed so every
/// shard carries identical weights) behind one router on loopback sockets.
pub struct Cluster {
    pub shards: Vec<ShardServer>,
    pub router: Router,
}

impl Cluster {
    /// Launch `n` native shards + a router.  Every shard gets `slots`
    /// engine slots and the same `seed` (identically-seeded shards are
    /// what make cross-shard migration bit-identical).  When
    /// `cfg.session_spill_dir` is set, each shard spills into its own
    /// `shard<i>` subdirectory so shards never clobber each other.
    pub fn launch_native(
        n: usize,
        shape: &LmShape,
        slots: usize,
        seed: u64,
        cfg: &ServeConfig,
    ) -> Result<Cluster, RouteError> {
        Cluster::launch_native_with(n, shape, slots, seed, cfg, BreakerConfig::default(), None)
    }

    /// [`Cluster::launch_native`] with explicit breaker tuning and an
    /// optional fault plan threaded into the router (the chaos tests
    /// stage shard kills and protocol-point faults through the plan).
    #[allow(clippy::too_many_arguments)]
    pub fn launch_native_with(
        n: usize,
        shape: &LmShape,
        slots: usize,
        seed: u64,
        cfg: &ServeConfig,
        breaker_cfg: BreakerConfig,
        faults: Option<Arc<FaultPlan>>,
    ) -> Result<Cluster, RouteError> {
        let mut shards = Vec::with_capacity(n);
        for i in 0..n {
            let mut shard_cfg = cfg.clone();
            if let Some(dir) = &cfg.session_spill_dir {
                shard_cfg.session_spill_dir = Some(format!("{dir}/shard{i}"));
            }
            shards.push(ShardServer::spawn_native(shape, slots, seed, shard_cfg)?);
        }
        let addrs: Vec<_> = shards.iter().map(|s| s.addr()).collect();
        let router = Router::new_with(&addrs, breaker_cfg, faults)?;
        Ok(Cluster { shards, router })
    }

    /// Aggregated health over the wire.
    pub fn report(&mut self) -> Result<AdminReport, RouteError> {
        Ok(AdminReport::aggregate(self.router.health()?))
    }

    /// Shut every shard down (in-flight work drains first).
    pub fn shutdown(self) {
        for s in self.shards {
            s.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_sums_and_renders() {
        let a = HealthReport {
            sessions_resident: 1,
            session_bytes: 100,
            session_hits: 2,
            session_misses: 1,
            in_flight: 0,
            requests_done: 3,
            tokens_generated: 12,
            prefill_tokens_saved: 40,
        };
        let mut b = a.clone();
        b.sessions_resident = 4;
        let rep = AdminReport::aggregate(vec![a, b]);
        assert_eq!(rep.total.sessions_resident, 5);
        assert_eq!(rep.total.requests_done, 6);
        assert_eq!(rep.total.tokens_generated, 24);
        let text = format!("{rep}");
        assert!(text.contains("total"), "{text}");
        assert!(text.lines().count() >= 4, "{text}");
    }

    #[test]
    fn cluster_launches_serves_and_reports() {
        let shape = LmShape::bench("nano").unwrap();
        let cfg = ServeConfig { max_batch: 2, linger_ms: 1, ..ServeConfig::default() };
        let mut cluster = Cluster::launch_native(2, &shape, 2, 11, &cfg).unwrap();
        let g = cluster.router.submit_in_session(1, vec![1, 2, 3], 3).unwrap();
        assert_eq!(g.len(), 3);
        let rep = cluster.report().unwrap();
        assert_eq!(rep.per_shard.len(), 2);
        assert_eq!(rep.total.requests_done, 1);
        assert_eq!(rep.total.sessions_resident, 1);
        cluster.shutdown();
    }
}
