//! Minimal property-based testing harness (proptest is unavailable in the
//! offline image; see DESIGN.md §6).
//!
//! A property runs against many seeded random cases; on failure the seed is
//! reported so the case can be replayed deterministically:
//!
//! ```
//! use laughing_hyena::util::prop::check;
//! use laughing_hyena::util::Prng;
//! check("abs is non-negative", 64, |rng: &mut Prng| {
//!     let x = rng.normal();
//!     if x.abs() >= 0.0 { Ok(()) } else { Err(format!("abs({x}) < 0")) }
//! });
//! ```

use super::prng::Prng;

/// Run `prop` for `cases` seeded cases; panics with seed + message on the
/// first failure.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Prng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xC0FFEE ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Prng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert |a - b| <= atol + rtol*|b| element-wise, with a useful message.
pub fn assert_close(a: &[f64], b: &[f64], rtol: f64, atol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol {
            return Err(format!("index {i}: {x} vs {y} (tol {tol:.3e})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("uniform in range", 32, |rng| {
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn check_reports_failures() {
        check("always fails", 4, |_| Err("boom".into()));
    }

    #[test]
    fn assert_close_tolerances() {
        assert!(assert_close(&[1.0], &[1.0 + 1e-9], 1e-6, 0.0).is_ok());
        assert!(assert_close(&[1.0], &[2.0], 1e-6, 0.0).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-6, 0.0).is_err());
    }
}
