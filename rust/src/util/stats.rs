//! Summary statistics for benchmark harnesses and experiment drivers.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on the sorted copy; `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

/// l2 norm.
pub fn norm2(xs: &[f64]) -> f64 {
    xs.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Relative l2 error ||a - b|| / ||b|| (inf if b == 0 and a != b).
pub fn rel_err(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let num: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt();
    let den = norm2(b);
    if den == 0.0 {
        if num == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        num / den
    }
}

/// Max absolute difference.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.118).abs() < 1e-3);
    }

    #[test]
    fn percentiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn relative_error() {
        assert_eq!(rel_err(&[1.0, 0.0], &[1.0, 0.0]), 0.0);
        assert!((rel_err(&[1.1, 0.0], &[1.0, 0.0]) - 0.1).abs() < 1e-12);
        assert_eq!(rel_err(&[0.0], &[0.0]), 0.0);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
