//! Small self-contained utilities: PRNG, statistics, property-testing.
//!
//! The offline build image ships only the `xla` crate's dependency closure
//! (no `rand`, no `proptest`, no `criterion`), so these substrates are
//! implemented in-repo (see DESIGN.md §6 "Substitutions").

pub mod prng;
pub mod prop;
pub mod stats;

pub use prng::Prng;
