//! Small self-contained utilities: PRNG, statistics, property-testing, and
//! a persistent worker pool.
//!
//! The offline build image ships only the `xla` crate's dependency closure
//! (no `rand`, no `proptest`, no `criterion`, no `rayon`), so these
//! substrates are implemented in-repo (see DESIGN.md §6 "Substitutions").

pub mod bytes;
pub mod pool;
pub mod prng;
pub mod prop;
pub mod stats;

pub use pool::Pool;
pub use prng::Prng;
