//! Byte-level substrates shared across the crate: the bounded
//! little-endian reader — the one implementation of "parse untrusted
//! length-prefixed bytes without ever panicking", used by the session
//! blob decoder ([`crate::session::SessionState`]) and the serve-layer
//! frame decoder (`serve::wire`), each mapping [`ReadErr`] into its own
//! error type — plus the stable byte hashes ([`fnv1a64`], [`splitmix64`])
//! behind the router's consistent-hash ring and the shape fingerprint in
//! the migration handshake.  One implementation each, so a fix lands in
//! every user.

/// Why a read failed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadErr {
    /// The input ended (or a length prefix pointed) past the buffer.
    Truncated,
    /// A length-prefixed string was not valid UTF-8.
    Utf8,
}

/// Cursor over a byte slice; every read is bounds-checked (including
/// against `pos + n` overflow) and advances the cursor.
pub struct ByteReader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(bytes: &'a [u8]) -> ByteReader<'a> {
        ByteReader { bytes, pos: 0 }
    }

    /// Whether the cursor consumed the whole input.
    pub fn is_exhausted(&self) -> bool {
        self.pos == self.bytes.len()
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], ReadErr> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.bytes.len())
            .ok_or(ReadErr::Truncated)?;
        let out = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8, ReadErr> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16, ReadErr> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn u32(&mut self) -> Result<u32, ReadErr> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64, ReadErr> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn i32(&mut self) -> Result<i32, ReadErr> {
        Ok(self.u32()? as i32)
    }

    /// `u32 len + UTF-8` string.
    pub fn string(&mut self) -> Result<String, ReadErr> {
        let len = self.u32()? as usize;
        let b = self.take(len)?;
        String::from_utf8(b.to_vec()).map_err(|_| ReadErr::Utf8)
    }
}

/// FNV-1a over arbitrary bytes — stable across builds and processes
/// (ring placement and handshake fingerprints must not depend on the
/// per-process seeds `DefaultHasher` uses).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// splitmix64: a fast, well-mixed permutation of a u64 — used to hash
/// session ids onto the ring (small sequential ids must spread uniformly).
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Constant-time byte-slice equality for secret comparison (the serve
/// handshake's shared-secret token): the comparison touches every byte of
/// equal-length inputs regardless of where they first differ, so response
/// timing does not leak a prefix-match oracle.  Lengths are compared
/// first — length is not secret here (tokens are operator-chosen).
pub fn ct_eq(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut acc: u8 = 0;
    for (&x, &y) in a.iter().zip(b.iter()) {
        acc |= x ^ y;
    }
    acc == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reads_primitives_and_tracks_exhaustion() {
        let mut buf = Vec::new();
        buf.push(7u8);
        buf.extend_from_slice(&0xBEEFu16.to_le_bytes());
        buf.extend_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
        buf.extend_from_slice(&u64::MAX.to_le_bytes());
        buf.extend_from_slice(&(-5i32).to_le_bytes());
        buf.extend_from_slice(&2u32.to_le_bytes());
        buf.extend_from_slice(b"hi");
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.u8(), Ok(7));
        assert_eq!(r.u16(), Ok(0xBEEF));
        assert_eq!(r.u32(), Ok(0xDEAD_BEEF));
        assert_eq!(r.u64(), Ok(u64::MAX));
        assert_eq!(r.i32(), Ok(-5));
        assert_eq!(r.string(), Ok("hi".to_string()));
        assert!(r.is_exhausted());
        assert_eq!(r.u8(), Err(ReadErr::Truncated));
    }

    #[test]
    fn ct_eq_matches_plain_equality() {
        assert!(ct_eq(b"", b""));
        assert!(ct_eq(b"secret", b"secret"));
        assert!(!ct_eq(b"secret", b"secret "), "length mismatch");
        assert!(!ct_eq(b"secret", b"secreT"), "last byte differs");
        assert!(!ct_eq(b"Xecret", b"secret"), "first byte differs");
    }

    #[test]
    fn truncation_and_bad_utf8_are_typed_never_panics() {
        // length prefix pointing past the end
        let mut buf = Vec::new();
        buf.extend_from_slice(&100u32.to_le_bytes());
        buf.push(b'x');
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.string(), Err(ReadErr::Truncated));
        // overflowing length prefix must not wrap
        let max = u32::MAX.to_le_bytes();
        let mut r = ByteReader::new(&max);
        assert_eq!(r.u32(), Ok(u32::MAX));
        assert_eq!(r.take(usize::MAX), Err(ReadErr::Truncated));
        // invalid utf-8 in a well-framed string
        let mut buf = Vec::new();
        buf.extend_from_slice(&2u32.to_le_bytes());
        buf.extend_from_slice(&[0xFF, 0xFE]);
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.string(), Err(ReadErr::Utf8));
    }
}
