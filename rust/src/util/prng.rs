//! xoshiro256++ PRNG with gaussian / uniform / categorical helpers.
//!
//! Deterministic and seedable so every experiment in EXPERIMENTS.md is
//! exactly reproducible from its recorded seed.

/// xoshiro256++ (Blackman & Vigna). Not cryptographic; plenty for
/// simulation workloads.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Generator for the `stream`-th derived substream of `seed`:
    /// deterministic per (seed, stream) and decorrelated across streams, so
    /// parallel construction over substreams matches sequential derivation
    /// at any thread count (used by the pooled engine/backbone setup).
    pub fn derived(seed: u64, stream: u64) -> Self {
        Prng::new(seed ^ stream.wrapping_add(1).wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Seed via splitmix64 so nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Prng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = (0..8).map({ let mut p = Prng::new(7); move |_| p.next_u64() }).collect();
        let b: Vec<u64> = (0..8).map({ let mut p = Prng::new(7); move |_| p.next_u64() }).collect();
        assert_eq!(a, b);
        let c: Vec<u64> = (0..8).map({ let mut p = Prng::new(8); move |_| p.next_u64() }).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut p = Prng::new(1);
        let n = 20_000;
        let mut mean = 0.0;
        for _ in 0..n {
            let x = p.uniform();
            assert!((0.0..1.0).contains(&x));
            mean += x;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut p = Prng::new(3);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[p.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.4, "ratio {ratio}");
    }

    #[test]
    fn below_bounds() {
        let mut p = Prng::new(4);
        for _ in 0..1000 {
            assert!(p.below(7) < 7);
        }
    }
}
