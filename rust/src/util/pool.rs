//! Persistent worker pool for embarrassingly parallel fan-out (rayon is
//! not in the offline crate set; see DESIGN.md §6 "Substitutions").
//!
//! The decode hot path fans out *every token step*: at one token of work
//! per row per call, the spawn cost of the old scoped-thread design
//! (`std::thread::scope` + N spawns per [`Pool::map`]) was a visible
//! fraction of the fused kernel.  Workers are therefore **long-lived**:
//! spawned once, parked on per-lane condvars, and handed each `map` call
//! through an epoch-stamped job cell.  The calling thread participates as
//! lane 0, so a `map` costs two mutex round-trips and the targeted
//! condvar wakeups — no thread creation anywhere on the steady-state
//! path.
//!
//! * **Handoff.**  `map` type-erases a per-lane dispatch closure into the
//!   shared cell, bumps the epoch, and wakes the workers; every worker
//!   runs each epoch exactly once and decrements a pending counter, on
//!   which the caller blocks.  Borrowed inputs (`&self`, `&mut` state
//!   rows) still flow into workers without `Arc` or cloning: the caller
//!   cannot return — not even by unwinding — before every worker is done
//!   with the erased borrow.
//! * **Determinism.**  Items are striped round-robin over the lanes and
//!   results are written back by original index, so `map` returns
//!   bit-identical output in the original order regardless of lane count
//!   (tested against the sequential path in `distill::pipeline`).
//! * **Panics** in any worker are caught, carried across the handoff, and
//!   re-raised on the calling thread with the original payload; the pool
//!   stays usable afterwards.
//! * **Lifecycle.**  [`Pool::auto`] and [`Pool::new`] are width-capped
//!   handles onto one process-global pool sized from
//!   `available_parallelism` (workers spawn on first use and live for the
//!   process).  [`Pool::dedicated`] builds a private pool whose `Drop`
//!   shuts the workers down and joins them — nothing leaks.
//! * **Re-entrancy & contention.**  A `map` issued from inside a pool
//!   worker (or from a caller already inside `map`) runs sequentially
//!   inline, and a `map` that finds the pool busy with another thread's
//!   epoch retries briefly then does the same instead of parking
//!   unboundedly — so no lock-ordering deadlock can form through user
//!   closures, and callers never convoy behind each other.  Fan-outs
//!   smaller than the worker set wake and wait for only the lanes they
//!   use; idle cores stay parked — and fan-outs of at most
//!   [`INLINE_CUTOVER`] items skip the handoff entirely and run inline on
//!   the caller (a 1-2 row decode step is cheaper than the condvar
//!   round-trip it would buy).
//!
//! ```
//! use laughing_hyena::util::pool::Pool;
//!
//! let pool = Pool::new(4);
//! let squares = pool.map((0..8u64).collect::<Vec<_>>(), |x| x * x);
//! assert_eq!(squares, vec![0, 1, 4, 9, 16, 25, 36, 49]);
//!
//! // Pool::auto() fans out over all available cores.
//! assert!(Pool::auto().threads() >= 1);
//! ```

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread::JoinHandle;

/// Poison-tolerant lock: a panic propagating out of `map` unwinds while
/// pool mutexes are held (that is by design — the panic is the caller's),
/// and the pool must stay fully usable afterwards.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Poison-tolerant condvar wait (see [`lock`]).
fn wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(|e| e.into_inner())
}

thread_local! {
    /// True on pool worker threads and on any thread currently inside
    /// [`Pool::map`]; nested `map` calls see it and run inline.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// Fan-outs of at most this many items run sequentially inline on the
/// caller instead of paying the epoch handoff (condvar wakeup + two mutex
/// round-trips).  At one token of work per row, a 1-2 row decode step
/// finishes faster than the handoff costs; results are identical either
/// way (the sequential path is the pool's own fallback), so this is a
/// pure constant-factor choice.  Picked conservatively — the decode
/// bench's per-batch `pool_speedup` column is the evidence for moving it.
pub const INLINE_CUTOVER: usize = 2;

/// Handle onto a worker pool, cheap to clone.  [`Pool::auto`] /
/// [`Pool::new`] share the process-global workers; [`Pool::dedicated`]
/// owns private ones.
#[derive(Clone)]
pub struct Pool {
    /// Max fan-out this handle uses (1 = sequential).
    width: usize,
    core: Arc<Core>,
}

/// Lifetime-erased `&dyn Fn(lane)` published for one epoch.  Only valid
/// while the publishing [`Core::run_epoch`] is on the stack: the caller
/// waits for every worker (even on unwind) before the referent dies.
#[derive(Clone, Copy)]
struct TaskPtr(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is `Sync` (shared by all workers) and `run_epoch`
// keeps it alive for as long as any worker can touch it.
unsafe impl Send for TaskPtr {}

/// The epoch handoff cell, guarded by `Shared::slot`.
struct Slot {
    /// Monotonic job id; every worker observes each epoch at most once.
    epoch: u64,
    job: Option<TaskPtr>,
    /// Lanes participating in the current epoch (lane 0 is the caller;
    /// workers with `lane >= lanes` skip the epoch without being waited
    /// on, so small fan-outs never pay for idle cores).
    lanes: usize,
    /// Participating background workers that have not finished the
    /// current epoch (`lanes - 1` at publish).
    pending: usize,
    /// Panic payloads caught from workers during the current epoch.
    panics: Vec<Box<dyn std::any::Any + Send>>,
    shutdown: bool,
}

struct Shared {
    slot: Mutex<Slot>,
    /// Per-worker parking spots (index `lane - 1`), all associated with
    /// the `slot` mutex.  Publishing an epoch notifies exactly the
    /// participating lanes, so a 2-lane decode step on a 64-core machine
    /// wakes one worker instead of storming all 63.
    work: Vec<Condvar>,
    /// The caller parks here waiting for `pending == 0`.
    done: Condvar,
    /// Live background workers (observability + the shutdown test).
    alive: AtomicUsize,
}

/// The long-lived part of a pool: parked workers plus the handoff cell.
struct Core {
    shared: Arc<Shared>,
    /// Serializes `map` calls: one epoch in flight at a time.
    call: Mutex<()>,
    /// Background workers actually running (the caller is lane 0 on top).
    bg: usize,
    /// Joined on drop (empty for the never-dropped global core only after
    /// shutdown).
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Core {
    /// Spawn `bg` parked workers.  If the OS refuses a spawn the pool
    /// simply runs with fewer lanes — never panics, never loses work.
    fn start(bg: usize) -> Core {
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot {
                epoch: 0,
                job: None,
                lanes: 0,
                pending: 0,
                panics: Vec::new(),
                shutdown: false,
            }),
            work: (0..bg).map(|_| Condvar::new()).collect(),
            done: Condvar::new(),
            alive: AtomicUsize::new(0),
        });
        let mut handles = Vec::with_capacity(bg);
        for lane in 1..=bg {
            let sh = Arc::clone(&shared);
            let builder = std::thread::Builder::new().name(format!("lh-pool-{lane}"));
            match builder.spawn(move || worker_loop(&sh, lane)) {
                Ok(h) => handles.push(h),
                Err(_) => break,
            }
        }
        let bg = handles.len();
        Core { shared, call: Mutex::new(()), bg, handles: Mutex::new(handles) }
    }

    /// Publish `task` to workers `1..lanes`, run lane 0 on the calling
    /// thread, wait for every participating worker, and re-raise the
    /// first worker panic.  Workers beyond `lanes` observe the epoch and
    /// skip it off the critical path — a 2-row decode step on a 64-core
    /// machine waits for exactly one worker, not 63.
    ///
    /// The caller must hold `self.call` (one epoch in flight at a time);
    /// `2 <= lanes <= self.bg + 1`.
    fn run_epoch(&self, lanes: usize, task: &(dyn Fn(usize) + Sync)) {
        {
            let mut slot = lock(&self.shared.slot);
            debug_assert!(slot.job.is_none(), "epochs never overlap");
            debug_assert!((2..=self.bg + 1).contains(&lanes));
            slot.epoch += 1;
            slot.job = Some(TaskPtr(task as *const (dyn Fn(usize) + Sync)));
            slot.lanes = lanes;
            slot.pending = lanes - 1;
            slot.panics.clear();
            // wake exactly the participating workers; the rest stay parked
            for cv in &self.shared.work[..lanes - 1] {
                cv.notify_one();
            }
        }
        // Wait-for-workers guard: runs on normal exit AND on unwind from
        // lane 0, so the erased borrow in the cell can never dangle.
        struct EpochGuard<'a>(&'a Shared);
        impl Drop for EpochGuard<'_> {
            fn drop(&mut self) {
                let mut slot = lock(&self.0.slot);
                while slot.pending > 0 {
                    slot = wait(&self.0.done, slot);
                }
                slot.job = None;
            }
        }
        {
            let _guard = EpochGuard(&self.shared);
            task(0); // the caller is lane 0
        }
        let payload = {
            let mut slot = lock(&self.shared.slot);
            if slot.panics.is_empty() {
                None
            } else {
                Some(slot.panics.remove(0))
            }
        };
        if let Some(p) = payload {
            resume_unwind(p);
        }
    }
}

impl Drop for Core {
    fn drop(&mut self) {
        {
            let mut slot = lock(&self.shared.slot);
            slot.shutdown = true;
            for cv in &self.shared.work {
                cv.notify_one();
            }
        }
        for h in lock(&self.handles).drain(..) {
            let _ = h.join();
        }
    }
}

/// Worker body: park on the condvar, run each published epoch exactly
/// once, decrement `pending`, repeat until shutdown.
fn worker_loop(shared: &Shared, lane: usize) {
    IN_POOL.with(|f| f.set(true));
    shared.alive.fetch_add(1, Ordering::SeqCst);
    struct AliveGuard<'a>(&'a AtomicUsize);
    impl Drop for AliveGuard<'_> {
        fn drop(&mut self) {
            self.0.fetch_sub(1, Ordering::SeqCst);
        }
    }
    let _alive = AliveGuard(&shared.alive);
    let mut seen = 0u64;
    loop {
        let task = {
            let mut slot = lock(&shared.slot);
            loop {
                if slot.shutdown {
                    return;
                }
                if slot.epoch != seen {
                    if let Some(t) = slot.job {
                        seen = slot.epoch;
                        if lane < slot.lanes {
                            break t;
                        }
                        // surplus worker this epoch: not counted in
                        // `pending`, so skipping is free for the caller
                    }
                }
                slot = wait(&shared.work[lane - 1], slot);
            }
        };
        // SAFETY: the publishing `run_epoch` keeps the pointee alive until
        // `pending` (decremented below) reaches zero.
        let result = catch_unwind(AssertUnwindSafe(|| unsafe { (*task.0)(lane) }));
        let mut slot = lock(&shared.slot);
        if let Err(p) = result {
            slot.panics.push(p);
        }
        slot.pending -= 1;
        if slot.pending == 0 {
            shared.done.notify_one();
        }
    }
}

/// The process-global core, sized from `available_parallelism` and spawned
/// on first use; it lives (parked) for the rest of the process.
fn global_core() -> Arc<Core> {
    static GLOBAL: OnceLock<Arc<Core>> = OnceLock::new();
    GLOBAL
        .get_or_init(|| {
            let lanes = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            Arc::new(Core::start(lanes.saturating_sub(1)))
        })
        .clone()
}

/// Raw output cursor handed to the lanes; each original index is written
/// by exactly one lane, so the writes are disjoint.
struct OutPtr<R>(*mut Option<R>);
impl<R> Clone for OutPtr<R> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<R> Copy for OutPtr<R> {}
// SAFETY: lanes write disjoint indices; the caller outlives the epoch.
unsafe impl<R: Send> Send for OutPtr<R> {}
unsafe impl<R: Send> Sync for OutPtr<R> {}

/// One lane's input bucket; only that lane touches it during an epoch.
struct LaneCell<T>(std::cell::UnsafeCell<Vec<(usize, T)>>);
// SAFETY: bucket `lane` is accessed only by lane `lane` (see dispatch
// closure in `Pool::map`), so there is never a concurrent access.
unsafe impl<T: Send> Sync for LaneCell<T> {}

impl Pool {
    /// Handle with a fixed max fan-out (clamped to at least 1) onto the
    /// shared process-global workers.  `Pool::new(1)` is the guaranteed
    /// sequential path.
    pub fn new(width: usize) -> Pool {
        Pool { width: width.max(1), core: global_core() }
    }

    /// Full-width handle onto the process-global pool (one lane per
    /// available core).
    pub fn auto() -> Pool {
        let core = global_core();
        Pool { width: core.bg + 1, core }
    }

    /// A private pool with its own `width - 1` background workers (the
    /// caller is the remaining lane).  Dropping it shuts the workers down
    /// and joins them; use this for isolation (tests, one-off tools) —
    /// the steady-state paths share the global pool via [`Pool::auto`].
    pub fn dedicated(width: usize) -> Pool {
        let width = width.max(1);
        Pool { width, core: Arc::new(Core::start(width - 1)) }
    }

    /// Max lanes a `map` on this handle fans out over (1 = sequential).
    pub fn threads(&self) -> usize {
        self.width.min(self.core.bg + 1)
    }

    /// Apply `f` to every item, in parallel, returning results in the
    /// original item order.
    ///
    /// Items are consumed by value so per-item `&mut` state bundles can be
    /// distributed to workers.  With one lane (or at most
    /// [`INLINE_CUTOVER`] items, or when called from inside the pool) this
    /// degenerates to a plain sequential map on the calling thread — same
    /// results, same order, no handoff cost.
    ///
    /// Panics if a worker panics (the original payload is re-raised).
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = items.len();
        let lanes = self.threads().min(n);
        if lanes <= 1 || n <= INLINE_CUTOVER || IN_POOL.with(|g| g.get()) {
            return items.into_iter().map(f).collect();
        }
        // One epoch in flight per core.  If another thread is mid-map on
        // this pool, retry briefly (epochs are short — a decode step's
        // lock hold is microseconds) and then run this call sequentially
        // inline rather than parking unboundedly: the results are
        // identical either way, and because no caller ever blocks
        // indefinitely on the handoff, no lock-ordering deadlock can form
        // through user closures (e.g. a lane-0 closure joining a helper
        // thread that itself maps) — the worst case is bounded yields
        // followed by inline execution.
        let mut spins = 0u32;
        let _call = loop {
            match self.core.call.try_lock() {
                Ok(g) => break g,
                Err(std::sync::TryLockError::Poisoned(e)) => break e.into_inner(),
                Err(std::sync::TryLockError::WouldBlock) if spins < 128 => {
                    spins += 1;
                    std::thread::yield_now();
                }
                Err(std::sync::TryLockError::WouldBlock) => {
                    return items.into_iter().map(f).collect();
                }
            }
        };
        // stripe round-robin, remembering each item's original index (no
        // worker can see the buckets until the epoch below, so filling
        // them is ordinary exclusive access)
        let mut buckets: Vec<LaneCell<T>> =
            (0..lanes).map(|_| LaneCell(std::cell::UnsafeCell::new(Vec::new()))).collect();
        for (i, item) in items.into_iter().enumerate() {
            buckets[i % lanes].0.get_mut().push((i, item));
        }
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let out_ptr = OutPtr(out.as_mut_ptr());
        let buckets = &buckets;
        let f = &f;
        let dispatch = move |lane: usize| {
            if lane >= lanes {
                return; // surplus worker this epoch
            }
            // SAFETY: each bucket is taken by exactly one lane, once.
            let bucket = unsafe { std::mem::take(&mut *buckets[lane].0.get()) };
            for (i, item) in bucket {
                let r = f(item);
                // SAFETY: index `i` belongs to exactly one lane, and `out`
                // outlives the epoch (run_epoch waits for all lanes).
                unsafe { *out_ptr.0.add(i) = Some(r) };
            }
        };
        {
            // nested maps from lane 0's user closure run inline
            struct ReentryGuard;
            impl Drop for ReentryGuard {
                fn drop(&mut self) {
                    IN_POOL.with(|g| g.set(false));
                }
            }
            IN_POOL.with(|g| g.set(true));
            let _reentry = ReentryGuard;
            self.core.run_epoch(lanes, &dispatch);
        }
        out.into_iter()
            .map(|r| r.expect("every index produces exactly one result"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_at_any_thread_count() {
        let items: Vec<usize> = (0..37).collect();
        let want: Vec<usize> = items.iter().map(|x| x * 3 + 1).collect();
        for threads in [1usize, 2, 4, 9, 64] {
            let got = Pool::new(threads).map(items.clone(), |x| x * 3 + 1);
            assert_eq!(got, want, "threads={threads}");
            let ded = Pool::dedicated(threads).map(items.clone(), |x| x * 3 + 1);
            assert_eq!(ded, want, "dedicated threads={threads}");
        }
    }

    #[test]
    fn handles_empty_and_single_item() {
        let pool = Pool::new(4);
        let empty: Vec<u32> = vec![];
        assert_eq!(pool.map(empty, |x| x + 1), Vec::<u32>::new());
        assert_eq!(pool.map(vec![41u32], |x| x + 1), vec![42]);
        let ded = Pool::dedicated(3);
        let empty: Vec<u32> = vec![];
        assert_eq!(ded.map(empty, |x| x + 1), Vec::<u32>::new());
        assert_eq!(ded.map(vec![41u32], |x| x + 1), vec![42]);
    }

    #[test]
    fn workers_receive_mutable_bundles() {
        // the engine-prefill shape: each item owns &mut into disjoint rows
        let mut rows = vec![vec![0.0f64; 8]; 5];
        let jobs: Vec<(usize, &mut Vec<f64>)> = rows.iter_mut().enumerate().collect();
        let sums = Pool::new(3).map(jobs, |(i, row)| {
            for (t, x) in row.iter_mut().enumerate() {
                *x = (i * 10 + t) as f64;
            }
            row.iter().sum::<f64>()
        });
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row[0], (i * 10) as f64);
            assert!((sums[i] - row.iter().sum::<f64>()).abs() < 1e-12);
        }
    }

    #[test]
    fn pool_is_deterministic_across_thread_counts() {
        // same seeded work, different parallelism -> bit-identical floats
        let items: Vec<u64> = (0..16).collect();
        let work = |seed: u64| {
            let mut rng = crate::util::Prng::new(seed);
            (0..100).map(|_| rng.normal()).sum::<f64>()
        };
        let seq = Pool::new(1).map(items.clone(), work);
        let par = Pool::new(8).map(items.clone(), work);
        let ded = Pool::dedicated(5).map(items, work);
        for ((a, b), c) in seq.iter().zip(&par).zip(&ded) {
            assert_eq!(a.to_bits(), b.to_bits());
            assert_eq!(a.to_bits(), c.to_bits());
        }
    }

    #[test]
    fn auto_pool_has_at_least_one_thread() {
        assert!(Pool::auto().threads() >= 1);
    }

    #[test]
    fn tiny_maps_run_inline_without_touching_the_handoff() {
        // white box: a map of <= INLINE_CUTOVER items must not publish an
        // epoch (no condvar round-trip), while a bigger one must
        let pool = Pool::dedicated(4);
        assert_eq!(pool.map(vec![10u64], |x| x + 1), vec![11]);
        assert_eq!(pool.map(vec![10u64, 20], |x| x + 1), vec![11, 21]);
        assert_eq!(
            lock(&pool.core.shared.slot).epoch,
            0,
            "tiny fan-outs must skip the epoch handoff"
        );
        let n = INLINE_CUTOVER + 1;
        let got = pool.map((0..n as u64).collect::<Vec<_>>(), |x| x + 1);
        assert_eq!(got, (1..=n as u64).collect::<Vec<_>>());
        if pool.core.bg > 0 {
            assert_eq!(
                lock(&pool.core.shared.slot).epoch,
                1,
                "a fan-out past the cutover takes the handoff path"
            );
        }
    }

    #[test]
    fn inline_cutover_results_match_the_pooled_path_bit_for_bit() {
        // the decode hot path's correctness contract: 1-2 row steps (now
        // inline) and wider steps (pooled) must agree exactly
        let work = |seed: u64| {
            let mut rng = crate::util::Prng::new(seed);
            (0..50).map(|_| rng.normal()).sum::<f64>()
        };
        let wide = Pool::dedicated(4);
        for n in 1..=INLINE_CUTOVER + 2 {
            let items: Vec<u64> = (0..n as u64).collect();
            let seq: Vec<f64> = items.iter().map(|&x| work(x)).collect();
            let got = wide.map(items, work);
            for (a, b) in seq.iter().zip(&got) {
                assert_eq!(a.to_bits(), b.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn map_reuses_the_same_workers_across_calls() {
        // persistent lifecycle: repeated maps must not grow the worker set
        let pool = Pool::dedicated(4);
        let mut counts = Vec::new();
        for _ in 0..5 {
            let _ = pool.map((0..64u64).collect::<Vec<_>>(), |x| x.wrapping_mul(3));
            counts.push(pool.core.shared.alive.load(Ordering::SeqCst));
        }
        for c in counts {
            assert_eq!(c, pool.core.bg, "worker set must stay fixed");
        }
    }

    #[test]
    fn drop_joins_all_workers() {
        let pool = Pool::dedicated(4);
        // `bg` rather than a literal: Core::start tolerates refused spawns
        let bg = pool.core.bg;
        let alive = Arc::clone(&pool.core.shared);
        // a completed map proves every participating worker has started
        let got = pool.map((0..32u64).collect::<Vec<_>>(), |x| x + 1);
        assert_eq!(got[31], 32);
        assert_eq!(alive.alive.load(Ordering::SeqCst), bg);
        drop(pool);
        assert_eq!(
            alive.alive.load(Ordering::SeqCst),
            0,
            "drop must join every worker thread"
        );
    }

    #[test]
    fn worker_panic_propagates_with_payload_and_pool_survives() {
        let pool = Pool::dedicated(3);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            // item 1 lands on lane 1 — a background worker whenever one
            // exists (round-robin striping); with every spawn refused the
            // map runs inline and the panic still propagates as required
            pool.map((0..16u64).collect::<Vec<_>>(), |x| {
                if x == 1 {
                    panic!("boom at {x}");
                }
                x
            })
        }));
        let payload = caught.expect_err("worker panic must propagate");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("boom at 1"), "payload carried verbatim: {msg}");
        // the pool is still fully functional afterwards
        assert_eq!(pool.map(vec![1u64, 2, 3], |x| x * 2), vec![2, 4, 6]);
    }

    #[test]
    fn caller_lane_panic_still_joins_the_epoch() {
        // item 0 is lane 0 (the caller): its unwind must wait for the
        // workers, then the pool must remain usable
        let pool = Pool::dedicated(4);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.map((0..16u64).collect::<Vec<_>>(), |x| {
                if x == 0 {
                    panic!("lane zero");
                }
                x
            })
        }));
        assert!(caught.is_err());
        assert_eq!(pool.map(vec![5u64], |x| x + 1), vec![6]);
    }

    #[test]
    fn nested_map_runs_inline_without_deadlock() {
        // a map issued from inside a map (from lane 0 or a worker thread)
        // must fall back to the sequential path instead of deadlocking
        let pool = Pool::dedicated(4);
        let outer = pool.map(vec![10u64, 20, 30], |x| {
            Pool::auto()
                .map(vec![1u64, 2, 3], move |y| x + y)
                .iter()
                .sum::<u64>()
        });
        assert_eq!(outer, vec![36, 66, 96]);
    }

    #[test]
    fn narrow_maps_skip_surplus_workers_and_leave_them_usable() {
        // a width-capped handle on a wider core only waits for the lanes
        // it uses; surplus workers observe the epoch, skip it, and stay
        // available for the next full-width call (interleaved to exercise
        // the seen-epoch bookkeeping of skipped epochs)
        let wide = Pool::dedicated(4);
        let narrow = Pool { width: 2, core: Arc::clone(&wide.core) };
        assert_eq!(narrow.threads(), 2usize.min(wide.core.bg + 1));
        let want: Vec<usize> = (0..23).map(|x| x * 7).collect();
        for _ in 0..3 {
            assert_eq!(narrow.map((0..23).collect::<Vec<_>>(), |x| x * 7), want);
            assert_eq!(wide.map((0..23).collect::<Vec<_>>(), |x| x * 7), want);
        }
    }

    #[test]
    fn concurrent_maps_from_many_threads_stay_correct() {
        // the global pool takes calls from any thread; whoever finds it
        // busy runs inline (try_lock fallback), and every caller gets its
        // own correct results either way
        let results: Vec<Vec<u64>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4u64)
                .map(|t| {
                    s.spawn(move || {
                        Pool::auto().map((0..20u64).collect::<Vec<_>>(), move |x| x * 10 + t)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (t, r) in results.iter().enumerate() {
            let want: Vec<u64> = (0..20u64).map(|x| x * 10 + t as u64).collect();
            assert_eq!(r, &want);
        }
    }
}
