//! Scoped thread pool for embarrassingly parallel fan-out (rayon is not in
//! the offline crate set; see DESIGN.md §6 "Substitutions").
//!
//! The distillery hot path — one independent modal fit per filter of a
//! multi-head filter bank — and the per-row engine prefill are pure
//! fan-out: no shared mutable state, results keyed by index. [`Pool::map`]
//! covers exactly that shape with `std::thread::scope`, so borrowed inputs
//! (`&self`, `&mut` state rows) flow into workers without `Arc` or cloning.
//!
//! Determinism: items are striped round-robin over workers and results are
//! written back by original index, so `map` returns bit-identical output in
//! the original order regardless of thread count (tested against the
//! sequential path in `distill::pipeline`).
//!
//! ```
//! use laughing_hyena::util::pool::Pool;
//!
//! let pool = Pool::new(4);
//! let squares = pool.map((0..8u64).collect::<Vec<_>>(), |x| x * x);
//! assert_eq!(squares, vec![0, 1, 4, 9, 16, 25, 36, 49]);
//!
//! // Pool::auto() sizes itself from the available cores.
//! assert!(Pool::auto().threads() >= 1);
//! ```

/// A lightweight scoped thread pool: threads are spawned per [`Pool::map`]
/// call inside a `std::thread::scope`, so there are no persistent workers,
/// no channels, and borrowed data can cross into the workers safely.
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// Pool with a fixed worker count (clamped to at least 1).
    pub fn new(threads: usize) -> Pool {
        Pool { threads: threads.max(1) }
    }

    /// Pool sized from `std::thread::available_parallelism` (1 if unknown).
    pub fn auto() -> Pool {
        Pool::new(
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
        )
    }

    /// Worker count this pool fans out over.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Apply `f` to every item, in parallel, returning results in the
    /// original item order.
    ///
    /// Items are consumed by value so per-item `&mut` state bundles can be
    /// distributed to workers. With one worker (or zero/one items) this
    /// degenerates to a plain sequential map on the calling thread — same
    /// results, same order, no spawn cost.
    ///
    /// Panics if a worker panics (the panic message is propagated).
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = items.len();
        let workers = self.threads.min(n);
        if workers <= 1 {
            return items.into_iter().map(f).collect();
        }
        // stripe round-robin, remembering each item's original index
        let mut buckets: Vec<Vec<(usize, T)>> = (0..workers).map(|_| Vec::new()).collect();
        for (i, item) in items.into_iter().enumerate() {
            buckets[i % workers].push((i, item));
        }
        let f = &f;
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        std::thread::scope(|scope| {
            let handles: Vec<_> = buckets
                .into_iter()
                .map(|bucket| {
                    scope.spawn(move || {
                        bucket
                            .into_iter()
                            .map(|(i, item)| (i, f(item)))
                            .collect::<Vec<(usize, R)>>()
                    })
                })
                .collect();
            for handle in handles {
                for (i, r) in handle.join().expect("pool worker panicked") {
                    out[i] = Some(r);
                }
            }
        });
        out.into_iter()
            .map(|r| r.expect("every index produces a result"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_at_any_thread_count() {
        let items: Vec<usize> = (0..37).collect();
        let want: Vec<usize> = items.iter().map(|x| x * 3 + 1).collect();
        for threads in [1usize, 2, 4, 9, 64] {
            let got = Pool::new(threads).map(items.clone(), |x| x * 3 + 1);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn handles_empty_and_single_item() {
        let pool = Pool::new(4);
        let empty: Vec<u32> = vec![];
        assert_eq!(pool.map(empty, |x| x + 1), Vec::<u32>::new());
        assert_eq!(pool.map(vec![41u32], |x| x + 1), vec![42]);
    }

    #[test]
    fn workers_receive_mutable_bundles() {
        // the engine-prefill shape: each item owns &mut into disjoint rows
        let mut rows = vec![vec![0.0f64; 8]; 5];
        let jobs: Vec<(usize, &mut Vec<f64>)> = rows.iter_mut().enumerate().collect();
        let sums = Pool::new(3).map(jobs, |(i, row)| {
            for (t, x) in row.iter_mut().enumerate() {
                *x = (i * 10 + t) as f64;
            }
            row.iter().sum::<f64>()
        });
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row[0], (i * 10) as f64);
            assert!((sums[i] - row.iter().sum::<f64>()).abs() < 1e-12);
        }
    }

    #[test]
    fn pool_is_deterministic_across_thread_counts() {
        // same seeded work, different parallelism -> bit-identical floats
        let items: Vec<u64> = (0..16).collect();
        let work = |seed: u64| {
            let mut rng = crate::util::Prng::new(seed);
            (0..100).map(|_| rng.normal()).sum::<f64>()
        };
        let seq = Pool::new(1).map(items.clone(), work);
        let par = Pool::new(8).map(items, work);
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn auto_pool_has_at_least_one_thread() {
        assert!(Pool::auto().threads() >= 1);
    }
}
