//! Hankel-operator analysis (paper §3.3): minimal distillation orders.
//!
//! Theorem 3.1 (Ho-Kalman): the McMillan degree of a filter equals the rank
//! of its infinite Hankel matrix `S = (h_{i+j-1})`.  Theorem 3.2 (AAK): the
//! best achievable order-d approximation error in Hankel norm is exactly
//! the (d+1)-th Hankel singular value.  Inspecting the decay of the
//! spectrum of the truncated `S_L` therefore *predicts* the distillation
//! order before any optimization runs — this module computes that.

use crate::linalg::eig_sym::{eig_sym, SymEig};
use crate::linalg::Mat;

/// Build the n x n principal Hankel sub-matrix from filter taps.
///
/// `taps[tau]` holds h_{tau+1} (the paper's Markov parameters; the h_0
/// passthrough never enters the Hankel operator). Entries beyond the
/// provided taps are zero (truncated filter, App. A.7).
pub fn hankel_matrix(taps: &[f64], n: usize) -> Mat {
    Mat::from_fn(n, n, |i, j| taps.get(i + j).copied().unwrap_or(0.0))
}

/// Hankel singular values of a filter (descending).
///
/// Uses the symmetry of S_L: sigma = |eigenvalues|. `n` defaults to the
/// full tap count when None.
pub fn hankel_singular_values(taps: &[f64], n: Option<usize>) -> Vec<f64> {
    let n = n.unwrap_or(taps.len());
    let s = hankel_matrix(taps, n);
    eig_sym(&s).values.into_iter().map(f64::abs).collect()
}

/// Full symmetric eigendecomposition of the Hankel matrix — Kung's balanced
/// truncation (paper App. E.3.2) needs the eigenvectors.
pub fn hankel_eig(taps: &[f64], n: usize) -> SymEig {
    eig_sym(&hankel_matrix(taps, n))
}

/// Suggested distillation order: smallest d such that sigma_{d+1} falls
/// below `tol * sigma_1` (the paper's "rule of thumb": d large enough for
/// sigma_{d+1} to be small). Returns at least 1 and at most n.
pub fn suggest_order(sigmas: &[f64], tol: f64) -> usize {
    if sigmas.is_empty() || sigmas[0] == 0.0 {
        return 1;
    }
    let s0 = sigmas[0];
    for (i, &s) in sigmas.iter().enumerate().skip(1) {
        if s < tol * s0 {
            return i.max(1);
        }
    }
    sigmas.len()
}

/// AAK lower bound (Thm 3.2): no order-d system can approximate the filter
/// with Hankel-norm error below sigma_{d+1}. Returns 0 beyond the spectrum.
pub fn aak_lower_bound(sigmas: &[f64], d: usize) -> f64 {
    sigmas.get(d).copied().unwrap_or(0.0)
}

/// "Effective dimension" summary used in the Figure D.9/D.10 analysis:
/// number of normalized singular values above the threshold.
pub fn effective_dimension(taps: &[f64], tol: f64) -> usize {
    let sv = hankel_singular_values(taps, None);
    if sv.is_empty() || sv[0] == 0.0 {
        return 0;
    }
    sv.iter().filter(|&&s| s > tol * sv[0]).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::C64;
    use crate::util::prop::check;

    /// Impulse response of sum_n R_n lambda_n^tau (real part).
    fn modal_taps(poles: &[C64], res: &[C64], len: usize) -> Vec<f64> {
        (0..len)
            .map(|t| {
                poles
                    .iter()
                    .zip(res)
                    .map(|(l, r)| (*r * l.powi(t as u64)).re)
                    .sum()
            })
            .collect()
    }

    #[test]
    fn hankel_structure() {
        let taps = [1.0, 2.0, 3.0, 4.0, 5.0];
        let s = hankel_matrix(&taps, 3);
        assert_eq!(s[(0, 0)], 1.0);
        assert_eq!(s[(0, 2)], 3.0);
        assert_eq!(s[(2, 0)], 3.0);
        assert_eq!(s[(2, 2)], 5.0);
    }

    #[test]
    fn rank_counts_modes_ho_kalman() {
        // A d-mode (conjugate-closed) modal filter has Hankel rank d.
        check("hankel rank == McMillan degree", 10, |rng| {
            let pairs = 1 + rng.below(3);
            let mut poles = vec![];
            let mut res = vec![];
            for _ in 0..pairs {
                let l = C64::polar(rng.range(0.5, 0.9), rng.range(0.3, 2.8));
                let r = C64::new(rng.normal(), rng.normal());
                poles.push(l);
                poles.push(l.conj());
                res.push(r);
                res.push(r.conj());
            }
            let d = poles.len();
            let taps = modal_taps(&poles, &res, 48);
            let sv = hankel_singular_values(&taps, Some(24));
            let rank = sv.iter().filter(|&&s| s > 1e-8 * sv[0]).count();
            if rank == d {
                Ok(())
            } else {
                Err(format!("rank {rank} != modes {d}; sv[..6]={:?}", &sv[..6.min(sv.len())]))
            }
        });
    }

    #[test]
    fn suggest_order_finds_knee() {
        let sigmas = [1.0, 0.5, 0.2, 1e-7, 1e-8];
        assert_eq!(suggest_order(&sigmas, 1e-4), 3);
        assert_eq!(suggest_order(&sigmas, 1e-9), 5);
        assert_eq!(suggest_order(&[0.0], 1e-4), 1);
    }

    #[test]
    fn aak_bound_is_spectrum_tail() {
        let sigmas = [2.0, 1.0, 0.1];
        assert_eq!(aak_lower_bound(&sigmas, 1), 1.0);
        assert_eq!(aak_lower_bound(&sigmas, 3), 0.0);
    }

    #[test]
    fn truncated_delay_line_is_full_rank() {
        // h = delta at tau=K: Hankel is an anti-diagonal line -> rank K+1
        let mut taps = vec![0.0; 12];
        taps[5] = 1.0;
        let sv = hankel_singular_values(&taps, Some(8));
        let rank = sv.iter().filter(|&&s| s > 1e-10).count();
        assert_eq!(rank, 6);
    }
}
