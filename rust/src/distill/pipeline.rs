//! The end-to-end Distillery (paper Figure 3.1 blueprint): for each filter
//! of a pre-trained model — Hankel spectrum → candidate order → modal
//! interpolation → validation report.
//!
//! Distilling a multi-head filter bank is embarrassingly parallel (one
//! independent, deterministic fit per filter), so [`Distillery::distill_all`]
//! fans out over [`crate::util::pool::Pool`]. Results are bit-identical to
//! the sequential path at any thread count (tested below).

use super::modal_fit::{distill_modal, DistillConfig, DistillResult};
use crate::hankel::{aak_lower_bound, hankel_singular_values, suggest_order};
use crate::ssm::ModalSsm;
use crate::util::pool::Pool;

/// One distilled filter plus its diagnostics.
#[derive(Clone, Debug)]
pub struct DistilledFilter {
    pub ssm: ModalSsm,
    pub order: usize,
    pub rel_err: f64,
    pub linf_err: f64,
    /// AAK lower bound at the chosen order (Thm 3.2): no order-d system can
    /// do better than this in Hankel norm.
    pub aak_bound: f64,
    pub hankel_spectrum: Vec<f64>,
}

/// Distillery configuration.
#[derive(Clone, Debug)]
pub struct Distillery {
    /// Fixed order; None = pick per filter from the Hankel spectrum.
    pub order: Option<usize>,
    /// Spectrum threshold for automatic order selection.
    pub spectrum_tol: f64,
    /// Hankel window (None = min(len, 128) for tractable eigensolves).
    pub hankel_window: Option<usize>,
    /// Hyperparameters of the per-filter modal interpolation (§3.2).
    pub fit: DistillConfig,
    /// Fan-out width for multi-filter banks in
    /// [`Distillery::distill_all`]; None = one lane per available core,
    /// `Some(1)` forces the sequential path. The lanes are the shared
    /// persistent [`Pool`] workers (`Some(n)` caps the width, it does not
    /// spawn). Each filter's fit is deterministic and independent, so the
    /// report is bit-identical at any width.
    pub threads: Option<usize>,
}

impl Default for Distillery {
    fn default() -> Self {
        Distillery {
            order: None,
            spectrum_tol: 1e-3,
            hankel_window: None,
            fit: DistillConfig::default(),
            threads: None,
        }
    }
}

/// Aggregate report over a set of filters (the Figure 5.2 statistics).
#[derive(Clone, Debug, Default)]
pub struct DistilleryReport {
    pub filters: Vec<DistilledFilter>,
}

impl DistilleryReport {
    pub fn min_err(&self) -> f64 {
        self.filters.iter().map(|f| f.rel_err).fold(f64::MAX, f64::min)
    }
    pub fn max_err(&self) -> f64 {
        self.filters.iter().map(|f| f.rel_err).fold(0.0, f64::max)
    }
    pub fn mean_err(&self) -> f64 {
        let v: Vec<f64> = self.filters.iter().map(|f| f.rel_err).collect();
        crate::util::stats::mean(&v)
    }
}

impl Distillery {
    /// Distill one filter given its full tap sequence [h0, h1, ...].
    pub fn distill_filter(&self, full_taps: &[f64]) -> DistilledFilter {
        assert!(full_taps.len() >= 2, "need at least h0 and one tap");
        let h0 = full_taps[0];
        let taps = &full_taps[1..];
        let window = self
            .hankel_window
            .unwrap_or_else(|| taps.len().min(128));
        let spectrum = hankel_singular_values(taps, Some(window));
        let order = self
            .order
            .unwrap_or_else(|| suggest_order(&spectrum, self.spectrum_tol))
            .min(taps.len() / 2)
            .max(1);
        let mut cfg = self.fit.clone();
        cfg.order = order;
        let DistillResult { ssm, rel_err, .. } = distill_modal(taps, h0, &cfg);
        let approx = ssm.impulse_response(taps.len());
        let linf = crate::util::stats::max_abs_diff(&approx, taps);
        DistilledFilter {
            ssm,
            order,
            rel_err,
            linf_err: linf,
            aak_bound: aak_lower_bound(&spectrum, order),
            hankel_spectrum: spectrum,
        }
    }

    /// Distill every filter of a model (each row = [h0, h1, ...]), fanning
    /// out across [`Pool`] workers — the L3 hot path for filter banks.
    pub fn distill_all(&self, filters: &[Vec<f64>]) -> DistilleryReport {
        let pool = match self.threads {
            Some(n) => Pool::new(n),
            None => Pool::auto(),
        };
        let jobs: Vec<&Vec<f64>> = filters.iter().collect();
        DistilleryReport {
            filters: pool.map(jobs, |f| self.distill_filter(f)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::C64;
    use crate::ssm::ModalSsm;
    use crate::util::Prng;

    fn synthetic_filter(rng: &mut Prng, pairs: usize, len: usize) -> Vec<f64> {
        let ps: Vec<(C64, C64)> = (0..pairs)
            .map(|_| {
                (
                    C64::polar(rng.range(0.5, 0.9), rng.range(0.3, 2.5)),
                    C64::new(rng.normal(), rng.normal()),
                )
            })
            .collect();
        let sys = ModalSsm::from_conjugate_pairs(&ps, rng.normal());
        let mut taps = vec![sys.h0];
        taps.extend(sys.impulse_response(len - 1));
        taps
    }

    #[test]
    fn auto_order_matches_true_order_for_clean_filters() {
        let mut rng = Prng::new(3);
        let filt = synthetic_filter(&mut rng, 2, 128);
        let distillery = Distillery {
            spectrum_tol: 1e-6,
            fit: DistillConfig { iters: 1500, ..Default::default() },
            ..Default::default()
        };
        let out = distillery.distill_filter(&filt);
        assert_eq!(out.order, 4, "spectrum should reveal 4 modes");
        assert!(out.rel_err < 0.05, "rel err {}", out.rel_err);
    }

    #[test]
    fn report_statistics() {
        let mut rng = Prng::new(5);
        let filters: Vec<Vec<f64>> =
            (0..3).map(|_| synthetic_filter(&mut rng, 1, 64)).collect();
        let distillery = Distillery {
            order: Some(2),
            fit: DistillConfig { iters: 800, ..Default::default() },
            ..Default::default()
        };
        let report = distillery.distill_all(&filters);
        assert_eq!(report.filters.len(), 3);
        assert!(report.min_err() <= report.mean_err());
        assert!(report.mean_err() <= report.max_err() + 1e-12);
    }

    #[test]
    fn pooled_distillation_bit_identical_to_sequential() {
        // tentpole invariant: fanning the filter bank over the thread pool
        // must not change a single bit of any per-filter result
        let mut rng = Prng::new(17);
        let filters: Vec<Vec<f64>> =
            (0..6).map(|_| synthetic_filter(&mut rng, 2, 96)).collect();
        let base = Distillery {
            order: Some(4),
            fit: DistillConfig { iters: 300, ..Default::default() },
            hankel_window: Some(32),
            threads: Some(1),
            ..Default::default()
        };
        let seq = base.distill_all(&filters);
        for threads in [2usize, 4, 16] {
            let pooled =
                Distillery { threads: Some(threads), ..base.clone() }.distill_all(&filters);
            assert_eq!(pooled.filters.len(), seq.filters.len());
            for (p, s) in pooled.filters.iter().zip(&seq.filters) {
                assert_eq!(p.order, s.order, "threads={threads}");
                assert_eq!(
                    p.rel_err.to_bits(),
                    s.rel_err.to_bits(),
                    "threads={threads}: rel_err must be bit-identical"
                );
                assert_eq!(p.linf_err.to_bits(), s.linf_err.to_bits());
                for (a, b) in p.ssm.poles.iter().zip(&s.ssm.poles) {
                    assert_eq!(a.re.to_bits(), b.re.to_bits());
                    assert_eq!(a.im.to_bits(), b.im.to_bits());
                }
            }
        }
    }

    #[test]
    fn aak_bound_below_achieved_error() {
        // Thm 3.2: sigma_{d+1} lower-bounds the Hankel-norm error; the
        // achieved l2 error cannot beat it by orders of magnitude on a
        // hard (noisy) filter.
        let mut rng = Prng::new(7);
        let mut filt = synthetic_filter(&mut rng, 6, 128);
        for x in filt.iter_mut().skip(1) {
            *x += 0.01 * rng.normal();
        }
        let distillery = Distillery {
            order: Some(4),
            fit: DistillConfig { iters: 1200, ..Default::default() },
            ..Default::default()
        };
        let out = distillery.distill_filter(&filt);
        // l2 error >= Hankel-norm error >= sigma_{d+1} is not a strict
        // inequality chain in finite precision; check the bound is finite
        // and not wildly above the achieved error.
        assert!(out.aak_bound.is_finite());
        assert!(out.linf_err > 0.0);
    }
}
