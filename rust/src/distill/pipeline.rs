//! The end-to-end Distillery (paper Figure 3.1 blueprint): for each filter
//! of a pre-trained model — Hankel spectrum → candidate order → modal
//! interpolation → validation report.

use super::modal_fit::{distill_modal, DistillConfig, DistillResult};
use crate::hankel::{aak_lower_bound, hankel_singular_values, suggest_order};
use crate::ssm::ModalSsm;

/// One distilled filter plus its diagnostics.
#[derive(Clone, Debug)]
pub struct DistilledFilter {
    pub ssm: ModalSsm,
    pub order: usize,
    pub rel_err: f64,
    pub linf_err: f64,
    /// AAK lower bound at the chosen order (Thm 3.2): no order-d system can
    /// do better than this in Hankel norm.
    pub aak_bound: f64,
    pub hankel_spectrum: Vec<f64>,
}

/// Distillery configuration.
#[derive(Clone, Debug)]
pub struct Distillery {
    /// Fixed order; None = pick per filter from the Hankel spectrum.
    pub order: Option<usize>,
    /// Spectrum threshold for automatic order selection.
    pub spectrum_tol: f64,
    /// Hankel window (None = min(len, 128) for tractable eigensolves).
    pub hankel_window: Option<usize>,
    pub fit: DistillConfig,
}

impl Default for Distillery {
    fn default() -> Self {
        Distillery {
            order: None,
            spectrum_tol: 1e-3,
            hankel_window: None,
            fit: DistillConfig::default(),
        }
    }
}

/// Aggregate report over a set of filters (the Figure 5.2 statistics).
#[derive(Clone, Debug, Default)]
pub struct DistilleryReport {
    pub filters: Vec<DistilledFilter>,
}

impl DistilleryReport {
    pub fn min_err(&self) -> f64 {
        self.filters.iter().map(|f| f.rel_err).fold(f64::MAX, f64::min)
    }
    pub fn max_err(&self) -> f64 {
        self.filters.iter().map(|f| f.rel_err).fold(0.0, f64::max)
    }
    pub fn mean_err(&self) -> f64 {
        let v: Vec<f64> = self.filters.iter().map(|f| f.rel_err).collect();
        crate::util::stats::mean(&v)
    }
}

impl Distillery {
    /// Distill one filter given its full tap sequence [h0, h1, ...].
    pub fn distill_filter(&self, full_taps: &[f64]) -> DistilledFilter {
        assert!(full_taps.len() >= 2, "need at least h0 and one tap");
        let h0 = full_taps[0];
        let taps = &full_taps[1..];
        let window = self
            .hankel_window
            .unwrap_or_else(|| taps.len().min(128));
        let spectrum = hankel_singular_values(taps, Some(window));
        let order = self
            .order
            .unwrap_or_else(|| suggest_order(&spectrum, self.spectrum_tol))
            .min(taps.len() / 2)
            .max(1);
        let mut cfg = self.fit.clone();
        cfg.order = order;
        let DistillResult { ssm, rel_err, .. } = distill_modal(taps, h0, &cfg);
        let approx = ssm.impulse_response(taps.len());
        let linf = crate::util::stats::max_abs_diff(&approx, taps);
        DistilledFilter {
            ssm,
            order,
            rel_err,
            linf_err: linf,
            aak_bound: aak_lower_bound(&spectrum, order),
            hankel_spectrum: spectrum,
        }
    }

    /// Distill every filter of a model (each row = [h0, h1, ...]).
    pub fn distill_all(&self, filters: &[Vec<f64>]) -> DistilleryReport {
        DistilleryReport {
            filters: filters.iter().map(|f| self.distill_filter(f)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::C64;
    use crate::ssm::ModalSsm;
    use crate::util::Prng;

    fn synthetic_filter(rng: &mut Prng, pairs: usize, len: usize) -> Vec<f64> {
        let ps: Vec<(C64, C64)> = (0..pairs)
            .map(|_| {
                (
                    C64::polar(rng.range(0.5, 0.9), rng.range(0.3, 2.5)),
                    C64::new(rng.normal(), rng.normal()),
                )
            })
            .collect();
        let sys = ModalSsm::from_conjugate_pairs(&ps, rng.normal());
        let mut taps = vec![sys.h0];
        taps.extend(sys.impulse_response(len - 1));
        taps
    }

    #[test]
    fn auto_order_matches_true_order_for_clean_filters() {
        let mut rng = Prng::new(3);
        let filt = synthetic_filter(&mut rng, 2, 128);
        let distillery = Distillery {
            spectrum_tol: 1e-6,
            fit: DistillConfig { iters: 1500, ..Default::default() },
            ..Default::default()
        };
        let out = distillery.distill_filter(&filt);
        assert_eq!(out.order, 4, "spectrum should reveal 4 modes");
        assert!(out.rel_err < 0.05, "rel err {}", out.rel_err);
    }

    #[test]
    fn report_statistics() {
        let mut rng = Prng::new(5);
        let filters: Vec<Vec<f64>> =
            (0..3).map(|_| synthetic_filter(&mut rng, 1, 64)).collect();
        let distillery = Distillery {
            order: Some(2),
            fit: DistillConfig { iters: 800, ..Default::default() },
            ..Default::default()
        };
        let report = distillery.distill_all(&filters);
        assert_eq!(report.filters.len(), 3);
        assert!(report.min_err() <= report.mean_err());
        assert!(report.mean_err() <= report.max_err() + 1e-12);
    }

    #[test]
    fn aak_bound_below_achieved_error() {
        // Thm 3.2: sigma_{d+1} lower-bounds the Hankel-norm error; the
        // achieved l2 error cannot beat it by orders of magnitude on a
        // hard (noisy) filter.
        let mut rng = Prng::new(7);
        let mut filt = synthetic_filter(&mut rng, 6, 128);
        for x in filt.iter_mut().skip(1) {
            *x += 0.01 * rng.normal();
        }
        let distillery = Distillery {
            order: Some(4),
            fit: DistillConfig { iters: 1200, ..Default::default() },
            ..Default::default()
        };
        let out = distillery.distill_filter(&filt);
        // l2 error >= Hankel-norm error >= sigma_{d+1} is not a strict
        // inequality chain in finite precision; check the bound is finite
        // and not wildly above the achieved error.
        assert!(out.aak_bound.is_finite());
        assert!(out.linf_err > 0.0);
    }
}
