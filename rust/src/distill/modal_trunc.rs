//! Modal truncation (paper App. E.3.1): reduce a *diagonal* SSM by keeping
//! the n modes with the largest H-infinity influence bound
//! |r_i| / |1 - |lambda_i|| and discarding the rest.

use crate::ssm::ModalSsm;

/// Influence bound of each mode (eq. E.2 summand).
pub fn mode_influence(sys: &ModalSsm) -> Vec<f64> {
    sys.poles
        .iter()
        .zip(&sys.residues)
        .map(|(l, r)| {
            let denom = (1.0 - l.abs()).abs().max(1e-12);
            r.abs() / denom
        })
        .collect()
}

/// Keep the n most influential modes (E.3.1). Preserves conjugate pairs by
/// construction when the input is conjugate-closed and n counts both
/// halves of each kept pair — callers pass even n for real filters.
pub fn modal_truncate(sys: &ModalSsm, n: usize) -> ModalSsm {
    let infl = mode_influence(sys);
    let mut order: Vec<usize> = (0..sys.order()).collect();
    order.sort_by(|&i, &j| infl[j].partial_cmp(&infl[i]).unwrap());
    let keep: Vec<usize> = order.into_iter().take(n.min(sys.order())).collect();
    ModalSsm::new(
        keep.iter().map(|&i| sys.poles[i]).collect(),
        keep.iter().map(|&i| sys.residues[i]).collect(),
        sys.h0,
    )
}

/// l-infinity impulse-response error of a reduction (the metric plotted in
/// Figure E.1).
pub fn linf_error(full: &ModalSsm, reduced: &ModalSsm, len: usize) -> f64 {
    let a = full.impulse_response(len);
    let b = reduced.impulse_response(len);
    crate::util::stats::max_abs_diff(&a, &b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::C64;
    use crate::util::prop::check;
    use crate::util::Prng;

    fn random_diag(rng: &mut Prng, pairs: usize) -> ModalSsm {
        let ps: Vec<(C64, C64)> = (0..pairs)
            .map(|_| {
                (
                    C64::polar(rng.range(0.3, 0.95), rng.range(0.2, 2.9)),
                    C64::new(rng.normal(), rng.normal()),
                )
            })
            .collect();
        ModalSsm::from_conjugate_pairs(&ps, 0.0)
    }

    #[test]
    fn full_order_truncation_is_identity() {
        let mut rng = Prng::new(1);
        let sys = random_diag(&mut rng, 3);
        let t = modal_truncate(&sys, sys.order());
        assert_eq!(t.order(), sys.order());
        let e = linf_error(&sys, &t, 32);
        assert!(e < 1e-12, "{e}");
    }

    #[test]
    fn error_bounded_by_discarded_influence() {
        // eq. E.2: the error of discarding a mode set is bounded by the sum
        // of the discarded influence terms |r|/|1-|lambda|| (times 2 for
        // the h-inf -> l-inf slack of the truncated response).
        check("modal truncation error <= influence bound", 8, |rng| {
            let sys = random_diag(rng, 6);
            let infl = mode_influence(&sys);
            let mut order: Vec<usize> = (0..sys.order()).collect();
            order.sort_by(|&i, &j| infl[j].partial_cmp(&infl[i]).unwrap());
            for n in (2..=12).step_by(2) {
                let e = linf_error(&sys, &modal_truncate(&sys, n), 64);
                let bound: f64 = order.iter().skip(n).map(|&i| infl[i]).sum();
                if e > 2.0 * bound + 1e-9 {
                    return Err(format!("n={n}: err {e} > bound {bound}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn error_vanishes_at_full_order_and_shrinks_on_average() {
        // Figure E.1 trend: increasing order reduces error overall (the
        // strict per-step monotonicity can be broken by cancellation).
        check("modal truncation trend", 8, |rng| {
            let sys = random_diag(rng, 6);
            let e2 = linf_error(&sys, &modal_truncate(&sys, 2), 64);
            let e12 = linf_error(&sys, &modal_truncate(&sys, 12), 64);
            if e12 < 1e-10 && e2 >= e12 {
                Ok(())
            } else {
                Err(format!("e2={e2}, e12={e12}"))
            }
        });
    }

    #[test]
    fn keeps_dominant_mode() {
        // one huge slow mode + tiny fast modes: order-2 truncation must
        // retain the dominant conjugate pair
        let ps = [
            (C64::polar(0.95, 0.5), C64::new(10.0, 0.0)),
            (C64::polar(0.3, 1.5), C64::new(0.01, 0.0)),
        ];
        let sys = ModalSsm::from_conjugate_pairs(&ps, 0.0);
        let t = modal_truncate(&sys, 2);
        assert!((t.poles[0].abs() - 0.95).abs() < 1e-12);
    }
}
