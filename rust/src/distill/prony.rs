//! Prony's method (1795): the classical two-stage linear solution of the
//! nonlinear least-squares interpolation problem (paper §3.2).
//!
//! Stage 1 — linear prediction: find denominator coefficients a such that
//! h_t ≈ -sum_{j=1..d} a_j h_{t-j} (least squares over t = d..L-1).
//! Stage 2 — poles are the prediction-polynomial roots; residues solve the
//! complex Vandermonde least-squares fit h_tau ≈ sum_n R_n lambda_n^tau.
//!
//! The paper notes these Prony/Padé-style methods "can be numerically
//! unstable" — the benchmark in benches/distillation.rs reproduces exactly
//! that comparison against gradient-based modal fitting.

use crate::dsp::poly::poly_roots;
use crate::dsp::C64;
use crate::linalg::lu::{lstsq_c64, solve_real};
use crate::linalg::Mat;
use crate::ssm::ModalSsm;

/// Distill taps (h_{tau+1}) into an order-d modal SSM via Prony's method.
/// Returns None when the linear systems are too ill-conditioned to solve.
pub fn prony(taps: &[f64], h0: f64, d: usize) -> Option<ModalSsm> {
    let l = taps.len();
    if l < 2 * d + 1 || d == 0 {
        return None;
    }
    // Stage 1: least-squares linear prediction via normal equations.
    // rows: t = d .. l-1;  A[t, j] = h_{t-1-j},  rhs = -h_t
    let rows = l - d;
    let mut ata = Mat::zeros(d, d);
    let mut atb = vec![0.0; d];
    for t in d..l {
        for i in 0..d {
            let hi = taps[t - 1 - i];
            atb[i] += hi * (-taps[t]);
            for j in 0..d {
                ata[(i, j)] += hi * taps[t - 1 - j];
            }
        }
    }
    // small ridge for conditioning
    let scale = (0..d).map(|i| ata[(i, i)].abs()).fold(0.0, f64::max);
    for i in 0..d {
        ata[(i, i)] += 1e-10 * scale.max(1e-30);
    }
    let a = solve_real(&ata, &atb)?;
    let _ = rows;

    // Stage 2a: poles = roots of z^d + a_1 z^{d-1} + ... + a_d
    let mut coeffs: Vec<C64> = Vec::with_capacity(d + 1);
    for k in (0..d).rev() {
        coeffs.push(C64::real(a[k]));
    }
    coeffs.push(C64::ONE);
    let poles = poly_roots(&coeffs);
    if poles.iter().any(|p| !p.is_finite()) {
        return None;
    }

    // Stage 2b: residues by Vandermonde least squares over all taps.
    let vand: Vec<Vec<C64>> = (0..l)
        .map(|t| poles.iter().map(|p| p.powi(t as u64)).collect())
        .collect();
    let rhs: Vec<C64> = taps.iter().map(|&x| C64::real(x)).collect();
    let residues = lstsq_c64(&vand, &rhs, 1e-12)?;
    if residues.iter().any(|r| !r.is_finite()) {
        return None;
    }
    Some(ModalSsm::new(poles, residues, h0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::stats::rel_err;

    #[test]
    fn exact_recovery_of_low_order_system() {
        check("prony recovers modal systems exactly", 10, |rng| {
            let pairs = 1 + rng.below(2);
            let ps: Vec<(C64, C64)> = (0..pairs)
                .map(|_| {
                    (
                        C64::polar(rng.range(0.5, 0.9), rng.range(0.4, 2.5)),
                        C64::new(rng.normal(), rng.normal()),
                    )
                })
                .collect();
            let sys = ModalSsm::from_conjugate_pairs(&ps, 0.3);
            let taps = sys.impulse_response(64);
            let got = match prony(&taps, 0.3, 2 * pairs) {
                Some(g) => g,
                None => return Err("prony failed".into()),
            };
            let err = rel_err(&got.impulse_response(64), &taps);
            if err < 1e-6 {
                Ok(())
            } else {
                Err(format!("rel err {err:.2e}"))
            }
        });
    }

    #[test]
    fn too_short_input_rejected() {
        assert!(prony(&[1.0, 0.5, 0.2], 0.0, 4).is_none());
    }

    #[test]
    fn noisy_taps_degrade_gracefully() {
        // with noise, the fit should still be finite and roughly track
        let mut rng = crate::util::Prng::new(42);
        let ps = [(C64::polar(0.8, 1.0), C64::new(1.0, -0.5))];
        let sys = ModalSsm::from_conjugate_pairs(&ps, 0.0);
        let mut taps = sys.impulse_response(64);
        for t in taps.iter_mut() {
            *t += 0.001 * rng.normal();
        }
        let got = prony(&taps, 0.0, 4).expect("prony");
        let err = rel_err(&got.impulse_response(64), &taps);
        assert!(err < 0.2, "rel err {err}");
    }
}
