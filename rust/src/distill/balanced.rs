//! Balanced truncation via Kung's Hankel-factorization method
//! (paper App. E.3.2, refs [21, 24]).
//!
//! Steps (paper's recipe around eq. E.5):
//!  1. Form the Hankel matrix S of the impulse response.
//!  2. Eigendecompose the symmetric S = V Λ V^T; Hankel singular values
//!     sigma = |Λ|; observability factor O = U Σ^{1/2} with U = V sign(Λ).
//!  3. Pick order n (Enns bound E.4 guides the choice).
//!  4. A = pinv(O[0:k-1, :n]) O[1:k, :n]  (shift-invariance least squares),
//!     C = O[0, :n], B = (Σ^{1/2} V^T e_1)[:n], D = h0.
//!
//! The paper observes this classical approach shows *non-monotonic* error
//! and occasional instability on pre-trained filters (Figures E.2-E.4) —
//! behaviour the Figure-E drivers reproduce with this implementation.

use crate::hankel::hankel_eig;
use crate::linalg::lu::solve_real;
use crate::linalg::Mat;
use crate::ssm::DenseSsm;

/// Enns upper bound (eq. E.4): 2 * sum of discarded singular values.
pub fn enns_bound(sigmas: &[f64], n: usize) -> f64 {
    2.0 * sigmas.iter().skip(n).sum::<f64>()
}

/// Kung's order-n balanced realization from filter taps (h_{tau+1}).
/// `window` is the Hankel dimension (defaults to len/2 when None).
pub fn balanced_truncate(taps: &[f64], h0: f64, n: usize, window: Option<usize>) -> Option<DenseSsm> {
    let k = window.unwrap_or(taps.len() / 2).max(n + 1);
    let eig = hankel_eig(taps, k);
    // O = U Sigma^{1/2}, U = V sign(lambda): O[i][m] = V[i][m] sgn * sqrt(|lam|)
    let mut obs = Mat::zeros(k, n);
    for m in 0..n {
        let lam = eig.values[m];
        let s = lam.abs().sqrt();
        let sgn = if lam >= 0.0 { 1.0 } else { -1.0 };
        for i in 0..k {
            obs[(i, m)] = eig.vectors[(i, m)] * s * sgn;
        }
    }
    // A from shift invariance: O_up A = O_down (least squares, n x n normal eqs)
    let mut ata = Mat::zeros(n, n);
    let mut atb = Mat::zeros(n, n);
    for i in 0..k - 1 {
        for p in 0..n {
            for q in 0..n {
                ata[(p, q)] += obs[(i, p)] * obs[(i, q)];
                atb[(p, q)] += obs[(i, p)] * obs[(i + 1, q)];
            }
        }
    }
    let mut a = Mat::zeros(n, n);
    for col in 0..n {
        let rhs: Vec<f64> = (0..n).map(|r| atb[(r, col)]).collect();
        let x = solve_real(&ata, &rhs)?;
        for r in 0..n {
            a[(r, col)] = x[r];
        }
    }
    // C = first row of O = U Sigma^{1/2}; B = first column of the
    // controllability factor Sigma^{1/2} V^T — note B carries no
    // sign(lambda) factor, unlike C (S = U Sigma V^T with U = V sign(L)).
    let c: Vec<f64> = (0..n).map(|m| obs[(0, m)]).collect();
    let b: Vec<f64> = (0..n)
        .map(|m| eig.values[m].abs().sqrt() * eig.vectors[(0, m)])
        .collect();
    Some(DenseSsm::new(a, b, c, h0))
}

/// l-infinity impulse-response error of an order-n balanced reduction — the
/// metric of Figures E.2-E.4.
pub fn balanced_error(taps: &[f64], n: usize, len: usize) -> Option<f64> {
    let sys = balanced_truncate(taps, 0.0, n, None)?;
    let approx = sys.impulse_response(len);
    let mut want = taps.to_vec();
    want.resize(len, 0.0);
    Some(crate::util::stats::max_abs_diff(&approx, &want))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::C64;
    use crate::hankel::hankel_singular_values;
    use crate::ssm::ModalSsm;
    use crate::util::prop::check;

    #[test]
    fn recovers_low_order_systems() {
        check("kung recovers modal systems", 8, |rng| {
            let pairs = 1 + rng.below(2);
            let ps: Vec<(C64, C64)> = (0..pairs)
                .map(|_| {
                    (
                        C64::polar(rng.range(0.5, 0.85), rng.range(0.4, 2.4)),
                        C64::new(rng.normal(), rng.normal()),
                    )
                })
                .collect();
            let sys = ModalSsm::from_conjugate_pairs(&ps, 0.0);
            let taps = sys.impulse_response(96);
            let d = 2 * pairs;
            let red = match balanced_truncate(&taps, 0.0, d, Some(40)) {
                Some(r) => r,
                None => return Err("solve failed".into()),
            };
            let got = red.impulse_response(64);
            let err = crate::util::stats::rel_err(&got, &taps[..64].to_vec());
            if err < 1e-4 {
                Ok(())
            } else {
                Err(format!("rel err {err:.2e}"))
            }
        });
    }

    #[test]
    fn enns_bound_decreases() {
        let sigmas = [2.0, 1.0, 0.5, 0.1];
        assert!(enns_bound(&sigmas, 1) > enns_bound(&sigmas, 3));
        assert_eq!(enns_bound(&sigmas, 4), 0.0);
    }

    #[test]
    fn error_roughly_bounded_by_enns_on_easy_filters() {
        let ps = [
            (C64::polar(0.9, 0.7), C64::new(1.0, 0.2)),
            (C64::polar(0.6, 1.9), C64::new(0.2, -0.1)),
        ];
        let sys = ModalSsm::from_conjugate_pairs(&ps, 0.0);
        let taps = sys.impulse_response(128);
        let sig = hankel_singular_values(&taps, Some(48));
        for n in [2usize, 4] {
            if let Some(err) = balanced_error(&taps, n, 96) {
                // Enns bounds the H-inf error; linf <= 2*Hinf in general —
                // allow slack for the truncated-window approximation.
                assert!(
                    err <= 4.0 * enns_bound(&sig, n) + 1e-9,
                    "n={n}: err {err} vs bound {}",
                    enns_bound(&sig, n)
                );
            }
        }
    }
}
