//! Gradient-based modal interpolation (paper §3.2) — the core distillation
//! algorithm, and the L3 performance hot path for all App.-D error sweeps.
//!
//! Parametrization (App. B.1): poles in polar form lambda_n = A_n e^{i th_n}
//! (A_n projected into [0, 0.9995] for deployable stability), residues in
//! cartesian form.  Objective: L-point nonlinear least squares
//! min sum_tau (Re sum_n R_n lambda_n^tau - h_{tau+1})^2, optimized with
//! Adam under a cosine learning-rate schedule.  Gradients are analytic —
//! the same contractions the L1 Pallas backward kernel computes:
//!
//!   dE/dRre[n] =  2 sum_t g_t A^t cos(th t)        g_t = h_hat_t - h_t
//!   dE/dRim[n] = -2 sum_t g_t A^t sin(th t)
//!   dE/dA[n]   =  2 sum_t g_t t A^(t-1) (Rre cos - Rim sin)
//!   dE/dth[n]  = -2 sum_t g_t t A^t      (Rre sin + Rim cos)

use crate::dsp::C64;
use crate::ssm::ModalSsm;

/// Distillation objective (paper §3.1). By Parseval the two are equal for
/// finite sequences; `H2` evaluates the loss in frequency domain (eq. B.9)
/// and is kept as an ablation/verification path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    L2,
    H2,
}

/// Hyperparameters of the modal interpolation program.
#[derive(Clone, Debug)]
pub struct DistillConfig {
    pub order: usize,
    pub iters: usize,
    pub lr: f64,
    pub objective: Objective,
    pub seed: u64,
    /// Stability projection radius for |lambda| (paper App. B.1 notes
    /// distillation itself needs no constraint; deployment does).
    pub max_radius: f64,
    /// Random restarts; the best final loss wins.
    pub restarts: usize,
}

impl Default for DistillConfig {
    fn default() -> Self {
        DistillConfig {
            order: 16,
            iters: 3000,
            lr: 0.05,
            objective: Objective::L2,
            seed: 0,
            max_radius: 0.9995,
            restarts: 1,
        }
    }
}

/// Outcome of one filter distillation.
#[derive(Clone, Debug)]
pub struct DistillResult {
    pub ssm: ModalSsm,
    /// Final squared-l2 interpolation error sum_tau (h_hat - h)^2.
    pub loss: f64,
    /// Relative l2 error ||h_hat - h|| / ||h||.
    pub rel_err: f64,
    pub iters_run: usize,
}

/// Optimization state: structure-of-arrays modal parameters.
struct Params {
    decay: Vec<f64>,
    theta: Vec<f64>,
    r_re: Vec<f64>,
    r_im: Vec<f64>,
}

impl Params {
    fn init(order: usize, rng: &mut crate::util::Prng) -> Params {
        // ring-of-poles init matching python/compile/model.py::init_modal:
        // magnitudes spread over timescales, phases over the half circle.
        let d = order;
        let decay = (0..d)
            .map(|n| {
                let base = if d == 1 { 0.9 } else { 0.6 + 0.37 * n as f64 / (d - 1) as f64 };
                (base + 0.01 * rng.normal()).clamp(0.05, 0.999)
            })
            .collect();
        let theta = (0..d)
            .map(|n| {
                let base = if d == 1 {
                    0.0
                } else {
                    std::f64::consts::PI * n as f64 / (d - 1) as f64
                };
                base + 0.01 * rng.normal()
            })
            .collect();
        Params {
            decay,
            theta,
            r_re: (0..d).map(|_| 0.01 * rng.normal()).collect(),
            r_im: vec![0.0; d],
        }
    }

    fn to_ssm(&self, h0: f64) -> ModalSsm {
        let poles: Vec<C64> = self
            .decay
            .iter()
            .zip(&self.theta)
            .map(|(&a, &t)| C64::polar(a, t))
            .collect();
        let residues: Vec<C64> = self
            .r_re
            .iter()
            .zip(&self.r_im)
            .map(|(&re, &im)| C64::new(re, im))
            .collect();
        ModalSsm::new(poles, residues, h0)
    }
}

/// Fused forward + gradient pass. Returns loss; writes gradients.
/// O(d L): per mode, incremental powers A^t, recurrence for cos/sin(th t).
#[allow(clippy::too_many_arguments)]
fn loss_and_grad(
    p: &Params,
    target: &[f64],
    resid: &mut [f64],
    g_decay: &mut [f64],
    g_theta: &mut [f64],
    g_rre: &mut [f64],
    g_rim: &mut [f64],
) -> f64 {
    let d = p.decay.len();
    let l = target.len();
    // forward: residual r_t = h_hat_t - h_t
    resid.copy_from_slice(target);
    for x in resid.iter_mut() {
        *x = -*x;
    }
    for n in 0..d {
        let (a, th) = (p.decay[n].max(1e-12), p.theta[n]);
        let (rre, rim) = (p.r_re[n], p.r_im[n]);
        // c_t = A^t cos(th t), s_t = A^t sin(th t), evaluated as FOUR
        // independent rotation streams (t mod 4) each advancing by rot^4 —
        // breaks the serial complex-multiply dependency chain (§Perf).
        let (mut cs, mut ss) = lane_init(a, th);
        let (r4c, r4s) = rot_pow(a, th, 4);
        let chunks = l / 4;
        for ch in 0..chunks {
            let base = 4 * ch;
            for k in 0..4 {
                resid[base + k] += rre * cs[k] - rim * ss[k];
                let c2 = cs[k] * r4c - ss[k] * r4s;
                ss[k] = cs[k] * r4s + ss[k] * r4c;
                cs[k] = c2;
            }
        }
        for (k, rt) in resid.iter_mut().enumerate().take(l).skip(4 * chunks) {
            let k = k - 4 * chunks;
            *rt += rre * cs[k] - rim * ss[k];
        }
    }
    let loss: f64 = resid.iter().map(|r| r * r).sum();
    // backward: four contractions per mode.  §Perf: 1/a hoisted out of the
    // loop and the shared g*t factor computed once (see EXPERIMENTS.md).
    for n in 0..d {
        let (a, th) = (p.decay[n].max(1e-12), p.theta[n]);
        let inv_a = 1.0 / a;
        let (rre, rim) = (p.r_re[n], p.r_im[n]);
        let (mut cs, mut ss) = lane_init(a, th);
        let (r4c, r4s) = rot_pow(a, th, 4);
        let (mut gd, mut gt, mut gr, mut gi) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        let chunks = l / 4;
        for ch in 0..chunks {
            let base = 4 * ch;
            for k in 0..4 {
                let g = resid[base + k];
                let gt_f = g * (base + k) as f64;
                gr += g * cs[k];
                gi -= g * ss[k];
                gd += gt_f * (rre * cs[k] - rim * ss[k]);
                gt -= gt_f * (rre * ss[k] + rim * cs[k]);
                let c2 = cs[k] * r4c - ss[k] * r4s;
                ss[k] = cs[k] * r4s + ss[k] * r4c;
                cs[k] = c2;
            }
        }
        for t in 4 * chunks..l {
            let k = t - 4 * chunks;
            let g = resid[t];
            let gt_f = g * t as f64;
            gr += g * cs[k];
            gi -= g * ss[k];
            gd += gt_f * (rre * cs[k] - rim * ss[k]);
            gt -= gt_f * (rre * ss[k] + rim * cs[k]);
        }
        g_rre[n] = 2.0 * gr;
        g_rim[n] = 2.0 * gi;
        g_decay[n] = 2.0 * gd * inv_a;
        g_theta[n] = 2.0 * gt;
    }
    loss
}

/// First four basis samples: (A^k cos(th k), A^k sin(th k)) for k = 0..3.
#[inline]
fn lane_init(a: f64, th: f64) -> ([f64; 4], [f64; 4]) {
    let mut cs = [0.0f64; 4];
    let mut ss = [0.0f64; 4];
    for k in 0..4 {
        let (rc, rs) = rot_pow(a, th, k as u32);
        cs[k] = rc;
        ss[k] = rs;
    }
    (cs, ss)
}

/// (A e^{i th})^p as (re, im).
#[inline]
fn rot_pow(a: f64, th: f64, p: u32) -> (f64, f64) {
    let amp = a.powi(p as i32);
    (amp * (th * p as f64).cos(), amp * (th * p as f64).sin())
}

/// Distill one filter.
///
/// `taps[tau]` = h_{tau+1} (Markov parameters, tau = 0..L-1); `h0` is the
/// passthrough assigned verbatim (§3.2: "the passthrough cannot be freely
/// assigned: it is simply h_0").
pub fn distill_modal(taps: &[f64], h0: f64, cfg: &DistillConfig) -> DistillResult {
    let mut best: Option<DistillResult> = None;
    for restart in 0..cfg.restarts.max(1) {
        let mut rng = crate::util::Prng::new(cfg.seed ^ (restart as u64).wrapping_mul(0x9E37));
        let r = run_single(taps, h0, cfg, &mut rng);
        if best.as_ref().map_or(true, |b| r.loss < b.loss) {
            best = Some(r);
        }
    }
    best.unwrap()
}

fn run_single(
    taps: &[f64],
    h0: f64,
    cfg: &DistillConfig,
    rng: &mut crate::util::Prng,
) -> DistillResult {
    let d = cfg.order;
    let l = taps.len();
    let mut p = Params::init(d, rng);
    // Adam state
    let mut m = vec![0.0f64; 4 * d];
    let mut v = vec![0.0f64; 4 * d];
    let (b1, b2, eps): (f64, f64, f64) = (0.9, 0.999, 1e-8);

    let mut resid = vec![0.0f64; l];
    let (mut gd, mut gt, mut gr, mut gi) =
        (vec![0.0; d], vec![0.0; d], vec![0.0; d], vec![0.0; d]);
    let mut loss = f64::MAX;
    for it in 0..cfg.iters {
        loss = loss_and_grad(&p, taps, &mut resid, &mut gd, &mut gt, &mut gr, &mut gi);
        let lr = cfg.lr * 0.5 * (1.0 + (std::f64::consts::PI * it as f64 / cfg.iters as f64).cos())
            + 1e-4;
        let t = (it + 1) as f64;
        let bc1 = 1.0 - b1.powf(t);
        let bc2 = 1.0 - b2.powf(t);
        let mut upd = |idx: usize, param: &mut [f64], grad: &[f64]| {
            for n in 0..d {
                let k = idx * d + n;
                m[k] = b1 * m[k] + (1.0 - b1) * grad[n];
                v[k] = b2 * v[k] + (1.0 - b2) * grad[n] * grad[n];
                param[n] -= lr * (m[k] / bc1) / ((v[k] / bc2).sqrt() + eps);
            }
        };
        upd(0, &mut p.decay, &gd);
        upd(1, &mut p.theta, &gt);
        upd(2, &mut p.r_re, &gr);
        upd(3, &mut p.r_im, &gi);
        // stability projection (projected gradient)
        for a in p.decay.iter_mut() {
            *a = a.clamp(0.0, cfg.max_radius);
        }
    }
    // final loss after the last update
    loss = loss.min(loss_and_grad(&p, taps, &mut resid, &mut gd, &mut gt, &mut gr, &mut gi));
    let norm: f64 = taps.iter().map(|x| x * x).sum::<f64>().sqrt();
    DistillResult {
        ssm: p.to_ssm(h0),
        loss,
        rel_err: loss.sqrt() / norm.max(1e-30),
        iters_run: cfg.iters,
    }
}

/// H2 objective value (eq. B.9) of a fitted system against target taps:
/// computed in frequency domain; equals the l2 loss by Parseval (tested).
pub fn h2_loss(ssm: &ModalSsm, taps: &[f64]) -> f64 {
    let l = taps.len();
    let hhat = ssm.impulse_response(l);
    let diff: Vec<f64> = hhat.iter().zip(taps).map(|(a, b)| a - b).collect();
    let spec = crate::dsp::fft::dft_real(&diff);
    spec.iter().map(|z| z.abs2()).sum::<f64>() / l as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::Prng;

    fn modal_taps(rng: &mut Prng, pairs: usize, l: usize) -> (Vec<f64>, usize) {
        let ps: Vec<(C64, C64)> = (0..pairs)
            .map(|_| {
                (
                    C64::polar(rng.range(0.5, 0.9), rng.range(0.3, 2.5)),
                    C64::new(rng.normal(), rng.normal()),
                )
            })
            .collect();
        let sys = ModalSsm::from_conjugate_pairs(&ps, 0.0);
        (sys.impulse_response(l), 2 * pairs)
    }

    #[test]
    fn gradients_match_finite_differences() {
        let mut rng = Prng::new(3);
        let (taps, _) = modal_taps(&mut rng, 2, 48);
        let d = 3;
        let p = Params::init(d, &mut rng);
        let l = taps.len();
        let mut resid = vec![0.0; l];
        let (mut gd, mut gt, mut gr, mut gi) =
            (vec![0.0; d], vec![0.0; d], vec![0.0; d], vec![0.0; d]);
        let base = loss_and_grad(&p, &taps, &mut resid, &mut gd, &mut gt, &mut gr, &mut gi);
        assert!(base.is_finite());
        let eps = 1e-6;
        let fields: [(&[f64], usize); 4] = [(&gd, 0), (&gt, 1), (&gr, 2), (&gi, 3)];
        for (grad, which) in fields {
            for n in 0..d {
                let mut p2 = Params {
                    decay: p.decay.clone(),
                    theta: p.theta.clone(),
                    r_re: p.r_re.clone(),
                    r_im: p.r_im.clone(),
                };
                let field = match which {
                    0 => &mut p2.decay,
                    1 => &mut p2.theta,
                    2 => &mut p2.r_re,
                    _ => &mut p2.r_im,
                };
                field[n] += eps;
                let mut r2 = vec![0.0; l];
                let (mut a, mut b, mut c, mut dd) =
                    (vec![0.0; d], vec![0.0; d], vec![0.0; d], vec![0.0; d]);
                let lp =
                    loss_and_grad(&p2, &taps, &mut r2, &mut a, &mut b, &mut c, &mut dd);
                let fd = (lp - base) / eps;
                assert!(
                    (fd - grad[n]).abs() < 1e-3 * (1.0 + grad[n].abs()),
                    "fd {fd} vs analytic {}",
                    grad[n]
                );
            }
        }
    }

    #[test]
    fn well_specified_recovery() {
        // a filter that IS a low-order modal SSM distills to ~zero error
        check("well-specified modal recovery", 4, |rng| {
            let pairs = 1 + rng.below(2);
            let (taps, d_true) = modal_taps(rng, pairs, 64);
            let cfg = DistillConfig {
                order: d_true + 2,
                iters: 2500,
                restarts: 2,
                seed: rng.next_u64(),
                ..DistillConfig::default()
            };
            let r = distill_modal(&taps, 0.0, &cfg);
            if r.rel_err < 0.02 {
                Ok(())
            } else {
                Err(format!("rel_err {:.4} (d_true={d_true})", r.rel_err))
            }
        });
    }

    #[test]
    fn stability_projection_holds() {
        let mut rng = Prng::new(9);
        let (taps, _) = modal_taps(&mut rng, 2, 64);
        let cfg = DistillConfig { order: 8, iters: 400, ..DistillConfig::default() };
        let r = distill_modal(&taps, 0.5, &cfg);
        assert!(r.ssm.spectral_radius() <= cfg.max_radius + 1e-12);
        assert_eq!(r.ssm.h0, 0.5);
    }

    #[test]
    fn more_order_no_worse() {
        let mut rng = Prng::new(11);
        let (taps, _) = modal_taps(&mut rng, 3, 96);
        let small = distill_modal(
            &taps,
            0.0,
            &DistillConfig { order: 2, iters: 1200, ..Default::default() },
        );
        let large = distill_modal(
            &taps,
            0.0,
            &DistillConfig { order: 10, iters: 1200, ..Default::default() },
        );
        assert!(large.rel_err <= small.rel_err * 1.05, "{} vs {}", large.rel_err, small.rel_err);
    }

    #[test]
    fn h2_equals_l2_by_parseval() {
        let mut rng = Prng::new(13);
        let (taps, _) = modal_taps(&mut rng, 2, 64);
        let r = distill_modal(
            &taps,
            0.0,
            &DistillConfig { order: 4, iters: 300, ..Default::default() },
        );
        let l2: f64 = {
            let hh = r.ssm.impulse_response(taps.len());
            hh.iter().zip(&taps).map(|(a, b)| (a - b) * (a - b)).sum()
        };
        let h2 = h2_loss(&r.ssm, &taps);
        assert!((l2 - h2).abs() < 1e-8 * l2.max(1e-12), "{l2} vs {h2}");
    }
}
