//! Padé rational interpolation (paper App. B.2 footnote 15): match the
//! first 2d+1 taps of the filter exactly by solving a d-dimensional linear
//! (Toeplitz) system — o(z^{-L}) error at infinity, but "known to often
//! become numerically ill-conditioned even with small d".

use crate::linalg::lu::solve_real;
use crate::linalg::Mat;
use crate::ssm::TransferFunction;

/// Order-d Padé approximant of the filter [h0, taps...] as a transfer
/// function: H(z) = (b0 + .. + bd z^-d) / (1 + a1 z^-1 + .. + ad z^-d)
/// matching h_t exactly for t = 0..2d.
pub fn pade(taps: &[f64], h0: f64, d: usize) -> Option<TransferFunction> {
    if taps.len() < 2 * d {
        return None;
    }
    // full tap sequence including the passthrough
    let mut h = Vec::with_capacity(taps.len() + 1);
    h.push(h0);
    h.extend_from_slice(taps);
    // Denominator from the linear system:
    //   sum_{j=1..d} a_j h_{t-j} = -h_t   for t = d+1 .. 2d
    let mut m = Mat::zeros(d, d);
    let mut rhs = vec![0.0; d];
    for (row, t) in (d + 1..=2 * d).enumerate() {
        for j in 1..=d {
            m[(row, j - 1)] = h[t - j];
        }
        rhs[row] = -h[t];
    }
    let a_tail = solve_real(&m, &rhs)?;
    let mut a = vec![1.0];
    a.extend(a_tail);
    // Numerator by forward substitution: b_t = h_t + sum_j a_j h_{t-j}
    let mut b = vec![0.0; d + 1];
    for t in 0..=d {
        let mut acc = h[t];
        for j in 1..=d.min(t) {
            acc += a[j] * h[t - j];
        }
        b[t] = acc;
    }
    Some(TransferFunction::new(b, a))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::C64;
    use crate::ssm::ModalSsm;
    use crate::util::prop::{assert_close, check};

    #[test]
    fn matches_first_2d_taps_exactly() {
        check("pade matches first 2d+1 taps", 12, |rng| {
            let d = 2 + rng.below(3);
            let taps = rng.normal_vec(4 * d);
            let h0 = rng.normal();
            let tf = match pade(&taps, h0, d) {
                Some(tf) => tf,
                None => return Ok(()), // singular Toeplitz draw
            };
            let got = tf.impulse_response(2 * d + 1);
            let mut want = vec![h0];
            want.extend(&taps[..2 * d]);
            assert_close(&got, &want, 1e-6, 1e-6)
        });
    }

    #[test]
    fn exact_on_rational_filters() {
        let ps = [(C64::polar(0.7, 0.9), C64::new(0.5, 1.0))];
        let sys = ModalSsm::from_conjugate_pairs(&ps, 0.2);
        let taps = sys.impulse_response(32);
        let tf = pade(&taps, 0.2, 2).expect("pade");
        // rational of true order: matches everywhere, not just 2d taps
        let got = tf.impulse_response(32);
        let mut want = vec![0.2];
        want.extend(&taps[..31]);
        assert_close(&got, &want, 1e-7, 1e-7).unwrap();
    }

    #[test]
    fn insufficient_taps_rejected() {
        assert!(pade(&[1.0, 2.0], 0.0, 4).is_none());
    }
}
