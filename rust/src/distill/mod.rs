//! The Laughing Hyena Distillery (paper §3) — native implementation.
//!
//! Given the taps of a pre-trained long-convolution filter, produce a
//! compact [`crate::ssm::ModalSsm`] whose impulse response interpolates it:
//!
//! * [`modal_fit`] — the paper's method: gradient-based nonlinear least
//!   squares over polar poles + cartesian residues (§3.2, App. B.1), with
//!   l2 or H2 objectives (§3.1) and Adam + cosine schedule.
//! * [`prony`] — Prony's 1795 two-stage linear solution (§3.2 mentions it
//!   as the classical, numerically fragile alternative).
//! * [`pade`] — Padé rational interpolation on the first 2d taps
//!   (App. B.2 footnote 15 baseline).
//! * [`modal_trunc`] / [`balanced`] — classical model-order reduction
//!   baselines from App. E.3.
//! * [`prefill`] — the three prompt-state initialization strategies of
//!   §3.4 (recurrent, closed-form powers, Prop-3.2 FFT).
//! * [`pipeline`] — the end-to-end distillery: Hankel spectrum → order
//!   selection → fit → validation report.

pub mod balanced;
pub mod modal_fit;
pub mod modal_trunc;
pub mod pade;
pub mod pipeline;
pub mod prefill;
pub mod prony;

pub use modal_fit::{DistillConfig, DistillResult, Objective};
pub use pipeline::{DistilledFilter, Distillery, DistilleryReport};
