//! Prompt pre-filling strategies (paper §3.4): initialize the recurrent
//! state x_T from a length-T prompt before auto-regressive generation.
//!
//! Three implementations with the paper's trade-offs:
//! * [`prefill_recurrent`] — O(dT) time, O(d) memory.
//! * [`prefill_powers`]    — same asymptotics, vectorization-friendly
//!   closed form x_n = sum_j lambda_n^{T-1-j} u_j (what the L2 JAX prefill
//!   graph computes on the MXU).
//! * [`FftPrefiller`]      — Prop. 3.2: one FFT convolution with
//!   g = Z^{-1} of 1/den gives the companion state in Õ(T); a fixed d x d
//!   similarity transform maps it to modal coordinates.

use crate::dsp::conv::causal_conv_fft;
use crate::dsp::C64;
use crate::linalg::lu::{lstsq_c64, solve_c64};
use crate::ssm::modal::ModalState;
use crate::ssm::{ModalSsm, TransferFunction};

/// O(dT) recurrent prefill (re-export of the ModalSsm method for symmetry).
pub fn prefill_recurrent(sys: &ModalSsm, u: &[f64]) -> ModalState {
    sys.prefill_recurrent(u)
}

/// Closed-form powers prefill: x_n = sum_{j} lambda_n^{T-1-j} u_j.
pub fn prefill_powers(sys: &ModalSsm, u: &[f64]) -> ModalState {
    let t = u.len();
    let d = sys.order();
    let mut state = vec![C64::ZERO; d];
    for (n, &lam) in sys.poles.iter().enumerate() {
        // Horner over the prompt: x = u_0; x = lam*x + u_j ...
        let mut acc = C64::ZERO;
        for &x in u.iter().take(t) {
            acc = lam * acc + C64::real(x);
        }
        state[n] = acc;
    }
    ModalState(state)
}

/// Precomputed Prop-3.2 FFT prefiller for one modal system.
///
/// Build once per distilled filter: converts the modal form to its rational
/// denominator, and solves the d x d similarity transform K with
/// x_modal = K x_companion (both are states of minimal realizations of the
/// same transfer function, so K is exact — Lemma A.3).
pub struct FftPrefiller {
    /// Denominator coefficients [1, a1..ad].
    den: Vec<f64>,
    /// Modal-from-companion transform K [d x dc] where dc is the order of
    /// the conjugate closure's companion realization.
    k: Vec<Vec<C64>>,
    d: usize,
    dc: usize,
    /// Cached g = Z^{-1}[1/den] taps, grown lazily (§Perf: recomputing g
    /// per prefill cost O(dT) and dominated short prompts).
    g_cache: std::cell::RefCell<Vec<f64>>,
}

impl FftPrefiller {
    pub fn new(sys: &ModalSsm) -> Option<FftPrefiller> {
        let d = sys.order();
        // distilled systems are not conjugate-closed; the real rational
        // form (hence the real-input convolution of Prop 3.2) requires the
        // order-2d closure
        let tf = TransferFunction::from_modal_real(sys);
        let comp = tf.to_companion();
        let dc = comp.order();
        // Solve K from simulated trajectories: drive both realizations with
        // a probe input; collect >= d samples of both states.
        let probe_len = 3 * d + 8;
        let mut rng = crate::util::Prng::new(0x5EED);
        let u: Vec<f64> = (0..probe_len).map(|_| rng.normal()).collect();
        let mut comp_st = comp.zero_state();
        let mut modal_st = sys.zero_state();
        let mut rows: Vec<Vec<C64>> = vec![]; // companion states (flattened)
        let mut rhs: Vec<Vec<C64>> = vec![]; // modal states
        for &x in &u {
            comp.step(&mut comp_st, x);
            sys.step(&mut modal_st, x);
            rows.push(companion_state_vec(&comp, &comp_st));
            rhs.push(modal_st.0.clone());
        }
        // K row m solves: rows * K[m]^T = rhs[:, m]
        let mut k = vec![vec![C64::ZERO; d]; d];
        for m in 0..d {
            let b: Vec<C64> = rhs.iter().map(|r| r[m]).collect();
            let sol = lstsq_c64(&rows, &b, 1e-12)?;
            k[m] = sol;
        }
        Some(FftPrefiller {
            den: tf.a.clone(),
            k,
            d,
            dc,
            g_cache: std::cell::RefCell::new(Vec::new()),
        })
    }

    /// Õ(T) prefill: v = g * u via FFT (spectral division by the
    /// denominator), companion state = last d taps of v, then x = K x_c.
    pub fn prefill(&self, u: &[f64]) -> ModalState {
        let t = u.len();
        // v from one FFT convolution with g (g truncated at prompt length
        // is exact for the needed v window because g is causal); g taps are
        // cached across calls and extended on demand
        {
            let mut cache = self.g_cache.borrow_mut();
            if cache.len() < t {
                *cache =
                    TransferFunction::new(vec![1.0], self.den.clone()).prefill_filter(t);
            }
        }
        let cache = self.g_cache.borrow();
        let v = causal_conv_fft(&cache[..t], u);
        let mut xc = vec![C64::ZERO; self.dc];
        for kk in 0..self.dc {
            let idx = t as isize - 1 - kk as isize;
            xc[kk] = if idx >= 0 { C64::real(v[idx as usize]) } else { C64::ZERO };
        }
        let state: Vec<C64> = (0..self.d)
            .map(|m| {
                let mut acc = C64::ZERO;
                for (kk, &x) in xc.iter().enumerate() {
                    acc += self.k[m][kk] * x;
                }
                acc
            })
            .collect();
        ModalState(state)
    }
}

fn companion_state_vec(
    comp: &crate::ssm::CompanionSsm,
    st: &crate::ssm::companion::CompanionState,
) -> Vec<C64> {
    // x^1..x^d in canonical order
    st.snapshot(comp.order()).into_iter().map(C64::real).collect()
}

/// Solve-based exactness check helper (used by tests): max |K xc - xm|.
pub fn transform_residual(pref: &FftPrefiller, xc: &[C64], xm: &[C64]) -> f64 {
    let mut worst = 0.0f64;
    for m in 0..pref.d {
        let mut acc = C64::ZERO;
        for (k, &x) in xc.iter().enumerate().take(pref.dc) {
            acc += pref.k[m][k] * x;
        }
        worst = worst.max((acc - xm[m]).abs());
    }
    worst
}

// keep solve_c64 linked for doc purposes
#[allow(dead_code)]
fn _unused(a: &[Vec<C64>], b: &[C64]) -> Option<Vec<C64>> {
    solve_c64(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::Prng;

    fn random_modal(rng: &mut Prng, pairs: usize) -> ModalSsm {
        let ps: Vec<(C64, C64)> = (0..pairs)
            .map(|_| {
                (
                    C64::polar(rng.range(0.4, 0.9), rng.range(0.3, 2.7)),
                    C64::new(rng.normal(), rng.normal()),
                )
            })
            .collect();
        ModalSsm::from_conjugate_pairs(&ps, 0.1)
    }

    #[test]
    fn powers_matches_recurrent() {
        check("powers prefill == recurrent prefill", 12, |rng| {
            let pairs = 1 + rng.below(3);
            let sys = random_modal(rng, pairs);
            let u = rng.normal_vec(40);
            let a = prefill_recurrent(&sys, &u);
            let b = prefill_powers(&sys, &u);
            for (x, y) in a.0.iter().zip(&b.0) {
                if (*x - *y).abs() > 1e-8 * (1.0 + y.abs()) {
                    return Err(format!("{x:?} vs {y:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fft_prefill_matches_recurrent() {
        check("prop 3.2 fft prefill == recurrent", 8, |rng| {
            let pairs = 1 + rng.below(2);
            let sys = random_modal(rng, pairs);
            let pref = match FftPrefiller::new(&sys) {
                Some(p) => p,
                None => return Err("prefiller build failed".into()),
            };
            let u = rng.normal_vec(64);
            let want = prefill_recurrent(&sys, &u);
            let got = pref.prefill(&u);
            for (x, y) in got.0.iter().zip(&want.0) {
                if (*x - *y).abs() > 1e-5 * (1.0 + y.abs()) {
                    return Err(format!("{x:?} vs {y:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn generation_after_prefill_is_seamless() {
        // prefill + decode == running the recurrence over prompt+tokens
        let mut rng = Prng::new(21);
        let sys = random_modal(&mut rng, 2);
        let prompt = rng.normal_vec(32);
        let cont = rng.normal_vec(8);
        // reference: one long recurrence
        let mut st_ref = sys.zero_state();
        for &x in &prompt {
            sys.step(&mut st_ref, x);
        }
        let ref_out: Vec<f64> = cont.iter().map(|&x| sys.step(&mut st_ref, x)).collect();
        // prefill path
        let mut st = prefill_powers(&sys, &prompt);
        let got: Vec<f64> = cont.iter().map(|&x| sys.step(&mut st, x)).collect();
        for (a, b) in got.iter().zip(&ref_out) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }
}
