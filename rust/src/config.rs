//! Configuration system: a TOML-subset parser (serde/toml are unavailable
//! offline) plus the typed configs the launcher consumes.
//!
//! Supported syntax: `[section]` headers, `key = value` with string,
//! integer, float and boolean values, `#` comments.

use std::collections::BTreeMap;

/// Parsed config: section -> key -> raw value.
#[derive(Clone, Debug, Default)]
pub struct RawConfig {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

/// Parse error with line information (`thiserror` is unavailable offline,
/// so `Display`/`Error` are implemented by hand).
#[derive(Debug)]
pub struct ParseError {
    /// 1-based line number the error was detected on.
    pub line: usize,
    /// Human-readable description of what went wrong on that line.
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl RawConfig {
    pub fn parse(text: &str) -> Result<RawConfig, ParseError> {
        let mut cfg = RawConfig::default();
        let mut section = String::new();
        for (no, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') || line.len() < 3 {
                    return Err(ParseError { line: no + 1, msg: format!("bad section: {line}") });
                }
                section = line[1..line.len() - 1].trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
            } else if let Some(eq) = line.find('=') {
                let key = line[..eq].trim().to_string();
                let mut val = line[eq + 1..].trim().to_string();
                if val.starts_with('"') && val.ends_with('"') && val.len() >= 2 {
                    val = val[1..val.len() - 1].to_string();
                }
                if key.is_empty() {
                    return Err(ParseError { line: no + 1, msg: "empty key".into() });
                }
                cfg.sections.entry(section.clone()).or_default().insert(key, val);
            } else {
                return Err(ParseError { line: no + 1, msg: format!("expected key = value: {line}") });
            }
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> anyhow::Result<RawConfig> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::parse(&text)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).unwrap_or(default)
    }
}

/// Model architecture config — mirrors python/compile/model.py::Config so
/// the launcher, the AOT manifests and the native engines agree on shapes.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub kind: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layer: usize,
    pub heads: usize,
    pub seq_len: usize,
    pub short_kw: usize,
    pub mlp_mult: usize,
    pub d_state: usize,
}

impl ModelConfig {
    /// Named presets matching aot.py's TINY / SMALL / AR configs.
    pub fn preset(name: &str) -> Option<ModelConfig> {
        let base = ModelConfig {
            kind: "multihyena".into(),
            vocab: 512,
            d_model: 96,
            n_layer: 3,
            heads: 8,
            seq_len: 256,
            short_kw: 3,
            mlp_mult: 2,
            d_state: 16,
        };
        match name {
            "small" => Some(base),
            "tiny" => Some(ModelConfig {
                vocab: 64,
                d_model: 32,
                n_layer: 2,
                heads: 4,
                seq_len: 64,
                d_state: 8,
                ..base
            }),
            "ar" => Some(ModelConfig {
                vocab: 128,
                d_model: 64,
                n_layer: 2,
                heads: 8,
                seq_len: 512,
                d_state: 8,
                ..base
            }),
            _ => None,
        }
    }

    /// Long-conv filters per layer (M for multihyena, D for plain hyena).
    pub fn n_filters(&self) -> usize {
        if self.kind == "hyena" {
            self.d_model
        } else {
            self.heads
        }
    }

    pub fn from_raw(raw: &RawConfig) -> ModelConfig {
        let mut base = ModelConfig::preset(raw.get_str("model", "preset", "small"))
            .unwrap_or_else(|| ModelConfig::preset("small").unwrap());
        base.kind = raw.get_str("model", "kind", &base.kind.clone()).to_string();
        base.vocab = raw.get_usize("model", "vocab", base.vocab);
        base.d_model = raw.get_usize("model", "d_model", base.d_model);
        base.n_layer = raw.get_usize("model", "n_layer", base.n_layer);
        base.heads = raw.get_usize("model", "heads", base.heads);
        base.seq_len = raw.get_usize("model", "seq_len", base.seq_len);
        base.d_state = raw.get_usize("model", "d_state", base.d_state);
        base
    }
}

/// When the write-ahead turn journal forces its appends to disk — the
/// durability/throughput ladder (`crate::session::journal`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// `fsync` after every appended record: an acked turn survives power
    /// loss, at one disk sync per turn.
    PerRecord,
    /// `fsync` at most once per window of this many milliseconds: a crash
    /// can lose at most the last window of acked turns (process crashes
    /// lose nothing — the bytes are in the page cache either way).
    Batched(u64),
    /// Never `fsync` (the OS flushes when it pleases).  Survives process
    /// crashes, not power loss.
    Off,
}

impl FsyncPolicy {
    /// Parse the config-file spelling: `per-record`, `batched:<ms>`, `off`.
    pub fn parse(s: &str) -> Option<FsyncPolicy> {
        match s {
            "per-record" => Some(FsyncPolicy::PerRecord),
            "off" => Some(FsyncPolicy::Off),
            _ => s
                .strip_prefix("batched:")
                .and_then(|ms| ms.parse().ok())
                .map(FsyncPolicy::Batched),
        }
    }
}

impl Default for FsyncPolicy {
    fn default() -> Self {
        FsyncPolicy::Batched(10)
    }
}

/// Serving coordinator config.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Fixed engine batch (artifact batch for the AOT path).
    pub max_batch: usize,
    /// Batching linger before dispatching a partial batch.
    pub linger_ms: u64,
    pub max_new_tokens: usize,
    /// Device memory budget for the admission ledger (bytes).
    pub mem_budget: u64,
    /// RAM budget for the multi-turn session store (bytes); LRU sessions
    /// beyond it are evicted (to `session_spill_dir` when set).
    pub session_budget: u64,
    /// Directory evicted session states spill to (None = drop on evict and
    /// re-prefill the transcript on the next turn).
    pub session_spill_dir: Option<String>,
    /// Byte cap of the disk spill tier's live records (0 = unbounded);
    /// past it the least-recently-spilled sessions are dropped from disk.
    pub session_spill_budget: u64,
    /// Idle-session TTL in milliseconds (0 = never expire).  A session
    /// untouched this long is fully forgotten — state, spill record, and
    /// coordinator-resident transcript — so abandoned conversations cost
    /// zero RAM.
    pub session_ttl_ms: u64,
    /// Admission-queue length cap (0 = unbounded); arrivals past it are
    /// refused with a typed `Overloaded` instead of queued.
    pub max_queue: usize,
    /// Directory the router's write-ahead turn journal lives in (None =
    /// no journal: a router crash forgets the transcript mirror, exactly
    /// the pre-journal behavior).
    pub journal_dir: Option<String>,
    /// When journal appends are forced to disk; see [`FsyncPolicy`].
    pub journal_fsync: FsyncPolicy,
    /// Shared-secret handshake token (None = open, the default).  With a
    /// token set, a shard requires the first frame after its Hello to be
    /// an `Auth` carrying the same token (compared in constant time) and
    /// refuses everything else with the typed `AuthFailed`.
    pub auth_token: Option<String>,
    /// Listener bind address (None = loopback `127.0.0.1`, the default).
    /// Non-loopback binds are opt-in and should travel with `auth_token`.
    pub bind_addr: Option<String>,
    /// Head-sample 1-in-N requests for engine hot-path profiling at the
    /// front door (0 = off, the default).  Sampled requests' traces gain
    /// per-stage engine spans and feed the `lh_engine_*` histograms;
    /// client-traced requests are always profiled regardless.
    pub profile_sample: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            linger_ms: 2,
            max_new_tokens: 64,
            mem_budget: 2 << 30,
            session_budget: 256 << 20,
            session_spill_dir: None,
            session_spill_budget: 0,
            session_ttl_ms: 0,
            max_queue: 0,
            journal_dir: None,
            journal_fsync: FsyncPolicy::default(),
            auth_token: None,
            bind_addr: None,
            profile_sample: 0,
        }
    }
}

impl ServeConfig {
    pub fn from_raw(raw: &RawConfig) -> ServeConfig {
        let d = ServeConfig::default();
        ServeConfig {
            max_batch: raw.get_usize("serve", "max_batch", d.max_batch),
            linger_ms: raw.get_usize("serve", "linger_ms", d.linger_ms as usize) as u64,
            max_new_tokens: raw.get_usize("serve", "max_new_tokens", d.max_new_tokens),
            mem_budget: raw.get_usize("serve", "mem_budget", d.mem_budget as usize) as u64,
            session_budget: raw
                .get_usize("serve", "session_budget", d.session_budget as usize)
                as u64,
            session_spill_dir: raw
                .get("serve", "session_spill_dir")
                .filter(|s| !s.is_empty())
                .map(|s| s.to_string()),
            session_spill_budget: raw
                .get_usize("serve", "session_spill_budget", d.session_spill_budget as usize)
                as u64,
            session_ttl_ms: raw
                .get_usize("serve", "session_ttl_ms", d.session_ttl_ms as usize)
                as u64,
            max_queue: raw.get_usize("serve", "max_queue", d.max_queue),
            journal_dir: raw
                .get("serve", "journal_dir")
                .filter(|s| !s.is_empty())
                .map(|s| s.to_string()),
            journal_fsync: raw
                .get("serve", "journal_fsync")
                .and_then(FsyncPolicy::parse)
                .unwrap_or(d.journal_fsync),
            auth_token: raw
                .get("serve", "auth_token")
                .filter(|s| !s.is_empty())
                .map(|s| s.to_string()),
            bind_addr: raw
                .get("serve", "bind_addr")
                .filter(|s| !s.is_empty())
                .map(|s| s.to_string()),
            profile_sample: raw
                .get_usize("serve", "profile_sample", d.profile_sample as usize)
                as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let raw = RawConfig::parse(
            "# comment\n[model]\npreset = \"tiny\"\nd_model = 48\n\n[serve]\nmax_batch = 4\nlinger_ms = 7\n",
        )
        .unwrap();
        assert_eq!(raw.get("model", "preset"), Some("tiny"));
        assert_eq!(raw.get_usize("serve", "max_batch", 0), 4);
        let mc = ModelConfig::from_raw(&raw);
        assert_eq!(mc.d_model, 48);
        assert_eq!(mc.vocab, 64); // from tiny preset
        let sc = ServeConfig::from_raw(&raw);
        assert_eq!(sc.linger_ms, 7);
        assert_eq!(sc.session_budget, 256 << 20); // default survives
        assert_eq!(sc.session_spill_dir, None);
    }

    #[test]
    fn parses_session_settings() {
        let raw = RawConfig::parse(
            "[serve]\nsession_budget = 1024\nsession_spill_dir = \"/tmp/spill\"\n\
             session_spill_budget = 4096\nsession_ttl_ms = 60000\nmax_queue = 128\n",
        )
        .unwrap();
        let sc = ServeConfig::from_raw(&raw);
        assert_eq!(sc.session_budget, 1024);
        assert_eq!(sc.session_spill_dir.as_deref(), Some("/tmp/spill"));
        assert_eq!(sc.session_spill_budget, 4096);
        assert_eq!(sc.session_ttl_ms, 60_000);
        assert_eq!(sc.max_queue, 128);
        // overload knobs default to "off" (0) so existing setups behave
        // exactly as before
        let d = ServeConfig::default();
        assert_eq!(d.session_spill_budget, 0);
        assert_eq!(d.session_ttl_ms, 0);
        assert_eq!(d.max_queue, 0);
    }

    #[test]
    fn parses_durability_and_transport_settings() {
        let raw = RawConfig::parse(
            "[serve]\njournal_dir = \"/tmp/wal\"\njournal_fsync = \"per-record\"\n\
             auth_token = \"hunter2\"\nbind_addr = \"0.0.0.0\"\nprofile_sample = 16\n",
        )
        .unwrap();
        let sc = ServeConfig::from_raw(&raw);
        assert_eq!(sc.journal_dir.as_deref(), Some("/tmp/wal"));
        assert_eq!(sc.journal_fsync, FsyncPolicy::PerRecord);
        assert_eq!(sc.auth_token.as_deref(), Some("hunter2"));
        assert_eq!(sc.bind_addr.as_deref(), Some("0.0.0.0"));
        assert_eq!(sc.profile_sample, 16);
        // defaults: no journal, batched fsync, open auth, loopback bind,
        // profiling off
        let d = ServeConfig::default();
        assert_eq!(d.journal_dir, None);
        assert_eq!(d.journal_fsync, FsyncPolicy::Batched(10));
        assert_eq!(d.auth_token, None);
        assert_eq!(d.bind_addr, None);
        assert_eq!(d.profile_sample, 0);
    }

    #[test]
    fn fsync_policy_parses_the_ladder_and_rejects_garbage() {
        assert_eq!(FsyncPolicy::parse("per-record"), Some(FsyncPolicy::PerRecord));
        assert_eq!(FsyncPolicy::parse("off"), Some(FsyncPolicy::Off));
        assert_eq!(FsyncPolicy::parse("batched:25"), Some(FsyncPolicy::Batched(25)));
        assert_eq!(FsyncPolicy::parse("batched:0"), Some(FsyncPolicy::Batched(0)));
        assert_eq!(FsyncPolicy::parse("batched:"), None);
        assert_eq!(FsyncPolicy::parse("batched:x"), None);
        assert_eq!(FsyncPolicy::parse("always"), None);
        assert_eq!(FsyncPolicy::parse(""), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(RawConfig::parse("not a kv line").is_err());
        assert!(RawConfig::parse("[unterminated\n").is_err());
    }

    #[test]
    fn presets_exist() {
        for p in ["tiny", "small", "ar"] {
            assert!(ModelConfig::preset(p).is_some(), "{p}");
        }
        assert!(ModelConfig::preset("nope").is_none());
    }

    #[test]
    fn hyena_filter_count_is_width() {
        let mut c = ModelConfig::preset("small").unwrap();
        assert_eq!(c.n_filters(), 8);
        c.kind = "hyena".into();
        assert_eq!(c.n_filters(), 96);
    }
}
