//! Append-only write-ahead turn journal — the durability substrate the
//! serve layer was missing.
//!
//! Every recovery path the cluster had before this module (shard
//! resurrection, TTL zero-RAM resume) bottoms out in the router's in-RAM
//! transcript mirror: one SIGKILL and every conversation is forgotten.
//! The journal closes that hole for a few KiB per turn — cheap precisely
//! because distillation makes per-session state constant-size, so a turn
//! record is `O(delta)` tokens, not an `O(t)` KV cache.
//!
//! ## Record format
//!
//! The on-disk framing deliberately mirrors the wire protocol
//! (`[u32 len][body][u64 fnv1a64(body)]`, little-endian throughout):
//!
//! ```text
//! [u32 len][u8 kind][payload; len-1 bytes][u64 fnv1a64(kind ++ payload)]
//! ```
//!
//! | kind | name | payload |
//! |------|------|---------|
//! | 1 | `Turn` | `[u64 session][u32 prior_len][tokens delta][tokens generated]` |
//! | 2 | `Set`  | `[u64 session][tokens transcript]` (snapshot / reconcile) |
//! | 3 | `End`  | `[u64 session]` |
//!
//! where `tokens` is `u32 count` followed by `count` `i32`s.  A `Turn`
//! record carries `prior_len` — the transcript length it extends — so
//! replay can detect both gaps (a turn whose prefix never landed: typed
//! corruption) and duplicates (the same turn appended twice because the
//! process crashed between append and ack: deduped, not double-applied).
//!
//! ## Torn tails vs corruption
//!
//! A crash mid-append leaves a *prefix* of a record at the end of the
//! **last** segment.  Replay truncates the file back to the last valid
//! record and carries on — that is expected physics, not an error.  The
//! same damage anywhere else (a short record in a sealed segment, a bad
//! checksum that is not the final bytes of the last segment, a length
//! field that no append could have produced) is surfaced as a typed
//! [`JournalError::Corrupt`] and never a panic: refusing to serve from a
//! journal that lies beats silently resurrecting the wrong transcript.
//!
//! ## Fsync ladder and compaction
//!
//! Appends sync per [`crate::config::FsyncPolicy`]: every record, at most
//! once per batched window (piggybacked on appends — no timer threads),
//! or never.  Segments rotate at a byte threshold; when sealed bytes
//! dwarf the live transcript set (the same live-ratio rule as the spill
//! tier's compaction) the journal rewrites itself as one snapshot
//! segment of `Set` records — plus a trailing `Turn` for each session's
//! last turn, so the crash-between-append-and-ack dedup window survives
//! compaction.  The snapshot goes tmp-file → `sync_all` → atomic rename
//! → directory fsync, so a crash mid-compaction leaves either the old
//! segments or the new snapshot, never a half state.

use std::collections::HashMap;
use std::fmt;
use std::fs::{self, File, OpenOptions};
use std::io::{self, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::FsyncPolicy;
use crate::serve::faults::{FaultPlan, Point};
use crate::util::bytes::{fnv1a64, ByteReader};

const REC_TURN: u8 = 1;
const REC_SET: u8 = 2;
const REC_END: u8 = 3;

/// Hard cap on one record's `len` field — matches the wire layer's frame
/// cap.  A torn append produces a *short* file, never a garbage length,
/// so an oversized length is always corruption, even at the tail.
const MAX_RECORD_BYTES: usize = 64 << 20;

/// Smallest possible record: 4 (len) + 1 (kind) + 8 (checksum).
const REC_MIN: usize = 13;

/// Why the journal failed.
#[derive(Debug)]
pub enum JournalError {
    Io(io::Error),
    /// A sealed record failed validation — not a torn tail.  The journal
    /// refuses to replay past it rather than guess at transcripts.
    Corrupt {
        segment: String,
        offset: u64,
        reason: String,
    },
}

impl fmt::Display for JournalError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JournalError::Io(e) => write!(f, "journal io: {e}"),
            JournalError::Corrupt { segment, offset, reason } => {
                write!(f, "journal corrupt: {segment} at byte {offset}: {reason}")
            }
        }
    }
}

impl std::error::Error for JournalError {}

impl From<io::Error> for JournalError {
    fn from(e: io::Error) -> JournalError {
        JournalError::Io(e)
    }
}

/// Where and how the journal lives.
#[derive(Clone, Debug)]
pub struct JournalConfig {
    pub dir: PathBuf,
    pub fsync: FsyncPolicy,
    /// Rotate the active segment once it reaches this many bytes.
    pub segment_bytes: u64,
}

impl JournalConfig {
    pub fn new(dir: impl Into<PathBuf>) -> JournalConfig {
        JournalConfig { dir: dir.into(), fsync: FsyncPolicy::default(), segment_bytes: 1 << 20 }
    }
}

/// Counters the `obs` registry scrapes (`lh_journal_*`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct JournalStats {
    /// Records durably appended by this process.
    pub appended: u64,
    /// Records applied during replay at open.
    pub replayed: u64,
    /// Duplicate turn records skipped (replay dedup + router retry dedup).
    pub deduped: u64,
    /// Torn tails truncated at open.
    pub truncated_tails: u64,
    /// Live-ratio compactions performed.
    pub compactions: u64,
    /// Appends that failed (including injected crash faults).
    pub append_errors: u64,
}

/// What replay reconstructed: the full transcript per live session, plus
/// each session's last `(delta, generated)` turn — the dedup window a
/// restarted router consults when a client retries a turn that was
/// journaled but never acked.
#[derive(Clone, Debug, Default)]
pub struct Replay {
    pub sessions: HashMap<u64, Vec<i32>>,
    pub last_turn: HashMap<u64, (Vec<i32>, Vec<i32>)>,
}

/// The append-only journal.  Single-writer by construction (`&mut self`
/// appends); the router serializes access behind its own lock.
pub struct Journal {
    dir: PathBuf,
    fsync: FsyncPolicy,
    segment_bytes: u64,
    file: File,
    seg_index: u64,
    seg_bytes: u64,
    /// Bytes in sealed (non-active) segments — the compaction trigger.
    sealed_bytes: u64,
    last_turn: HashMap<u64, (Vec<i32>, Vec<i32>)>,
    last_sync: Instant,
    dirty: bool,
    faults: Option<Arc<FaultPlan>>,
    stats: JournalStats,
}

fn segment_name(k: u64) -> String {
    format!("wal{k}.log")
}

fn parse_segment_name(name: &str) -> Option<u64> {
    name.strip_prefix("wal")?.strip_suffix(".log")?.parse().ok()
}

/// fsync the directory so renames / new files are themselves durable.
fn sync_dir(dir: &Path) -> io::Result<()> {
    #[cfg(unix)]
    {
        File::open(dir)?.sync_all()?;
    }
    #[cfg(not(unix))]
    {
        let _ = dir;
    }
    Ok(())
}

fn encode_record(kind: u8, payload: &[u8]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(REC_MIN + payload.len());
    buf.extend_from_slice(&((1 + payload.len()) as u32).to_le_bytes());
    buf.push(kind);
    buf.extend_from_slice(payload);
    let mut body = Vec::with_capacity(1 + payload.len());
    body.push(kind);
    body.extend_from_slice(payload);
    buf.extend_from_slice(&fnv1a64(&body).to_le_bytes());
    buf
}

fn push_tokens(buf: &mut Vec<u8>, toks: &[i32]) {
    buf.extend_from_slice(&(toks.len() as u32).to_le_bytes());
    for &t in toks {
        buf.extend_from_slice(&t.to_le_bytes());
    }
}

fn read_tokens(r: &mut ByteReader<'_>) -> Result<Vec<i32>, String> {
    let n = r.u32().map_err(|_| "truncated token count".to_string())? as usize;
    let bytes = n
        .checked_mul(4)
        .ok_or_else(|| "token count overflows".to_string())?;
    let raw = r.take(bytes).map_err(|_| "truncated token list".to_string())?;
    Ok(raw
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// One decoded record.
enum Record {
    Turn { session: u64, prior_len: u32, delta: Vec<i32>, gen: Vec<i32> },
    Set { session: u64, transcript: Vec<i32> },
    End { session: u64 },
}

fn decode_record(kind: u8, payload: &[u8]) -> Result<Record, String> {
    let mut r = ByteReader::new(payload);
    let rec = match kind {
        REC_TURN => {
            let session = r.u64().map_err(|_| "truncated session id")?;
            let prior_len = r.u32().map_err(|_| "truncated prior length")?;
            let delta = read_tokens(&mut r)?;
            let gen = read_tokens(&mut r)?;
            Record::Turn { session, prior_len, delta, gen }
        }
        REC_SET => {
            let session = r.u64().map_err(|_| "truncated session id")?;
            let transcript = read_tokens(&mut r)?;
            Record::Set { session, transcript }
        }
        REC_END => {
            let session = r.u64().map_err(|_| "truncated session id")?;
            Record::End { session }
        }
        other => return Err(format!("unknown record kind {other}")),
    };
    if !r.is_exhausted() {
        return Err("trailing bytes after record payload".to_string());
    }
    Ok(rec)
}

impl Journal {
    /// Open (or create) the journal at `cfg.dir`, replaying every segment
    /// in order.  Returns the journal ready for appends plus the replayed
    /// session set.  A torn tail on the *last* segment is truncated in
    /// place (counted in [`JournalStats::truncated_tails`]); any other
    /// invalid record is a typed [`JournalError::Corrupt`].
    pub fn open(cfg: JournalConfig) -> Result<(Journal, Replay), JournalError> {
        fs::create_dir_all(&cfg.dir)?;
        let mut segments: Vec<u64> = Vec::new();
        for entry in fs::read_dir(&cfg.dir)? {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if name.ends_with(".tmp") {
                // Leftover of an interrupted compaction: never renamed,
                // so never authoritative.  Discard.
                let _ = fs::remove_file(entry.path());
                continue;
            }
            if let Some(k) = parse_segment_name(&name) {
                segments.push(k);
            }
        }
        segments.sort_unstable();

        let mut replay = Replay::default();
        let mut stats = JournalStats::default();
        let mut sealed_bytes = 0u64;
        let mut active_bytes = 0u64;
        let n = segments.len();
        for (i, &k) in segments.iter().enumerate() {
            let last = i + 1 == n;
            let path = cfg.dir.join(segment_name(k));
            let kept = replay_segment(&path, last, &mut replay, &mut stats)?;
            if last {
                active_bytes = kept;
            } else {
                sealed_bytes += kept;
            }
        }

        let seg_index = segments.last().copied().unwrap_or(0);
        let path = cfg.dir.join(segment_name(seg_index));
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        sync_dir(&cfg.dir)?;

        let mut journal = Journal {
            dir: cfg.dir,
            fsync: cfg.fsync,
            segment_bytes: cfg.segment_bytes.max(1),
            file,
            seg_index,
            seg_bytes: active_bytes,
            sealed_bytes,
            last_turn: replay.last_turn.clone(),
            last_sync: Instant::now(),
            dirty: false,
            faults: None,
            stats,
        };
        if journal.seg_bytes >= journal.segment_bytes {
            journal.rotate()?;
        }
        Ok((journal, replay))
    }

    /// Attach a fault plan so tests can drive the four crash windows
    /// (`JournalBeforeAppend` / `JournalAfterAppend` / `JournalTornWrite`
    /// / `JournalLostFsync`).
    pub fn set_faults(&mut self, faults: Option<Arc<FaultPlan>>) {
        self.faults = faults;
    }

    pub fn stats(&self) -> JournalStats {
        self.stats
    }

    /// Record a completed turn: the transcript for `session` was
    /// `prior_len` tokens and grew by `delta ++ gen`.  Must be called
    /// *before* the turn is acked to the client — that ordering is the
    /// whole durability contract.
    pub fn append_turn(
        &mut self,
        session: u64,
        prior_len: u32,
        delta: &[i32],
        gen: &[i32],
    ) -> Result<(), JournalError> {
        let mut payload = Vec::with_capacity(16 + 4 * (delta.len() + gen.len()));
        payload.extend_from_slice(&session.to_le_bytes());
        payload.extend_from_slice(&prior_len.to_le_bytes());
        push_tokens(&mut payload, delta);
        push_tokens(&mut payload, gen);
        self.append(REC_TURN, &payload)?;
        self.last_turn.insert(session, (delta.to_vec(), gen.to_vec()));
        Ok(())
    }

    /// Record the full transcript for `session` (migration landings,
    /// recovery reconciles — anywhere the mirror is *set*, not extended).
    pub fn append_set(&mut self, session: u64, transcript: &[i32]) -> Result<(), JournalError> {
        let mut payload = Vec::with_capacity(12 + 4 * transcript.len());
        payload.extend_from_slice(&session.to_le_bytes());
        push_tokens(&mut payload, transcript);
        self.append(REC_SET, &payload)?;
        self.last_turn.remove(&session);
        Ok(())
    }

    /// Record that `session` ended; replay forgets it.
    pub fn append_end(&mut self, session: u64) -> Result<(), JournalError> {
        self.append(REC_END, &session.to_le_bytes())?;
        self.last_turn.remove(&session);
        Ok(())
    }

    /// Count a router-side retry dedup (the replayed last-turn window
    /// answered a duplicate without touching a shard).
    pub fn note_dedup(&mut self) {
        self.stats.deduped += 1;
    }

    /// Force any batched-but-unsynced bytes to disk.
    pub fn flush(&mut self) -> Result<(), JournalError> {
        if self.dirty {
            self.file.sync_all()?;
            self.dirty = false;
            self.last_sync = Instant::now();
        }
        Ok(())
    }

    fn append(&mut self, kind: u8, payload: &[u8]) -> Result<(), JournalError> {
        let bytes = encode_record(kind, payload);
        if let Some(action) = self.faults.as_ref().and_then(|f| f.fire_local(Point::JournalBeforeAppend)) {
            let _ = action;
            self.stats.append_errors += 1;
            return Err(JournalError::Io(io::Error::new(
                io::ErrorKind::BrokenPipe,
                "injected crash before journal append",
            )));
        }
        if let Some(action) = self.faults.as_ref().and_then(|f| f.fire_local(Point::JournalTornWrite)) {
            let _ = action;
            // Half the record reaches the file — the torn-tail physics a
            // real crash mid-write produces — then the process "dies".
            let half = bytes.len() / 2;
            self.file.write_all(&bytes[..half])?;
            self.file.sync_all()?;
            self.stats.append_errors += 1;
            return Err(JournalError::Io(io::Error::new(
                io::ErrorKind::BrokenPipe,
                "injected torn write during journal append",
            )));
        }
        if let Err(e) = self.file.write_all(&bytes) {
            self.stats.append_errors += 1;
            return Err(JournalError::Io(e));
        }
        self.seg_bytes += bytes.len() as u64;
        self.stats.appended += 1;
        self.dirty = true;
        self.maybe_sync()?;
        if let Some(action) = self.faults.as_ref().and_then(|f| f.fire_local(Point::JournalAfterAppend)) {
            let _ = action;
            // The record IS durable — force it — but the caller never
            // hears, so the turn is journaled-but-unacked.
            self.file.sync_all()?;
            self.dirty = false;
            self.stats.append_errors += 1;
            return Err(JournalError::Io(io::Error::new(
                io::ErrorKind::BrokenPipe,
                "injected crash after journal append, before ack",
            )));
        }
        if self.seg_bytes >= self.segment_bytes {
            self.rotate()?;
        }
        Ok(())
    }

    fn maybe_sync(&mut self) -> Result<(), JournalError> {
        let due = match self.fsync {
            FsyncPolicy::PerRecord => true,
            FsyncPolicy::Batched(ms) => self.last_sync.elapsed() >= Duration::from_millis(ms),
            FsyncPolicy::Off => false,
        };
        if due && self.dirty {
            if self.faults.as_ref().and_then(|f| f.fire_local(Point::JournalLostFsync)).is_some() {
                // Lying disk: pretend the sync happened.
                self.last_sync = Instant::now();
                return Ok(());
            }
            self.file.sync_all()?;
            self.dirty = false;
            self.last_sync = Instant::now();
        }
        Ok(())
    }

    fn rotate(&mut self) -> Result<(), JournalError> {
        self.file.sync_all()?;
        self.dirty = false;
        self.sealed_bytes += self.seg_bytes;
        self.seg_index += 1;
        let path = self.dir.join(segment_name(self.seg_index));
        self.file = OpenOptions::new().create(true).append(true).open(path)?;
        self.seg_bytes = 0;
        sync_dir(&self.dir)?;
        Ok(())
    }

    /// Compact when sealed bytes exceed twice the live set (the spill
    /// tier's live-ratio rule), given the authoritative live transcripts.
    /// Returns whether a compaction ran.
    pub fn maybe_compact(
        &mut self,
        sessions: &HashMap<u64, Vec<i32>>,
    ) -> Result<bool, JournalError> {
        if self.sealed_bytes <= self.segment_bytes {
            return Ok(false);
        }
        let live: u64 = sessions.values().map(|t| 25 + 4 * t.len() as u64).sum();
        if self.sealed_bytes <= live.saturating_mul(2) {
            return Ok(false);
        }
        self.compact(sessions)?;
        Ok(true)
    }

    /// Rewrite the journal as one snapshot segment.  Each session becomes
    /// a `Set` of its transcript — except when its remembered last turn
    /// still forms the transcript's suffix, in which case we write
    /// `Set(prefix)` + `Turn(last)` so the append-vs-ack dedup window
    /// survives the rewrite.
    pub fn compact(&mut self, sessions: &HashMap<u64, Vec<i32>>) -> Result<(), JournalError> {
        self.file.sync_all()?;
        self.dirty = false;
        let snap_index = self.seg_index + 1;
        let snap_name = segment_name(snap_index);
        let tmp_path = self.dir.join(format!("{snap_name}.tmp"));
        let final_path = self.dir.join(&snap_name);

        let mut snap = File::create(&tmp_path)?;
        let mut snap_bytes = 0u64;
        let mut ids: Vec<u64> = sessions.keys().copied().collect();
        ids.sort_unstable();
        for id in ids {
            let transcript = &sessions[&id];
            let records = match self.last_turn.get(&id) {
                Some((delta, gen))
                    if {
                        let tail = delta.len() + gen.len();
                        transcript.len() >= tail
                            && transcript[transcript.len() - tail..transcript.len() - gen.len()]
                                == delta[..]
                            && transcript[transcript.len() - gen.len()..] == gen[..]
                    } =>
                {
                    let prior = transcript.len() - delta.len() - gen.len();
                    let mut set_payload = Vec::new();
                    set_payload.extend_from_slice(&id.to_le_bytes());
                    push_tokens(&mut set_payload, &transcript[..prior]);
                    let mut turn_payload = Vec::new();
                    turn_payload.extend_from_slice(&id.to_le_bytes());
                    turn_payload.extend_from_slice(&(prior as u32).to_le_bytes());
                    push_tokens(&mut turn_payload, delta);
                    push_tokens(&mut turn_payload, gen);
                    vec![
                        encode_record(REC_SET, &set_payload),
                        encode_record(REC_TURN, &turn_payload),
                    ]
                }
                _ => {
                    let mut payload = Vec::new();
                    payload.extend_from_slice(&id.to_le_bytes());
                    push_tokens(&mut payload, transcript);
                    vec![encode_record(REC_SET, &payload)]
                }
            };
            for rec in records {
                snap.write_all(&rec)?;
                snap_bytes += rec.len() as u64;
            }
        }
        snap.sync_all()?;
        drop(snap);
        fs::rename(&tmp_path, &final_path)?;
        sync_dir(&self.dir)?;

        // Old segments (everything below the snapshot) are now dead.
        for k in 0..snap_index {
            let p = self.dir.join(segment_name(k));
            if p.exists() {
                fs::remove_file(p)?;
            }
        }
        self.seg_index = snap_index + 1;
        let active = self.dir.join(segment_name(self.seg_index));
        self.file = OpenOptions::new().create(true).append(true).open(active)?;
        self.seg_bytes = 0;
        self.sealed_bytes = snap_bytes;
        sync_dir(&self.dir)?;
        self.stats.compactions += 1;
        Ok(())
    }
}

/// Replay one segment file into `replay`.  Returns how many bytes of the
/// file are valid (the truncation point when a torn tail is found on the
/// last segment).
fn replay_segment(
    path: &Path,
    last: bool,
    replay: &mut Replay,
    stats: &mut JournalStats,
) -> Result<u64, JournalError> {
    let data = match fs::read(path) {
        Ok(d) => d,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(0),
        Err(e) => return Err(JournalError::Io(e)),
    };
    let name = path.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
    let total = data.len();
    let mut off = 0usize;
    let mut torn = false;
    while off < total {
        let rem = total - off;
        if rem < REC_MIN {
            if last {
                torn = true;
                break;
            }
            return Err(JournalError::Corrupt {
                segment: name,
                offset: off as u64,
                reason: format!("{rem} trailing bytes, too short for any record"),
            });
        }
        let len =
            u32::from_le_bytes([data[off], data[off + 1], data[off + 2], data[off + 3]]) as usize;
        if len == 0 || len > MAX_RECORD_BYTES {
            // A torn append writes a short file, never a garbage length:
            // always corruption.
            return Err(JournalError::Corrupt {
                segment: name,
                offset: off as u64,
                reason: format!("record length {len} out of range"),
            });
        }
        let full = 4 + len + 8;
        if rem < full {
            if last {
                torn = true;
                break;
            }
            return Err(JournalError::Corrupt {
                segment: name,
                offset: off as u64,
                reason: "record extends past end of sealed segment".to_string(),
            });
        }
        let body = &data[off + 4..off + 4 + len];
        let want = u64::from_le_bytes(
            data[off + 4 + len..off + full].try_into().expect("8-byte checksum slice"),
        );
        if fnv1a64(body) != want {
            // A bad checksum is a torn write only if it is the very last
            // record of the last segment (its tail bytes simply never
            // landed); anywhere else the segment is lying.
            if last && off + full == total {
                torn = true;
                break;
            }
            return Err(JournalError::Corrupt {
                segment: name,
                offset: off as u64,
                reason: "record checksum mismatch".to_string(),
            });
        }
        let kind = body[0];
        let rec = decode_record(kind, &body[1..]).map_err(|reason| JournalError::Corrupt {
            segment: name.clone(),
            offset: off as u64,
            reason,
        })?;
        apply(rec, replay, stats, &name, off as u64)?;
        off += full;
    }
    if torn {
        stats.truncated_tails += 1;
        let f = OpenOptions::new().write(true).open(path)?;
        f.set_len(off as u64)?;
        f.sync_all()?;
    }
    Ok(off as u64)
}

fn apply(
    rec: Record,
    replay: &mut Replay,
    stats: &mut JournalStats,
    segment: &str,
    offset: u64,
) -> Result<(), JournalError> {
    match rec {
        Record::Turn { session, prior_len, delta, gen } => {
            let m = replay.sessions.entry(session).or_default();
            let prior = prior_len as usize;
            if prior > m.len() {
                return Err(JournalError::Corrupt {
                    segment: segment.to_string(),
                    offset,
                    reason: format!(
                        "turn record expects transcript length {prior}, have {}",
                        m.len()
                    ),
                });
            }
            let tail = delta.len() + gen.len();
            let dup = m.len() == prior + tail
                && m[prior..prior + delta.len()] == delta[..]
                && m[prior + delta.len()..] == gen[..];
            if dup {
                // The same turn journaled twice — the process crashed
                // between append and ack, the client retried, and both
                // appends landed.  Apply once.
                stats.deduped += 1;
            } else {
                m.truncate(prior);
                m.extend_from_slice(&delta);
                m.extend_from_slice(&gen);
                stats.replayed += 1;
            }
            replay.last_turn.insert(session, (delta, gen));
        }
        Record::Set { session, transcript } => {
            replay.sessions.insert(session, transcript);
            replay.last_turn.remove(&session);
            stats.replayed += 1;
        }
        Record::End { session } => {
            replay.sessions.remove(&session);
            replay.last_turn.remove(&session);
            stats.replayed += 1;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::faults::{FaultAction, Rule};

    fn scratch(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("lh_journal_{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn cfg(dir: &Path) -> JournalConfig {
        JournalConfig { dir: dir.to_path_buf(), fsync: FsyncPolicy::PerRecord, segment_bytes: 1 << 20 }
    }

    #[test]
    fn empty_journal_opens_clean() {
        let dir = scratch("empty");
        let (j, replay) = Journal::open(cfg(&dir)).unwrap();
        assert!(replay.sessions.is_empty());
        assert!(replay.last_turn.is_empty());
        assert_eq!(j.stats(), JournalStats::default());
    }

    #[test]
    fn turns_survive_reopen_bit_exact() {
        let dir = scratch("reopen");
        {
            let (mut j, _) = Journal::open(cfg(&dir)).unwrap();
            j.append_turn(7, 0, &[1, 2], &[3, 4, 5]).unwrap();
            j.append_turn(7, 5, &[6], &[7, 8]).unwrap();
            j.append_turn(9, 0, &[-1], &[-2]).unwrap();
            assert_eq!(j.stats().appended, 3);
        }
        let (j, replay) = Journal::open(cfg(&dir)).unwrap();
        assert_eq!(replay.sessions[&7], vec![1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(replay.sessions[&9], vec![-1, -2]);
        assert_eq!(replay.last_turn[&7], (vec![6], vec![7, 8]));
        assert_eq!(j.stats().replayed, 3);
        assert_eq!(j.stats().truncated_tails, 0);
    }

    #[test]
    fn duplicate_turn_record_is_deduped_on_replay() {
        let dir = scratch("dedup");
        {
            let (mut j, _) = Journal::open(cfg(&dir)).unwrap();
            j.append_turn(1, 0, &[10], &[11, 12]).unwrap();
            // The crash-between-append-and-ack retry: same turn again.
            j.append_turn(1, 0, &[10], &[11, 12]).unwrap();
        }
        let (j, replay) = Journal::open(cfg(&dir)).unwrap();
        assert_eq!(replay.sessions[&1], vec![10, 11, 12], "applied exactly once");
        assert_eq!(j.stats().deduped, 1);
        assert_eq!(j.stats().replayed, 1);
    }

    #[test]
    fn end_record_removes_the_session() {
        let dir = scratch("end");
        {
            let (mut j, _) = Journal::open(cfg(&dir)).unwrap();
            j.append_turn(4, 0, &[1], &[2]).unwrap();
            j.append_end(4).unwrap();
            j.append_turn(5, 0, &[3], &[4]).unwrap();
        }
        let (_, replay) = Journal::open(cfg(&dir)).unwrap();
        assert!(!replay.sessions.contains_key(&4));
        assert!(!replay.last_turn.contains_key(&4));
        assert_eq!(replay.sessions[&5], vec![3, 4]);
    }

    #[test]
    fn set_record_replaces_and_clears_dedup_window() {
        let dir = scratch("set");
        {
            let (mut j, _) = Journal::open(cfg(&dir)).unwrap();
            j.append_turn(2, 0, &[1], &[2]).unwrap();
            j.append_set(2, &[9, 9, 9]).unwrap();
        }
        let (_, replay) = Journal::open(cfg(&dir)).unwrap();
        assert_eq!(replay.sessions[&2], vec![9, 9, 9]);
        assert!(!replay.last_turn.contains_key(&2), "set clears the turn window");
    }

    #[test]
    fn torn_tail_is_truncated_exactly_at_last_valid_record() {
        let dir = scratch("torn");
        let valid_len;
        {
            let (mut j, _) = Journal::open(cfg(&dir)).unwrap();
            j.append_turn(3, 0, &[1, 2, 3], &[4]).unwrap();
            valid_len = fs::metadata(dir.join("wal0.log")).unwrap().len();
            j.append_turn(3, 4, &[5], &[6]).unwrap();
        }
        // Crash mid-second-append: only part of the record landed.
        let full = fs::metadata(dir.join("wal0.log")).unwrap().len();
        let f = OpenOptions::new().write(true).open(dir.join("wal0.log")).unwrap();
        f.set_len(valid_len + (full - valid_len) / 2).unwrap();
        drop(f);

        let (mut j, replay) = Journal::open(cfg(&dir)).unwrap();
        assert_eq!(replay.sessions[&3], vec![1, 2, 3, 4], "only the complete turn survives");
        assert_eq!(j.stats().truncated_tails, 1);
        assert_eq!(
            fs::metadata(dir.join("wal0.log")).unwrap().len(),
            valid_len,
            "file truncated back to the last valid record"
        );
        // The journal keeps working after truncation.
        j.append_turn(3, 4, &[5], &[6]).unwrap();
        let (_, replay) = Journal::open(cfg(&dir)).unwrap();
        assert_eq!(replay.sessions[&3], vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn flipped_bit_in_sealed_record_is_a_typed_error() {
        let dir = scratch("flip");
        {
            let (mut j, _) = Journal::open(cfg(&dir)).unwrap();
            j.append_turn(6, 0, &[1], &[2]).unwrap();
            j.append_turn(6, 2, &[3], &[4]).unwrap();
        }
        // Flip a payload byte of the FIRST record: not the tail, so this
        // must be corruption, not a torn write.
        let mut data = fs::read(dir.join("wal0.log")).unwrap();
        data[6] ^= 0x40;
        fs::write(dir.join("wal0.log"), &data).unwrap();
        match Journal::open(cfg(&dir)) {
            Err(JournalError::Corrupt { offset, reason, .. }) => {
                assert_eq!(offset, 0);
                assert!(reason.contains("checksum"), "reason: {reason}");
            }
            other => panic!("expected Corrupt, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn flipped_bit_in_final_record_reads_as_torn_tail() {
        // A checksum failure on the very last bytes of the last segment
        // is indistinguishable from a write whose tail never landed, so
        // the journal takes the forgiving branch: truncate, don't refuse.
        let dir = scratch("flip_tail");
        {
            let (mut j, _) = Journal::open(cfg(&dir)).unwrap();
            j.append_turn(6, 0, &[1], &[2]).unwrap();
            j.append_turn(6, 2, &[3], &[4]).unwrap();
        }
        let path = dir.join("wal0.log");
        let mut data = fs::read(&path).unwrap();
        let n = data.len();
        data[n - 1] ^= 0x01;
        fs::write(&path, &data).unwrap();
        let (j, replay) = Journal::open(cfg(&dir)).unwrap();
        assert_eq!(replay.sessions[&6], vec![1, 2], "damaged final record dropped");
        assert_eq!(j.stats().truncated_tails, 1);
    }

    #[test]
    fn garbage_length_field_is_corruption_even_at_the_tail() {
        let dir = scratch("badlen");
        {
            let (mut j, _) = Journal::open(cfg(&dir)).unwrap();
            j.append_turn(8, 0, &[1], &[2]).unwrap();
        }
        let path = dir.join("wal0.log");
        let mut data = fs::read(&path).unwrap();
        // Append a full-size bogus header claiming an absurd record.
        data.extend_from_slice(&u32::MAX.to_le_bytes());
        data.extend_from_slice(&[0u8; 16]);
        fs::write(&path, &data).unwrap();
        match Journal::open(cfg(&dir)) {
            Err(JournalError::Corrupt { reason, .. }) => {
                assert!(reason.contains("out of range"), "reason: {reason}");
            }
            other => panic!("expected Corrupt, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn gap_in_turn_chain_is_a_typed_error() {
        let dir = scratch("gap");
        {
            let (mut j, _) = Journal::open(cfg(&dir)).unwrap();
            // prior_len 5 on an empty transcript: the prefix never landed.
            j.append_turn(1, 5, &[1], &[2]).unwrap();
        }
        match Journal::open(cfg(&dir)) {
            Err(JournalError::Corrupt { reason, .. }) => {
                assert!(reason.contains("expects transcript length"), "reason: {reason}");
            }
            other => panic!("expected Corrupt, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn replay_crosses_segment_rotation_boundary() {
        let dir = scratch("rotate");
        {
            let mut c = cfg(&dir);
            c.segment_bytes = 64; // force rotation every record or two
            let (mut j, _) = Journal::open(c).unwrap();
            for t in 0..10i32 {
                j.append_turn(1, (2 * t) as u32, &[t], &[t + 100]).unwrap();
            }
        }
        let segs = fs::read_dir(&dir).unwrap().count();
        assert!(segs > 2, "expected multiple segments, found {segs}");
        let (_, replay) = Journal::open(cfg(&dir)).unwrap();
        let want: Vec<i32> = (0..10).flat_map(|t| [t, t + 100]).collect();
        assert_eq!(replay.sessions[&1], want);
        assert_eq!(replay.last_turn[&1], (vec![9], vec![109]));
    }

    #[test]
    fn compaction_reclaims_bytes_and_preserves_replay() {
        let dir = scratch("compact");
        let mut c = cfg(&dir);
        c.segment_bytes = 128;
        let (mut j, _) = Journal::open(c.clone()).unwrap();
        let mut live: HashMap<u64, Vec<i32>> = HashMap::new();
        for t in 0..40i32 {
            let sess = (t % 2) as u64;
            let m = live.entry(sess).or_default();
            let prior = m.len() as u32;
            j.append_turn(sess, prior, &[t], &[t * 10]).unwrap();
            m.extend_from_slice(&[t, t * 10]);
        }
        // Overwrite-heavy history: sealed bytes dwarf the live set only
        // after enough turns; force the decision explicitly.
        assert!(j.maybe_compact(&live).unwrap(), "live-ratio trigger should fire");
        assert_eq!(j.stats().compactions, 1);
        let disk: u64 = fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().metadata().unwrap().len())
            .sum();
        let live_bytes: u64 = live.values().map(|t| 25 + 4 * t.len() as u64).sum();
        assert!(
            disk <= live_bytes * 3,
            "compacted journal should be near the live set: disk={disk} live={live_bytes}"
        );
        // Appends continue after compaction and replay sees everything.
        j.append_turn(0, live[&0].len() as u32, &[777], &[778]).unwrap();
        drop(j);
        let (_, replay) = Journal::open(c).unwrap();
        let mut want0 = live[&0].clone();
        want0.extend_from_slice(&[777, 778]);
        assert_eq!(replay.sessions[&0], want0);
        assert_eq!(replay.sessions[&1], live[&1]);
    }

    #[test]
    fn compaction_preserves_the_dedup_window() {
        let dir = scratch("compact_dedup");
        let c = cfg(&dir);
        let (mut j, _) = Journal::open(c.clone()).unwrap();
        let mut live: HashMap<u64, Vec<i32>> = HashMap::new();
        j.append_turn(1, 0, &[1, 2], &[3]).unwrap();
        live.insert(1, vec![1, 2, 3]);
        j.compact(&live).unwrap();
        drop(j);
        let (_, replay) = Journal::open(c).unwrap();
        assert_eq!(replay.sessions[&1], vec![1, 2, 3]);
        assert_eq!(
            replay.last_turn.get(&1),
            Some(&(vec![1, 2], vec![3])),
            "the last-turn dedup window must survive compaction"
        );
    }

    #[test]
    fn fault_points_drive_the_four_crash_windows() {
        let dir = scratch("faults");
        let (mut j, _) = Journal::open(cfg(&dir)).unwrap();
        let plan = Arc::new(FaultPlan::new());
        j.set_faults(Some(plan.clone()));

        // (a) crash before append: nothing reaches the file.
        plan.add_rule(Rule::once(Point::JournalBeforeAppend, FaultAction::SeverAfter));
        assert!(j.append_turn(1, 0, &[1], &[2]).is_err());
        assert_eq!(fs::metadata(dir.join("wal0.log")).unwrap().len(), 0);

        // (b) torn write: half a record lands; replay truncates it away.
        plan.add_rule(Rule::once(Point::JournalTornWrite, FaultAction::SeverAfter));
        assert!(j.append_turn(1, 0, &[1], &[2]).is_err());
        assert!(fs::metadata(dir.join("wal0.log")).unwrap().len() > 0);
        drop(j);
        let (mut j, replay) = Journal::open(cfg(&dir)).unwrap();
        assert!(replay.sessions.is_empty(), "torn record must not replay");
        assert_eq!(j.stats().truncated_tails, 1);

        // (c) crash after append, before ack: the record IS durable.
        j.set_faults(Some(plan.clone()));
        plan.add_rule(Rule::once(Point::JournalAfterAppend, FaultAction::SeverAfter));
        assert!(j.append_turn(1, 0, &[1], &[2]).is_err());
        drop(j);
        let (mut j, replay) = Journal::open(cfg(&dir)).unwrap();
        assert_eq!(replay.sessions[&1], vec![1, 2], "append-before-ack record survives");
        assert_eq!(replay.last_turn[&1], (vec![1], vec![2]), "and feeds the dedup window");

        // (d) lost fsync: the append "succeeds" but durability was never
        // forced — observable only as the skipped sync (the data may
        // still reach disk on a clean close; the point is the hook).
        j.set_faults(Some(plan.clone()));
        plan.add_rule(Rule::once(Point::JournalLostFsync, FaultAction::SeverAfter));
        j.append_turn(1, 2, &[3], &[4]).unwrap();
        assert_eq!(plan.rules_pending(), 0, "every staged fault fired");
        assert_eq!(plan.hits().len(), 4);
    }

    #[test]
    fn fsync_ladder_smoke() {
        for (name, policy) in [
            ("per_record", FsyncPolicy::PerRecord),
            ("batched", FsyncPolicy::Batched(5)),
            ("off", FsyncPolicy::Off),
        ] {
            let dir = scratch(&format!("ladder_{name}"));
            let mut c = cfg(&dir);
            c.fsync = policy;
            let (mut j, _) = Journal::open(c.clone()).unwrap();
            for t in 0..5i32 {
                j.append_turn(1, (2 * t) as u32, &[t], &[t]).unwrap();
            }
            j.flush().unwrap();
            drop(j);
            let (_, replay) = Journal::open(c).unwrap();
            assert_eq!(replay.sessions[&1].len(), 10, "policy {name} lost records");
        }
    }
}
