//! Session subsystem: O(1) snapshot/resume of per-sequence SSM state.
//!
//! The paper's deployment claim (Lemma 2.2) is that a distilled layer
//! carries a *constant-size* recurrence state per sequence.  That makes an
//! entire in-flight conversation checkpointable in O(state) bytes — a
//! KV-cached Transformer would have to persist an O(t)-growing cache, and a
//! conv-mode model the full gated-signal history.  This module turns that
//! observation into a serving feature:
//!
//! * [`state::SessionState`] — a versioned, byte-exact blob of one slot's
//!   generation state, extracted and reinstalled through
//!   [`crate::coordinator::state::SlotEngine::snapshot_slot`] /
//!   [`crate::coordinator::state::SlotEngine::restore_slot`].
//! * [`store::Store`] — a byte-budgeted LRU session store with hit/miss
//!   accounting and optional spill-to-disk through the existing
//!   [`crate::runtime::checkpoint`] serialization.
//! * [`journal::Journal`] — an append-only, checksummed write-ahead turn
//!   journal: the crash-durability substrate the serve layer replays on
//!   cold restart so acked turns survive a process death.
//!
//! The coordinator (`coordinator/server.rs`) wires both into
//! `submit_in_session`: a resumed turn restores the stored state into a
//! free slot and feeds only the *new* tokens, skipping the re-prefill of
//! the whole transcript — while guaranteeing bit-identical tokens to a
//! single uninterrupted generation (asserted in the server tests).

pub mod journal;
pub mod state;
pub mod store;

pub use journal::{Journal, JournalConfig, JournalError, JournalStats, Replay};
pub use state::{Plane, SessionError, SessionState, FORMAT_VERSION, WIRE_MAGIC};
pub use store::{Store, StoreConfig, StoreStats};
