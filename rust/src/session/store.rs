//! Byte-budgeted LRU session store with optional spill-to-disk.
//!
//! Holds [`SessionState`] blobs between turns of a conversation.  RAM
//! residency is bounded by `budget_bytes`; least-recently-used sessions are
//! evicted first, and — when a spill directory is configured — written to
//! disk through the checkpoint serialization instead of being dropped, so a
//! later turn can still resume in O(state) I/O rather than re-prefilling
//! the whole transcript.
//!
//! `take` removes the state (it moves into an engine slot); the coordinator
//! `put`s a fresh snapshot back at retire.  Hit/miss/eviction/spill
//! accounting feeds the coordinator metrics.

use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;

use super::state::SessionState;
use crate::runtime::checkpoint::Checkpoint;

/// Store configuration.
#[derive(Clone, Debug)]
pub struct StoreConfig {
    /// RAM budget for resident session states.
    pub budget_bytes: u64,
    /// Evicted states spill here instead of being dropped (None = drop).
    pub spill_dir: Option<PathBuf>,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig { budget_bytes: 256 << 20, spill_dir: None }
    }
}

/// Counters exported to the coordinator metrics.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// RAM-resident lookup hits.
    pub hits: u64,
    /// Lookups served by loading a spilled blob from disk.
    pub disk_hits: u64,
    /// Lookups that found nothing (state was dropped or never stored).
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    /// Evictions that were persisted to the spill directory.
    pub spills: u64,
}

struct Entry {
    state: SessionState,
    bytes: u64,
    tick: u64,
}

/// The LRU session store.
pub struct Store {
    cfg: StoreConfig,
    entries: HashMap<u64, Entry>,
    /// recency index: monotone tick -> session id (oldest first).
    recency: BTreeMap<u64, u64>,
    used: u64,
    tick: u64,
    pub stats: StoreStats,
}

impl Store {
    pub fn new(cfg: StoreConfig) -> Store {
        if let Some(dir) = &cfg.spill_dir {
            let _ = std::fs::create_dir_all(dir);
        }
        Store {
            cfg,
            entries: HashMap::new(),
            recency: BTreeMap::new(),
            used: 0,
            tick: 0,
            stats: StoreStats::default(),
        }
    }

    /// Resident states (excludes spilled-to-disk sessions).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Bytes currently held in RAM.
    pub fn bytes_used(&self) -> u64 {
        self.used
    }

    pub fn contains_resident(&self, id: u64) -> bool {
        self.entries.contains_key(&id)
    }

    /// Whether the store holds this session anywhere — RAM-resident or
    /// spilled to disk.  Unlike [`Store::take`] this does not move the
    /// state out and does not touch the hit/miss stats (it backs the
    /// coordinator's `session_known` query, not the resume path).
    pub fn contains(&self, id: u64) -> bool {
        if self.entries.contains_key(&id) {
            return true;
        }
        self.spill_base(id)
            .map(|base| base.with_extension("bin").exists())
            .unwrap_or(false)
    }

    /// Insert (or replace) the state for a session, then enforce the byte
    /// budget by evicting least-recently-used sessions.
    pub fn put(&mut self, id: u64, mut state: SessionState) {
        state.session_id = id;
        self.remove_resident(id);
        let bytes = state.state_bytes();
        self.tick += 1;
        self.recency.insert(self.tick, id);
        self.entries.insert(id, Entry { state, bytes, tick: self.tick });
        self.used += bytes;
        self.stats.inserts += 1;
        self.evict_to_budget();
    }

    /// Remove and return the state for a session: RAM first, then the spill
    /// directory.  The state moves into an engine slot, so on success it no
    /// longer lives in the store (the coordinator re-`put`s at retire).
    pub fn take(&mut self, id: u64) -> Option<SessionState> {
        if let Some(e) = self.entries.remove(&id) {
            self.recency.remove(&e.tick);
            self.used -= e.bytes;
            self.stats.hits += 1;
            return Some(e.state);
        }
        if let Some(base) = self.spill_base(id) {
            if base.with_extension("bin").exists() {
                if let Ok(ck) = Checkpoint::load(&base) {
                    if let Ok(state) = SessionState::from_checkpoint(&ck) {
                        let _ = std::fs::remove_file(base.with_extension("bin"));
                        let _ = std::fs::remove_file(base.with_extension("manifest.txt"));
                        self.stats.disk_hits += 1;
                        return Some(state);
                    }
                }
            }
        }
        self.stats.misses += 1;
        None
    }

    /// Drop a session entirely (RAM and disk); returns whether anything
    /// existed.
    pub fn evict_session(&mut self, id: u64) -> bool {
        let mut found = self.remove_resident(id);
        if let Some(base) = self.spill_base(id) {
            if base.with_extension("bin").exists() {
                let _ = std::fs::remove_file(base.with_extension("bin"));
                let _ = std::fs::remove_file(base.with_extension("manifest.txt"));
                found = true;
            }
        }
        found
    }

    fn remove_resident(&mut self, id: u64) -> bool {
        if let Some(e) = self.entries.remove(&id) {
            self.recency.remove(&e.tick);
            self.used -= e.bytes;
            true
        } else {
            false
        }
    }

    fn spill_base(&self, id: u64) -> Option<PathBuf> {
        self.cfg.spill_dir.as_ref().map(|d| d.join(format!("session_{id:016x}")))
    }

    fn evict_to_budget(&mut self) {
        while self.used > self.cfg.budget_bytes {
            // oldest tick = least recently used
            let (tick, id) = match self.recency.iter().next() {
                Some((&tick, &id)) => (tick, id),
                None => break,
            };
            self.recency.remove(&tick);
            let e = self.entries.remove(&id).expect("recency/entries in sync");
            self.used -= e.bytes;
            self.stats.evictions += 1;
            if let Some(base) = self.spill_base(id) {
                if e.state.to_checkpoint().save(&base).is_ok() {
                    self.stats.spills += 1;
                } else {
                    eprintln!("session store: failed to spill session {id:#x}");
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::state::SessionState;

    fn state(tag: i32, floats: usize) -> SessionState {
        let mut st = SessionState::new("test", tag);
        st.push_plane("x", (0..floats).map(|i| i as f32 + tag as f32).collect());
        st
    }

    #[test]
    fn put_take_roundtrip_and_stats() {
        let mut s = Store::new(StoreConfig { budget_bytes: 1 << 20, spill_dir: None });
        s.put(1, state(10, 100));
        s.put(2, state(20, 100));
        assert_eq!(s.len(), 2);
        let a = s.take(1).unwrap();
        assert_eq!(a.last_token, 10);
        assert_eq!(a.session_id, 1);
        assert!(s.take(1).is_none()); // moved out
        assert_eq!(s.stats.hits, 1);
        assert_eq!(s.stats.misses, 1);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn lru_eviction_respects_byte_budget_and_recency() {
        let one = state(0, 100).state_bytes();
        // room for exactly two states
        let mut s = Store::new(StoreConfig { budget_bytes: 2 * one, spill_dir: None });
        s.put(1, state(1, 100));
        s.put(2, state(2, 100));
        // touch 1 so 2 becomes LRU
        let st1 = s.take(1).unwrap();
        s.put(1, st1);
        s.put(3, state(3, 100));
        assert_eq!(s.stats.evictions, 1);
        assert!(s.contains_resident(1), "recently-touched survives");
        assert!(!s.contains_resident(2), "LRU evicted");
        assert!(s.contains_resident(3));
        assert!(s.bytes_used() <= 2 * one);
    }

    #[test]
    fn eviction_spills_to_disk_and_take_restores_bit_exact() {
        let dir = std::env::temp_dir().join(format!("lh_sess_store_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let one = state(0, 64).state_bytes();
        let mut s = Store::new(StoreConfig { budget_bytes: one, spill_dir: Some(dir.clone()) });
        let mut a = state(7, 64);
        a.planes[0].data[0] = f32::NAN; // must survive the disk trip bit-exactly
        let want_bits = a.planes[0].data[0].to_bits();
        s.put(1, a);
        s.put(2, state(8, 64)); // evicts 1 -> disk
        assert_eq!(s.stats.spills, 1);
        assert!(!s.contains_resident(1));
        let back = s.take(1).expect("disk hit");
        assert_eq!(s.stats.disk_hits, 1);
        assert_eq!(back.last_token, 7);
        assert_eq!(back.planes[0].data[0].to_bits(), want_bits);
        // the spill file is consumed by take
        assert!(s.take(1).is_none());
        assert_eq!(s.stats.misses, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn oversized_state_is_evicted_immediately() {
        let mut s = Store::new(StoreConfig { budget_bytes: 8, spill_dir: None });
        s.put(1, state(1, 1000)); // bigger than the whole budget
        assert_eq!(s.len(), 0);
        assert_eq!(s.stats.evictions, 1);
        assert_eq!(s.bytes_used(), 0);
    }

    #[test]
    fn replacing_a_session_does_not_leak_bytes() {
        let mut s = Store::new(StoreConfig { budget_bytes: 1 << 20, spill_dir: None });
        s.put(1, state(1, 100));
        let b = s.bytes_used();
        s.put(1, state(2, 100));
        assert_eq!(s.bytes_used(), b);
        assert_eq!(s.len(), 1);
        assert_eq!(s.take(1).unwrap().last_token, 2);
        assert_eq!(s.bytes_used(), 0);
    }

    #[test]
    fn evict_session_drops_ram_and_disk() {
        let dir = std::env::temp_dir().join(format!("lh_sess_evict_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let one = state(0, 32).state_bytes();
        let mut s = Store::new(StoreConfig { budget_bytes: one, spill_dir: Some(dir.clone()) });
        s.put(1, state(1, 32));
        s.put(2, state(2, 32)); // 1 spilled
        let before = (s.stats.hits, s.stats.disk_hits, s.stats.misses);
        assert!(s.contains(1), "spilled session still counts as held");
        assert!(s.contains(2), "resident session counts as held");
        assert!(!s.contains(3));
        assert_eq!(
            before,
            (s.stats.hits, s.stats.disk_hits, s.stats.misses),
            "contains must not touch the hit/miss stats"
        );
        assert!(s.evict_session(1), "disk copy dropped");
        assert!(s.evict_session(2), "ram copy dropped");
        assert!(!s.evict_session(3));
        assert!(s.take(1).is_none() && s.take(2).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
