//! Tiered byte-budgeted LRU session store: RAM tier + capped disk tier.
//!
//! Holds [`SessionState`] blobs between turns of a conversation.  RAM
//! residency is bounded by `budget_bytes`; least-recently-used sessions
//! are evicted first, and — when a spill directory is configured —
//! written into the disk tier instead of being dropped, so a later turn
//! can still resume in O(state) I/O rather than re-prefilling the whole
//! transcript.
//!
//! The disk tier is a **segmented spill log** with its own LRU and byte
//! cap (`spill_budget_bytes`): evicted states append as self-describing,
//! checksummed records (`[u64 id][u32 len][wire blob][u64 fnv1a64]`)
//! into segment files (`spill_%08u.seg`), capped at `segment_bytes`
//! each.  Each append is one buffered write followed by `sync_all`, so a
//! process crash can tear at most the final record of the active segment
//! — and re-index *quarantines* any record whose length or checksum does
//! not verify (counted in [`StoreStats::quarantined`]) instead of
//! serving a torn blob as session state.  Deletes are
//! logical (the in-RAM index forgets the record); [`Store::maintain`]
//! compacts sealed segments whose live ratio fell below one half by
//! rewriting the surviving records into the active segment — run it from
//! the coordinator's idle ticks so reclamation never sits on a turn's
//! critical path.  When the disk tier itself exceeds its cap, its
//! least-recently-spilled sessions are dropped entirely; the transcript
//! re-prefill path makes that loss graceful rather than fatal.  On
//! construction the tier re-indexes any segments a previous process left
//! behind, so spilled sessions survive a coordinator restart.
//!
//! `take` removes the state (it moves into an engine slot); the
//! coordinator `put`s a fresh snapshot back at retire.  Hit / miss /
//! eviction / spill / compaction accounting feeds the coordinator
//! metrics.

use std::collections::{BTreeMap, HashMap};
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use super::state::SessionState;
use crate::util::bytes::fnv1a64;

/// Store configuration.
#[derive(Clone, Debug)]
pub struct StoreConfig {
    /// RAM budget for resident session states.
    pub budget_bytes: u64,
    /// Evicted states spill here instead of being dropped (None = drop).
    pub spill_dir: Option<PathBuf>,
    /// Byte cap of the disk tier's *live* records (0 = unbounded).  Past
    /// it, the least-recently-spilled sessions are dropped from disk.
    pub spill_budget_bytes: u64,
    /// Roll the active spill segment once it grows past this many bytes.
    pub segment_bytes: u64,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig {
            budget_bytes: 256 << 20,
            spill_dir: None,
            spill_budget_bytes: 0,
            segment_bytes: 4 << 20,
        }
    }
}

/// Counters exported to the coordinator metrics.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// RAM-resident lookup hits.
    pub hits: u64,
    /// Lookups served by loading a spilled blob from disk.
    pub disk_hits: u64,
    /// Lookups that found nothing (state was dropped or never stored).
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    /// Evictions that were persisted to the spill tier.
    pub spills: u64,
    /// Sessions the disk tier dropped to stay under its byte cap.
    pub spill_evictions: u64,
    /// Sealed segments rewritten by [`Store::maintain`].
    pub compactions: u64,
    /// Spill records refused at re-index (length or checksum failed to
    /// verify) — torn or corrupted blobs that were never served.
    pub quarantined: u64,
}

struct Entry {
    state: SessionState,
    bytes: u64,
    tick: u64,
}

/// Where one spilled record lives.
struct DiskEntry {
    seg: u64,
    off: u64,
    len: u64,
    tick: u64,
}

#[derive(Default)]
struct Segment {
    /// Bytes of records still referenced by the index.
    live: u64,
    /// Bytes ever appended (file size).
    total: u64,
}

/// Per-record header: session id + payload length.
const REC_HEADER: u64 = 8 + 4;

/// Per-record trailer: fnv1a64 of the payload bytes.
const REC_TRAILER: u64 = 8;

/// The segmented spill log (disk tier).  All bookkeeping is in RAM;
/// segment files hold only the blob records.
struct DiskTier {
    dir: PathBuf,
    budget: u64,
    segment_bytes: u64,
    index: HashMap<u64, DiskEntry>,
    segments: BTreeMap<u64, Segment>,
    next_seg: u64,
    /// Live record bytes across all segments (headers included).
    live_bytes: u64,
    /// recency index: spill tick -> session id (oldest first).
    recency: BTreeMap<u64, u64>,
    /// Records refused at re-index (bad length or checksum).
    quarantined: u64,
}

impl DiskTier {
    fn seg_path(dir: &Path, seg: u64) -> PathBuf {
        dir.join(format!("spill_{seg:08}.seg"))
    }

    /// fsync the spill directory so newly created / deleted segment files
    /// are themselves durable (best-effort on non-unix).
    fn sync_dir(dir: &Path) {
        #[cfg(unix)]
        if let Ok(f) = File::open(dir) {
            let _ = f.sync_all();
        }
        #[cfg(not(unix))]
        let _ = dir;
    }

    /// Open the tier, re-indexing any segments left by a previous
    /// process (later records for the same session win; a truncated tail
    /// record ends that segment's scan).
    fn open(dir: PathBuf, budget: u64, segment_bytes: u64) -> DiskTier {
        let _ = std::fs::create_dir_all(&dir);
        let mut tier = DiskTier {
            dir,
            budget,
            segment_bytes,
            index: HashMap::new(),
            segments: BTreeMap::new(),
            next_seg: 0,
            live_bytes: 0,
            recency: BTreeMap::new(),
            quarantined: 0,
        };
        let mut seg_ids = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&tier.dir) {
            for e in rd.flatten() {
                let name = e.file_name().to_string_lossy().to_string();
                if let Some(num) = name.strip_prefix("spill_").and_then(|s| s.strip_suffix(".seg"))
                {
                    if let Ok(seg) = num.parse::<u64>() {
                        seg_ids.push(seg);
                    }
                }
            }
        }
        seg_ids.sort_unstable();
        let mut tick = 0u64;
        for seg in seg_ids {
            let path = Self::seg_path(&tier.dir, seg);
            let bytes = match std::fs::read(&path) {
                Ok(b) => b,
                Err(_) => continue,
            };
            let mut segment = Segment::default();
            let mut off = 0u64;
            while (off + REC_HEADER + REC_TRAILER) as usize <= bytes.len() {
                let o = off as usize;
                let id = u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap());
                let len = u32::from_le_bytes(bytes[o + 8..o + 12].try_into().unwrap()) as u64;
                let rec = REC_HEADER + len + REC_TRAILER;
                if (off + rec) as usize > bytes.len() {
                    break; // truncated tail record: ignore it and stop
                }
                let blob = &bytes[o + 12..o + 12 + len as usize];
                let sum_off = o + 12 + len as usize;
                let sum =
                    u64::from_le_bytes(bytes[sum_off..sum_off + 8].try_into().unwrap());
                if fnv1a64(blob) != sum {
                    // well-framed but its payload does not verify:
                    // quarantine (never serve it) and keep scanning —
                    // later records are still correctly framed.
                    tier.quarantined += 1;
                    off += rec;
                    continue;
                }
                tick += 1;
                // a later record for the same id supersedes the earlier one
                if let Some(old) = tier.index.remove(&id) {
                    let dead = REC_HEADER + old.len + REC_TRAILER;
                    if let Some(s) = tier.segments.get_mut(&old.seg) {
                        s.live -= dead;
                    } else if old.seg == seg {
                        segment.live -= dead;
                    }
                    tier.live_bytes -= dead;
                    tier.recency.remove(&old.tick);
                }
                tier.index.insert(id, DiskEntry { seg, off, len, tick });
                tier.recency.insert(tick, id);
                segment.live += rec;
                tier.live_bytes += rec;
                off += rec;
            }
            segment.total = off;
            tier.segments.insert(seg, segment);
            tier.next_seg = tier.next_seg.max(seg + 1);
        }
        tier
    }

    fn contains(&self, id: u64) -> bool {
        self.index.contains_key(&id)
    }

    fn live_bytes(&self) -> u64 {
        self.live_bytes
    }

    fn len(&self) -> usize {
        self.index.len()
    }

    /// The active (append) segment id, rolling if the current one is full.
    fn active_segment(&mut self) -> u64 {
        if let Some((&seg, s)) = self.segments.iter().next_back() {
            if s.total < self.segment_bytes {
                return seg;
            }
        }
        let seg = self.next_seg;
        self.next_seg += 1;
        self.segments.insert(seg, Segment::default());
        seg
    }

    /// Forget a record (logical delete).  The bytes stay in the segment
    /// file until [`DiskTier::maintain`] compacts it away.
    fn forget(&mut self, id: u64) -> bool {
        match self.index.remove(&id) {
            None => false,
            Some(e) => {
                let dead = REC_HEADER + e.len + REC_TRAILER;
                if let Some(s) = self.segments.get_mut(&e.seg) {
                    s.live -= dead;
                }
                self.live_bytes -= dead;
                self.recency.remove(&e.tick);
                true
            }
        }
    }

    /// Append one spilled blob; returns false (and spills nothing) on an
    /// I/O error.  `evictions` counts sessions dropped to honor the cap.
    fn put(&mut self, id: u64, blob: &[u8], tick: u64, evictions: &mut u64) -> bool {
        self.forget(id);
        let seg = self.active_segment();
        let path = Self::seg_path(&self.dir, seg);
        let mut record =
            Vec::with_capacity((REC_HEADER + REC_TRAILER) as usize + blob.len());
        record.extend_from_slice(&id.to_le_bytes());
        record.extend_from_slice(&(blob.len() as u32).to_le_bytes());
        record.extend_from_slice(blob);
        record.extend_from_slice(&fnv1a64(blob).to_le_bytes());
        let new_file = self.segments.get(&seg).map(|s| s.total == 0).unwrap_or(true);
        let appended = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .and_then(|mut f| {
                // one write + one sync per spill: a crash can tear at
                // most the final record, which re-index quarantines
                f.write_all(&record)?;
                f.sync_all()
            });
        if appended.is_err() {
            return false;
        }
        if new_file {
            Self::sync_dir(&self.dir);
        }
        let s = self.segments.get_mut(&seg).expect("active segment exists");
        let off = s.total;
        let rec = record.len() as u64;
        s.total += rec;
        s.live += rec;
        self.live_bytes += rec;
        self.index.insert(id, DiskEntry { seg, off, len: blob.len() as u64, tick });
        self.recency.insert(tick, id);
        // disk-tier LRU: drop the least-recently-spilled sessions past
        // the cap (never the record just written — it is the newest)
        while self.budget > 0 && self.live_bytes > self.budget && self.index.len() > 1 {
            let oldest = match self.recency.iter().next() {
                Some((_, &sid)) if sid != id => sid,
                _ => break,
            };
            self.forget(oldest);
            *evictions += 1;
        }
        true
    }

    /// Read one record's payload at its indexed position; the record's
    /// own header must agree with the index (id and length), otherwise
    /// the segment is out of sync and the record is treated as lost.
    fn read_record(&self, id: u64, e: &DiskEntry) -> Option<Vec<u8>> {
        let path = Self::seg_path(&self.dir, e.seg);
        let mut f = File::open(path).ok()?;
        f.seek(SeekFrom::Start(e.off)).ok()?;
        let mut header = [0u8; REC_HEADER as usize];
        f.read_exact(&mut header).ok()?;
        let rec_id = u64::from_le_bytes(header[0..8].try_into().unwrap());
        let len = u32::from_le_bytes(header[8..12].try_into().unwrap()) as u64;
        if rec_id != id || len != e.len {
            return None;
        }
        let mut blob = vec![0u8; len as usize];
        f.read_exact(&mut blob).ok()?;
        let mut sum = [0u8; REC_TRAILER as usize];
        f.read_exact(&mut sum).ok()?;
        if u64::from_le_bytes(sum) != fnv1a64(&blob) {
            return None; // corrupted on disk: a miss, never garbage state
        }
        Some(blob)
    }

    /// Remove and return a spilled blob.
    fn take(&mut self, id: u64) -> Option<Vec<u8>> {
        let blob = {
            let e = self.index.get(&id)?;
            self.read_record(id, e)
        };
        self.forget(id);
        blob
    }

    /// Compact sealed segments whose live ratio fell below one half:
    /// surviving records are re-appended to the active segment, the old
    /// file is deleted.  Returns the number of segments compacted.
    fn maintain(&mut self) -> u64 {
        let active = match self.segments.iter().next_back() {
            Some((&seg, _)) => seg,
            None => return 0,
        };
        let victims: Vec<u64> = self
            .segments
            .iter()
            .filter(|(&seg, s)| seg != active && s.live * 2 < s.total)
            .map(|(&seg, _)| seg)
            .collect();
        let mut compacted = 0;
        for seg in victims {
            // collect the survivors (id, tick, payload) before mutating
            let residents: Vec<(u64, u64)> = self
                .index
                .iter()
                .filter(|(_, e)| e.seg == seg)
                .map(|(&id, e)| (id, e.tick))
                .collect();
            let mut survivors = Vec::with_capacity(residents.len());
            for &(id, tick) in &residents {
                let blob = self.index.get(&id).and_then(|e| self.read_record(id, e));
                match blob {
                    Some(b) => survivors.push((id, tick, b)),
                    // a read failure loses that record; the transcript
                    // re-prefill path covers the session
                    None => {
                        self.forget(id);
                    }
                }
            }
            for (id, tick, blob) in &survivors {
                // preserve the original recency tick across the rewrite
                let mut scratch = 0u64;
                if self.put(*id, blob, *tick, &mut scratch) {
                    debug_assert_eq!(scratch, 0, "compaction must not evict");
                }
            }
            self.segments.remove(&seg);
            let _ = std::fs::remove_file(Self::seg_path(&self.dir, seg));
            Self::sync_dir(&self.dir);
            compacted += 1;
        }
        compacted
    }
}

/// The tiered LRU session store.
pub struct Store {
    cfg: StoreConfig,
    entries: HashMap<u64, Entry>,
    /// recency index: monotone tick -> session id (oldest first).
    recency: BTreeMap<u64, u64>,
    used: u64,
    tick: u64,
    disk: Option<DiskTier>,
    pub stats: StoreStats,
}

impl Store {
    pub fn new(cfg: StoreConfig) -> Store {
        let disk = cfg
            .spill_dir
            .clone()
            .map(|dir| DiskTier::open(dir, cfg.spill_budget_bytes, cfg.segment_bytes.max(1)));
        // keep ticks monotone across a restart that re-indexed old spill
        // segments, so RAM recency never collides with disk recency
        let tick = disk
            .as_ref()
            .and_then(|d| d.recency.keys().next_back().copied())
            .unwrap_or(0);
        let stats = StoreStats {
            quarantined: disk.as_ref().map(|d| d.quarantined).unwrap_or(0),
            ..StoreStats::default()
        };
        Store { cfg, entries: HashMap::new(), recency: BTreeMap::new(), used: 0, tick, disk, stats }
    }

    /// Resident states (excludes spilled-to-disk sessions).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Bytes currently held in RAM.
    pub fn bytes_used(&self) -> u64 {
        self.used
    }

    /// Live bytes held by the disk tier (0 without a spill dir).
    pub fn spill_bytes(&self) -> u64 {
        self.disk.as_ref().map(|d| d.live_bytes()).unwrap_or(0)
    }

    /// Sessions currently held by the disk tier.
    pub fn spilled_len(&self) -> usize {
        self.disk.as_ref().map(|d| d.len()).unwrap_or(0)
    }

    pub fn contains_resident(&self, id: u64) -> bool {
        self.entries.contains_key(&id)
    }

    /// Every session id the store holds state for, RAM-resident or
    /// spilled, sorted (bulk export enumerates with this).
    pub fn ids(&self) -> Vec<u64> {
        let mut out: Vec<u64> = self.entries.keys().copied().collect();
        if let Some(d) = &self.disk {
            out.extend(d.index.keys().copied());
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Whether the store holds this session anywhere — RAM-resident or
    /// spilled to disk.  Unlike [`Store::take`] this does not move the
    /// state out and does not touch the hit/miss stats (it backs the
    /// coordinator's `session_known` query, not the resume path).
    pub fn contains(&self, id: u64) -> bool {
        self.entries.contains_key(&id)
            || self.disk.as_ref().map(|d| d.contains(id)).unwrap_or(false)
    }

    /// Insert (or replace) the state for a session, then enforce the byte
    /// budget by evicting least-recently-used sessions.
    pub fn put(&mut self, id: u64, mut state: SessionState) {
        state.session_id = id;
        self.remove_resident(id);
        // a fresher snapshot supersedes any stale disk copy
        if let Some(d) = &mut self.disk {
            d.forget(id);
        }
        let bytes = state.state_bytes();
        self.tick += 1;
        self.recency.insert(self.tick, id);
        self.entries.insert(id, Entry { state, bytes, tick: self.tick });
        self.used += bytes;
        self.stats.inserts += 1;
        self.evict_to_budget();
    }

    /// Remove and return the state for a session: RAM first, then the spill
    /// tier.  The state moves into an engine slot, so on success it no
    /// longer lives in the store (the coordinator re-`put`s at retire).
    pub fn take(&mut self, id: u64) -> Option<SessionState> {
        if let Some(e) = self.entries.remove(&id) {
            self.recency.remove(&e.tick);
            self.used -= e.bytes;
            self.stats.hits += 1;
            return Some(e.state);
        }
        if let Some(d) = &mut self.disk {
            if let Some(blob) = d.take(id) {
                if let Ok(state) = SessionState::from_wire_bytes(&blob) {
                    self.stats.disk_hits += 1;
                    return Some(state);
                }
            }
        }
        self.stats.misses += 1;
        None
    }

    /// Drop a session entirely (RAM and disk); returns whether anything
    /// existed.
    pub fn evict_session(&mut self, id: u64) -> bool {
        let resident = self.remove_resident(id);
        let spilled = self.disk.as_mut().map(|d| d.forget(id)).unwrap_or(false);
        resident || spilled
    }

    /// Off-critical-path housekeeping: compact spill segments whose live
    /// ratio fell below one half.  Run from the coordinator's idle ticks.
    /// Returns the number of segments compacted.
    pub fn maintain(&mut self) -> u64 {
        let compacted = self.disk.as_mut().map(|d| d.maintain()).unwrap_or(0);
        self.stats.compactions += compacted;
        compacted
    }

    fn remove_resident(&mut self, id: u64) -> bool {
        if let Some(e) = self.entries.remove(&id) {
            self.recency.remove(&e.tick);
            self.used -= e.bytes;
            true
        } else {
            false
        }
    }

    fn evict_to_budget(&mut self) {
        while self.used > self.cfg.budget_bytes {
            // oldest tick = least recently used
            let (tick, id) = match self.recency.iter().next() {
                Some((&tick, &id)) => (tick, id),
                None => break,
            };
            self.recency.remove(&tick);
            let e = self.entries.remove(&id).expect("recency/entries in sync");
            self.used -= e.bytes;
            self.stats.evictions += 1;
            if let Some(d) = &mut self.disk {
                let blob = e.state.to_wire_bytes();
                if d.put(id, &blob, tick, &mut self.stats.spill_evictions) {
                    self.stats.spills += 1;
                } else {
                    eprintln!("session store: failed to spill session {id:#x}");
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(tag: i32, floats: &[f32]) -> SessionState {
        let mut s = SessionState::new("test-engine", tag);
        s.tokens_seen = tag as u64 + 100;
        s.push_plane("h", floats.to_vec());
        s
    }

    fn tmp(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("lh_store_{}_{}", name, std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    /// On-disk record size of one `state()` blob (independent of tag/id:
    /// both are fixed-width in the wire format).
    fn rec_bytes(floats: &[f32]) -> u64 {
        REC_HEADER + REC_TRAILER + state(1, floats).to_wire_bytes().len() as u64
    }

    #[test]
    fn put_take_roundtrip_and_stats() {
        let mut st = Store::new(StoreConfig::default());
        assert!(st.is_empty());
        st.put(7, state(1, &[1.0, 2.0]));
        assert_eq!(st.len(), 1);
        assert!(st.contains(7));
        assert!(st.contains_resident(7));
        let got = st.take(7).expect("resident hit");
        assert_eq!(got.session_id, 7, "store stamps the owning id");
        assert_eq!(got.plane("h").unwrap(), &[1.0, 2.0]);
        assert!(st.take(7).is_none(), "take moves the state out");
        assert_eq!(st.bytes_used(), 0);
        assert_eq!(st.stats.hits, 1);
        assert_eq!(st.stats.misses, 1);
        assert_eq!(st.stats.inserts, 1);
    }

    #[test]
    fn lru_eviction_respects_byte_budget_and_recency() {
        let floats = [0.5f32; 64];
        let one = state(1, &floats).state_bytes();
        let mut st = Store::new(StoreConfig {
            budget_bytes: 2 * one,
            ..StoreConfig::default()
        });
        st.put(1, state(1, &floats));
        st.put(2, state(2, &floats));
        // refresh 1 so 2 becomes the LRU victim
        let s1 = st.take(1).unwrap();
        st.put(1, s1);
        st.put(3, state(3, &floats));
        assert!(st.contains_resident(1));
        assert!(!st.contains_resident(2), "LRU victim evicted");
        assert!(st.contains_resident(3));
        assert_eq!(st.stats.evictions, 1);
        assert!(st.bytes_used() <= 2 * one);
        assert!(st.take(2).is_none(), "no spill dir: eviction drops");
    }

    #[test]
    fn eviction_spills_to_disk_and_take_restores_bit_exact() {
        let dir = tmp("spill");
        let weird = [f32::from_bits(0x7fc0_0123), -0.0, 1.5e-39];
        let one = state(1, &weird).state_bytes();
        let mut st = Store::new(StoreConfig {
            budget_bytes: one, // second put evicts the first
            spill_dir: Some(dir.clone()),
            ..StoreConfig::default()
        });
        st.put(1, state(1, &weird));
        st.put(2, state(2, &[9.0, 9.0, 9.0]));
        assert!(!st.contains_resident(1));
        assert!(st.contains(1), "spilled session still known");
        assert_eq!(st.stats.spills, 1);
        assert!(st.spill_bytes() > 0);
        assert_eq!(st.spilled_len(), 1);
        let got = st.take(1).expect("disk hit");
        let bits: Vec<u32> = got.plane("h").unwrap().iter().map(|f| f.to_bits()).collect();
        let want: Vec<u32> = weird.iter().map(|f| f.to_bits()).collect();
        assert_eq!(bits, want, "spill round-trip must be bit-exact");
        assert_eq!(got.tokens_seen, 101);
        assert_eq!(st.stats.disk_hits, 1);
        assert_eq!(st.spill_bytes(), 0, "take removes the spilled record");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn oversized_state_is_evicted_immediately() {
        let mut st = Store::new(StoreConfig { budget_bytes: 16, ..StoreConfig::default() });
        st.put(1, state(1, &[0.0; 128]));
        assert_eq!(st.len(), 0);
        assert_eq!(st.bytes_used(), 0);
        assert_eq!(st.stats.evictions, 1);
    }

    #[test]
    fn replacing_a_session_does_not_leak_bytes() {
        let mut st = Store::new(StoreConfig::default());
        st.put(5, state(1, &[0.0; 100]));
        st.put(5, state(2, &[0.0; 10]));
        assert_eq!(st.len(), 1);
        assert_eq!(st.bytes_used(), state(2, &[0.0; 10]).state_bytes());
        assert_eq!(st.take(5).unwrap().tokens_seen, 102, "newest snapshot wins");
    }

    #[test]
    fn evict_session_drops_ram_and_disk() {
        let dir = tmp("evict");
        let one = state(1, &[1.0; 16]).state_bytes();
        let mut st = Store::new(StoreConfig {
            budget_bytes: one,
            spill_dir: Some(dir.clone()),
            ..StoreConfig::default()
        });
        st.put(1, state(1, &[1.0; 16]));
        st.put(2, state(2, &[2.0; 16])); // spills 1
        assert!(st.evict_session(1), "spilled session existed");
        assert!(st.evict_session(2), "resident session existed");
        assert!(!st.evict_session(3), "unknown session");
        assert!(!st.contains(1));
        assert!(!st.contains(2));
        assert_eq!(st.spilled_len(), 0);
        assert_eq!(st.spill_bytes(), 0);
        assert_eq!(st.bytes_used(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_cap_drops_least_recently_spilled_first() {
        let dir = tmp("cap");
        let floats = [3.25f32; 8];
        let rec = rec_bytes(&floats);
        let mut st = Store::new(StoreConfig {
            budget_bytes: 0, // every put spills immediately
            spill_dir: Some(dir.clone()),
            spill_budget_bytes: 2 * rec,
            ..StoreConfig::default()
        });
        st.put(1, state(1, &floats));
        st.put(2, state(2, &floats));
        assert_eq!(st.spilled_len(), 2);
        st.put(3, state(3, &floats));
        // cap fits two records: the least recently spilled (1) is dropped
        assert!(!st.contains(1));
        assert!(st.contains(2));
        assert!(st.contains(3));
        assert_eq!(st.stats.spill_evictions, 1);
        assert_eq!(st.spill_bytes(), 2 * rec);
        assert!(st.take(2).is_some(), "survivor restores");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_reclaims_dead_segments_and_preserves_blobs_bit_exact() {
        let dir = tmp("compact");
        let weird = [f32::from_bits(0xff80_0001), f32::MIN_POSITIVE, -0.0];
        let rec = rec_bytes(&weird);
        let mut st = Store::new(StoreConfig {
            budget_bytes: 0,
            spill_dir: Some(dir.clone()),
            spill_budget_bytes: 0,
            segment_bytes: 3 * rec, // three records per segment
        });
        for id in 1..=6u64 {
            st.put(id, state(id as i32, &weird));
        }
        // segment 0 holds {1,2,3}; drop two of three -> live ratio 1/3
        assert!(st.evict_session(1));
        assert!(st.evict_session(2));
        let seg0 = DiskTier::seg_path(&dir, 0);
        assert!(seg0.exists());
        assert_eq!(st.maintain(), 1, "exactly the dead-heavy sealed segment");
        assert_eq!(st.stats.compactions, 1);
        assert!(!seg0.exists(), "compacted segment file deleted");
        assert_eq!(st.spilled_len(), 4);
        assert_eq!(st.spill_bytes(), 4 * rec);
        // the survivor that was rewritten restores bit-exactly
        let got = st.take(3).expect("survivor restores after compaction");
        let bits: Vec<u32> = got.plane("h").unwrap().iter().map(|f| f.to_bits()).collect();
        let want: Vec<u32> = weird.iter().map(|f| f.to_bits()).collect();
        assert_eq!(bits, want);
        assert_eq!(st.maintain(), 0, "nothing left to compact");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_spill_record_is_quarantined_on_reindex() {
        let dir = tmp("quarantine");
        let cfg = StoreConfig {
            budget_bytes: 0, // every put spills immediately
            spill_dir: Some(dir.clone()),
            ..StoreConfig::default()
        };
        {
            let mut st = Store::new(cfg.clone());
            st.put(1, state(1, &[1.0; 8]));
            st.put(2, state(2, &[2.0; 8]));
        }
        // flip a payload byte of the FIRST record on disk
        let seg0 = DiskTier::seg_path(&dir, 0);
        let mut bytes = std::fs::read(&seg0).unwrap();
        bytes[REC_HEADER as usize + 3] ^= 0x10;
        std::fs::write(&seg0, &bytes).unwrap();
        let mut st = Store::new(cfg);
        assert_eq!(st.stats.quarantined, 1);
        assert!(!st.contains(1), "corrupt blob must never be served");
        let got = st.take(2).expect("well-framed later record still restores");
        assert_eq!(got.tokens_seen, 102);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn take_refuses_a_blob_corrupted_after_indexing() {
        let dir = tmp("take_corrupt");
        let cfg = StoreConfig {
            budget_bytes: 0,
            spill_dir: Some(dir.clone()),
            ..StoreConfig::default()
        };
        let mut st = Store::new(cfg);
        st.put(1, state(1, &[1.0; 8]));
        let seg0 = DiskTier::seg_path(&dir, 0);
        let mut bytes = std::fs::read(&seg0).unwrap();
        let cut = bytes.len() - (REC_TRAILER as usize + 4); // inside the blob
        bytes[cut] ^= 0x01;
        std::fs::write(&seg0, &bytes).unwrap();
        assert!(st.take(1).is_none(), "checksum mismatch is a miss, not garbage state");
        assert_eq!(st.stats.misses, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_reindexes_spilled_sessions_latest_record_wins() {
        let dir = tmp("reopen");
        let cfg = StoreConfig {
            budget_bytes: 0,
            spill_dir: Some(dir.clone()),
            ..StoreConfig::default()
        };
        {
            let mut st = Store::new(cfg.clone());
            st.put(1, state(1, &[1.0, 2.0]));
            st.put(1, state(9, &[7.0, 8.0])); // supersedes the first record
            st.put(2, state(2, &[4.0; 4]));
        }
        let mut st = Store::new(cfg);
        assert_eq!(st.spilled_len(), 2, "restart re-indexes spill segments");
        let got = st.take(1).expect("survives restart");
        assert_eq!(got.tokens_seen, 109, "latest record for the id wins");
        assert_eq!(got.plane("h").unwrap(), &[7.0, 8.0]);
        assert!(st.take(2).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
