//! Versioned, byte-exact snapshot of one sequence's generation state.
//!
//! A [`SessionState`] is engine-agnostic: named f32 planes plus the pending
//! greedy token.  Engines define their own plane layout (the recurrent
//! engine stores `x_re`/`x_im`/`sc` concatenated over layers; the
//! Transformer baseline stores per-layer KV planes) and validate it on
//! restore, so a blob can never be reinstalled into the wrong engine or
//! shape.  Serialization reuses [`crate::runtime::checkpoint`] — the same
//! manifest + little-endian blob format the AOT checkpoints use — and is
//! bit-exact: `f32::to_le_bytes`/`from_le_bytes` round-trip every bit
//! pattern, and non-float metadata rides along via `f32::from_bits`.

use crate::runtime::checkpoint::{Checkpoint, Tensor};
use crate::util::bytes::{ByteReader, ReadErr};

/// Blob format version; bump on any layout change so stale spills are
/// rejected instead of misread.
pub const FORMAT_VERSION: u32 = 1;

/// One named f32 buffer of a snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct Plane {
    pub name: String,
    pub data: Vec<f32>,
}

/// A full per-sequence generation-state snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionState {
    /// [`FORMAT_VERSION`] at snapshot time.
    pub version: u32,
    /// Owning session id (stamped by the store on insert).
    pub session_id: u64,
    /// Engine tag (`SlotEngine::state_tag`); restore refuses foreign blobs.
    pub engine: String,
    /// Greedy token sampled after the last consumed position — it has NOT
    /// been fed through the recurrence yet.  Resume feeds it first.
    pub last_token: i32,
    /// Tokens the state has consumed (prompt + generated, excluding the
    /// pending `last_token`) — exactly the prefill work a resume skips.
    pub tokens_seen: u64,
    pub planes: Vec<Plane>,
}

impl SessionState {
    pub fn new(engine: &str, last_token: i32) -> SessionState {
        SessionState {
            version: FORMAT_VERSION,
            session_id: 0,
            engine: engine.to_string(),
            last_token,
            tokens_seen: 0,
            planes: Vec::new(),
        }
    }

    pub fn push_plane(&mut self, name: &str, data: Vec<f32>) {
        self.planes.push(Plane { name: name.to_string(), data });
    }

    pub fn plane(&self, name: &str) -> Option<&[f32]> {
        self.planes.iter().find(|p| p.name == name).map(|p| p.data.as_slice())
    }

    /// Restore-side validation: the blob must carry this engine's tag.
    pub fn check_engine(&self, tag: &str) -> Result<(), SessionError> {
        if self.version != FORMAT_VERSION {
            return Err(SessionError::Version { got: self.version });
        }
        if self.engine != tag {
            return Err(SessionError::EngineMismatch {
                expected: tag.to_string(),
                got: self.engine.clone(),
            });
        }
        Ok(())
    }

    /// Fetch a plane and validate its exact element count.
    pub fn plane_checked(&self, name: &str, len: usize) -> Result<&[f32], SessionError> {
        let p = self
            .plane(name)
            .ok_or_else(|| SessionError::MissingPlane { plane: name.to_string() })?;
        if p.len() != len {
            return Err(SessionError::PlaneMismatch {
                plane: name.to_string(),
                expected: len,
                got: p.len(),
            });
        }
        Ok(p)
    }

    /// Bytes this snapshot occupies (LRU-ledger accounting): plane data
    /// plus name/metadata overhead.
    pub fn state_bytes(&self) -> u64 {
        let planes: u64 = self
            .planes
            .iter()
            .map(|p| 4 * p.data.len() as u64 + p.name.len() as u64 + 16)
            .sum();
        32 + self.engine.len() as u64 + planes
    }

    /// Encode as a [`Checkpoint`] (the spill-to-disk format).  Metadata is
    /// packed bit-exactly into a `meta` tensor via `f32::from_bits`.
    pub fn to_checkpoint(&self) -> Checkpoint {
        let meta = vec![
            f32::from_bits(self.version),
            f32::from_bits(self.last_token as u32),
            f32::from_bits(self.tokens_seen as u32),
            f32::from_bits((self.tokens_seen >> 32) as u32),
            f32::from_bits(self.session_id as u32),
            f32::from_bits((self.session_id >> 32) as u32),
        ];
        let mut tensors = vec![Tensor { path: "meta".into(), shape: vec![6], data: meta }];
        // the engine tag rides in a tensor path (checkpoints store f32 only)
        tensors.push(Tensor {
            path: format!("engine/{}", self.engine),
            shape: vec![],
            data: vec![0.0],
        });
        for p in &self.planes {
            tensors.push(Tensor {
                path: format!("plane/{}", p.name),
                shape: vec![p.data.len()],
                data: p.data.clone(),
            });
        }
        Checkpoint { tensors }
    }

    /// Decode a spilled checkpoint back into a snapshot.
    pub fn from_checkpoint(ck: &Checkpoint) -> Result<SessionState, SessionError> {
        let meta = ck
            .get("meta")
            .ok_or_else(|| SessionError::Corrupt("missing meta tensor".into()))?;
        if meta.data.len() != 6 {
            return Err(SessionError::Corrupt("meta tensor malformed".into()));
        }
        let version = meta.data[0].to_bits();
        if version != FORMAT_VERSION {
            return Err(SessionError::Version { got: version });
        }
        let engine = ck
            .tensors
            .iter()
            .find_map(|t| t.path.strip_prefix("engine/"))
            .ok_or_else(|| SessionError::Corrupt("missing engine tag".into()))?
            .to_string();
        let planes = ck
            .tensors
            .iter()
            .filter_map(|t| {
                t.path
                    .strip_prefix("plane/")
                    .map(|name| Plane { name: name.to_string(), data: t.data.clone() })
            })
            .collect();
        Ok(SessionState {
            version,
            session_id: (meta.data[4].to_bits() as u64)
                | ((meta.data[5].to_bits() as u64) << 32),
            engine,
            last_token: meta.data[1].to_bits() as i32,
            tokens_seen: (meta.data[2].to_bits() as u64)
                | ((meta.data[3].to_bits() as u64) << 32),
            planes,
        })
    }

    /// Encode as a self-contained little-endian byte blob for shipping over
    /// a socket (cross-process session migration).  Bit-exact: plane floats
    /// travel as raw `to_bits` words, so NaN payloads, signed zeros and
    /// denormals survive the trip.  Layout: [`WIRE_MAGIC`], format version,
    /// session id, pending token, tokens seen, engine tag, then the planes
    /// (name + raw f32 words each).
    pub fn to_wire_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64 + self.state_bytes() as usize);
        out.extend_from_slice(&WIRE_MAGIC);
        out.extend_from_slice(&self.version.to_le_bytes());
        out.extend_from_slice(&self.session_id.to_le_bytes());
        out.extend_from_slice(&self.last_token.to_le_bytes());
        out.extend_from_slice(&self.tokens_seen.to_le_bytes());
        out.extend_from_slice(&(self.engine.len() as u32).to_le_bytes());
        out.extend_from_slice(self.engine.as_bytes());
        out.extend_from_slice(&(self.planes.len() as u32).to_le_bytes());
        for p in &self.planes {
            out.extend_from_slice(&(p.name.len() as u32).to_le_bytes());
            out.extend_from_slice(p.name.as_bytes());
            out.extend_from_slice(&(p.data.len() as u32).to_le_bytes());
            for v in &p.data {
                out.extend_from_slice(&v.to_bits().to_le_bytes());
            }
        }
        out
    }

    /// Decode a wire blob produced by [`SessionState::to_wire_bytes`].  A
    /// foreign magic or format version is rejected *before* anything else
    /// is parsed, so a stale or mismatched blob can never be restored.
    pub fn from_wire_bytes(bytes: &[u8]) -> Result<SessionState, SessionError> {
        // the shared bounded reader; its typed errors map onto Corrupt
        let corrupt = |e: ReadErr| {
            SessionError::Corrupt(
                match e {
                    ReadErr::Truncated => "truncated session blob",
                    ReadErr::Utf8 => "non-utf8 string in session blob",
                }
                .into(),
            )
        };
        let mut r = ByteReader::new(bytes);
        let magic = r.take(4).map_err(corrupt)?;
        if magic != &WIRE_MAGIC[..] {
            return Err(SessionError::Corrupt("bad session blob magic".into()));
        }
        let version = r.u32().map_err(corrupt)?;
        if version != FORMAT_VERSION {
            return Err(SessionError::Version { got: version });
        }
        let session_id = r.u64().map_err(corrupt)?;
        let last_token = r.i32().map_err(corrupt)?;
        let tokens_seen = r.u64().map_err(corrupt)?;
        let engine = r.string().map_err(corrupt)?;
        let n_planes = r.u32().map_err(corrupt)? as usize;
        let mut planes = Vec::with_capacity(n_planes.min(1024));
        for _ in 0..n_planes {
            let name = r.string().map_err(corrupt)?;
            let len = r.u32().map_err(corrupt)? as usize;
            let raw = r.take(4 * len).map_err(corrupt)?;
            let data = raw
                .chunks_exact(4)
                .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
                .collect();
            planes.push(Plane { name, data });
        }
        if !r.is_exhausted() {
            return Err(SessionError::Corrupt("trailing bytes after session blob".into()));
        }
        Ok(SessionState { version, session_id, engine, last_token, tokens_seen, planes })
    }
}

/// Magic prefix of the socket blob format ("LHSB" = Laughing Hyena Session
/// Blob); distinct from the checkpoint spill format so the two can never be
/// confused.
pub const WIRE_MAGIC: [u8; 4] = *b"LHSB";

/// Why a snapshot could not be taken or reinstalled.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SessionError {
    /// The engine does not implement snapshot/restore.
    Unsupported,
    /// Blob written by an incompatible format version.
    Version { got: u32 },
    /// Blob belongs to a different engine implementation.
    EngineMismatch { expected: String, got: String },
    /// A plane's element count does not match the engine's layout.
    PlaneMismatch { plane: String, expected: usize, got: usize },
    MissingPlane { plane: String },
    /// Spilled blob failed to parse.
    Corrupt(String),
    /// The coordinator holds no trace of this session (no stored state, no
    /// transcript, nothing in flight) — a strict resume refuses instead of
    /// silently starting a fresh conversation, so a router can distinguish
    /// "migrate the session here" from "re-prefill from transcript".
    Unknown { id: u64 },
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionError::Unsupported => write!(f, "engine does not support session snapshots"),
            SessionError::Version { got } => {
                write!(f, "session blob version {got} != supported {FORMAT_VERSION}")
            }
            SessionError::EngineMismatch { expected, got } => {
                write!(f, "session blob for engine '{got}', expected '{expected}'")
            }
            SessionError::PlaneMismatch { plane, expected, got } => {
                write!(f, "plane '{plane}' has {got} elements, expected {expected}")
            }
            SessionError::MissingPlane { plane } => write!(f, "plane '{plane}' missing"),
            SessionError::Corrupt(msg) => write!(f, "corrupt session blob: {msg}"),
            SessionError::Unknown { id } => {
                write!(f, "session {id:#x} is unknown to this coordinator")
            }
        }
    }
}

impl std::error::Error for SessionError {}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SessionState {
        let mut st = SessionState::new("test-engine", 42);
        st.session_id = 0xDEAD_BEEF_0123_4567;
        st.tokens_seen = (7u64 << 33) | 99;
        // adversarial bit patterns: NaN, -0.0, denormals must survive
        st.push_plane("x_re", vec![1.5, -0.0, f32::NAN, f32::MIN_POSITIVE / 2.0]);
        st.push_plane("sc", vec![0.0; 8]);
        st
    }

    #[test]
    fn checkpoint_roundtrip_is_bit_exact() {
        let st = sample();
        let back = SessionState::from_checkpoint(&st.to_checkpoint()).unwrap();
        assert_eq!(back.version, st.version);
        assert_eq!(back.session_id, st.session_id);
        assert_eq!(back.engine, st.engine);
        assert_eq!(back.last_token, st.last_token);
        assert_eq!(back.tokens_seen, st.tokens_seen);
        assert_eq!(back.planes.len(), st.planes.len());
        for (a, b) in st.planes.iter().zip(&back.planes) {
            assert_eq!(a.name, b.name);
            let bits_a: Vec<u32> = a.data.iter().map(|v| v.to_bits()).collect();
            let bits_b: Vec<u32> = b.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits_a, bits_b, "plane {} not bit-exact", a.name);
        }
    }

    #[test]
    fn disk_roundtrip_is_bit_exact() {
        let st = sample();
        let dir = std::env::temp_dir().join(format!("lh_sess_state_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("blob");
        st.to_checkpoint().save(&base).unwrap();
        let back =
            SessionState::from_checkpoint(&Checkpoint::load(&base).unwrap()).unwrap();
        let bits = |s: &SessionState| -> Vec<u32> {
            s.planes.iter().flat_map(|p| p.data.iter().map(|v| v.to_bits())).collect()
        };
        assert_eq!(bits(&st), bits(&back));
        assert_eq!(back.last_token, 42);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn validation_catches_mismatches() {
        let st = sample();
        assert!(st.check_engine("test-engine").is_ok());
        assert!(matches!(
            st.check_engine("other"),
            Err(SessionError::EngineMismatch { .. })
        ));
        assert!(st.plane_checked("x_re", 4).is_ok());
        assert!(matches!(
            st.plane_checked("x_re", 5),
            Err(SessionError::PlaneMismatch { .. })
        ));
        assert!(matches!(
            st.plane_checked("nope", 1),
            Err(SessionError::MissingPlane { .. })
        ));
        let mut old = st.clone();
        old.version = 999;
        assert!(matches!(old.check_engine("test-engine"), Err(SessionError::Version { .. })));
    }

    #[test]
    fn wire_bytes_roundtrip_is_bit_exact() {
        let mut st = sample();
        st.last_token = -7; // negative pending tokens must survive the cast
        let bytes = st.to_wire_bytes();
        let back = SessionState::from_wire_bytes(&bytes).unwrap();
        assert_eq!(back.version, st.version);
        assert_eq!(back.session_id, st.session_id);
        assert_eq!(back.engine, st.engine);
        assert_eq!(back.last_token, -7);
        assert_eq!(back.tokens_seen, st.tokens_seen);
        assert_eq!(back.planes.len(), st.planes.len());
        for (a, b) in st.planes.iter().zip(&back.planes) {
            assert_eq!(a.name, b.name);
            let bits_a: Vec<u32> = a.data.iter().map(|v| v.to_bits()).collect();
            let bits_b: Vec<u32> = b.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits_a, bits_b, "plane {} not bit-exact over the wire", a.name);
        }
    }

    #[test]
    fn wire_bytes_reject_bad_magic_version_and_truncation() {
        let st = sample();
        let good = st.to_wire_bytes();
        // foreign magic
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(matches!(
            SessionState::from_wire_bytes(&bad),
            Err(SessionError::Corrupt(_))
        ));
        // bumped format version: typed rejection before any plane is parsed
        let mut newer = good.clone();
        newer[4..8].copy_from_slice(&(FORMAT_VERSION + 1).to_le_bytes());
        assert!(matches!(
            SessionState::from_wire_bytes(&newer),
            Err(SessionError::Version { got }) if got == FORMAT_VERSION + 1
        ));
        // truncation anywhere must error, never panic
        for cut in [0, 3, 7, good.len() / 2, good.len() - 1] {
            assert!(
                SessionState::from_wire_bytes(&good[..cut]).is_err(),
                "truncated at {cut} must be rejected"
            );
        }
        // trailing garbage is rejected too
        let mut long = good.clone();
        long.push(0);
        assert!(SessionState::from_wire_bytes(&long).is_err());
    }

    #[test]
    fn state_bytes_tracks_plane_payload() {
        let st = sample();
        assert!(st.state_bytes() > 4 * (4 + 8));
        let empty = SessionState::new("e", 0);
        assert!(empty.state_bytes() < st.state_bytes());
    }
}
