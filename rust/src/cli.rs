//! Minimal CLI argument parser (clap is unavailable offline): positional
//! subcommands plus `--key value` / `--flag` options.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Positional arguments in order (subcommand first).
    pub positional: Vec<String>,
    /// `--key value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                // --key=value or --key value or --flag
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |nxt| !nxt.starts_with("--")) {
                    out.options.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// `--key value` with a default (the common launcher pattern).
    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("experiment fig1.1 --batch 8 --steps=100 --verbose");
        assert_eq!(a.subcommand(), Some("experiment"));
        assert_eq!(a.positional[1], "fig1.1");
        assert_eq!(a.get_usize("batch", 0), 8);
        assert_eq!(a.get_usize("steps", 0), 100);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn flag_followed_by_positional_not_swallowed() {
        // a flag at the end stays a flag; option detection needs a value
        let a = parse("serve --quiet");
        assert!(a.has_flag("quiet"));
        assert_eq!(a.get("quiet"), None);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_f64("missing", 0.5), 0.5);
        assert_eq!(a.get_str("missing", "nano"), "nano");
        let b = parse("serve --shape micro");
        assert_eq!(b.get_str("shape", "nano"), "micro");
    }
}
