//! Experiment drivers: one per table/figure of the paper's evaluation
//! (DESIGN.md §4 maps each ID to the sections/modules involved).
//!
//! Every driver prints the paper-shaped table to stdout and writes CSV
//! under `results/`.  Scaled-down defaults run on this CPU testbed;
//! `--steps/--batch/--orders` options widen them.

pub mod common;
pub mod fig1_1;
pub mod fig5_1;
pub mod fig5_2;
pub mod fig5_3;
pub mod fig5_4;
pub mod figd11;
pub mod figd_distill;
pub mod figd_filters;
pub mod figd_hankel;
pub mod fige;
pub mod tab5_1;
pub mod tab5_2;
pub mod tabe1;

use crate::cli::Args;

/// All experiment IDs in paper order.
pub const ALL: &[&str] = &[
    "fig1.1",
    "tab5.1",
    "fig5.1",
    "fig5.2",
    "tab5.2",
    "fig5.3",
    "fig5.4",
    "figD.distill-errors",
    "figD.filters",
    "figD.hankel",
    "figD.11",
    "tabE.1",
    "figE.1",
    "figE.2",
];

/// Dispatch an experiment by ID.
pub fn run(id: &str, args: &Args) -> anyhow::Result<()> {
    match id {
        "fig1.1" => fig1_1::run(args),
        "tab5.1" => tab5_1::run(args),
        "fig5.1" => fig5_1::run(args),
        "fig5.2" => fig5_2::run(args),
        "tab5.2" => tab5_2::run(args),
        "fig5.3" => fig5_3::run(args),
        "fig5.4" => fig5_4::run(args),
        "figD.distill-errors" => figd_distill::run(args),
        "figD.filters" => figd_filters::run(args),
        "figD.hankel" => figd_hankel::run(args),
        "figD.11" => figd11::run(args),
        "tabE.1" => tabe1::run(args),
        "figE.1" => fige::run_modal(args),
        "figE.2" => fige::run_balanced(args),
        "all" => {
            for id in ALL {
                println!("\n################ {id} ################");
                run(id, args)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown experiment '{other}'; known: {ALL:?} or 'all'"),
    }
}
