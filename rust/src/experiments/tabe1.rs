//! Table E.1 — associative recall: MultiHyena (weight-tied heads) vs plain
//! Hyena at matched size, via the AOT `train_step_*_ar` artifacts.
//! Paper result: MultiHyena 98 vs Hyena 65 at long sequence / larger vocab
//! (Theorem 4.1's multi-head advantage).

use crate::benchkit::Table;
use crate::cli::Args;
use crate::data::assoc_recall::AssocRecall;
use crate::runtime::artifact::{Runtime, Value};
use crate::runtime::trainer::Trainer;

pub fn run(args: &Args) -> anyhow::Result<()> {
    let dir = super::common::require_artifacts()?;
    let steps = args.get_usize("steps", 400);
    let pairs = args.get_usize("pairs", 24); // vocab pressure: 2s+1 <= 128
    let rt = Runtime::cpu()?;
    let mut table = Table::new(&["model", "train steps", "recall acc %"]);
    for kind in ["hyena", "multihyena"] {
        let tag = format!("{kind}_ar");
        let mut tr = Trainer::new(&rt, &dir, &tag)?;
        let mut gen = AssocRecall::new(pairs, tr.seq_len, 17);
        for i in 0..steps {
            let (tok, tgt, mask, _) = gen.batch(tr.batch);
            let loss = tr.step(&tok, &tgt, &mask)?;
            if i % 50 == 0 {
                println!("  {kind} step {i}: loss {loss:.4}");
            }
        }
        // evaluation: argmax at the query position must be the value token
        let fwd = rt.load(&dir, &format!("eval_loss_{tag}")).ok();
        let _ = fwd; // accuracy via logits below
        let logits_art = if kind == "multihyena" {
            rt.load(&dir, "fwd_logits_multihyena_ar").ok()
        } else {
            None
        };
        let mut eval_gen = AssocRecall::new(pairs, tr.seq_len, 999);
        let acc = if let Some(art) = logits_art {
            // exact accuracy through the logits artifact
            let mut hits = 0usize;
            let mut total = 0usize;
            for _ in 0..4 {
                let (tok, _tgt, _mask, answers) = eval_gen.batch(tr.batch);
                let mut inputs: Vec<Value> = tr.params.clone();
                inputs.push(Value::i32(tok.clone(), &[tr.batch, tr.seq_len]));
                let out = art.execute(&inputs)?;
                let logits = out[0].as_f32()?;
                let vocab = out[0].shape()[2];
                for (r, (qpos, ans)) in answers.iter().enumerate() {
                    let base = (r * tr.seq_len + qpos) * vocab;
                    let row = &logits[base..base + vocab];
                    let mut best = 0;
                    let mut bv = f32::MIN;
                    for (i, &x) in row.iter().enumerate() {
                        if x > bv {
                            bv = x;
                            best = i;
                        }
                    }
                    if best == *ans as usize {
                        hits += 1;
                    }
                    total += 1;
                }
            }
            100.0 * hits as f64 / total as f64
        } else {
            // proxy: masked eval loss -> per-token accuracy lower bound via
            // exp(-loss) (hyena_ar has no logits artifact; loss compares
            // directly across models)
            let mut losses = vec![];
            for _ in 0..4 {
                let (tok, tgt, mask, _) = eval_gen.batch(tr.batch);
                losses.push(tr.eval(&tok, &tgt, &mask)? as f64);
            }
            100.0 * (-crate::util::stats::mean(&losses)).exp()
        };
        table.row(&[kind.into(), steps.to_string(), format!("{acc:.1}")]);
    }
    table.print(&format!(
        "Table E.1 (scaled: {pairs} kv-pairs, seq {}, synthetic episodes)",
        512
    ));
    table.write_csv("tabE_1.csv")?;
    println!("paper shape: MultiHyena >> Hyena at high vocab pressure (98 vs 65)");
    Ok(())
}
