//! Figure 5.3 — generation throughput vs prompt length at fixed batch:
//! LCSM prefill scales ~linearly while Transformer prefill is quadratic, so
//! the gap widens with T.

use crate::benchkit::Table;
use crate::cli::Args;
use crate::engine::conv_cache::ConvCacheEngine;
use crate::engine::recurrent::RecurrentEngine;
use crate::engine::transformer::TransformerEngine;
use crate::engine::{run_generation, Engine, LmShape};
use crate::util::Prng;

pub fn run(args: &Args) -> anyhow::Result<()> {
    let shape = LmShape::bench(args.get("shape").unwrap_or("nano")).expect("shape");
    let batch = args.get_usize("batch", 4);
    let k = args.get_usize("tokens", 16);
    let lens = [32usize, 64, 128, 256];
    let mut rng = Prng::new(3);
    let mut table = Table::new(&["T", "engine", "prefill s", "decode tok/s", "e2e tok/s"]);
    for &t in &lens {
        let prompts: Vec<Vec<i32>> = (0..batch)
            .map(|_| (0..t).map(|_| rng.below(shape.vocab) as i32).collect())
            .collect();
        for which in ["transformer", "hyena-conv", "laughing-hyena"] {
            let mut eng: Box<dyn Engine> = match which {
                "transformer" => Box::new(TransformerEngine::new(&shape, batch, 7)),
                "hyena-conv" => Box::new(ConvCacheEngine::new(&shape, batch, 7)),
                _ => Box::new(RecurrentEngine::new(&shape, batch, 7)),
            };
            let r = run_generation(eng.as_mut(), &prompts, k);
            table.row(&[
                t.to_string(),
                which.into(),
                format!("{:.3}", r.prefill_s),
                format!("{:.1}", (batch * (k - 1)) as f64 / r.decode_s),
                format!("{:.1}", (batch * k) as f64 / (r.prefill_s + r.decode_s)),
            ]);
        }
    }
    table.print(&format!(
        "Figure 5.3 (shape {}, batch {batch}, K={k}): throughput vs prompt length",
        shape.name
    ));
    table.write_csv("fig5_3.csv")?;
    println!("paper shape: e2e throughput gap vs transformer widens as T grows");
    Ok(())
}
