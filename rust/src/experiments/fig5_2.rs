//! Figure 5.2 — distillation error profiles (min/mean/max over channels) at
//! increasing orders, side by side with the Hankel singular-value spectrum
//! that *predicts* them (§3.3: errors drop once d passes the spectrum knee).

use crate::benchkit::Table;
use crate::cli::Args;
use crate::data::filters::{model_filters, Family};
use crate::distill::{DistillConfig, Distillery};
use crate::hankel::hankel_singular_values;

pub fn run(args: &Args) -> anyhow::Result<()> {
    let n_filters = args.get_usize("filters", 8);
    let len = args.get_usize("len", 256);
    let iters = args.get_usize("iters", 1500);
    let orders = [2usize, 4, 8, 16, 32];
    let filters = model_filters(Family::MultiHyena, n_filters, len, 0xF16);

    // Hankel spectrum (averaged over filters, normalized)
    let mut spectrum = vec![0.0f64; 48];
    for f in &filters {
        let sv = hankel_singular_values(&f[1..], Some(64));
        for (i, s) in sv.iter().take(48).enumerate() {
            spectrum[i] += s / sv[0] / n_filters as f64;
        }
    }
    let mut spec_tab = Table::new(&["n", "sigma_n/sigma_1"]);
    for (i, s) in spectrum.iter().enumerate().step_by(4) {
        spec_tab.row(&[format!("{}", i + 1), format!("{s:.2e}")]);
    }
    spec_tab.print("Figure 5.2 right: Hankel singular values (mean, normalized)");
    spec_tab.write_csv("fig5_2_spectrum.csv")?;

    let mut table = Table::new(&["order", "min rel err", "mean rel err", "max rel err", "AAK bound"]);
    for &d in &orders {
        let distillery = Distillery {
            order: Some(d),
            fit: DistillConfig { iters, ..Default::default() },
            hankel_window: Some(64),
            ..Default::default()
        };
        let report = distillery.distill_all(&filters);
        let aak = crate::util::stats::mean(
            &report.filters.iter().map(|f| f.aak_bound).collect::<Vec<_>>(),
        );
        table.row(&[
            d.to_string(),
            format!("{:.3e}", report.min_err()),
            format!("{:.3e}", report.mean_err()),
            format!("{:.3e}", report.max_err()),
            format!("{:.3e}", aak),
        ]);
        println!("  order {d}: mean rel err {:.4}", report.mean_err());
    }
    table.print("Figure 5.2 left: approximation error vs distillation order (MultiHyena-like filters)");
    table.write_csv("fig5_2.csv")?;
    println!("paper shape: errors fall with order, tracking the spectrum decay; knee ≈ 16");
    Ok(())
}
