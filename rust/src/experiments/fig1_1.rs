//! Figure 1.1 — peak generation throughput vs batch size for Transformer,
//! conv-mode Hyena and LaughingHyena.
//!
//! Measured at CPU bench scale (shape `nano` by default), plus the
//! paper-scale analytic frontier: under an 80 GiB fp16 budget the maximum
//! admissible batch per engine (the mechanism behind the paper's 10x peak
//! throughput gap — Transformers OOM on KV caches long before the
//! recurrent model runs out of state memory).

use crate::benchkit::Table;
use crate::cli::Args;
use crate::engine::conv_cache::ConvCacheEngine;
use crate::engine::memory::{self, F32};
use crate::engine::recurrent::RecurrentEngine;
use crate::engine::transformer::TransformerEngine;
use crate::engine::{run_generation, Engine, LmShape};
use crate::util::Prng;

pub fn run(args: &Args) -> anyhow::Result<()> {
    let shape = LmShape::bench(args.get("shape").unwrap_or("nano")).expect("shape");
    let t = args.get_usize("prompt", 192);
    let k = args.get_usize("tokens", 64);
    let max_batch = args.get_usize("max-batch", 8);
    // the CPU testbed "device" budget: scaled so the transformer hits its
    // frontier inside the sweep (KV bytes at L = t+k decide admission)
    let budget = args.get_u64(
        "budget",
        memory::weight_bytes(&shape, F32)
            + (max_batch / 2).max(1) as u64 * memory::kv_cache_bytes(&shape, t + k, F32),
    );

    let mut table = Table::new(&[
        "batch", "engine", "admitted", "decode tok/s", "total tok/s", "state",
    ]);
    let mut rng = Prng::new(42);
    let mut batch = 1usize;
    while batch <= max_batch {
        let prompts: Vec<Vec<i32>> = (0..batch)
            .map(|_| (0..t).map(|_| rng.below(shape.vocab) as i32).collect())
            .collect();
        for which in ["transformer", "hyena-conv", "laughing-hyena"] {
            // admission under the byte budget (weights + per-seq state)
            let per_seq = match which {
                "transformer" => memory::kv_cache_bytes(&shape, t + k, F32),
                "hyena-conv" => memory::conv_cache_bytes(&shape, t + k, F32),
                _ => memory::ssm_state_bytes(&shape, F32),
            };
            let admitted = memory::max_batch(per_seq, memory::weight_bytes(&shape, F32), budget)
                .min(batch);
            if admitted == 0 {
                table.row(&[
                    batch.to_string(),
                    which.into(),
                    "0 (OOM)".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            }
            let sub = &prompts[..admitted];
            let mut eng: Box<dyn Engine> = match which {
                "transformer" => Box::new(TransformerEngine::new(&shape, admitted, 7)),
                "hyena-conv" => Box::new(ConvCacheEngine::new(&shape, admitted, 7)),
                _ => Box::new(RecurrentEngine::new(&shape, admitted, 7)),
            };
            let r = run_generation(eng.as_mut(), sub, k);
            let decode_tps = (admitted * (k - 1)) as f64 / r.decode_s;
            let total_tps = (admitted * k) as f64 / (r.prefill_s + r.decode_s);
            table.row(&[
                batch.to_string(),
                which.into(),
                admitted.to_string(),
                format!("{decode_tps:.1}"),
                format!("{total_tps:.1}"),
                crate::benchkit::fmt_bytes(r.peak_state_bytes),
            ]);
        }
        batch *= 2;
    }
    table.print(&format!(
        "Figure 1.1 (measured, shape {}, T={t}, K={k}, budget {})",
        shape.name,
        crate::benchkit::fmt_bytes(budget)
    ));
    table.write_csv("fig1_1.csv")?;

    // paper-scale analytic frontier (fp16, A100-80GB)
    let mut frontier = Table::new(&["size", "engine", "max batch", "peak tok/s (rel)"]);
    for size in ["355m", "1.3b", "2.7b"] {
        let s = LmShape::paper(size).unwrap();
        let w = memory::weight_bytes(&s, 2);
        let budget = 80u64 << 30;
        let l = 512 + 256; // the paper's T=512, K=256 workload
        let engines: [(&str, u64); 3] = [
            ("transformer", memory::kv_cache_bytes(&s, l, 2)),
            ("hyena-conv", memory::conv_cache_bytes(&s, l, 2)),
            ("laughing-hyena", memory::ssm_state_bytes(&s, 2)),
        ];
        let b_tr = memory::max_batch(engines[0].1, w, budget).max(1);
        for (name, per_seq) in engines {
            let b = memory::max_batch(per_seq, w, budget);
            // throughput ∝ admitted batch at the compute-saturated plateau
            frontier.row(&[
                size.into(),
                name.into(),
                b.to_string(),
                format!("{:.1}x", b as f64 / b_tr as f64),
            ]);
        }
    }
    frontier.print("Figure 1.1 (paper-scale admission frontier, fp16, 80GiB)");
    frontier.write_csv("fig1_1_frontier.csv")?;
    Ok(())
}
