//! Figure 5.1 — errors between logits of the pre-trained (conv-mode) and
//! distilled (recurrent-mode) model, across sorted-logit percentiles.
//!
//! Path: trained checkpoint → `filters_*` artifact → native distillery →
//! `set_modal` on the served model → teacher-forced recurrent decode vs the
//! conv forward pass (`fwd_logits` artifact).

use crate::benchkit::Table;
use crate::cli::Args;
use crate::data::corpus::Corpus;
use crate::runtime::artifact::{Runtime, Value};
use crate::runtime::checkpoint::Checkpoint;
use crate::runtime::lm::ServedModel;

pub fn run(args: &Args) -> anyhow::Result<()> {
    let dir = super::common::require_artifacts()?;
    let tag = "multihyena_small";
    let order = args.get_usize("order", 16);
    let iters = args.get_usize("iters", 2500);
    let rt = Runtime::cpu()?;

    // prefer the tab5.1-trained checkpoint; fall back to init params
    let trained = std::path::Path::new("results/trained_multihyena_small.bin");
    let ck = if trained.exists() {
        println!("using trained checkpoint results/trained_{tag}");
        Checkpoint::load(std::path::Path::new("results/trained_multihyena_small"))?
    } else {
        println!("note: results/trained_{tag} missing (run tab5.1 first); using init params");
        Checkpoint::load(&dir.join(format!("params_{tag}")))?
    };
    let params: Vec<Value> =
        ck.tensors.iter().map(|t| Value::f32(t.data.clone(), &t.shape)).collect();

    // 1) extract trained filters + distill
    let filters = super::common::extract_filters(&rt, &dir, tag, &params)?;
    let mut lm = ServedModel::new(&rt, &dir, tag)?;
    let (systems, rel_errs) =
        super::common::distill_filters(&filters, order, lm.shape.d_state, iters);
    println!(
        "filter rel-l2 errors @ order {order}: min {:.3} mean {:.3} max {:.3}",
        rel_errs.iter().cloned().fold(f64::MAX, f64::min),
        crate::util::stats::mean(&rel_errs),
        rel_errs.iter().cloned().fold(0.0, f64::max),
    );
    // install trained weights + distilled filters into the served model
    lm.set_params(params.clone());
    lm.set_modal(&systems)?;

    // 2) conv-mode logits over an eval batch
    let fwd = rt.load(&dir, &format!("fwd_logits_{tag}"))?;
    let (b, t, v) = (lm.shape.batch, lm.shape.seq_len, lm.shape.vocab);
    let mut corpus = Corpus::new(v, 4, 777);
    let (tokens, _) = corpus.batch(b, t);
    let mut inputs = params.clone();
    inputs.push(Value::i32(tokens.clone(), &[b, t]));
    let conv_logits = fwd.execute(&inputs)?[0].as_f32()?.to_vec();

    // 3) recurrent-mode logits: prefill T0 tokens, teacher-force K steps
    let t0 = args.get_usize("prefill", t / 2);
    let k = args.get_usize("horizon", 16.min(t - t0 - 1));
    let prompts: Vec<Vec<i32>> =
        (0..b).map(|r| tokens[r * t..r * t + t0].to_vec()).collect();
    lm.prefill_batch(&prompts)?;
    let mut rel_errors = vec![];
    let mut pairs: Vec<(f32, f64)> = vec![]; // (conv logit, |rel err|)
    for j in 0..k {
        // teacher forcing: feed the true next token
        for r in 0..b {
            lm.last_tokens[r] = tokens[r * t + t0 + j];
        }
        let rec = lm.decode_step_logits()?;
        for r in 0..b {
            let want = &conv_logits[(r * t + t0 + j) * v..(r * t + t0 + j + 1) * v];
            let got = &rec[r * v..(r + 1) * v];
            rel_errors.push(super::common::rel_l1(got, want));
            for c in 0..v {
                let denom = want[c].abs().max(1e-3);
                pairs.push((want[c], ((got[c] - want[c]).abs() / denom) as f64));
            }
        }
    }

    // 4) the paper's percentile profile: sort by conv logit magnitude
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut table = Table::new(&["percentile", "logit", "rel err"]);
    for q in [1.0, 10.0, 50.0, 90.0, 99.0, 99.9, 99.99] {
        let idx = ((q / 100.0) * (pairs.len() - 1) as f64) as usize;
        table.row(&[
            format!("{q}"),
            format!("{:.3}", pairs[idx].0),
            format!("{:.2e}", pairs[idx].1),
        ]);
    }
    table.print(&format!(
        "Figure 5.1 (order {order}): rel error across sorted logits; mean rel-l1 {:.3e}",
        crate::util::stats::mean(&rel_errors)
    ));
    table.write_csv("fig5_1.csv")?;
    println!(
        "paper shape: rel err < 1e-2 up to the 99.99th percentile at d=16 \
         (largest errors live on small-magnitude logits)"
    );
    Ok(())
}
