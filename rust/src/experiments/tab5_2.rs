//! Table 5.2 — downstream quality before/after distillation at orders
//! {4, 8, 16}.  LM-Eval-Harness/HELM are unavailable offline; the synthetic
//! downstream suite measures the same quantity (does generation quality
//! survive distillation at a given order?) via:
//!   * next-token accuracy on held-out corpus, evaluated fully in
//!     recurrent mode (prefill 1 token + teacher-forced decode), and
//!   * agreement with the conv-mode model's greedy choices.

use crate::benchkit::Table;
use crate::cli::Args;
use crate::data::corpus::Corpus;
use crate::runtime::artifact::{Runtime, Value};
use crate::runtime::checkpoint::Checkpoint;
use crate::runtime::lm::ServedModel;

pub fn run(args: &Args) -> anyhow::Result<()> {
    let dir = super::common::require_artifacts()?;
    let tag = "multihyena_small";
    let iters = args.get_usize("iters", 2000);
    let horizon = args.get_usize("horizon", 48);
    let rt = Runtime::cpu()?;

    let trained_base = std::path::Path::new("results/trained_multihyena_small");
    let ck = if trained_base.with_extension("bin").exists() {
        Checkpoint::load(trained_base)?
    } else {
        println!("note: run tab5.1 first for a trained checkpoint; using init params");
        Checkpoint::load(&dir.join(format!("params_{tag}")))?
    };
    let params: Vec<Value> =
        ck.tensors.iter().map(|t| Value::f32(t.data.clone(), &t.shape)).collect();

    let mut lm = ServedModel::new(&rt, &dir, tag)?;
    lm.set_params(params.clone());
    let (b, t, v) = (lm.shape.batch, lm.shape.seq_len, lm.shape.vocab);

    // eval data: held-out samples of the SAME process tab5.1 trained on
    let mut corpus = Corpus::new(v, 4, 1234).fork(3);
    let (tokens, targets) = corpus.batch(b, t);
    let fwd = rt.load(&dir, &format!("fwd_logits_{tag}"))?;
    let mut inputs = params.clone();
    inputs.push(Value::i32(tokens.clone(), &[b, t]));
    let conv_logits = fwd.execute(&inputs)?[0].as_f32()?.to_vec();
    let t0 = t - horizon - 1;
    let conv_acc = next_token_acc_from_logits(&conv_logits, &targets, b, t, v, t0, horizon);

    let filters = super::common::extract_filters(&rt, &dir, tag, &params)?;
    let mut table = Table::new(&["model", "next-tok acc", "greedy agreement w/ base"]);
    table.row(&[
        format!("{tag} (conv mode)"),
        format!("{:.3}", conv_acc),
        "1.000".into(),
    ]);
    for order in [16usize, 8, 4] {
        let (systems, errs) =
            super::common::distill_filters(&filters, order, lm.shape.d_state, iters);
        println!(
            "  order {order}: mean filter rel err {:.4}",
            crate::util::stats::mean(&errs)
        );
        lm.set_modal(&systems)?;
        // recurrent-mode evaluation: prefill up to t0, teacher-forced decode
        let prompts: Vec<Vec<i32>> =
            (0..b).map(|r| tokens[r * t..r * t + t0].to_vec()).collect();
        lm.prefill_batch(&prompts)?;
        let (mut hits, mut agree, mut total) = (0usize, 0usize, 0usize);
        for j in 0..horizon {
            for r in 0..b {
                lm.last_tokens[r] = tokens[r * t + t0 + j];
            }
            let logits = lm.decode_step_logits()?;
            for r in 0..b {
                let pos = t0 + j;
                let pred = argmax(&logits[r * v..(r + 1) * v]);
                let conv_pred =
                    argmax(&conv_logits[(r * t + pos) * v..(r * t + pos + 1) * v]);
                if pred == targets[r * t + pos] as usize {
                    hits += 1;
                }
                if pred == conv_pred {
                    agree += 1;
                }
                total += 1;
            }
        }
        table.row(&[
            format!("LaughingHyena-{order}"),
            format!("{:.3}", hits as f64 / total as f64),
            format!("{:.3}", agree as f64 / total as f64),
        ]);
    }
    table.print("Table 5.2 (synthetic downstream): quality pre/post distillation");
    table.write_csv("tab5_2.csv")?;
    println!("paper shape: order >= 16 ≈ no degradation; order 4 degrades clearly");
    Ok(())
}

fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::MIN;
    for (i, &v) in x.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

fn next_token_acc_from_logits(
    logits: &[f32],
    targets: &[i32],
    b: usize,
    t: usize,
    v: usize,
    t0: usize,
    horizon: usize,
) -> f64 {
    let mut hits = 0usize;
    let mut total = 0usize;
    for r in 0..b {
        for pos in t0..t0 + horizon {
            let pred = argmax(&logits[(r * t + pos) * v..(r * t + pos + 1) * v]);
            if pred == targets[r * t + pos] as usize {
                hits += 1;
            }
            total += 1;
        }
    }
    hits as f64 / total as f64
}
