//! Table 5.1 — pre-training perplexity of GPT vs Hyena vs MultiHyena at
//! increasing token budgets (scaled down: synthetic Zipf-Markov corpus,
//! hundreds of steps instead of billions of tokens; DESIGN.md §6).
//!
//! Drives the AOT `train_step_*_small` artifacts from rust; Python never
//! runs.  Checkpoints land in `results/` for figD.filters and fig5.1.

use crate::benchkit::Table;
use crate::cli::Args;
use crate::data::corpus::Corpus;
use crate::runtime::artifact::Runtime;
use crate::runtime::checkpoint::Checkpoint;
use crate::runtime::trainer::Trainer;

pub fn run(args: &Args) -> anyhow::Result<()> {
    let dir = super::common::require_artifacts()?;
    let budgets: Vec<usize> = {
        let max = args.get_usize("steps", 240);
        vec![max / 4, max / 2, max]
    };
    let kinds = ["gpt", "hyena", "multihyena"];
    let rt = Runtime::cpu()?;
    let mut table = Table::new(&["model", "params", "steps@ppl…", "", "", "tok/budget"]);
    let mut rows: Vec<Vec<String>> = vec![];
    for kind in kinds {
        let tag = format!("{kind}_small");
        let mut tr = Trainer::new(&rt, &dir, &tag)?;
        let ck0 = Checkpoint::load(&dir.join(format!("params_{tag}")))?;
        let n_params = ck0.total_params();
        let corpus_master = Corpus::new(512, 4, 1234);
        let mut corpus = corpus_master.fork(1);
        let mut heldout = corpus_master.fork(2);
        let mask = vec![1.0f32; tr.batch * tr.seq_len];
        let mut ppls = vec![];
        let mut done = 0usize;
        for &budget in &budgets {
            while done < budget {
                let (tok, tgt) = corpus.batch(tr.batch, tr.seq_len);
                tr.step(&tok, &tgt, &mask)?;
                done += 1;
            }
            // held-out perplexity over 4 eval batches
            let mut losses = vec![];
            for _ in 0..4 {
                let (tok, tgt) = heldout.batch(tr.batch, tr.seq_len);
                losses.push(tr.eval(&tok, &tgt, &mask)? as f64);
            }
            let ppl = crate::util::stats::mean(&losses).exp();
            println!("  {kind}: {done} steps -> ppl {ppl:.3}");
            ppls.push(ppl);
        }
        // save the trained checkpoint for downstream experiments
        std::fs::create_dir_all("results")?;
        tr.checkpoint(&ck0)
            .save(std::path::Path::new(&format!("results/trained_{tag}")))?;
        let tokens_per_budget = budgets
            .iter()
            .map(|b| format!("{}k", b * tr.batch * tr.seq_len / 1000))
            .collect::<Vec<_>>()
            .join("/");
        rows.push(vec![
            kind.to_string(),
            format!("{:.2}M", n_params as f64 / 1e6),
            format!("{}@{:.2}", budgets[0], ppls[0]),
            format!("{}@{:.2}", budgets[1], ppls[1]),
            format!("{}@{:.2}", budgets[2], ppls[2]),
            tokens_per_budget,
        ]);
    }
    for r in &rows {
        table.row(r);
    }
    table.print("Table 5.1 (scaled: held-out ppl on Zipf-Markov corpus at step budgets)");
    table.write_csv("tab5_1.csv")?;
    println!(
        "paper shape to reproduce: MultiHyena < Hyena ≈ GPT at every budget \
         (checkpoints saved under results/trained_*)"
    );
    Ok(())
}
