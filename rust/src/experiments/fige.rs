//! Figures E.1–E.4 — classical model-order-reduction baselines.
//!
//! E.1: modal truncation of diagonal (H3-like) SSMs — error decreases
//! (essentially monotonically) with kept order.
//! E.2–E.4: Kung's balanced truncation on H3/Hyena/MultiHyena filters —
//! the paper observes *non-monotonic* error and occasional instability on
//! the rough (Hyena-family) filters.

use crate::benchkit::Table;
use crate::cli::Args;
use crate::data::filters::{model_filters, Family};
use crate::distill::balanced::balanced_error;
use crate::distill::modal_trunc::{linf_error, modal_truncate};
use crate::dsp::C64;
use crate::ssm::ModalSsm;
use crate::util::Prng;

pub fn run_modal(args: &Args) -> anyhow::Result<()> {
    let n_sys = args.get_usize("filters", 6);
    let mut rng = Prng::new(0xE1);
    let orders = [2usize, 4, 8, 12, 16];
    let mut table = Table::new(&["order", "mean linf err", "max linf err"]);
    // H3-like diagonal systems of true order 16
    let systems: Vec<ModalSsm> = (0..n_sys)
        .map(|_| {
            let pairs: Vec<(C64, C64)> = (0..8)
                .map(|k| {
                    (
                        C64::polar(0.95 - 0.07 * k as f64, rng.range(0.1, 2.8)),
                        C64::new(rng.normal() * 0.4, rng.normal() * 0.2),
                    )
                })
                .collect();
            ModalSsm::from_conjugate_pairs(&pairs, 0.0)
        })
        .collect();
    for &n in &orders {
        let errs: Vec<f64> = systems
            .iter()
            .map(|s| linf_error(s, &modal_truncate(s, n), 128))
            .collect();
        table.row(&[
            n.to_string(),
            format!("{:.3e}", crate::util::stats::mean(&errs)),
            format!("{:.3e}", errs.iter().cloned().fold(0.0, f64::max)),
        ]);
    }
    table.print("Figure E.1: modal truncation error vs order (diagonal H3-like SSMs)");
    table.write_csv("figE_1.csv")?;
    println!("paper shape: error decreases with order");
    Ok(())
}

pub fn run_balanced(args: &Args) -> anyhow::Result<()> {
    let n_filters = args.get_usize("filters", 5);
    let len = args.get_usize("len", 192);
    let orders = [2usize, 4, 8, 16, 24];
    let mut table =
        Table::new(&["family", "order", "mean linf err", "non-monotonic?"]);
    for fam in [Family::H3Iir, Family::Hyena, Family::MultiHyena] {
        let filters = model_filters(fam, n_filters, len, 0xE2 + fam as u64);
        let mut prev = f64::MAX;
        let mut nonmono = false;
        for &n in &orders {
            let errs: Vec<f64> = filters
                .iter()
                .filter_map(|f| balanced_error(&f[1..], n, 128))
                .collect();
            let mean = crate::util::stats::mean(&errs);
            if mean > prev * 1.02 {
                nonmono = true;
            }
            table.row(&[
                fam.label().into(),
                n.to_string(),
                format!("{mean:.3e}"),
                if nonmono { "yes".into() } else { "-".to_string() },
            ]);
            prev = mean;
        }
        println!("  {} done", fam.label());
    }
    table.print("Figures E.2-E.4: balanced truncation (Kung) error vs order");
    table.write_csv("figE_2.csv")?;
    println!(
        "paper shape: clean on H3-like filters; non-monotonic/unstable cases \
         appear on the rough Hyena-family filters"
    );
    Ok(())
}
